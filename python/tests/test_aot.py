"""AOT pipeline tests: manifest consistency and HLO-text properties.

These pin the contract between python/compile/aot.py and the Rust runtime
(rust/src/runtime/manifest.rs): argument ordering, shapes, and the HLO-text
interchange invariants.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile.configs import CONFIGS
from compile import model as M
from compile.aot import lower_one, param_manifest, to_hlo_text

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestManifestContract:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(ARTIFACTS, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("run `make artifacts` first")
        with open(path) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self, manifest):
        for a in manifest["artifacts"]:
            p = os.path.join(ARTIFACTS, a["file"])
            assert os.path.exists(p), a["file"]
            assert os.path.getsize(p) > 1000

    def test_param_layout_matches_model(self, manifest):
        for size, fmts in manifest["params"].items():
            cfg = CONFIGS[size]
            for fmt, entries in fmts.items():
                flat = M.flat_args_for(cfg, fmt)
                assert len(entries) == len(flat), (size, fmt)
                for e, (name, dt, shape) in zip(entries, flat):
                    assert e["name"] == name
                    assert e["dtype"] == dt
                    assert tuple(e["shape"]) == tuple(shape)

    def test_artifact_input_counts(self, manifest):
        for a in manifest["artifacts"]:
            cfg = CONFIGS[a["config"]]
            data = M.example_data_args(cfg, a["fn"])
            assert len(a["data_inputs"]) == len(data), a["file"]
            assert a["n_param_inputs"] == len(M.flat_args_for(cfg, a["format"]))

    def test_lattice_param_counts(self, manifest):
        for size, c in manifest["configs"].items():
            assert c["lattice_params"] == CONFIGS[size].lattice_param_count()

    def test_gen_outputs_token_grid(self, manifest):
        for a in manifest["artifacts"]:
            if a["fn"] != "gen":
                continue
            cfg = CONFIGS[a["config"]]
            (out,) = a["outputs"]
            assert out["dtype"] == "i32"
            assert out["shape"] == [cfg.b_gen, cfg.t_dec]


class TestHloText:
    def test_hlo_text_parses_as_entry_module(self):
        cfg = CONFIGS["nano"]
        text, _, _ = lower_one(cfg, "fp", "loss")
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text

    def test_param_manifest_kinds(self):
        cfg = CONFIGS["nano"]
        wq = param_manifest(cfg, "wq")
        kinds = {e["kind"] for e in wq}
        assert kinds == {"fp", "lattice_q", "scale"}
        fp = param_manifest(cfg, "fp")
        kinds = {e["kind"] for e in fp}
        assert kinds == {"fp", "lattice_as_fp"}
        # every lattice_q is immediately followed by its scale
        for i, e in enumerate(wq):
            if e["kind"] == "lattice_q":
                assert wq[i + 1]["kind"] == "scale"
                assert wq[i + 1]["name"] == e["name"][:-2] + ".s"

    def test_to_hlo_text_roundtrips_simple_fn(self):
        def f(x):
            return (x * 2.0 + 1.0,)

        lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
        text = to_hlo_text(lowered)
        assert "HloModule" in text

    def test_init_hints_present_for_fp(self):
        cfg = CONFIGS["nano"]
        fp = param_manifest(cfg, "fp")
        for e in fp:
            assert "init" in e, e["name"]
            assert e["init"][0] in ("normal", "ones", "zeros")


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
