"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every test pins a Pallas kernel against its pure-jnp oracle from
``compile.kernels.ref``. Hypothesis sweeps shapes (including degenerate and
non-power-of-two dims) and value ranges (including the INT4 lattice subset
and boundary values ±127).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant_matmul, w8a8_matmul, ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def _mk(rng, m, k, n, qlo, qhi, xscale=1.0):
    x = jnp.asarray(rng.normal(size=(m, k)).astype("float32") * xscale)
    q = jnp.asarray(rng.integers(qlo, qhi + 1, size=(k, n)).astype("int8"))
    s = jnp.asarray((rng.random(n).astype("float32") + 0.05) * 0.04)
    return x, q, s


class TestQuantMatmul:
    def test_exact_small(self):
        rng = np.random.default_rng(1)
        x, q, s = _mk(rng, 4, 8, 8, -7, 7)
        np.testing.assert_allclose(
            quant_matmul(x, q, s), ref.quant_matmul_ref(x, q, s), rtol=1e-6, atol=1e-6
        )

    def test_identity_scale_integer_inputs_is_exact(self):
        # Integer activations + unit scales: result must be bit-exact.
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(-3, 4, size=(8, 16)).astype("float32"))
        q = jnp.asarray(rng.integers(-7, 8, size=(16, 8)).astype("int8"))
        s = jnp.ones(8, dtype=jnp.float32)
        got = np.asarray(quant_matmul(x, q, s))
        want = np.asarray(x) @ np.asarray(q, dtype=np.float32)
        assert (got == want).all()

    def test_zero_weights(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(8, 16)).astype("float32"))
        q = jnp.zeros((16, 8), dtype=jnp.int8)
        s = jnp.ones(8, dtype=jnp.float32)
        assert float(jnp.max(jnp.abs(quant_matmul(x, q, s)))) == 0.0

    def test_per_channel_scale_applied_to_correct_axis(self):
        # Column j scaled by s_j: doubling s_j must double only column j.
        rng = np.random.default_rng(4)
        x, q, s = _mk(rng, 4, 8, 6, -7, 7)
        base = np.asarray(quant_matmul(x, q, s))
        s2 = np.asarray(s).copy()
        s2[2] *= 2.0
        bumped = np.asarray(quant_matmul(x, q, jnp.asarray(s2)))
        np.testing.assert_allclose(bumped[:, 2], base[:, 2] * 2.0, rtol=1e-6)
        np.testing.assert_allclose(np.delete(bumped, 2, 1), np.delete(base, 2, 1), rtol=1e-6)

    @given(
        m=st.integers(1, 48),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        qmax=st.sampled_from([1, 7, 127]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_on_random_shapes(self, m, k, n, qmax, seed):
        rng = np.random.default_rng(seed)
        x, q, s = _mk(rng, m, k, n, -qmax, qmax)
        np.testing.assert_allclose(
            quant_matmul(x, q, s), ref.quant_matmul_ref(x, q, s), rtol=1e-5, atol=1e-5
        )

    @given(
        bm=st.sampled_from([1, 3, 8, 64, 256]),
        bk=st.sampled_from([1, 4, 32, 256]),
        bn=st.sampled_from([2, 16, 128]),
    )
    def test_block_shape_invariance(self, bm, bk, bn):
        # Result must not depend on tiling choices.
        rng = np.random.default_rng(7)
        x, q, s = _mk(rng, 24, 36, 20, -7, 7)
        got = quant_matmul(x, q, s, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(
            got, ref.quant_matmul_ref(x, q, s), rtol=1e-5, atol=1e-5
        )

    def test_int8_boundary_values(self):
        rng = np.random.default_rng(8)
        x, q, s = _mk(rng, 4, 8, 4, -127, 127)
        np.testing.assert_allclose(
            quant_matmul(x, q, s), ref.quant_matmul_ref(x, q, s), rtol=1e-5, atol=1e-5
        )


class TestW8A8Matmul:
    def test_matches_ref_small(self):
        rng = np.random.default_rng(11)
        x, q, s = _mk(rng, 8, 32, 16, -127, 127)
        np.testing.assert_allclose(
            w8a8_matmul(x, q, s), ref.w8a8_matmul_ref(x, q, s), rtol=1e-4, atol=1e-4
        )

    def test_close_to_fp_matmul_for_wellscaled_inputs(self):
        # W8A8 introduces activation-quantization error bounded by xs/2 per
        # element; the result must stay within that envelope of the FP ref.
        rng = np.random.default_rng(12)
        x, q, s = _mk(rng, 16, 64, 32, -127, 127)
        fp = np.asarray(ref.quant_matmul_ref(x, q, s))
        got = np.asarray(w8a8_matmul(x, q, s))
        absmax = float(np.max(np.abs(np.asarray(x))))
        xs = absmax / 127.0
        # per-element bound: K * (xs/2) * max|w_deq| — loose but indicative
        bound = 64 * (xs / 2) * float(np.max(np.abs(np.asarray(q) * np.asarray(s)[None, :])))
        assert np.max(np.abs(got - fp)) <= bound

    def test_all_zero_activations(self):
        q = jnp.ones((16, 8), dtype=jnp.int8)
        s = jnp.ones(8, dtype=jnp.float32)
        x = jnp.zeros((4, 16), dtype=jnp.float32)
        out = np.asarray(w8a8_matmul(x, q, s))
        assert np.all(out == 0.0)

    @given(
        m=st.integers(1, 32),
        k=st.integers(1, 64),
        n=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
        xscale=st.sampled_from([1e-3, 1.0, 50.0]),
    )
    def test_matches_ref_on_random_shapes(self, m, k, n, seed, xscale):
        rng = np.random.default_rng(seed)
        x, q, s = _mk(rng, m, k, n, -127, 127, xscale=xscale)
        np.testing.assert_allclose(
            w8a8_matmul(x, q, s), ref.w8a8_matmul_ref(x, q, s), rtol=1e-4, atol=1e-4
        )

    def test_scale_invariance_of_quant_grid(self):
        # Scaling x by c scales the output by ~c (up to requantization noise).
        rng = np.random.default_rng(13)
        x, q, s = _mk(rng, 8, 32, 16, -127, 127)
        a = np.asarray(w8a8_matmul(x, q, s))
        b = np.asarray(w8a8_matmul(x * 4.0, q, s))
        np.testing.assert_allclose(b, a * 4.0, rtol=1e-4, atol=1e-4)


class TestRefInternals:
    def test_quantize_act_ref_range(self):
        rng = np.random.default_rng(21)
        x = jnp.asarray(rng.normal(size=(32, 32)).astype("float32") * 10)
        xq, xs = ref.quantize_act_ref(x)
        assert float(jnp.max(jnp.abs(xq))) <= 127.0
        # round-trip error bounded by half a grid step
        assert float(jnp.max(jnp.abs(xq * xs - x))) <= float(xs) / 2 + 1e-6

    def test_dequant_shape(self):
        q = jnp.zeros((8, 4), dtype=jnp.int8)
        s = jnp.ones(4, dtype=jnp.float32)
        assert ref.dequant(q, s).shape == (8, 4)


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
