"""L2 model correctness: shapes, padding semantics, KV-cache fidelity,
gradient sanity, and format consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import CONFIGS
from compile import model as M

CFG = CONFIGS["nano"]


def init_params(fmt, seed=0, scale_mag=0.01):
    rng = np.random.default_rng(seed)
    args = []
    for name, dt, shape in M.flat_args_for(CFG, fmt):
        if dt == "i8":
            args.append(jnp.asarray(rng.integers(-7, 8, size=shape, dtype=np.int8)))
        elif name.endswith(".s"):
            args.append(jnp.asarray((rng.random(shape).astype("float32") + 0.5) * scale_mag))
        else:
            args.append(jnp.asarray(rng.normal(0, 0.06, size=shape).astype("float32")))
    return args


def full_mask_inputs(rng, b, s):
    tokens = jnp.asarray(rng.integers(1, CFG.vocab, size=(b, s)), dtype=jnp.int32)
    pos = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None, :], (b, 1))
    mask = jnp.ones((b, s), dtype=jnp.float32)
    return tokens, pos, mask


class TestForward:
    def test_logit_shape(self):
        rng = np.random.default_rng(1)
        p = M.unflatten_params(CFG, "wq", init_params("wq"))
        tokens, pos, mask = full_mask_inputs(rng, 2, CFG.s_train)
        logits, kvs = M.forward(CFG, "wq", p, tokens, pos, mask)
        assert logits.shape == (2, CFG.s_train, CFG.vocab)
        assert len(kvs) == CFG.n_layers

    def test_causality(self):
        # Changing a future token must not affect past logits.
        rng = np.random.default_rng(2)
        p = M.unflatten_params(CFG, "wq", init_params("wq"))
        tokens, pos, mask = full_mask_inputs(rng, 1, 16)
        la, _ = M.forward(CFG, "wq", p, tokens, pos, mask)
        t2 = np.array(tokens)
        t2[0, 10] = (t2[0, 10] + 1) % CFG.vocab
        lb, _ = M.forward(CFG, "wq", p, jnp.asarray(t2), pos, mask)
        np.testing.assert_allclose(la[0, :10], lb[0, :10], rtol=1e-5, atol=1e-6)
        assert not np.allclose(la[0, 10:], lb[0, 10:])

    def test_left_pad_equals_unpadded(self):
        # A left-padded sequence with correct pos_ids/mask must produce the
        # same final-position logits as the unpadded sequence.
        rng = np.random.default_rng(3)
        p = M.unflatten_params(CFG, "wq", init_params("wq"))
        s_real, pad = 10, 6
        tokens, pos, mask = full_mask_inputs(rng, 1, s_real)
        la, _ = M.forward(CFG, "wq", p, tokens, pos, mask)
        padded = jnp.concatenate(
            [jnp.zeros((1, pad), jnp.int32), tokens], axis=1)
        pos_p = jnp.concatenate(
            [jnp.zeros((1, pad), jnp.int32), pos], axis=1)
        mask_p = jnp.concatenate(
            [jnp.zeros((1, pad), jnp.float32), mask], axis=1)
        lb, _ = M.forward(CFG, "wq", p, padded, pos_p, mask_p)
        np.testing.assert_allclose(la[0, -1], lb[0, -1], rtol=1e-4, atol=1e-5)

    def test_fp_matches_dequantized_wq(self):
        # Running fp format with W = q*s must equal the wq format exactly
        # (same float ops, modulo association: tolerate tiny eps).
        rng = np.random.default_rng(4)
        wq_args = init_params("wq")
        fp_args = []
        it = iter(wq_args)
        for spec in M.param_specs(CFG):
            if spec.kind == "lattice":
                q = next(it); s = next(it)
                fp_args.append(q.astype(jnp.float32) * s[None, :])
            else:
                fp_args.append(next(it))
        tokens, pos, mask = full_mask_inputs(rng, 2, 12)
        la, _ = M.forward(CFG, "wq", M.unflatten_params(CFG, "wq", wq_args),
                          tokens, pos, mask)
        lb, _ = M.forward(CFG, "fp", M.unflatten_params(CFG, "fp", fp_args),
                          tokens, pos, mask)
        np.testing.assert_allclose(la, lb, rtol=1e-4, atol=1e-5)


class TestGen:
    def _gen(self, fmt, prompt, plen, tau, gumbel, params):
        fn = M.exported_fn(CFG, fmt, "gen")
        return jax.jit(fn)(prompt, plen, tau, gumbel, *params)[0]

    def test_greedy_matches_full_recompute_with_padding(self):
        rng = np.random.default_rng(5)
        args = init_params("wq")
        p = M.unflatten_params(CFG, "wq", args)
        B, Sp = CFG.b_gen, CFG.s_prompt
        lens = np.array([Sp, Sp - 3, 5, Sp - 1] * (B // 4), dtype=np.int32)
        prompt = np.zeros((B, Sp), dtype=np.int32)
        for i, L in enumerate(lens):
            prompt[i, Sp - L:] = rng.integers(1, CFG.vocab, size=L)
        gumbel = jnp.zeros((B, CFG.t_dec, CFG.vocab), jnp.float32)
        out = np.array(self._gen("wq", jnp.asarray(prompt), jnp.asarray(lens),
                                 jnp.float32(0.0), gumbel, args))
        # manual: full forward on the growing, still-left-padded sequence
        seq = prompt.copy()
        manual = []
        for t in range(4):
            S = seq.shape[1]
            pad = Sp - lens
            slots = np.arange(S)[None, :]
            mask = (slots >= pad[:, None]).astype("float32")
            pos = np.maximum(slots - pad[:, None], 0).astype("int32")
            logits, _ = M.forward(CFG, "wq", p, jnp.asarray(seq),
                                  jnp.asarray(pos), jnp.asarray(mask))
            nxt = np.argmax(np.asarray(logits[:, -1, :]), axis=-1).astype("int32")
            manual.append(nxt)
            seq = np.concatenate([seq, nxt[:, None]], axis=1)
        manual = np.stack(manual, axis=1)
        assert (out[:, :4] == manual).all()

    def test_tau_zero_deterministic(self):
        rng = np.random.default_rng(6)
        args = init_params("wq")
        B, Sp = CFG.b_gen, CFG.s_prompt
        prompt = jnp.asarray(rng.integers(1, CFG.vocab, size=(B, Sp)), dtype=jnp.int32)
        lens = jnp.full((B,), Sp, dtype=jnp.int32)
        g1 = jnp.asarray(rng.gumbel(size=(B, CFG.t_dec, CFG.vocab)).astype("float32"))
        g2 = jnp.asarray(rng.gumbel(size=(B, CFG.t_dec, CFG.vocab)).astype("float32"))
        a = self._gen("wq", prompt, lens, jnp.float32(0.0), g1, args)
        b = self._gen("wq", prompt, lens, jnp.float32(0.0), g2, args)
        assert (np.array(a) == np.array(b)).all()

    def test_tau_changes_samples(self):
        rng = np.random.default_rng(7)
        args = init_params("wq")
        B, Sp = CFG.b_gen, CFG.s_prompt
        prompt = jnp.asarray(rng.integers(1, CFG.vocab, size=(B, Sp)), dtype=jnp.int32)
        lens = jnp.full((B,), Sp, dtype=jnp.int32)
        g = jnp.asarray(rng.gumbel(size=(B, CFG.t_dec, CFG.vocab)).astype("float32"))
        a = self._gen("wq", prompt, lens, jnp.float32(0.0), g, args)
        b = self._gen("wq", prompt, lens, jnp.float32(5.0), g, args)
        assert (np.array(a) != np.array(b)).any()


class TestLossGrad:
    def _loss_inputs(self, rng):
        b, s = CFG.b_train, CFG.s_train
        tokens = jnp.asarray(rng.integers(1, CFG.vocab, size=(b, s)), dtype=jnp.int32)
        pos = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None, :], (b, 1))
        mask = jnp.ones((b, s), jnp.float32)
        targets = jnp.asarray(rng.integers(1, CFG.vocab, size=(b, s)), dtype=jnp.int32)
        lmask = jnp.ones((b, s), jnp.float32)
        return tokens, pos, mask, targets, lmask

    def test_loss_finite_and_near_uniform_at_init(self):
        rng = np.random.default_rng(8)
        args = init_params("wq", scale_mag=0.001)
        fn = M.exported_fn(CFG, "wq", "loss")
        sum_ce, n_tok, n_corr = jax.jit(fn)(*self._loss_inputs(rng), *args)
        mean = float(sum_ce) / float(n_tok)
        # near-random init => CE close to log(V)
        assert abs(mean - np.log(CFG.vocab)) < 1.0
        assert 0 <= float(n_corr) <= float(n_tok)

    def test_loss_mask_excludes_positions(self):
        rng = np.random.default_rng(9)
        args = init_params("wq")
        fn = jax.jit(M.exported_fn(CFG, "wq", "loss"))
        tokens, pos, mask, targets, lmask = self._loss_inputs(rng)
        full = fn(tokens, pos, mask, targets, lmask, *args)
        half_mask = np.array(lmask)
        half_mask[:, : CFG.s_train // 2] = 0.0
        half = fn(tokens, pos, mask, targets, jnp.asarray(half_mask), *args)
        assert float(half[1]) == pytest.approx(float(full[1]) / 2)
        assert float(half[0]) < float(full[0])

    def test_grad_descends(self):
        rng = np.random.default_rng(10)
        args = init_params("fp")
        gfn = jax.jit(M.exported_fn(CFG, "fp", "grad"))
        inputs = self._loss_inputs(rng)
        out = gfn(*inputs, *args)
        loss0, grads = out[0], out[1:]
        assert len(grads) == len(args)
        lr = 0.5
        new_args = [a - lr * g for a, g in zip(args, grads)]
        loss1 = gfn(*inputs, *new_args)[0]
        assert float(loss1) < float(loss0)

    def test_cls_correct_counting(self):
        rng = np.random.default_rng(11)
        args = init_params("wq")
        fn = jax.jit(M.exported_fn(CFG, "wq", "cls"))
        b, s = CFG.b_train, CFG.s_train
        tokens = jnp.asarray(rng.integers(1, CFG.vocab, size=(b, s)), dtype=jnp.int32)
        pos = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None, :], (b, 1))
        mask = jnp.ones((b, s), jnp.float32)
        cls_pos = jnp.full((b,), s - 1, dtype=jnp.int32)
        class_ids = jnp.asarray([3, 5, 7, 9, 11, 13, 15, 17], dtype=jnp.int32)
        labels = jnp.asarray(rng.integers(0, 8, size=(b,)), dtype=jnp.int32)
        sum_ce, n_corr, scores = fn(tokens, pos, mask, cls_pos, class_ids, labels, *args)
        assert scores.shape == (b, 8)
        # recompute correctness from the returned scores
        pred = np.argmax(np.asarray(scores), axis=-1)
        assert float(n_corr) == float((pred == np.asarray(labels)).sum())


class TestParamLayout:
    def test_flat_args_roundtrip(self):
        flat = M.flat_args_for(CFG, "wq")
        # every lattice tensor contributes exactly (q, s)
        n_lat = sum(1 for s in M.param_specs(CFG) if s.kind == "lattice")
        n_fp = sum(1 for s in M.param_specs(CFG) if s.kind == "fp")
        assert len(flat) == 2 * n_lat + n_fp

    def test_fp_layout(self):
        flat = M.flat_args_for(CFG, "fp")
        assert len(flat) == len(M.param_specs(CFG))
        assert all(dt == "f32" for _, dt, _ in flat)

    def test_lattice_param_count_matches_config(self):
        total = 0
        for s in M.param_specs(CFG):
            if s.kind == "lattice":
                n = 1
                for d in s.shape:
                    n *= d
                total += n
        assert total == CFG.lattice_param_count()


if __name__ == "__main__":
    import sys
    sys.exit(pytest.main([__file__, "-q"]))
