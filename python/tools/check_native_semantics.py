"""Validate the Rust native backend's ALGORITHM against the JAX ground
truth (python/compile/model.py), by porting the exact op sequence of
rust/src/runtime/native/{mod,autograd}.rs to NumPy and diffing:

  1. loss graph (wq):  (sum_ce, n_tok, n_correct)
  2. cls graph (wq):   scores [B, 8]
  3. gen graph (wq):   decoded tokens, greedy AND gumbel-sampled
  4. grad graph (fp):  per-tensor gradients vs jax.grad
  5. continuous-batching scheduler (rust/src/sched): slot-arena greedy
     decode must reproduce gen_fn's greedy tokens (up to EOS retirement)
     and be invariant to slot count and admission order

A pass means the Rust implementation's semantics (left-pad geometry,
cache slots, bias construction, GELU/LN variants, argmax ties, backward
derivation, arena bookkeeping) match the compiled model; remaining risk
is Rust-level transcription only.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

import compile.model as M
from compile.configs import CONFIGS

cfg = CONFIGS["nano"]
rng = np.random.default_rng(7)

D, F, V, H, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_heads, cfg.n_layers
DH = D // H
NEG_INF = -1e9


# ---- parameter construction ------------------------------------------------
def make_params(fmt):
    """{name: np tensor | (q, s)} + the flat arg list model.py expects."""
    p, flat = {}, []
    for spec in M.param_specs(cfg):
        if spec.init[0] == "normal":
            w = rng.normal(0, spec.init[1], spec.shape).astype(np.float32)
        elif spec.init[0] == "ones":
            w = np.ones(spec.shape, np.float32)
        else:
            w = np.zeros(spec.shape, np.float32)
        if spec.kind == "lattice" and fmt == "wq":
            # per-channel symmetric PTQ onto [-7, 7] (quant::ptq_quantize)
            absmax = np.abs(w).max(axis=0)
            s = np.where(absmax > 0, absmax / 7.0, 1.0).astype(np.float32)
            q = np.clip(np.round(w / s), -7, 7).astype(np.int8)
            p[spec.name] = (q, s)
            flat += [q, s]
        else:
            p[spec.name] = w
            flat.append(w)
    return p, flat


def lin(x, wspec, fmt):
    """The native fused dequant-GEMM order: (x @ q) * scale."""
    if fmt == "wq":
        q, s = wspec
        return (x @ q.astype(np.float32)) * s
    return x @ wspec


# ---- native forward (port of runtime/native/mod.rs) ------------------------
def layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / np.sqrt(var + eps) * g + b


def gelu(x):
    c = np.float32(0.7978845608028654)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x**3)))


def softmax(x):
    m = x.max(-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(-1, keepdims=True)


def attend_full(q, k, v, mask):
    B, S, _ = q.shape
    qh = q.reshape(B, S, H, DH).transpose(0, 2, 1, 3)
    kh = k.reshape(B, S, H, DH).transpose(0, 2, 1, 3)
    vh = v.reshape(B, S, H, DH).transpose(0, 2, 1, 3)
    logits = qh @ kh.transpose(0, 1, 3, 2) / np.sqrt(np.float32(DH))
    causal = np.tril(np.ones((S, S), np.float32))
    bias = np.where((causal[None, None] * mask[:, None, None, :]) > 0, 0.0, NEG_INF)
    att = softmax(logits + bias)
    out = att @ vh
    return out.transpose(0, 2, 1, 3).reshape(B, S, H * DH), att


def forward_full(p, fmt, tokens, pos_ids, mask, want_kv=False):
    h = p["tok_emb"][tokens] + p["pos_emb"][pos_ids]
    kvs = []
    for i in range(L):
        pre = f"layers.{i}."
        x = layernorm(h, p[pre + "ln1.g"], p[pre + "ln1.b"])
        q = lin(x, p[pre + "attn.wq"], fmt)
        k = lin(x, p[pre + "attn.wk"], fmt)
        v = lin(x, p[pre + "attn.wv"], fmt)
        a, _ = attend_full(q, k, v, mask)
        h = h + lin(a, p[pre + "attn.wo"], fmt)
        x = layernorm(h, p[pre + "ln2.g"], p[pre + "ln2.b"])
        h = h + lin(gelu(lin(x, p[pre + "mlp.w1"], fmt)), p[pre + "mlp.w2"], fmt)
        if want_kv:
            kvs.append((k, v))
    return h, kvs


def head(p, h):
    hf = layernorm(h, p["lnf.g"], p["lnf.b"])
    return hf @ p["tok_emb"].T


# ---- 1 & 4: loss + grads ---------------------------------------------------
def native_loss(p, fmt, tokens, pos_ids, mask, targets, loss_mask):
    h, _ = forward_full(p, fmt, tokens, pos_ids, mask)
    logits = head(p, h)
    m = logits.max(-1, keepdims=True)
    logz = m[..., 0] + np.log(np.exp(logits - m).sum(-1))
    nll = logz - np.take_along_axis(logits, targets[..., None], -1)[..., 0]
    sum_ce = (nll * loss_mask).sum()
    pred = logits.argmax(-1)
    n_correct = ((pred == targets) * loss_mask).sum()
    return sum_ce, loss_mask.sum(), n_correct


B, S = cfg.b_train, cfg.s_train
tokens = rng.integers(2, 40, (B, S)).astype(np.int32)
pos_ids = np.tile(np.arange(S, dtype=np.int32), (B, 1))
mask = (rng.random((B, S)) < 0.9).astype(np.float32)
mask[:, :4] = 1.0
targets = rng.integers(2, 40, (B, S)).astype(np.int32)
loss_mask = (rng.random((B, S)) < 0.5).astype(np.float32) * mask

for fmt in ("wq", "fp"):
    p, flat = make_params(fmt)
    jl = M.exported_fn(cfg, fmt, "loss")(tokens, pos_ids, mask, targets, loss_mask, *flat)
    nl = native_loss(p, fmt, tokens, pos_ids, mask, targets, loss_mask)
    for name, a, b in zip(("sum_ce", "n_tok", "n_correct"), jl, nl):
        rel = abs(float(a) - float(b)) / max(abs(float(a)), 1.0)
        assert rel < 2e-3, (fmt, name, float(a), float(b))
    print(f"loss[{fmt}]  OK   jax={float(jl[0]):.4f} native={float(nl[0]):.4f} "
          f"correct {float(jl[2])}=={float(nl[2])}")

# ---- 2: cls ---------------------------------------------------------------
p, flat = make_params("wq")
cls_pos = rng.integers(1, S - 1, (B,)).astype(np.int32)
class_ids = np.array([24, 25, 26, 24, 24, 24, 24, 24], np.int32)
labels = rng.integers(0, 3, (B,)).astype(np.int32)
jcls = M.exported_fn(cfg, "wq", "cls")(tokens, pos_ids, mask, cls_pos, class_ids, labels, *flat)
h, _ = forward_full(p, "wq", tokens, pos_ids, mask)
at = head(p, h)[np.arange(B), cls_pos]          # [B, V] rows at cls_pos
nscores = at[:, class_ids]
jscores = np.asarray(jcls[2])
err = np.abs(jscores - nscores).max()
assert err < 2e-3, err
print(f"cls[wq]    OK   max|scores diff|={err:.2e}")

# ---- 3: gen (port of NativeBackend::generate) ------------------------------
def native_gen(p, fmt, prompt, lens, tau, gumbel):
    b, sp, t_dec = cfg.b_gen, cfg.s_prompt, cfg.t_dec
    st = sp + t_dec
    pad = sp - lens
    slots = np.arange(sp)[None, :]
    mask = (slots >= pad[:, None]).astype(np.float32)
    pos = np.maximum(slots - pad[:, None], 0).astype(np.int32)
    h, kvs = forward_full(p, fmt, prompt, pos, mask, want_kv=True)
    last = head(p, h)[:, -1, :]
    kc = [np.zeros((b, st, D), np.float32) for _ in range(L)]
    vc = [np.zeros((b, st, D), np.float32) for _ in range(L)]
    for i, (k, v) in enumerate(kvs):
        kc[i][:, :sp] = k
        vc[i][:, :sp] = v
    keymask = np.zeros((b, st), np.float32)
    keymask[:, :sp] = mask
    out = np.zeros((b, t_dec), np.int32)
    for t in range(t_dec):
        val = last + tau * gumbel[:, t, :]
        out[:, t] = val.argmax(-1)          # np.argmax = first max, like jnp
        if t + 1 == t_dec:
            break
        slot = sp + t
        keymask[:, slot] = 1.0
        h1 = p["tok_emb"][out[:, t]] + p["pos_emb"][lens + t]   # [b, D]
        h1 = h1[:, None, :]
        for i in range(L):
            pre = f"layers.{i}."
            x = layernorm(h1, p[pre + "ln1.g"], p[pre + "ln1.b"])
            qh = lin(x, p[pre + "attn.wq"], fmt)
            kh = lin(x, p[pre + "attn.wk"], fmt)
            vh = lin(x, p[pre + "attn.wv"], fmt)
            kc[i][:, slot] = kh[:, 0]
            vc[i][:, slot] = vh[:, 0]
            # single-query attention over the cache
            q4 = qh.reshape(b, 1, H, DH).transpose(0, 2, 1, 3)
            k4 = kc[i].reshape(b, st, H, DH).transpose(0, 2, 1, 3)
            v4 = vc[i].reshape(b, st, H, DH).transpose(0, 2, 1, 3)
            logits = q4 @ k4.transpose(0, 1, 3, 2) / np.sqrt(np.float32(DH))
            bias = np.where(keymask[:, None, None, :] > 0, 0.0, NEG_INF)
            att = softmax(logits + bias)
            a = (att @ v4).transpose(0, 2, 1, 3).reshape(b, 1, D)
            h1 = h1 + lin(a, p[pre + "attn.wo"], fmt)
            x = layernorm(h1, p[pre + "ln2.g"], p[pre + "ln2.b"])
            h1 = h1 + lin(gelu(lin(x, p[pre + "mlp.w1"], fmt)), p[pre + "mlp.w2"], fmt)
        last = head(p, h1)[:, 0, :]
    return out


bg, sp, td = cfg.b_gen, cfg.s_prompt, cfg.t_dec
lens = rng.integers(3, sp + 1, (bg,)).astype(np.int32)
prompt = np.zeros((bg, sp), np.int32)
for i in range(bg):
    prompt[i, sp - lens[i]:] = rng.integers(2, 40, (lens[i],))
for tau, gseed in ((0.0, None), (0.7, 3)):
    gumbel = (np.zeros((bg, td, V), np.float32) if gseed is None
              else rng.standard_normal((bg, td, V)).astype(np.float32))
    jflat = [jnp.asarray(a) for a in flat]
    jtoks = np.asarray(M.exported_fn(cfg, "wq", "gen")(
        jnp.asarray(prompt), jnp.asarray(lens), jnp.float32(tau),
        jnp.asarray(gumbel), *jflat)[0])
    ntoks = native_gen(p, "wq", prompt, lens, np.float32(tau), gumbel)
    match = (jtoks == ntoks).mean()
    assert match == 1.0, (tau, match, jtoks[:2], ntoks[:2])
    print(f"gen[wq]    OK   tau={tau} tokens exact-match")

# ---- 5: continuous-batching scheduler (port of rust/src/sched) --------------
def sched_gen(p, fmt, prompt, lens, slots, max_new, order):
    """Port of sched::Scheduler: slot KV arena + free-list, batched prefill
    over the newly admitted, one batched decode across all live slots, EOS
    retirement with slot recycling. Greedy. Returns {request: tokens}."""
    sp, EOS = cfg.s_prompt, 20
    s_max = sp + max_new
    kc = [np.zeros((slots, s_max, D), np.float32) for _ in range(L)]
    vc = [np.zeros((slots, s_max, D), np.float32) for _ in range(L)]
    keymask = np.zeros((slots, s_max), np.float32)
    free = list(range(slots))[::-1]
    waiting = [dict(t=t, plen=int(lens[t])) for t in order]
    live, done = [], {}
    while waiting or live:
        newly = []
        while waiting and free:
            slot = free.pop()
            lv = waiting.pop(0)
            lv.update(slot=slot, toks=[], logits=None)
            keymask[slot] = 0.0
            live.append(lv)
            newly.append(lv)
        if newly:
            b = len(newly)
            toks = np.zeros((b, sp), np.int32)
            pos = np.zeros((b, sp), np.int32)
            mask = np.zeros((b, sp), np.float32)
            for i, lv in enumerate(newly):
                pad = sp - lv["plen"]
                toks[i] = prompt[lv["t"]]
                pos[i, pad:] = np.arange(lv["plen"])
                mask[i, pad:] = 1.0
            h, kvs = forward_full(p, fmt, toks, pos, mask, want_kv=True)
            last = head(p, h)[:, -1, :]
            for i, lv in enumerate(newly):
                s = lv["slot"]
                for li in range(L):
                    kc[li][s, :sp] = kvs[li][0][i]
                    vc[li][s, :sp] = kvs[li][1][i]
                keymask[s, :sp] = mask[i]
                lv["logits"] = last[i]
        nxt = []
        for lv in live:
            tok = int(lv["logits"].argmax())
            lv["toks"].append(tok)
            if tok == EOS or len(lv["toks"]) >= max_new:
                done[lv["t"]] = lv["toks"]
                free.append(lv["slot"])
            else:
                nxt.append(lv)
        live = nxt
        if not live:
            continue
        m = len(live)
        h1 = np.zeros((m, D), np.float32)
        for i, lv in enumerate(live):
            h1[i] = p["tok_emb"][lv["toks"][-1]] + p["pos_emb"][lv["plen"] + len(lv["toks"]) - 1]
        for li in range(L):
            pre = f"layers.{li}."
            x = layernorm(h1, p[pre + "ln1.g"], p[pre + "ln1.b"])
            qh = lin(x, p[pre + "attn.wq"], fmt)
            kh = lin(x, p[pre + "attn.wk"], fmt)
            vh = lin(x, p[pre + "attn.wv"], fmt)
            a = np.zeros((m, D), np.float32)
            for i, lv in enumerate(live):
                s, pos_slot = lv["slot"], sp + len(lv["toks"]) - 1
                kc[li][s, pos_slot] = kh[i]
                vc[li][s, pos_slot] = vh[i]
                keymask[s, pos_slot] = 1.0
            for i, lv in enumerate(live):
                st, s = sp + len(lv["toks"]), lv["slot"]
                q4 = qh[i].reshape(H, 1, DH)
                k4 = kc[li][s, :st].reshape(st, H, DH).transpose(1, 0, 2)
                v4 = vc[li][s, :st].reshape(st, H, DH).transpose(1, 0, 2)
                lg = (q4 @ k4.transpose(0, 2, 1))[:, 0, :] / np.sqrt(np.float32(DH))
                bias = np.where(keymask[s, :st] > 0, 0.0, NEG_INF)
                att = softmax(lg + bias)
                a[i] = (att[:, None, :] @ v4).reshape(D)
            h1 = h1 + lin(a, p[pre + "attn.wo"], fmt)
            x = layernorm(h1, p[pre + "ln2.g"], p[pre + "ln2.b"])
            h1 = h1 + lin(gelu(lin(x, p[pre + "mlp.w1"], fmt)), p[pre + "mlp.w2"], fmt)
        last = head(p, h1[:, None, :])[:, 0, :]
        for i, lv in enumerate(live):
            lv["logits"] = last[i]
    return done


greedy = native_gen(p, "wq", prompt, lens, np.float32(0.0), np.zeros((bg, td, V), np.float32))
ref = sched_gen(p, "wq", prompt, lens, slots=bg, max_new=td, order=list(range(bg)))
for t in range(bg):
    full = list(int(x) for x in greedy[t])
    want = full[: full.index(20) + 1] if 20 in full else full
    assert ref[t] == want, (t, ref[t], want)
for slots in (1, 2, 3, bg):
    for order in (list(range(bg)), list(range(bg))[::-1], list(range(1, bg)) + [0]):
        got = sched_gen(p, "wq", prompt, lens, slots, td, order)
        assert got == ref, ("sched divergence", slots, order)
print("sched[wq]  OK   continuous batching == gen_fn greedy, slot/order-invariant")

# ---- 4: grads (port of runtime/native/autograd.rs) -------------------------
def native_grads(p, tokens, pos_ids, mask, targets, loss_mask):
    fmt = "fp"
    R = B * S
    tok2 = tokens.reshape(R)
    pos2 = pos_ids.reshape(R)
    E = p["tok_emb"]
    h = (E[tok2] + p["pos_emb"][pos2]).astype(np.float32)
    caches = []
    mask2 = mask
    for i in range(L):
        pre = f"layers.{i}."
        c = {}
        g1, b1 = p[pre + "ln1.g"], p[pre + "ln1.b"]
        hb = h.reshape(B, S, D)
        mu = hb.mean(-1, keepdims=True)
        var = ((hb - mu) ** 2).mean(-1, keepdims=True)
        c["rstd1"] = 1.0 / np.sqrt(var + 1e-5)
        c["xhat1"] = (hb - mu) * c["rstd1"]
        c["x1"] = c["xhat1"] * g1 + b1
        q = c["x1"] @ p[pre + "attn.wq"]
        k = c["x1"] @ p[pre + "attn.wk"]
        v = c["x1"] @ p[pre + "attn.wv"]
        c["q"], c["k"], c["v"] = q, k, v
        a, att = attend_full(q, k, v, mask2)
        c["att"], c["amerge"] = att, a
        h = (hb + a @ p[pre + "attn.wo"]).reshape(R, D)
        hb = h.reshape(B, S, D)
        mu = hb.mean(-1, keepdims=True)
        var = ((hb - mu) ** 2).mean(-1, keepdims=True)
        c["rstd2"] = 1.0 / np.sqrt(var + 1e-5)
        c["xhat2"] = (hb - mu) * c["rstd2"]
        c["x2"] = c["xhat2"] * p[pre + "ln2.g"] + p[pre + "ln2.b"]
        c["u"] = c["x2"] @ p[pre + "mlp.w1"]
        c["gu"] = gelu(c["u"])
        h = (hb + c["gu"] @ p[pre + "mlp.w2"]).reshape(R, D)
        caches.append(c)
    hb = h.reshape(B, S, D)
    mu = hb.mean(-1, keepdims=True)
    var = ((hb - mu) ** 2).mean(-1, keepdims=True)
    rstdf = 1.0 / np.sqrt(var + 1e-5)
    xhatf = (hb - mu) * rstdf
    hf = xhatf * p["lnf.g"] + p["lnf.b"]
    logits = hf @ E.T
    m = logits.max(-1, keepdims=True)
    logz = m[..., 0] + np.log(np.exp(logits - m).sum(-1))
    n_tok = max(loss_mask.sum(), 1.0)
    probs = np.exp(logits - logz[..., None])
    onehot = np.eye(V, dtype=np.float32)[targets]
    dlogits = (loss_mask[..., None] / n_tok) * (probs - onehot)

    g = {name: np.zeros_like(p[name]) for name in p}

    def ln_bwd(dy, xhat, rstd, gain):
        dxh = dy * gain
        m1 = dxh.mean(-1, keepdims=True)
        m2 = (dxh * xhat).mean(-1, keepdims=True)
        dg = (dy * xhat).sum((0, 1))
        db = dy.sum((0, 1))
        return rstd * (dxh - m1 - xhat * m2), dg, db

    dhf = dlogits @ E
    g["tok_emb"] += np.einsum("bsv,bsd->vd", dlogits, hf)
    dh, dgf, dbf = ln_bwd(dhf, xhatf, rstdf, p["lnf.g"])
    g["lnf.g"] += dgf
    g["lnf.b"] += dbf
    for i in reversed(range(L)):
        pre = f"layers.{i}."
        c = caches[i]
        g[pre + "mlp.w2"] += np.einsum("bsf,bsd->fd", c["gu"], dh)
        dgu = dh @ p[pre + "mlp.w2"].T
        cc = np.float32(0.7978845608028654)
        t = np.tanh(cc * (c["u"] + 0.044715 * c["u"] ** 3))
        du = dgu * (0.5 * (1 + t) + 0.5 * c["u"] * (1 - t * t) * cc * (1 + 3 * 0.044715 * c["u"] ** 2))
        g[pre + "mlp.w1"] += np.einsum("bsd,bsf->df", c["x2"], du)
        dx2 = du @ p[pre + "mlp.w1"].T
        dln2, dg2, db2 = ln_bwd(dx2, c["xhat2"], c["rstd2"], p[pre + "ln2.g"])
        g[pre + "ln2.g"] += dg2
        g[pre + "ln2.b"] += db2
        dh_mid = dh + dln2
        g[pre + "attn.wo"] += np.einsum("bsd,bse->de", c["amerge"], dh_mid)
        da = dh_mid @ p[pre + "attn.wo"].T
        dah = da.reshape(B, S, H, DH).transpose(0, 2, 1, 3)
        vh = c["v"].reshape(B, S, H, DH).transpose(0, 2, 1, 3)
        kh = c["k"].reshape(B, S, H, DH).transpose(0, 2, 1, 3)
        qh = c["q"].reshape(B, S, H, DH).transpose(0, 2, 1, 3)
        att = c["att"]
        datt = dah @ vh.transpose(0, 1, 3, 2)
        dv4 = att.transpose(0, 1, 3, 2) @ dah
        dot = (datt * att).sum(-1, keepdims=True)
        dlog = att * (datt - dot)
        scale = 1.0 / np.sqrt(np.float32(DH))
        dq4 = dlog @ kh * scale
        dk4 = dlog.transpose(0, 1, 3, 2) @ qh * scale
        dq = dq4.transpose(0, 2, 1, 3).reshape(B, S, D)
        dk = dk4.transpose(0, 2, 1, 3).reshape(B, S, D)
        dv = dv4.transpose(0, 2, 1, 3).reshape(B, S, D)
        g[pre + "attn.wq"] += np.einsum("bsd,bse->de", c["x1"], dq)
        g[pre + "attn.wk"] += np.einsum("bsd,bse->de", c["x1"], dk)
        g[pre + "attn.wv"] += np.einsum("bsd,bse->de", c["x1"], dv)
        dx1 = dq @ p[pre + "attn.wq"].T + dk @ p[pre + "attn.wk"].T + dv @ p[pre + "attn.wv"].T
        dln1, dg1, db1 = ln_bwd(dx1, c["xhat1"], c["rstd1"], p[pre + "ln1.g"])
        g[pre + "ln1.g"] += dg1
        g[pre + "ln1.b"] += db1
        dh = dh_mid + dln1
    dh2 = dh.reshape(R, D)
    np.add.at(g["tok_emb"], tok2, dh2)
    np.add.at(g["pos_emb"], pos2, dh2)
    return g


p, flat = make_params("fp")
grad_fn = M.exported_fn(cfg, "fp", "grad")
jout = grad_fn(tokens, pos_ids, mask, targets, loss_mask, *flat)
jgrads = [np.asarray(x) for x in jout[1:]]
ngr = native_grads(p, tokens, pos_ids, mask, targets, loss_mask)
names = [n for n, _, _ in M.flat_args_for(cfg, "fp")]
worst = 0.0
for name, jg in zip(names, jgrads):
    ng = ngr[name]
    denom = max(np.abs(jg).max(), 1e-6)
    rel = np.abs(jg - ng).max() / denom
    worst = max(worst, rel)
    assert rel < 5e-2, (name, rel, float(np.abs(jg).max()))
print(f"grad[fp]   OK   worst per-tensor rel err={worst:.2e}")
print("ALL NATIVE-SEMANTICS CHECKS PASSED")
