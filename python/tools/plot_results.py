#!/usr/bin/env python
"""Render the experiment CSVs under results/ into figures (matplotlib).

Usage: python python/tools/plot_results.py [results_dir] [out_dir]

Produces:
  fig2.png — training curves (QuZO vs QES vs Full-Residual)
  fig3.png — discrete-grid optimization toy (§5 temporal equivalence)
  table9.png — replay overhead vs window K
"""

import csv
import os
import sys


def read_csv(path):
    with open(path) as f:
        rows = list(csv.DictReader(f))
    return rows


def main():
    results = sys.argv[1] if len(sys.argv) > 1 else "results"
    out = sys.argv[2] if len(sys.argv) > 2 else "results"
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("matplotlib not available; skipping plots")
        return 0

    # ---- Figure 2: training curves ----
    series = {}
    for name in ["quzo", "qes", "qes_full_residual"]:
        p = os.path.join(results, f"fig2_{name}.csv")
        if os.path.exists(p):
            rows = read_csv(p)
            series[name] = (
                [int(r["gen"]) for r in rows],
                [float(r["mean_reward"]) for r in rows],
            )
    if series:
        plt.figure(figsize=(7, 4))
        colors = {"quzo": "tab:orange", "qes": "tab:green",
                  "qes_full_residual": "tab:blue"}
        for name, (x, y) in series.items():
            plt.plot(x, y, label=name, color=colors.get(name))
        plt.xlabel("generation")
        plt.ylabel("mean rollout reward")
        plt.title("Figure 2: Countdown training curves")
        plt.legend()
        plt.tight_layout()
        plt.savefig(os.path.join(out, "fig2.png"), dpi=120)
        print("wrote fig2.png")

    # ---- Figure 3: toy grid optimization ----
    p = os.path.join(results, "fig3.csv")
    if os.path.exists(p):
        rows = read_csv(p)
        x = [int(r["step"]) for r in rows]
        plt.figure(figsize=(7, 4))
        for col, style in [
            ("continuous", "-"),
            ("naive_round", "--"),
            ("stochastic_round", ":"),
            ("qes", "-."),
        ]:
            plt.plot(x, [float(r[col]) for r in rows], style, label=col)
        plt.xlabel("step")
        plt.ylabel("w")
        plt.title("Figure 3: optimization on a discrete grid")
        plt.legend()
        plt.tight_layout()
        plt.savefig(os.path.join(out, "fig3.png"), dpi=120)
        print("wrote fig3.png")

    # ---- Table 9: replay overhead vs K ----
    p = os.path.join(results, "table9.csv")
    if os.path.exists(p):
        rows = [r for r in read_csv(p) if r["variant"] == "seed-replay"]
        if rows:
            ks = [int(r["k"]) for r in rows]
            ov = [float(r["overhead"]) for r in rows]
            plt.figure(figsize=(6, 4))
            plt.plot(ks, ov, "o-")
            plt.axhline(1.0, color="gray", ls="--", label="full-residual oracle")
            plt.xlabel("replay window K")
            plt.ylabel("total time vs oracle")
            plt.title("Table 9: replay overhead vs K")
            plt.legend()
            plt.tight_layout()
            plt.savefig(os.path.join(out, "table9.png"), dpi=120)
            print("wrote table9.png")
    return 0


if __name__ == "__main__":
    sys.exit(main())
