#!/usr/bin/env python3
"""Collect `BENCH {json}` records from bench logs into one JSON artifact.

The bench harness (rust/src/util/bench.rs) prints one machine-readable
line per case and per speedup record:

    BENCH {"group":"L3 hot paths","case":"rollout_grouped/pop8/nano/int4",...}
    BENCH {"group":"speedup","case":"rollout_grouped/pop8","kernel":"avx2",...}

CI pipes bench output through this script to publish a perf artifact
(e.g. BENCH_PR7.json) that tracks the perf trajectory across PRs without
anyone re-grepping raw logs.

Usage:
    cargo bench --bench hotpaths | python python/tools/collect_bench.py \
        --out BENCH_PR7.json [--require rollout_grouped/pop8 ...]

Reads stdin (or files passed as positional args), writes a JSON document:

    {"records": [...], "speedups": {case: ratio, ...}}

`--require CASE` fails (exit 1) when no record for CASE was seen — a
speedup record (matched on its case name) or a plain measurement record
(matched on "group/case", e.g. serve_saturation/c8). This is the CI gate
that a bench refactor can't silently drop a tracked case.
`--min CASE:RATIO` additionally enforces a floor on a speedup record.
"""

import argparse
import fileinput
import json
import sys

PREFIX = "BENCH "


def parse_lines(lines):
    records = []
    for line in lines:
        line = line.strip()
        if not line.startswith(PREFIX):
            continue
        payload = line[len(PREFIX):]
        try:
            records.append(json.loads(payload))
        except json.JSONDecodeError as e:
            print(f"collect_bench: unparseable BENCH line ({e}): {payload}",
                  file=sys.stderr)
            return None
    return records


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="bench logs (default: stdin)")
    ap.add_argument("--out", required=True, help="output JSON path")
    ap.add_argument("--require", action="append", default=[],
                    metavar="CASE",
                    help="fail unless a record for CASE exists (speedup "
                         "case name, or group/case for plain records)")
    ap.add_argument("--min", action="append", default=[],
                    metavar="CASE:RATIO",
                    help="fail unless speedup[CASE] >= RATIO")
    args = ap.parse_args()

    records = parse_lines(fileinput.input(args.files))
    if records is None:
        return 1
    if not records:
        print("collect_bench: no BENCH lines found in input", file=sys.stderr)
        return 1

    speedups = {
        r["case"]: r["speedup"]
        for r in records
        if r.get("group") == "speedup" and "speedup" in r
    }

    # plain (non-speedup) records are addressable as "group/case"
    plain = {
        f"{r['group']}/{r['case']}"
        for r in records
        if r.get("group") != "speedup" and "group" in r and "case" in r
    }

    ok = True
    for case in args.require:
        if case not in speedups and case not in plain:
            print(f"collect_bench: REQUIRED record missing: {case}",
                  file=sys.stderr)
            ok = False
    for spec in args.min:
        case, _, floor = spec.rpartition(":")
        if not case:
            print(f"collect_bench: bad --min spec {spec!r} (want CASE:RATIO)",
                  file=sys.stderr)
            ok = False
            continue
        if case not in speedups:
            print(f"collect_bench: --min case missing: {case}", file=sys.stderr)
            ok = False
        elif speedups[case] < float(floor):
            print(f"collect_bench: speedup[{case}] = {speedups[case]:.3f} "
                  f"< required {float(floor):.3f}", file=sys.stderr)
            ok = False

    with open(args.out, "w") as f:
        json.dump({"records": records, "speedups": speedups}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    print(f"collect_bench: wrote {len(records)} records "
          f"({len(speedups)} speedups) to {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
