#!/usr/bin/env python3
"""Generate golden vectors for rust/tests/rng_golden.rs.

The O(1) stream-positioning contract (`SplitMix64::jump`,
`NoiseStream::at`) is what every chunk-parallel kernel builds on, but the
Rust tests only checked the streams against *themselves* (jump vs a
sequential walk of the same generator). A refactor that changed GAMMA,
the output mixer or the draws-per-element accounting would stay
self-consistent and pass — while silently invalidating every stored
(gen_seed, fitness) history. This script pins the streams against an
independent re-implementation:

* `SplitMix64` outputs and jumps are pure 64-bit integer arithmetic —
  reproduced here exactly.
* `uniform01` is exact in f32 (24-bit integer times a power of two).
* `NoiseStream` deltas go through Box-Muller (f64 ln, f32 cos), where
  libm implementations may differ by an ulp. Every emitted delta is
  therefore checked to be ROBUST: the discrete decisions (floor cell,
  Bernoulli comparison) must hold under +-8 ulp perturbation of the
  gaussian, or the candidate window is rejected and the search moves on.

Run from repo root:  python python/tools/gen_rng_goldens.py
Paste the emitted arrays into rust/tests/rng_golden.rs.
"""

import math

import numpy as np

M64 = (1 << 64) - 1
GAMMA = 0x9E3779B97F4A7C15
F32 = np.float32


def mix(z):
    z &= M64
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9 & M64
    z = (z ^ (z >> 27)) * 0x94D049BB133111EB & M64
    return (z ^ (z >> 31)) & M64


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & M64

    def jump(self, n):
        self.state = (self.state + (GAMMA * n & M64)) & M64

    def next_u64(self):
        self.state = (self.state + GAMMA) & M64
        return mix(self.state)

    def uniform01(self):
        # (next_u64() >> 40) as f32 * (1 / 2^24): both steps exact in f32
        return F32(self.next_u64() >> 40) * F32(2.0**-24)


def member_seed(gen_seed, member):
    z = (gen_seed ^ (member * 0xFF51AFD7ED558CCD & M64)) & M64
    z = (z ^ (z >> 33)) * 0xC4CEB9FE1A85EC53 & M64
    return (z ^ (z >> 33)) & M64


def normal(rng):
    """Box-Muller exactly as rng::SplitMix64::normal (f64 ln, f32 cos)."""
    u1 = F32(1.0) - rng.uniform01()
    u2 = rng.uniform01()
    r = F32(math.sqrt(-2.0 * math.log(float(u1))))
    two_pi = F32(2.0) * F32(np.pi)  # exact: power-of-two multiply
    theta = two_pi * u2
    # f32 cos: compute in f64, round. Rust's cosf may differ by an ulp —
    # the robustness check below absorbs that.
    return F32(r) * F32(math.cos(float(theta))), float(r), float(theta)


def pair_deltas(sigma, z, u):
    xp = F32(sigma) * z
    xm = F32(-xp)
    fp = F32(np.floor(xp))
    fm = F32(np.floor(xm))
    dp = int(fp) + (1 if u < xp - fp else 0)
    dm = int(fm) + (1 if u < xm - fm else 0)
    return dp, dm


def robust_pair(sigma, z, u):
    """The (dp, dm) decision, or None if any discrete decision flips under
    +-8 ulp perturbation of z (covers libm cos/ln divergence)."""
    base = pair_deltas(sigma, z, u)
    eps = np.spacing(z) if z != 0 else np.float32(1e-38)
    for k in (-8, 8):
        if pair_deltas(sigma, F32(z + F32(k) * eps), u) != base:
            return None
    # Bernoulli margin: u must not sit within 1e-5 of either threshold
    xp = F32(sigma) * z
    for x in (xp, F32(-xp)):
        frac = x - F32(np.floor(x))
        if abs(float(u) - float(frac)) < 1e-5:
            return None
    return base


def delta_window(seed, sigma, start, n):
    """Deltas [start, start+n) of the delta-view stream, or None if any
    element is non-robust. Mirrors NoiseStream::at + next_pair_deltas."""
    rng = SplitMix64(seed)
    rng.jump(3 * start)  # DELTA_DRAWS_PER_ELEM = 3
    out = []
    for _ in range(n):
        z, _, _ = normal(rng)
        u = rng.uniform01()
        pair = robust_pair(sigma, z, u)
        if pair is None:
            return None
        out.append(pair)
    return out


def main():
    print("// --- SplitMix64 goldens (exact integer arithmetic) ---")
    for seed in (0, 42, 0xDEADBEEF, M64):
        r = SplitMix64(seed)
        vals = [r.next_u64() for _ in range(4)]
        print(f"// seed {seed:#x}: {[hex(v) for v in vals]}")

    print("\n// jump goldens: (seed, n_draws) -> next two outputs")
    for seed, n in ((42, 1), (42, 10**6), (7, 123_456_789_012), (M64, 3 * (1 << 40))):
        r = SplitMix64(seed)
        r.jump(n)
        print(f"// ({seed:#x}, {n}): {hex(r.next_u64())}, {hex(r.next_u64())}")

    print("\n// member_seed goldens")
    for g, m in ((0, 0), (0xABCDEF, 1), (42, 7), (M64, 1000)):
        print(f"// member_seed({g:#x}, {m}) = {hex(member_seed(g, m))}")

    print("\n// uniform01 goldens (f32 bit patterns, exact)")
    for seed in (3, 0x5EED):
        r = SplitMix64(seed)
        bits = [hex(int(r.uniform01().view(np.uint32))) for _ in range(4)]
        print(f"// seed {seed:#x}: {bits}")

    print("\n// NoiseStream::at delta goldens (robust to ulp-level libm skew)")
    for seed, sigma, start in (
        (0x5EED, 0.8, 0),
        (0x5EED, 0.8, 1_000),
        (77, 1.6, 123_456_789),
        (9, 0.45, 1 << 33),
    ):
        n = 24
        win = delta_window(seed, sigma, start, n)
        tries = 0
        s = start
        while win is None and tries < 200:
            s += n  # slide until every element in the window is robust
            win = delta_window(seed, sigma, s, n)
            tries += 1
        assert win is not None, f"no robust window near {(seed, sigma, start)}"
        dps = [p for p, _ in win]
        dms = [m for _, m in win]
        print(f"// (seed={seed:#x}, sigma={sigma}, start={s}):")
        print(f"//   dp: {dps}")
        print(f"//   dm: {dms}")


if __name__ == "__main__":
    main()
