"""Layer-2: the QES backbone as JAX functions, AOT-lowered to HLO.

A GPT-style decoder whose linear layers live on the integer lattice and are
executed through the L1 Pallas kernels (``quant_matmul`` / ``w8a8_matmul``).
Embeddings, layer norms and the (weight-tied) LM head stay FP32, following
the LLM-QAT convention the paper adopts (§A.1).

Three weight *formats* are compiled (DESIGN.md §3):

* ``wq``   — int8 lattice weights + per-channel scales, FP activations.
             Serves both INT4 and INT8: the bit-width only changes the
             lattice *range*, which the Rust coordinator enforces.
* ``w8a8`` — same weights, activations dynamically quantized to INT8 inside
             the kernel.
* ``fp``   — plain f32 weights; used by the MeZO / first-order baselines and
             by pretraining (the ``grad`` artifact).

Four *functions* are exported per (config, format):

* ``gen``  — batched autoregressive generation: prefill + ``lax.scan`` decode
             with an in-graph KV cache, gumbel-noise sampling (τ=0 ⇒ greedy).
             One PJRT call per rollout batch — Python is never on the
             request path, and neither is a per-token round-trip.
* ``loss`` — teacher-forced masked cross-entropy + correct-token count.
* ``cls``  — verbalizer-token classification (LM-BFF style): softmax over a
             class-token subset at a per-example position.
* ``grad`` — (fp only) loss + gradients for every parameter; powers the
             in-repo pretraining pipeline and the FO/STE baselines.

Sequence convention: prompts are LEFT-padded to a fixed length; explicit
``pos_ids`` and a key ``mask`` are inputs everywhere, so padding never
affects positional semantics. Left-padding makes decode-time cache writes
uniform across the batch (slot ``s_prompt + step`` for everyone).
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import quant_matmul, w8a8_matmul

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------

class ParamSpec:
    """A single named parameter tensor.

    kind: 'fp'      — always-FP32 tensor (embeddings, norms)
          'lattice' — quantized linear weight; materialized as one f32 arg in
                      the fp format, or as (int8 q, f32 per-channel scale)
                      in the quantized formats.
    """

    def __init__(self, name, shape, kind, init):
        self.name = name
        self.shape = tuple(shape)
        self.kind = kind
        self.init = init  # ('normal', std) | ('zeros',) | ('ones',)

    def __repr__(self):
        return f"ParamSpec({self.name}, {self.shape}, {self.kind})"


def param_specs(cfg: ModelConfig):
    """The canonical, ordered parameter list. The Rust side mirrors this
    order via the manifest; never reorder without bumping the manifest."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    std = 0.06
    specs = [
        ParamSpec("tok_emb", (v, d), "fp", ("normal", std)),
        ParamSpec("pos_emb", (cfg.s_total if cfg.s_total > cfg.s_train else cfg.s_train, d),
                  "fp", ("normal", std)),
    ]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs += [
            ParamSpec(p + "ln1.g", (d,), "fp", ("ones",)),
            ParamSpec(p + "ln1.b", (d,), "fp", ("zeros",)),
            ParamSpec(p + "attn.wq", (d, d), "lattice", ("normal", std)),
            ParamSpec(p + "attn.wk", (d, d), "lattice", ("normal", std)),
            ParamSpec(p + "attn.wv", (d, d), "lattice", ("normal", std)),
            ParamSpec(p + "attn.wo", (d, d), "lattice", ("normal", std)),
            ParamSpec(p + "ln2.g", (d,), "fp", ("ones",)),
            ParamSpec(p + "ln2.b", (d,), "fp", ("zeros",)),
            ParamSpec(p + "mlp.w1", (d, f), "lattice", ("normal", std)),
            ParamSpec(p + "mlp.w2", (f, d), "lattice", ("normal", std)),
        ]
    specs += [
        ParamSpec("lnf.g", (d,), "fp", ("ones",)),
        ParamSpec("lnf.b", (d,), "fp", ("zeros",)),
    ]
    return specs


def flat_args_for(cfg: ModelConfig, fmt: str):
    """The flattened (name, dtype, shape) argument layout for params under a
    given format — exactly what the manifest records and Rust marshals."""
    out = []
    for s in param_specs(cfg):
        if s.kind == "lattice" and fmt in ("wq", "w8a8"):
            out.append((s.name + ".q", "i8", s.shape))
            out.append((s.name + ".s", "f32", (s.shape[1],)))
        else:
            out.append((s.name, "f32", s.shape))
    return out


def unflatten_params(cfg: ModelConfig, fmt: str, args):
    """Rebuild {name: tensor | (q, s)} from the flat positional args."""
    params = {}
    it = iter(args)
    for s in param_specs(cfg):
        if s.kind == "lattice" and fmt in ("wq", "w8a8"):
            q = next(it)
            sc = next(it)
            params[s.name] = (q, sc)
        else:
            params[s.name] = next(it)
    return params


# ---------------------------------------------------------------------------
# Model internals
# ---------------------------------------------------------------------------

def _linear(x, w, fmt):
    """Apply a (possibly quantized) linear layer to x[..., K]."""
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if fmt == "fp":
        y = jnp.matmul(x2, w, preferred_element_type=jnp.float32)
    elif fmt == "wq":
        q, s = w
        y = quant_matmul(x2, q, s)
    elif fmt == "w8a8":
        q, s = w
        y = w8a8_matmul(x2, q, s)
    else:
        raise ValueError(fmt)
    return y.reshape(lead + (y.shape[-1],))


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _attend(q, k, v, bias):
    """q[B,H,Sq,dh] x k,v[B,H,Sk,dh] with additive bias[B,1,Sq,Sk]."""
    dh = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    att = jax.nn.softmax(logits + bias, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", att, v)


def _block_full(cfg, fmt, p, i, h, bias):
    """Full-sequence transformer block (prefill / training)."""
    pre = f"layers.{i}."
    x = _layernorm(h, p[pre + "ln1.g"], p[pre + "ln1.b"])
    q = _split_heads(_linear(x, p[pre + "attn.wq"], fmt), cfg.n_heads)
    k = _split_heads(_linear(x, p[pre + "attn.wk"], fmt), cfg.n_heads)
    v = _split_heads(_linear(x, p[pre + "attn.wv"], fmt), cfg.n_heads)
    a = _merge_heads(_attend(q, k, v, bias))
    h = h + _linear(a, p[pre + "attn.wo"], fmt)
    x = _layernorm(h, p[pre + "ln2.g"], p[pre + "ln2.b"])
    x = _linear(x, p[pre + "mlp.w1"], fmt)
    x = jax.nn.gelu(x)
    h = h + _linear(x, p[pre + "mlp.w2"], fmt)
    return h, k, v


def _embed(cfg, p, tokens, pos_ids):
    te = p["tok_emb"][tokens]          # [B,S,D]
    pe = p["pos_emb"][pos_ids]         # [B,S,D]
    return te + pe


def _logits(cfg, p, h):
    h = _layernorm(h, p["lnf.g"], p["lnf.b"])
    return jnp.matmul(h, p["tok_emb"].T, preferred_element_type=jnp.float32)


def forward(cfg, fmt, p, tokens, pos_ids, mask):
    """Full-sequence forward.

    Args:
      tokens: i32[B,S]; pos_ids: i32[B,S]; mask: f32[B,S] (1=real, 0=pad).

    Returns:
      logits f32[B,S,V], per-layer (k, v) for cache priming.
    """
    b, s = tokens.shape
    causal = jnp.tril(jnp.ones((s, s), dtype=jnp.float32))
    keymask = mask[:, None, None, :]                        # [B,1,1,S]
    bias = jnp.where((causal[None, None] * keymask) > 0, 0.0, NEG_INF)
    h = _embed(cfg, p, tokens, pos_ids)
    kvs = []
    for i in range(cfg.n_layers):
        h, k, v = _block_full(cfg, fmt, p, i, h, bias)
        kvs.append((k, v))
    return _logits(cfg, p, h), kvs


# ---------------------------------------------------------------------------
# Exported functions
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, fmt: str):
    """(tokens, pos_ids, mask, targets, loss_mask, *params) ->
    (sum_ce f32, n_tokens f32, n_correct f32)."""

    def loss_fn(tokens, pos_ids, mask, targets, loss_mask, *args):
        p = unflatten_params(cfg, fmt, args)
        logits, _ = forward(cfg, fmt, p, tokens, pos_ids, mask)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        sum_ce = jnp.sum(nll * loss_mask)
        n_tok = jnp.sum(loss_mask)
        pred = jnp.argmax(logits, axis=-1)
        n_correct = jnp.sum((pred == targets).astype(jnp.float32) * loss_mask)
        return sum_ce, n_tok, n_correct

    return loss_fn


def make_cls_fn(cfg: ModelConfig, fmt: str):
    """Verbalizer classification (LM-BFF): score class tokens at cls_pos.

    (tokens, pos_ids, mask, cls_pos i32[B], class_ids i32[C], labels i32[B],
     *params) -> (sum_ce, n_correct, scores f32[B,C])
    """

    def cls_fn(tokens, pos_ids, mask, cls_pos, class_ids, labels, *args):
        p = unflatten_params(cfg, fmt, args)
        logits, _ = forward(cfg, fmt, p, tokens, pos_ids, mask)   # [B,S,V]
        at = jnp.take_along_axis(
            logits, cls_pos[:, None, None].astype(jnp.int32), axis=1
        )[:, 0, :]                                                # [B,V]
        scores = at[:, class_ids]                                 # [B,C]
        logp = jax.nn.log_softmax(scores, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        pred = jnp.argmax(scores, axis=-1)
        n_correct = jnp.sum((pred == labels).astype(jnp.float32))
        return jnp.sum(nll), n_correct, scores

    return cls_fn


def make_grad_fn(cfg: ModelConfig):
    """FP-only: (tokens, pos_ids, mask, targets, loss_mask, *params) ->
    (mean_loss, *grads) in canonical param order."""
    loss_fn = make_loss_fn(cfg, "fp")
    n_params = len(flat_args_for(cfg, "fp"))

    def mean_loss(tokens, pos_ids, mask, targets, loss_mask, *args):
        sum_ce, n_tok, _ = loss_fn(tokens, pos_ids, mask, targets, loss_mask, *args)
        return sum_ce / jnp.maximum(n_tok, 1.0)

    def grad_fn(tokens, pos_ids, mask, targets, loss_mask, *args):
        argnums = tuple(range(5, 5 + n_params))
        loss, grads = jax.value_and_grad(mean_loss, argnums=argnums)(
            tokens, pos_ids, mask, targets, loss_mask, *args
        )
        return (loss,) + tuple(grads)

    return grad_fn


def make_gen_fn(cfg: ModelConfig, fmt: str):
    """Batched autoregressive generation, fully in-graph.

    (prompt i32[B,Sp] (LEFT-padded), prompt_len i32[B], tau f32[],
     gumbel f32[B,T,V], *params) -> tokens i32[B,T]

    Sampling: argmax(logits + tau * gumbel) == sampling from softmax(l/tau);
    tau = 0 is greedy. The KV cache is carried through a lax.scan; thanks to
    left-padding every example writes cache slot `s_prompt + t` at step t.
    """
    sp, t_dec, st = cfg.s_prompt, cfg.t_dec, cfg.s_total

    def gen_fn(prompt, prompt_len, tau, gumbel, *args):
        p = unflatten_params(cfg, fmt, args)
        b = prompt.shape[0]
        pad = sp - prompt_len                                  # [B]
        slots = jnp.arange(sp)[None, :]                        # [1,Sp]
        mask = (slots >= pad[:, None]).astype(jnp.float32)     # [B,Sp]
        pos_ids = jnp.maximum(slots - pad[:, None], 0).astype(jnp.int32)

        logits, kvs = forward(cfg, fmt, p, prompt, pos_ids, mask)
        last = logits[:, -1, :]                                # [B,V]

        # Pad caches and mask to the full decode horizon.
        def padcache(x):                                       # [B,H,Sp,dh] -> [B,H,St,dh]
            return jnp.pad(x, ((0, 0), (0, 0), (0, t_dec), (0, 0)))

        ks = [padcache(k) for k, _ in kvs]
        vs = [padcache(v) for _, v in kvs]
        keymask0 = jnp.pad(mask, ((0, 0), (0, t_dec)))         # [B,St]

        def step(carry, g_t):
            ks, vs, keymask, last_logits, t = carry
            nxt = jnp.argmax(last_logits + tau * g_t, axis=-1).astype(jnp.int32)  # [B]
            slot = sp + t
            pos = (prompt_len + t).astype(jnp.int32)           # [B]
            h = p["tok_emb"][nxt] + p["pos_emb"][pos]          # [B,D]
            h = h[:, None, :]                                  # [B,1,D]
            keymask = keymask.at[:, slot].set(1.0)
            new_ks, new_vs = [], []
            for i in range(cfg.n_layers):
                pre = f"layers.{i}."
                x = _layernorm(h, p[pre + "ln1.g"], p[pre + "ln1.b"])
                qh = _split_heads(_linear(x, p[pre + "attn.wq"], fmt), cfg.n_heads)
                kh = _split_heads(_linear(x, p[pre + "attn.wk"], fmt), cfg.n_heads)
                vh = _split_heads(_linear(x, p[pre + "attn.wv"], fmt), cfg.n_heads)
                k_cache = jax.lax.dynamic_update_slice_in_dim(ks[i], kh, slot, axis=2)
                v_cache = jax.lax.dynamic_update_slice_in_dim(vs[i], vh, slot, axis=2)
                bias = jnp.where(keymask[:, None, None, :] > 0, 0.0, NEG_INF)
                a = _merge_heads(_attend(qh, k_cache, v_cache, bias))
                h = h + _linear(a, p[pre + "attn.wo"], fmt)
                x = _layernorm(h, p[pre + "ln2.g"], p[pre + "ln2.b"])
                x = jax.nn.gelu(_linear(x, p[pre + "mlp.w1"], fmt))
                h = h + _linear(x, p[pre + "mlp.w2"], fmt)
                new_ks.append(k_cache)
                new_vs.append(v_cache)
            logits_t = _logits(cfg, p, h)[:, 0, :]             # [B,V]
            return (new_ks, new_vs, keymask, logits_t, t + 1), nxt

        gumbel_t = jnp.transpose(gumbel, (1, 0, 2))            # [T,B,V]
        (_, _, _, _, _), toks = jax.lax.scan(
            step, (ks, vs, keymask0, last, 0), gumbel_t
        )
        return (jnp.transpose(toks, (1, 0)),)                  # i32[B,T]

    return gen_fn


# ---------------------------------------------------------------------------
# Wrappers returning tuple outputs (AOT requires tuple returns)
# ---------------------------------------------------------------------------

def exported_fn(cfg: ModelConfig, fmt: str, which: str):
    if which == "gen":
        return make_gen_fn(cfg, fmt)
    if which == "loss":
        f = make_loss_fn(cfg, fmt)
        return lambda *a: tuple(f(*a))
    if which == "cls":
        f = make_cls_fn(cfg, fmt)
        return lambda *a: tuple(f(*a))
    if which == "grad":
        assert fmt == "fp", "grad artifact exists only in fp format"
        return make_grad_fn(cfg)
    raise ValueError(which)


def example_data_args(cfg: ModelConfig, which: str):
    """ShapeDtypeStructs for the *data* (non-param) inputs, in order."""
    i32, f32 = jnp.int32, jnp.float32
    b, bt, sp, t, st, v, c = (
        cfg.b_gen, cfg.b_train, cfg.s_prompt, cfg.t_dec, cfg.s_train,
        cfg.vocab, 8,
    )
    S = jax.ShapeDtypeStruct
    if which == "gen":
        return [
            ("prompt", S((b, sp), i32)),
            ("prompt_len", S((b,), i32)),
            ("tau", S((), f32)),
            ("gumbel", S((b, t, v), f32)),
        ]
    if which in ("loss", "grad"):
        return [
            ("tokens", S((bt, st), i32)),
            ("pos_ids", S((bt, st), i32)),
            ("mask", S((bt, st), f32)),
            ("targets", S((bt, st), i32)),
            ("loss_mask", S((bt, st), f32)),
        ]
    if which == "cls":
        return [
            ("tokens", S((bt, st), i32)),
            ("pos_ids", S((bt, st), i32)),
            ("mask", S((bt, st), f32)),
            ("cls_pos", S((bt,), i32)),
            ("class_ids", S((c,), i32)),
            ("labels", S((bt,), i32)),
        ]
    raise ValueError(which)
