"""Model-family configuration — the single source of truth for shapes.

The Rust coordinator never reads this file; it reads the ``manifest.json``
that ``aot.py`` derives from it. Sizes are scaled for the single-core CPU
PJRT testbed (see DESIGN.md §2 substitutions): they stand in for the paper's
Qwen2.5-1.5B/-3B and Llama-3.1-8B backbones. The *lattice geometry* —
which is what QES's mechanisms act on — is preserved exactly.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int          # token vocabulary (char-level; mirrors rust tokenizer)
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    s_prompt: int       # fixed (left-padded) prompt length for generation
    t_dec: int          # decode steps in the `gen` artifact
    s_train: int        # sequence length for the `loss`/`cls`/`grad` artifacts
    b_gen: int          # generation batch (problems per PJRT call)
    b_train: int        # training/loss batch

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def s_total(self) -> int:
        return self.s_prompt + self.t_dec

    def lattice_param_count(self) -> int:
        """Number of integer-lattice (quantized) parameters."""
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        return self.n_layers * per_layer


# Char-level vocabulary is defined by the Rust tokenizer; both sides agree on
# its size. 48 symbols cover digits, operators, separators and a small
# letterset for the SFT templates.
VOCAB = 48

CONFIGS = {
    # paper analog: RoBERTa-large (SFT backbone) — smallest, fastest
    "nano": ModelConfig("nano", VOCAB, 48, 2, 3, 96, 16, 12, 32, 8, 8),
    # paper analog: Qwen2.5-1.5B
    "micro": ModelConfig("micro", VOCAB, 96, 3, 4, 192, 24, 16, 48, 8, 8),
    # paper analog: Qwen2.5-3B
    "small": ModelConfig("small", VOCAB, 160, 5, 5, 320, 24, 16, 48, 8, 8),
    # paper analog: Llama-3.1-8B (scaling case study, Table 5)
    "base": ModelConfig("base", VOCAB, 256, 6, 8, 512, 24, 20, 48, 8, 8),
}
