"""Emit ``artifacts/manifest.json`` WITHOUT lowering HLO (no jax needed).

``aot.py`` is the full pipeline: it lowers every exported function to HLO
text and writes the manifest alongside. But the manifest alone — model
configs, per-format parameter layouts, and per-artifact I/O specs — is a
pure function of ``configs.py`` + ``model.py``'s layout rules, and the Rust
crate's entire optimizer/test suite needs only the manifest (the PJRT
engines additionally need the ``.hlo.txt`` files, and gate themselves off
when those are absent).

This script derives the identical manifest schema by hand so the Rust
tier-1 tests can run on a box without jax. Keep the layout rules here in
lockstep with ``model.py``:

* ``param_specs`` / ``flat_args_for`` — parameter order and quantized
  (q, s) splitting;
* ``example_data_args`` — the data-input specs per exported function;
* output shapes — gen: ``i32[B,T]``; loss: three f32 scalars;
  cls: two f32 scalars + ``f32[B,8]`` scores; grad: f32 scalar + one
  gradient per flat fp arg.

Usage:  python -m compile.manifest_only --out-dir ../rust/artifacts
"""

import argparse
import json
import os
import sys

from .configs import CONFIGS

FORMATS = ("wq", "w8a8", "fp")
FNS = ("gen", "loss", "cls")  # + "grad" for fp
N_CLS = 8  # class-token slots in the cls artifact (mirrors model.py)


def param_specs(cfg):
    """(name, shape, kind, init) in canonical order — mirrors model.py."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    std = 0.06
    pos_rows = cfg.s_total if cfg.s_total > cfg.s_train else cfg.s_train
    specs = [
        ("tok_emb", (v, d), "fp", ("normal", std)),
        ("pos_emb", (pos_rows, d), "fp", ("normal", std)),
    ]
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        specs += [
            (p + "ln1.g", (d,), "fp", ("ones",)),
            (p + "ln1.b", (d,), "fp", ("zeros",)),
            (p + "attn.wq", (d, d), "lattice", ("normal", std)),
            (p + "attn.wk", (d, d), "lattice", ("normal", std)),
            (p + "attn.wv", (d, d), "lattice", ("normal", std)),
            (p + "attn.wo", (d, d), "lattice", ("normal", std)),
            (p + "ln2.g", (d,), "fp", ("ones",)),
            (p + "ln2.b", (d,), "fp", ("zeros",)),
            (p + "mlp.w1", (d, f), "lattice", ("normal", std)),
            (p + "mlp.w2", (f, d), "lattice", ("normal", std)),
        ]
    specs += [
        ("lnf.g", (d,), "fp", ("ones",)),
        ("lnf.b", (d,), "fp", ("zeros",)),
    ]
    return specs


def flat_args_for(cfg, fmt):
    out = []
    for name, shape, kind, init in param_specs(cfg):
        if kind == "lattice" and fmt in ("wq", "w8a8"):
            out.append((name + ".q", "i8", shape, "lattice_q", None))
            out.append((name + ".s", "f32", (shape[1],), "scale", None))
        else:
            pkind = "lattice_as_fp" if kind == "lattice" else "fp"
            out.append((name, "f32", shape, pkind, init))
    return out


def param_manifest(cfg, fmt):
    out = []
    for name, dt, shape, kind, init in flat_args_for(cfg, fmt):
        entry = {"name": name, "dtype": dt, "shape": list(shape), "kind": kind}
        if init is not None:
            entry["init"] = list(init)
        out.append(entry)
    return out


def data_inputs_for(cfg, which):
    b, bt, sp, t, st = cfg.b_gen, cfg.b_train, cfg.s_prompt, cfg.t_dec, cfg.s_train
    if which == "gen":
        return [
            {"name": "prompt", "dtype": "i32", "shape": [b, sp]},
            {"name": "prompt_len", "dtype": "i32", "shape": [b]},
            {"name": "tau", "dtype": "f32", "shape": []},
            {"name": "gumbel", "dtype": "f32", "shape": [b, t, cfg.vocab]},
        ]
    if which in ("loss", "grad"):
        return [
            {"name": "tokens", "dtype": "i32", "shape": [bt, st]},
            {"name": "pos_ids", "dtype": "i32", "shape": [bt, st]},
            {"name": "mask", "dtype": "f32", "shape": [bt, st]},
            {"name": "targets", "dtype": "i32", "shape": [bt, st]},
            {"name": "loss_mask", "dtype": "f32", "shape": [bt, st]},
        ]
    if which == "cls":
        return [
            {"name": "tokens", "dtype": "i32", "shape": [bt, st]},
            {"name": "pos_ids", "dtype": "i32", "shape": [bt, st]},
            {"name": "mask", "dtype": "f32", "shape": [bt, st]},
            {"name": "cls_pos", "dtype": "i32", "shape": [bt]},
            {"name": "class_ids", "dtype": "i32", "shape": [N_CLS]},
            {"name": "labels", "dtype": "i32", "shape": [bt]},
        ]
    raise ValueError(which)


def outputs_for(cfg, fmt, which):
    if which == "gen":
        return [{"dtype": "i32", "shape": [cfg.b_gen, cfg.t_dec]}]
    if which == "loss":
        return [{"dtype": "f32", "shape": []} for _ in range(3)]
    if which == "cls":
        return [
            {"dtype": "f32", "shape": []},
            {"dtype": "f32", "shape": []},
            {"dtype": "f32", "shape": [cfg.b_train, N_CLS]},
        ]
    if which == "grad":
        outs = [{"dtype": "f32", "shape": []}]
        for _, _, shape, _, _ in flat_args_for(cfg, "fp"):
            outs.append({"dtype": "f32", "shape": list(shape)})
        return outs
    raise ValueError(which)


def build(out_dir, sizes):
    manifest = {"version": 1, "configs": {}, "params": {}, "artifacts": []}
    for size in sizes:
        cfg = CONFIGS[size]
        manifest["configs"][size] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "s_prompt": cfg.s_prompt,
            "t_dec": cfg.t_dec,
            "s_train": cfg.s_train,
            "b_gen": cfg.b_gen,
            "b_train": cfg.b_train,
            "lattice_params": cfg.lattice_param_count(),
        }
        manifest["params"][size] = {
            fmt: param_manifest(cfg, fmt) for fmt in FORMATS
        }
        for fmt in FORMATS:
            fns = FNS + (("grad",) if fmt == "fp" else ())
            for which in fns:
                manifest["artifacts"].append({
                    "file": f"{size}_{fmt}_{which}.hlo.txt",
                    "config": size,
                    "format": fmt,
                    "fn": which,
                    "data_inputs": data_inputs_for(cfg, which),
                    "n_param_inputs": len(flat_args_for(cfg, fmt)),
                    "outputs": outputs_for(cfg, fmt, which),
                })
    os.makedirs(out_dir, exist_ok=True)
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[manifest-only] wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../rust/artifacts")
    ap.add_argument("--sizes", default="nano,micro,small")
    args = ap.parse_args()
    sizes = [s for s in args.sizes.split(",") if s]
    unknown = [s for s in sizes if s not in CONFIGS]
    if unknown:
        sys.exit(f"unknown sizes: {unknown} (have: {list(CONFIGS)})")
    build(args.out_dir, sizes)


if __name__ == "__main__":
    main()
