"""W8A8 quantized matmul as a Pallas kernel.

The paper's W8A8 format (LLM-Compressor) quantizes *both* weights and
activations to INT8. Weights use the static symmetric per-channel grid the
Rust coordinator maintains; activations are quantized dynamically per tensor
with an absmax scale at every layer invocation.

The kernel is split in two phases so the activation scale is a true
per-tensor absmax (a single fused kernel could only see one tile at a time):

1. ``_absmax``: a tiny jnp reduction producing the dynamic scale ``xs``.
2. ``_kernel``: tiled integer-grid matmul — quantize the x tile in VMEM,
   multiply against the int8 weight tile (accumulated in f32, exact for
   int8×int8 sums up to 2^24), and dequantize with ``xs * scale`` on the
   final k-step.

On a real TPU the absmax pass fuses into the preceding layer's epilogue; we
keep it explicit for clarity under interpret=True.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import A8_QMAX


def _kernel(x_ref, q_ref, s_ref, xs_ref, o_ref, *, n_k: int):
    """One (m, n, k) grid step of the integer-grid matmul."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xs = xs_ref[0]
    xq = jnp.clip(jnp.round(x_ref[...] / xs), -A8_QMAX, A8_QMAX)
    o_ref[...] += jnp.dot(
        xq, q_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] *= xs * s_ref[...][None, :]


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def w8a8_matmul(x, q, scale, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """W8A8 matmul: dynamic per-tensor INT8 activations × per-channel INT8
    weights, f32 accumulation.

    Args:
      x: f32[M, K] activations.
      q: int8[K, N] lattice weights.
      scale: f32[N] per-channel weight scales.

    Returns:
      f32[M, N].
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert scale.shape == (n,), f"scale must be [{n}], got {scale.shape}"

    # Phase 1: dynamic activation scale (per tensor).
    xs = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / A8_QMAX
    xs = jnp.reshape(xs, (1,))

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, q, scale, xs)
