"""Weight-only quantized matmul as a Pallas kernel.

Computes ``x @ (q * scale)`` where ``q`` is an int8 lattice tensor and
``scale`` a per-output-channel f32 vector — the forward hot-spot of every
quantized linear layer in the QES backbone (paper §4.1: GPTQ-style symmetric
per-channel grids).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks output tiles
(M/bm, N/bn) with an inner accumulation loop over K/bk. Each step brings an
int8 weight tile HBM→VMEM, dequantizes it once in VMEM (the analog of CUDA's
dequant-into-shared-memory idiom), and feeds the f32 tile to the MXU. The
accumulator lives in the output ref across the k-steps of one (m, n) tile.

CPU execution uses ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, o_ref, *, n_k: int):
    """One (m, n, k) grid step: o[m,n] += x[m,k] @ dequant(q[k,n])."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = q_ref[...].astype(jnp.float32) * s_ref[...][None, :]
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (block shapes must tile
    the array exactly so the interpret path and the BlockSpec agree)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def quant_matmul(x, q, scale, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """``x @ (q.astype(f32) * scale)`` via a tiled Pallas kernel.

    Args:
      x: f32[M, K] activations.
      q: int8[K, N] lattice weights.
      scale: f32[N] per-output-channel scales.
      bm/bn/bk: tile-size *targets*; actual tiles are the largest divisors
        of each dimension not exceeding the target.

    Returns:
      f32[M, N].
    """
    m, k = x.shape
    k2, n = q.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert scale.shape == (n,), f"scale must be [{n}], got {scale.shape}"

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, q, scale)
