"""Pure-jnp oracles for the L1 kernels.

These are the ground truth the Pallas kernels are pinned against. They are
deliberately written in the most obvious way possible — no tiling, no
cleverness — so that a mismatch always indicts the kernel.

Quantization convention (matches the paper, Appendix A.1, and the Rust
``quant`` module):

* symmetric, per-output-channel grid;
* scale ``s_j = max_i |W_ij| / (2^{B-1} - 1)``;
* lattice range ``[-(2^{B-1}-1), 2^{B-1}-1]`` (note: -8 is *excluded* for
  INT4, keeping the grid symmetric);
* dequantization ``W_ij = q_ij * s_j``.

Weights are stored as int8 regardless of B; the lattice *range* is enforced
by the caller (the Rust coordinator's boundary gating, Eq. 4 of the paper).
"""

import jax.numpy as jnp

# INT8 activation grid for W8A8 (symmetric, per-tensor, dynamic).
A8_QMAX = 127.0


def dequant(q, scale):
    """Dequantize a lattice tensor.

    Args:
      q: int8[K, N] lattice values.
      scale: f32[N] per-output-channel scales.

    Returns:
      f32[K, N] dequantized weights.
    """
    return q.astype(jnp.float32) * scale[None, :]


def quant_matmul_ref(x, q, scale):
    """Oracle for the weight-only quantized matmul.

    Args:
      x: f32[M, K] activations.
      q: int8[K, N] lattice weights.
      scale: f32[N] per-channel scales.

    Returns:
      f32[M, N] = x @ dequant(q, scale).
    """
    return jnp.matmul(x, dequant(q, scale), preferred_element_type=jnp.float32)


def quantize_act_ref(x):
    """Dynamic symmetric per-tensor INT8 quantization of activations.

    Returns (q, s) with q = round(x / s) clipped to [-127, 127] and
    s = absmax(x) / 127 (with a floor to avoid division by zero on an
    all-zero tensor).
    """
    absmax = jnp.max(jnp.abs(x))
    s = jnp.maximum(absmax, 1e-8) / A8_QMAX
    q = jnp.clip(jnp.round(x / s), -A8_QMAX, A8_QMAX)
    return q, s


def w8a8_matmul_ref(x, q, scale):
    """Oracle for the W8A8 matmul: quantize activations dynamically to INT8,
    multiply on the integer grid (emulated in f32, which is exact for
    products of integers up to 2^24), and dequantize.

    Args:
      x: f32[M, K] activations.
      q: int8[K, N] lattice weights.
      scale: f32[N] per-channel weight scales.

    Returns:
      f32[M, N].
    """
    xq, xs = quantize_act_ref(x)
    acc = jnp.matmul(xq, q.astype(jnp.float32), preferred_element_type=jnp.float32)
    return acc * xs * scale[None, :]
