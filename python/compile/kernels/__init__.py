"""Layer-1 Pallas kernels for QES.

The compute hot-spot of QES rollouts is the quantized linear layer:
dequantize an integer-lattice weight tensor with per-output-channel scales
and multiply. Two variants are provided:

- ``quant_matmul``: weights on the INT-B lattice (stored as int8), activations
  in FP32. Used for the paper's INT4/INT8 formats (the bit-width only changes
  the lattice *range*, which the Rust coordinator enforces; the dequant math
  ``w * s`` is identical).
- ``w8a8_matmul``: additionally quantizes the activations to INT8 with a
  dynamic per-tensor absmax scale, emulating the paper's W8A8 format.

All kernels run under ``interpret=True`` so they lower to plain HLO and run
on the CPU PJRT client (real-TPU lowering would emit a Mosaic custom-call the
CPU plugin cannot execute). Correctness is pinned against the pure-jnp
oracles in :mod:`ref` by the pytest suite.
"""

from .quant_matmul import quant_matmul
from .w8a8_matmul import w8a8_matmul
from . import ref

__all__ = ["quant_matmul", "w8a8_matmul", "ref"]
