"""AOT pipeline: lower every exported L2 function to HLO *text* + manifest.

HLO text (NOT ``lowered.compiler_ir('hlo')`` protos, NOT ``.serialize()``) is
the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the rust crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):

* ``{config}_{format}_{fn}.hlo.txt`` — one module per exported function;
* ``manifest.json`` — the contract with the Rust runtime: model configs,
  per-format parameter layouts (names, dtypes, shapes, init hints) and, per
  artifact, the exact positional input/output specs the Rust side marshals.

Usage:  python -m compile.aot --out-dir ../artifacts [--sizes nano,micro]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS
from . import model as M

FORMATS = ("wq", "w8a8", "fp")
FNS = ("gen", "loss", "cls")          # + "grad" for fp


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"int32": "i32", "float32": "f32", "int8": "i8"}[jnp.dtype(dt).name]


def param_arg_structs(cfg, fmt):
    structs = []
    for name, dt, shape in M.flat_args_for(cfg, fmt):
        jdt = {"i8": jnp.int8, "f32": jnp.float32}[dt]
        structs.append(jax.ShapeDtypeStruct(shape, jdt))
    return structs


def param_manifest(cfg, fmt):
    """Per-format parameter layout with kinds + init hints for the Rust side."""
    specs = {s.name: s for s in M.param_specs(cfg)}
    out = []
    for name, dt, shape in M.flat_args_for(cfg, fmt):
        base = name[:-2] if name.endswith((".q", ".s")) else name
        spec = specs[base]
        if name.endswith(".q"):
            kind = "lattice_q"
        elif name.endswith(".s"):
            kind = "scale"
        else:
            kind = "lattice_as_fp" if spec.kind == "lattice" else "fp"
        entry = {"name": name, "dtype": dt, "shape": list(shape), "kind": kind}
        if kind in ("fp", "lattice_as_fp"):
            entry["init"] = list(spec.init)
        out.append(entry)
    return out


def lower_one(cfg, fmt, which):
    fn = M.exported_fn(cfg, fmt, which)
    data = M.example_data_args(cfg, which)
    args = [s for _, s in data] + param_arg_structs(cfg, fmt)
    lowered = jax.jit(fn).lower(*args)
    out_shapes = jax.eval_shape(fn, *args)
    outputs = [
        {"dtype": _dtype_name(o.dtype), "shape": list(o.shape)}
        for o in jax.tree_util.tree_leaves(out_shapes)
    ]
    data_inputs = [
        {"name": n, "dtype": _dtype_name(s.dtype), "shape": list(s.shape)}
        for n, s in data
    ]
    return to_hlo_text(lowered), data_inputs, outputs


def build(out_dir: str, sizes, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "configs": {},
        "params": {},
        "artifacts": [],
    }
    for size in sizes:
        cfg = CONFIGS[size]
        manifest["configs"][size] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "s_prompt": cfg.s_prompt,
            "t_dec": cfg.t_dec,
            "s_train": cfg.s_train,
            "b_gen": cfg.b_gen,
            "b_train": cfg.b_train,
            "lattice_params": cfg.lattice_param_count(),
        }
        manifest["params"][size] = {
            fmt: param_manifest(cfg, fmt) for fmt in FORMATS
        }
        for fmt in FORMATS:
            fns = FNS + (("grad",) if fmt == "fp" else ())
            for which in fns:
                fname = f"{size}_{fmt}_{which}.hlo.txt"
                if verbose:
                    print(f"[aot] lowering {fname} ...", flush=True)
                text, data_inputs, outputs = lower_one(cfg, fmt, which)
                path = os.path.join(out_dir, fname)
                with open(path, "w") as f:
                    f.write(text)
                manifest["artifacts"].append({
                    "file": fname,
                    "config": size,
                    "format": fmt,
                    "fn": which,
                    "data_inputs": data_inputs,
                    "n_param_inputs": len(M.flat_args_for(cfg, fmt)),
                    "outputs": outputs,
                    "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                })
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"[aot] wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="nano,micro,small",
                    help="comma-separated subset of " + ",".join(CONFIGS))
    args = ap.parse_args()
    sizes = [s for s in args.sizes.split(",") if s]
    unknown = [s for s in sizes if s not in CONFIGS]
    if unknown:
        sys.exit(f"unknown sizes: {unknown}")
    build(args.out_dir, sizes)


if __name__ == "__main__":
    main()
