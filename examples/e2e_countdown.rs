//! END-TO-END DRIVER (the EXPERIMENTS.md §E2E run): the full system on a
//! real workload, proving all three layers compose.
//!
//! Pipeline (all in-process, Python nowhere on the path):
//!   1. pretrain an fp32 transformer on the Countdown corpus with Adam over
//!      the AOT `grad` artifact (L2 backward pass through PJRT),
//!   2. GPTQ-quantize it to INT4 using calibration activations,
//!   3. fine-tune on the integer lattice with QES (Algorithm 2) and with
//!      QuZO as the baseline, logging the full reward curve,
//!   4. report the accuracy table + memory + timing summary, and write
//!      results/e2e_countdown.csv.
//!
//! Run: `cargo run --release --example e2e_countdown` (~4 minutes; scale
//! with E2E_GENS / E2E_PRETRAIN env vars).

use qes::coordinator::{
    finetune_store, pretrain_gen, EngineSet, FinetuneCfg, GenWorkload, PretrainCfg, Session,
    Variant, Workload,
};
use qes::model::{init::init_fp, AsParams, ParamStore};
use qes::opt::EsHyper;
use qes::quant::Format;
use qes::rng::SplitMix64;
use qes::runtime::Manifest;
use qes::tasks::gen_task;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> anyhow::Result<()> {
    let size = std::env::var("E2E_SIZE").unwrap_or_else(|_| "nano".into());
    let pretrain_steps = env_usize("E2E_PRETRAIN", 2000);
    let gens = env_usize("E2E_GENS", 150);
    let man = Manifest::load("artifacts/manifest.json")?;
    println!(
        "kernel: {} (set QES_KERNEL=scalar|avx2|neon|auto to override)",
        qes::kernel::active().name()
    );

    // ---- 1. pretrain (L2 grad artifact + Rust Adam) ----
    println!("== [1/4] pretraining {} on the Countdown corpus ({} steps) ==", size, pretrain_steps);
    let t0 = std::time::Instant::now();
    let fp_session = Session::new(&man, &size, Format::Fp32, EngineSet::pretrain())?;
    let task = gen_task("countdown", fp_session.cfg.s_prompt, fp_session.cfg.t_dec)?;
    let mut fp = ParamStore::from_manifest(&man, &size, Format::Fp32)?;
    init_fp(&mut fp, 7);
    let loss = pretrain_gen(
        &fp_session,
        task.as_ref(),
        &mut fp,
        &PretrainCfg { steps: pretrain_steps, verbose: true, ..Default::default() },
    )?;
    println!("   pretraining loss {:.3} in {:.1?}", loss, t0.elapsed());

    // ---- 2. GPTQ quantization with real calibration activations ----
    println!("== [2/4] GPTQ quantization to INT4 ==");
    // Calibration: random embedding-space activations standing in for the
    // per-layer input distribution (per-tensor calibration hook).
    let mut calib_rng = SplitMix64::new(99);
    let mut calib = |_name: &str, rows: usize, _cols: usize| -> Option<Vec<f32>> {
        let ns = 32usize;
        Some((0..ns * rows).map(|_| calib_rng.normal() * 0.5).collect())
    };
    let q0 = ParamStore::quantize_from(&fp, &man, Format::Int4, Some(&mut calib))?;
    println!(
        "   {} lattice params, packed {}",
        q0.lattice_dim(),
        qes::util::human_bytes(q0.weight_bytes())
    );

    // ---- 3. lattice fine-tuning: QES vs QuZO ----
    println!("== [3/4] lattice fine-tuning ({} generations) ==", gens);
    let session = Session::new(&man, &size, Format::Int4, EngineSet::gen_only())?;
    let cfg = FinetuneCfg {
        hyper: EsHyper { sigma: 0.02, alpha: 0.08, gamma: 0.98, pairs: 8, k_window: 8 },
        gens,
        tau: 0.0,
        batches_per_gen: 4,
        train_pool: 512,
        eval_every: 25,
        eval_n: 128,
        seed: 42,
        verbose: true,
    };
    let workload = GenWorkload::new(
        gen_task("countdown", session.cfg.s_prompt, session.cfg.t_dec)?,
        &session.cfg,
        &cfg,
    );
    let base_acc = workload.eval_accuracy(&session, &q0.params_view())?;
    let (qes_log, _q_qes) =
        finetune_store(&session, &workload, q0.clone(), Variant::Qes, &cfg, None)?;
    let (quzo_log, _q_quzo) =
        finetune_store(&session, &workload, q0.clone(), Variant::Quzo, &cfg, None)?;

    // ---- 4. report ----
    println!("\n== [4/4] results ==");
    println!("   {:<28} {:>8}", "model", "acc (%)");
    let fp_acc = workload.eval_accuracy(&fp_session, &fp.params_view())?;
    println!("   {:<28} {:>8.2}", format!("{} fp32 (pretrained)", size), fp_acc);
    println!("   {:<28} {:>8.2}", format!("{} INT4 base (GPTQ)", size), base_acc);
    println!("   {:<28} {:>8.2}", format!("{} INT4 + QuZO", size), quzo_log.final_acc);
    println!("   {:<28} {:>8.2}", format!("{} INT4 + QES", size), qes_log.final_acc);
    println!(
        "   QES optimizer state {} | rollout {:.0} ms/gen | update {:.0} ms/gen",
        qes::util::human_bytes(qes_log.optimizer_state_bytes),
        qes_log.mean_rollout_ms(),
        qes_log.mean_update_ms()
    );
    std::fs::create_dir_all("results")?;
    std::fs::write("results/e2e_countdown_qes.csv", qes_log.to_csv())?;
    std::fs::write("results/e2e_countdown_quzo.csv", quzo_log.to_csv())?;
    std::fs::write(
        "results/e2e_countdown.csv",
        format!(
            "config,accuracy\nfp32,{:.2}\nint4_base,{:.2}\nint4_quzo,{:.2}\nint4_qes,{:.2}\n",
            fp_acc, base_acc, quzo_log.final_acc, qes_log.final_acc
        ),
    )?;
    println!("   wrote results/e2e_countdown*.csv");
    Ok(())
}
