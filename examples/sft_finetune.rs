//! SFT example: k-shot classification fine-tuning on the W8 lattice
//! (the Table 1 setting) — QES vs QuZO on SNLI-syn, fitness = -CE on the
//! 16-shot train batches (LM-BFF verbalizer protocol).
//!
//! Run: `cargo run --release --example sft_finetune`

use qes::coordinator::{
    finetune_store, pretrain_cls, ClsWorkload, EngineSet, FinetuneCfg, PretrainCfg, Session,
    Variant,
};
use qes::model::{init::init_fp, ParamStore};
use qes::opt::EsHyper;
use qes::quant::Format;
use qes::runtime::Manifest;
use qes::tasks::cls_task;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load("artifacts/manifest.json")?;
    println!(
        "kernel: {} (set QES_KERNEL=scalar|avx2|neon|auto to override)",
        qes::kernel::active().name()
    );
    let task = cls_task("snli")?;

    println!("== LM-warmup of the backbone (fp32) ==");
    let fp_session = Session::new(&man, "nano", Format::Fp32, EngineSet {
        grad: true,
        cls: true,
        ..Default::default()
    })?;
    let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32)?;
    init_fp(&mut fp, 3);
    pretrain_cls(
        &fp_session,
        task.as_ref(),
        &mut fp,
        &PretrainCfg { steps: 200, verbose: false, ..Default::default() },
    )?;

    println!("== quantize to W8 (the paper's SFT backbone precision) ==");
    let q0 = ParamStore::quantize_from(&fp, &man, Format::Int8, None)?;
    let session = Session::new(&man, "nano", Format::Int8, EngineSet::cls_only())?;

    let cfg = FinetuneCfg {
        hyper: EsHyper { sigma: 0.02, alpha: 0.3, gamma: 0.95, pairs: 8, k_window: 8 },
        gens: 120,
        tau: 0.0,
        batches_per_gen: 1,
        train_pool: 0,
        eval_every: 30,
        eval_n: 96,
        seed: 42,
        verbose: true,
    };
    let workload = ClsWorkload::new(qes::tasks::cls_task("snli")?, &session.cfg, &cfg, 16);
    for (name, variant) in [("QES", Variant::Qes), ("QuZO", Variant::Quzo)] {
        let (log, _store) = finetune_store(&session, &workload, q0.clone(), variant, &cfg, None)?;
        println!(
            "{}: final eval accuracy {:.2}% (fitness {:.4} -> {:.4}), state {}",
            name,
            log.final_acc,
            log.entries.first().map(|e| e.mean_reward).unwrap_or(0.0),
            log.entries.last().map(|e| e.mean_reward).unwrap_or(0.0),
            qes::util::human_bytes(log.optimizer_state_bytes)
        );
    }
    Ok(())
}
