//! Quickstart: the whole QES pipeline in one minute on the nano backbone.
//!
//! 1. initialize + briefly pretrain an fp32 base model on Countdown,
//! 2. post-training-quantize it to INT4 (symmetric per-channel grid),
//! 3. fine-tune DIRECTLY on the integer lattice with QES (Algorithm 2:
//!    accumulated error feedback + stateless seed replay),
//! 4. report accuracy and the optimizer-state footprint.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use qes::coordinator::{
    finetune_store, pretrain_gen, EngineSet, FinetuneCfg, GenWorkload, PretrainCfg, Session,
    Variant, Workload,
};
use qes::model::{init::init_fp, AsParams, ParamStore};
use qes::opt::EsHyper;
use qes::quant::Format;
use qes::runtime::Manifest;
use qes::tasks::gen_task;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load("artifacts/manifest.json")?;
    println!(
        "kernel: {} (set QES_KERNEL=scalar|avx2|neon|auto to override)",
        qes::kernel::active().name()
    );

    // --- 1. base model ---
    println!("== pretraining a base model (fp32, 600 Adam steps) ==");
    let fp_session = Session::new(&man, "nano", Format::Fp32, EngineSet::pretrain())?;
    let task = gen_task("countdown", fp_session.cfg.s_prompt, fp_session.cfg.t_dec)?;
    let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32)?;
    init_fp(&mut fp, 1);
    let loss = pretrain_gen(
        &fp_session,
        task.as_ref(),
        &mut fp,
        &PretrainCfg { steps: 600, verbose: false, ..Default::default() },
    )?;
    println!("   final pretraining loss: {:.3}", loss);

    // --- 2. quantize ---
    println!("== PTQ to INT4 (symmetric per-output-channel grid) ==");
    let q = ParamStore::quantize_from(&fp, &man, Format::Int4, None)?;
    println!(
        "   {} lattice params in [-7, 7], packed weights: {}",
        q.lattice_dim(),
        qes::util::human_bytes(q.weight_bytes())
    );

    // --- 3. QES fine-tuning on the lattice ---
    println!("== QES fine-tuning (stateless seed replay) ==");
    let session = Session::new(&man, "nano", Format::Int4, EngineSet::gen_only())?;
    let cfg = FinetuneCfg {
        hyper: EsHyper { sigma: 0.02, alpha: 0.1, gamma: 0.97, pairs: 8, k_window: 8 },
        gens: 30,
        tau: 0.0,
        batches_per_gen: 2,
        train_pool: 128,
        eval_every: 10,
        eval_n: 64,
        seed: 42,
        verbose: true,
    };
    let workload = GenWorkload::new(
        gen_task("countdown", session.cfg.s_prompt, session.cfg.t_dec)?,
        &session.cfg,
        &cfg,
    );
    let base_acc = workload.eval_accuracy(&session, &q.params_view())?;
    let (log, q) = finetune_store(&session, &workload, q, Variant::Qes, &cfg, None)?;

    // --- 4. report ---
    println!("\n== results ==");
    println!("   base INT4 accuracy:      {:.2}%", base_acc);
    println!("   after QES fine-tuning:   {:.2}%", log.final_acc);
    println!(
        "   optimizer state:         {} (vs {} for an fp16-residual oracle)",
        qes::util::human_bytes(log.optimizer_state_bytes),
        qes::util::human_bytes(2 * q.lattice_dim() as u64),
    );
    println!(
        "   mean rollout {:.0} ms / update {:.0} ms per generation",
        log.mean_rollout_ms(),
        log.mean_update_ms()
    );
    Ok(())
}
