//! Memory-footprint demo (the Table 8 accounting, interactive form):
//! exact byte accounting for weights + optimizer state across methods and
//! model sizes, demonstrating QES's d-independent optimizer state — plus
//! the sharded COW plane's layout: per-shard slab sizes and what a
//! rollout snapshot actually costs to publish (O(shards) Arc bumps vs the
//! old full-store clone).
//!
//! Run: `cargo run --release --example memory_footprint`

use qes::model::{ParamStore, ShardedParamStore, TensorData};
use qes::opt::{EsHyper, LatticeOptimizer, QesFullResidual, QuzoOptimizer, SeedReplayQes};
use qes::quant::Format;
use qes::runtime::Manifest;
use qes::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load("artifacts/manifest.json")?;
    println!(
        "kernel: {} (set QES_KERNEL=scalar|avx2|neon|auto to override)",
        qes::kernel::active().name()
    );
    println!(
        "{:<8} {:<6} {:>12} {:>14} {:>14} {:>14}",
        "model", "fmt", "weights", "quzo state", "full-res state", "qes state"
    );
    for size in man.configs.keys() {
        for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
            let store = ParamStore::from_manifest(&man, size, fmt)?;
            let d = store.lattice_dim();
            let hyper = EsHyper { pairs: 25, k_window: 50, ..Default::default() };
            let quzo = QuzoOptimizer::new(d, fmt.qmax(), hyper.clone());
            let full = QesFullResidual::new(d, fmt.qmax(), hyper.clone());
            // fill replay history to K for honest worst-case accounting
            let mut replay = SeedReplayQes::new(d, fmt.qmax(), hyper.clone());
            let mut s2 = ShardedParamStore::with_default_shards(store.clone())?;
            let mut rng = qes::rng::SplitMix64::new(1);
            for _ in 0..hyper.k_window {
                let spec = qes::opt::PopulationSpec {
                    gen_seed: rng.next_u64(),
                    pairs: hyper.pairs,
                    sigma: 0.01,
                };
                replay.update(&mut s2, &spec, &vec![0.0; spec.n_members()])?;
            }
            println!(
                "{:<8} {:<6} {:>12} {:>14} {:>14} {:>14}",
                size,
                fmt.name(),
                human_bytes(store.weight_bytes()),
                human_bytes(quzo.state_bytes()),
                human_bytes(full.state_bytes()),
                human_bytes(replay.state_bytes()),
            );
        }

        // --- sharded plane layout + snapshot publication cost (per size) ---
        let store = ParamStore::from_manifest(&man, size, Format::Int4)?;
        // what the pre-sharding leader cloned per generation: every entry
        let full_clone_bytes: u64 = store
            .entries
            .iter()
            .map(|e| match &e.data {
                TensorData::F32(v) => v.len() as u64 * 4,
                TensorData::I8(v) => v.len() as u64,
            })
            .sum();
        let mut sp = ShardedParamStore::with_default_shards(store)?;
        let plan = sp.plan().clone();
        // steady state: publish, then touch one shard, then publish again
        let _snap = sp.snapshot();
        sp.apply_deltas(&[(0, 1)]);
        let dirty = sp.dirty_shards();
        let cow_bytes: u64 = plan.bounds(0).1 as u64; // the one shard touched above
        let publish_bytes = plan.n_shards as u64 * 8; // one Arc bump per shard
        println!(
            "  plane({}, int4): {} shards x {} elems (last {}), slab <= {}",
            size,
            plan.n_shards,
            plan.shard_len,
            plan.bounds(plan.n_shards - 1).1,
            human_bytes(plan.shard_len as u64),
        );
        println!(
            "  snapshot publish: {} Arc bumps (~{}) + {}/{} dirty shards COW-copied ({}) — vs full clone {}",
            plan.n_shards,
            human_bytes(publish_bytes),
            dirty,
            plan.n_shards,
            human_bytes(cow_bytes),
            human_bytes(full_clone_bytes),
        );

        // --- serving-side KV arena: paged vs dense (per size) ---
        // the paged arena allocates bytes/page on demand, so resident KV
        // tracks occupancy; the dense model reserved bytes/slot x slots
        // up front whatever the sequences actually used
        let c = man.config(size)?;
        let s_max = c.s_prompt + c.t_dec;
        let page_rows = match qes::sched::default_page_rows() {
            0 => s_max,
            p => p.min(s_max),
        };
        let slot_bytes = c.n_layers * 2 * s_max * c.d_model * 4;
        let page_bytes = c.n_layers * 2 * page_rows * c.d_model * 4;
        // a typical half-occupancy sequence (prompt + some decode)
        let half_pages = (s_max / 2 + page_rows - 1) / page_rows;
        println!(
            "  kv arena({}): dense bound {}/slot x {} slots = {} | paged {}/page ({} rows); a half-length sequence holds {} pages = {}\n",
            size,
            human_bytes(slot_bytes as u64),
            c.b_gen,
            human_bytes((slot_bytes * c.b_gen) as u64),
            human_bytes(page_bytes as u64),
            page_rows,
            half_pages,
            human_bytes((half_pages * page_bytes) as u64),
        );
    }
    println!(
        "\nQES's optimizer state is K*(seed + population rewards) — constant in d.\n\
         The full-residual oracle pays 2 bytes (FP16) per lattice parameter.\n\
         A QAT-style first-order pipeline pays 16 bytes/param (w,g,m,v in fp32).\n\
         Publishing a rollout snapshot is O(shards) Arc bumps; a generation's\n\
         update then COW-copies only the shards it actually changed."
    );
    Ok(())
}
