//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links the XLA C++ runtime, which the offline build image
//! does not ship. This stub mirrors the exact API surface
//! `qes::runtime::engine` consumes so the crate compiles and the pure-Rust
//! surface (quantizers, optimizers, RNG, checkpointing, experiment math)
//! runs everywhere; every entry point that would need the real runtime
//! returns an error instead.
//!
//! Callers that need a live backend must gate on [`available`] — the
//! in-repo convention is `qes::runtime::backend_available()`, which
//! engine-bound tests check before constructing a `Session`. Swapping this
//! stub for the real bindings is a path change in `rust/Cargo.toml` plus an
//! `available() -> true` shim.

use std::fmt;

/// Whether a real PJRT runtime backs this crate. The stub is always `false`.
pub fn available() -> bool {
    false
}

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla backend unavailable (offline stub): {} requires the real PJRT runtime",
        what
    ))
}

/// Element dtypes the runtime marshals (the subset the manifest uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
}

/// Host-side literal. The stub can be constructed for scalars (so argument
/// assembly code is exercisable) but holds no data.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!available());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
    }
}
