//! Offline, API-compatible mini implementation of the `anyhow` crate.
//!
//! The build environment cannot reach a registry, so the workspace vendors
//! the small `anyhow` subset the codebase uses:
//!
//! * [`Error`] / [`Result`] — a boxed, `Send + Sync` dynamic error with a
//!   message and an optional source chain;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both `Result`
//!   and `Option`;
//! * blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts std and crate errors.
//!
//! `{:#}` formatting renders the cause chain inline ("msg: cause"), and
//! `{:?}` renders it as a "Caused by:" block, matching real anyhow closely
//! enough for logs and test output.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the same defaulted error parameter as
/// the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: human-readable message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string(), source: None }
    }

    /// Construct from a concrete error value, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(err: E) -> Error {
        Error { msg: err.to_string(), source: Some(Box::new(err)) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{}: {}", context, self.msg), source: self.source }
    }

    /// The error chain below the message, outermost first.
    pub fn chain<'a>(&'a self) -> impl Iterator<Item = &'a (dyn StdError + 'static)> + 'a {
        let mut next: Option<&'a (dyn StdError + 'static)> =
            self.source.as_deref().map(|e| e as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }

    /// The root cause's message (diagnostics only).
    pub fn root_cause_message(&self) -> String {
        self.chain().last().map(|e| e.to_string()).unwrap_or_else(|| self.msg.clone())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                let s = cause.to_string();
                if s != self.msg {
                    write!(f, ": {}", s)?;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut first = true;
        for cause in self.chain() {
            let s = cause.to_string();
            if s == self.msg {
                continue;
            }
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {}", s)?;
        }
        Ok(())
    }
}

// Mirrors real anyhow: `Error` deliberately does NOT implement
// `std::error::Error` itself, which is what makes this blanket `From`
// coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(format!("{}", e), "plain");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{}", e), "x = 3");

        fn bails() -> Result<()> {
            bail!("gone {}", "wrong");
        }
        assert_eq!(bails().unwrap_err().to_string(), "gone wrong");

        fn ensures(v: usize) -> Result<()> {
            ensure!(v < 10, "v too big: {}", v);
            ensure!(v != 5);
            Ok(())
        }
        assert!(ensures(3).is_ok());
        assert_eq!(ensures(12).unwrap_err().to_string(), "v too big: 12");
        assert!(ensures(5).unwrap_err().to_string().contains("v != 5"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: missing file");
        assert_eq!(format!("{:#}", e), "reading manifest: missing file: missing file");

        let o: Option<u32> = None;
        let e = o.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
    }

    #[test]
    fn error_is_send_sync() {
        fn takes<T: Send + Sync>(_: T) {}
        takes(anyhow!("x"));
    }
}
