//! Sharded copy-on-write parameter plane.
//!
//! The leader's training-time view of a quantized store: the flat lattice
//! space is partitioned into fixed shards (boundaries aligned to
//! [`SHARD_ALIGN`], a `KernelPolicy` chunk multiple), each held as an
//! `Arc`-backed slab. Publishing a rollout snapshot to the worker pool is
//! then O(number of shards) reference bumps instead of an O(d) clone of
//! the whole store, and an update after a publish copies only the shards
//! it actually writes (`Arc::make_mut` unshares lazily, per shard).
//!
//! Shard boundaries never affect results: the fused kernels in
//! `opt::kernels` chunk the flat element space identically for any
//! segmentation, so lattices and residuals are bit-identical across shard
//! counts — the determinism contract extended to the storage layer
//! (enforced by `tests/equivalence.rs` over shard counts {1, 2, 8}).

use std::borrow::Cow;
use std::sync::Arc;

use crate::model::{ParamStore, TensorData};
use crate::quant::Format;

/// Shard boundary alignment in lattice elements. `opt::kernels` defines
/// its default chunk size (`DEFAULT_CHUNK`) as exactly this constant, so
/// default-policy chunks never straddle a shard boundary.
pub const SHARD_ALIGN: usize = 8192;

/// Default shard count requested for leader planes. The plan rounds the
/// shard length up to a [`SHARD_ALIGN`] multiple, so small lattices may
/// end up with fewer shards than requested.
pub const DEFAULT_SHARDS: usize = 8;

/// Fixed partition of the flat lattice space `[0, d)` into shards of
/// `shard_len` elements (the last shard may be shorter). `shard_len` is
/// always a [`SHARD_ALIGN`] multiple.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub d: usize,
    pub shard_len: usize,
    pub n_shards: usize,
}

impl ShardPlan {
    /// Plan `shards` shards over `d` elements, aligning boundaries to
    /// [`SHARD_ALIGN`] multiples. The realized shard count is
    /// `ceil(d / shard_len)` and may be below the request.
    pub fn new(d: usize, shards: usize) -> ShardPlan {
        let want = shards.max(1);
        let raw = (d + want - 1) / want;
        let shard_len = (((raw + SHARD_ALIGN - 1) / SHARD_ALIGN) * SHARD_ALIGN).max(SHARD_ALIGN);
        let n_shards = if d == 0 { 1 } else { (d + shard_len - 1) / shard_len };
        ShardPlan { d, shard_len, n_shards }
    }

    /// `(start, len)` of shard `s` in flat element space.
    pub fn bounds(&self, s: usize) -> (usize, usize) {
        let start = s * self.shard_len;
        (start, self.shard_len.min(self.d - start))
    }

    /// Which shard holds flat element `j`.
    #[inline]
    pub fn shard_of(&self, j: usize) -> usize {
        j / self.shard_len
    }
}

/// A read-only view of model parameters: the static entries (shapes,
/// fp tensors, scales) plus the lattice values as canonical-flat-order
/// segments with ARBITRARY segmentation (per-tensor for a plain store,
/// per-shard for a sharded plane or snapshot). Everything downstream of
/// the store — engine marshalling, perturbation fill — consumes this.
pub struct ParamsView<'a> {
    pub store: &'a ParamStore,
    pub lattice: Vec<&'a [i8]>,
}

impl<'a> ParamsView<'a> {
    /// Total lattice elements covered by the view's segments.
    pub fn lattice_len(&self) -> usize {
        self.lattice.iter().map(|s| s.len()).sum()
    }

    /// Contiguous values of lattice tensor `k` (indexing
    /// `store.lattice_indices()`): borrowed when segment `k` is exactly
    /// that tensor (per-tensor views), assembled from the flat segments
    /// otherwise (sharded views).
    pub fn lattice_tensor(&self, k: usize) -> Cow<'a, [i8]> {
        let lat = self.store.lattice_indices();
        let numel = self.store.entries[lat[k]].numel();
        let start: usize = lat[..k].iter().map(|&i| self.store.entries[i].numel()).sum();
        if self.lattice.len() == lat.len() {
            let seg_start: usize = self.lattice[..k].iter().map(|s| s.len()).sum();
            if seg_start == start && self.lattice[k].len() == numel {
                return Cow::Borrowed(self.lattice[k]);
            }
        }
        let mut out = Vec::with_capacity(numel);
        let mut off = 0usize;
        for seg in &self.lattice {
            let end = off + seg.len();
            if end > start && off < start + numel {
                let lo = start.max(off) - off;
                let hi = (start + numel).min(end) - off;
                out.extend_from_slice(&seg[lo..hi]);
            }
            off = end;
        }
        assert_eq!(out.len(), numel, "lattice view shorter than tensor {}", k);
        Cow::Owned(out)
    }
}

/// Anything that can present itself as a [`ParamsView`]: plain stores,
/// the leader's sharded plane, and published snapshots. Object safe, so
/// trait objects (e.g. `Workload` methods) can take `&dyn AsParams`.
pub trait AsParams {
    fn params_view(&self) -> ParamsView<'_>;
}

impl AsParams for ParamStore {
    fn params_view(&self) -> ParamsView<'_> {
        let lattice =
            if self.format == Format::Fp32 { Vec::new() } else { self.lattice_i8() };
        ParamsView { store: self, lattice }
    }
}

impl AsParams for ParamsView<'_> {
    fn params_view(&self) -> ParamsView<'_> {
        ParamsView { store: self.store, lattice: self.lattice.clone() }
    }
}

/// The leader's copy-on-write sharded parameter plane.
///
/// Owns the authoritative lattice values as `Arc`-backed shard slabs; the
/// wrapped base store keeps every non-lattice entry (embeddings, norms,
/// scales) plus the layout metadata, with its lattice entry payloads
/// emptied (the plane is the single source of truth).
pub struct ShardedParamStore {
    base: Arc<ParamStore>,
    plan: ShardPlan,
    shards: Vec<Arc<Vec<i8>>>,
    /// Per-shard dirty-since-last-publish flags (telemetry for the
    /// O(dirty) snapshot cost model; correctness never depends on them).
    dirty: Vec<bool>,
    publishes: u64,
}

impl ShardedParamStore {
    /// Shard a quantized store into `shards` COW slabs (see
    /// [`ShardPlan::new`] for the realized count). Consumes the store;
    /// its lattice entry payloads move into the plane.
    pub fn new(store: ParamStore, shards: usize) -> anyhow::Result<ShardedParamStore> {
        anyhow::ensure!(
            store.format != Format::Fp32,
            "sharded plane requires a quantized store (fp runs use ParamStore directly)"
        );
        let d = store.lattice_dim();
        let plan = ShardPlan::new(d, shards);
        let mut flat: Vec<i8> = Vec::with_capacity(d);
        for t in store.lattice_i8() {
            flat.extend_from_slice(t);
        }
        debug_assert_eq!(flat.len(), d);
        let mut slabs = Vec::with_capacity(plan.n_shards);
        for s in 0..plan.n_shards {
            let (start, len) = plan.bounds(s);
            slabs.push(Arc::new(flat[start..start + len].to_vec()));
        }
        let mut store = store;
        let lat: Vec<usize> = store.lattice_indices().to_vec();
        for &i in &lat {
            store.entries[i].data = TensorData::I8(Vec::new());
        }
        let n = plan.n_shards;
        Ok(ShardedParamStore {
            base: Arc::new(store),
            plan,
            shards: slabs,
            dirty: vec![false; n],
            publishes: 0,
        })
    }

    /// [`ShardedParamStore::new`] with the [`DEFAULT_SHARDS`] request.
    pub fn with_default_shards(store: ParamStore) -> anyhow::Result<ShardedParamStore> {
        ShardedParamStore::new(store, DEFAULT_SHARDS)
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards
    }

    pub fn format(&self) -> Format {
        self.base.format
    }

    pub fn size(&self) -> &str {
        &self.base.size
    }

    pub fn lattice_dim(&self) -> usize {
        self.plan.d
    }

    /// The shard slabs as canonical-flat-order read-only segments — what
    /// the fused update kernels consume directly (no layout translation).
    pub fn lattice_segments(&self) -> Vec<&[i8]> {
        self.shards.iter().map(|s| s.as_slice()).collect()
    }

    /// Apply a sparse update (global flat index, new value — ascending by
    /// index, as the kernels emit), unsharing only the shards actually
    /// written (copy-on-write) and marking them dirty. Returns the number
    /// of distinct shards this call touched. Indices must be in range;
    /// values are written verbatim (gating happened in the kernel).
    pub fn apply_deltas(&mut self, deltas: &[(usize, i8)]) -> usize {
        let mut touched = 0usize;
        let mut last: Option<usize> = None;
        for &(j, v) in deltas {
            let s = self.plan.shard_of(j);
            if last != Some(s) {
                last = Some(s);
                touched += 1;
                self.dirty[s] = true;
            }
            let off = j - s * self.plan.shard_len;
            Arc::make_mut(&mut self.shards[s])[off] = v;
        }
        touched
    }

    /// Shards written since the last publish.
    pub fn dirty_shards(&self) -> usize {
        self.dirty.iter().filter(|&&b| b).count()
    }

    pub fn publishes(&self) -> u64 {
        self.publishes
    }

    /// Publish the current lattice as an immutable snapshot: O(n_shards)
    /// reference bumps, no element copies. Subsequent leader updates
    /// unshare (clone) only the shards they write, so the snapshot is
    /// isolated from them.
    pub fn snapshot(&mut self) -> Snapshot {
        self.publishes += 1;
        self.dirty.fill(false);
        Snapshot { base: self.base.clone(), plan: self.plan.clone(), shards: self.shards.clone() }
    }

    /// Materialize a plain per-tensor store (checkpointing, hand-off to
    /// non-sharded tooling). O(d) — an endpoint operation, not a per-
    /// generation one.
    pub fn materialize(&self) -> ParamStore {
        let mut out = (*self.base).clone();
        let lat: Vec<usize> = out.lattice_indices().to_vec();
        let mut it = self.shards.iter().flat_map(|s| s.iter().copied());
        for &i in &lat {
            let numel = out.entries[i].numel();
            let v: Vec<i8> = it.by_ref().take(numel).collect();
            debug_assert_eq!(v.len(), numel);
            out.entries[i].data = TensorData::I8(v);
        }
        out
    }

    /// Weight footprint in bytes with true packed lattice width (the base
    /// store's lattice entries are empty, so account the plane here). INT4
    /// packing is counted per tensor, matching `ParamStore::weight_bytes`
    /// exactly — sharding must never change the reported footprint.
    pub fn weight_bytes(&self) -> u64 {
        let lattice: u64 = match self.base.format {
            Format::Int4 => self
                .base
                .lattice_indices()
                .iter()
                .map(|&i| (self.base.entries[i].numel() as u64 + 1) / 2)
                .sum(),
            _ => self.plan.d as u64,
        };
        self.base.weight_bytes() + lattice
    }
}

impl AsParams for ShardedParamStore {
    fn params_view(&self) -> ParamsView<'_> {
        ParamsView { store: &self.base, lattice: self.lattice_segments() }
    }
}

/// An immutable, cheaply clonable published view of the plane (what the
/// leader broadcasts to the worker pool each generation). Clone is
/// O(n_shards) `Arc` bumps.
#[derive(Clone)]
pub struct Snapshot {
    base: Arc<ParamStore>,
    plan: ShardPlan,
    shards: Vec<Arc<Vec<i8>>>,
}

impl Snapshot {
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn lattice_segments(&self) -> Vec<&[i8]> {
        self.shards.iter().map(|s| s.as_slice()).collect()
    }
}

impl AsParams for Snapshot {
    fn params_view(&self) -> ParamsView<'_> {
        ParamsView { store: &self.base, lattice: self.lattice_segments() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp;
    use crate::runtime::manifest::Manifest;

    fn quant_store(seed: u64) -> ParamStore {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, seed);
        ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap()
    }

    fn flat(segs: &[&[i8]]) -> Vec<i8> {
        segs.iter().flat_map(|s| s.iter().copied()).collect()
    }

    #[test]
    fn plan_aligns_and_covers() {
        for d in [1usize, 100, SHARD_ALIGN, SHARD_ALIGN + 1, 36864, 147456] {
            for shards in [1usize, 2, 5, 8, 100] {
                let p = ShardPlan::new(d, shards);
                assert_eq!(p.shard_len % SHARD_ALIGN, 0, "d={} shards={}", d, shards);
                let mut covered = 0usize;
                for s in 0..p.n_shards {
                    let (start, len) = p.bounds(s);
                    assert_eq!(start, covered);
                    assert!(len >= 1);
                    covered += len;
                }
                assert_eq!(covered, d, "d={} shards={}", d, shards);
            }
        }
    }

    #[test]
    fn sharding_roundtrips_through_materialize() {
        let q = quant_store(3);
        let want: Vec<i8> = q.lattice_i8().iter().flat_map(|t| t.iter().copied()).collect();
        for shards in [1usize, 2, 8] {
            let sp = ShardedParamStore::new(q.clone(), shards).unwrap();
            assert_eq!(flat(&sp.lattice_segments()), want, "shards={}", shards);
            let back = sp.materialize();
            let got: Vec<i8> =
                back.lattice_i8().iter().flat_map(|t| t.iter().copied()).collect();
            assert_eq!(got, want, "shards={}", shards);
            assert_eq!(back.format, q.format);
        }
    }

    #[test]
    fn snapshot_is_isolated_from_leader_updates() {
        let q = quant_store(5);
        let mut sp = ShardedParamStore::new(q, 8).unwrap();
        let before = flat(&sp.lattice_segments());
        let snap = sp.snapshot();
        // write through every shard after publishing
        let d = sp.lattice_dim();
        let deltas: Vec<(usize, i8)> = (0..d)
            .step_by(1000)
            .map(|j| (j, if before[j] == 7 { -7 } else { 7 }))
            .collect();
        sp.apply_deltas(&deltas);
        assert_eq!(flat(&snap.lattice_segments()), before, "snapshot mutated");
        // and the leader did change
        assert_ne!(flat(&sp.lattice_segments()), before);
    }

    #[test]
    fn apply_deltas_marks_only_touched_shards_dirty() {
        let q = quant_store(7);
        let mut sp = ShardedParamStore::new(q, 8).unwrap();
        let _ = sp.snapshot(); // clears dirty
        assert_eq!(sp.dirty_shards(), 0);
        let touched = sp.apply_deltas(&[(0, 1), (1, 2)]);
        assert_eq!(touched, 1);
        assert_eq!(sp.dirty_shards(), 1);
        // a second publish resets the flags again
        let _ = sp.snapshot();
        assert_eq!(sp.dirty_shards(), 0);
    }

    #[test]
    fn weight_bytes_matches_materialized_store() {
        // Sharding is storage, not accounting: the plane must report the
        // exact Table 8 footprint of its materialized per-tensor form.
        let q = quant_store(11);
        let sp = ShardedParamStore::new(q.clone(), 8).unwrap();
        assert_eq!(sp.weight_bytes(), q.weight_bytes());
        assert_eq!(sp.weight_bytes(), sp.materialize().weight_bytes());
    }

    #[test]
    fn views_agree_between_plain_and_sharded() {
        let q = quant_store(9);
        let plain_flat: Vec<i8> = {
            let v = q.params_view();
            v.lattice.iter().flat_map(|s| s.iter().copied()).collect()
        };
        let sp = ShardedParamStore::new(q.clone(), 8).unwrap();
        let view = sp.params_view();
        let sharded_flat: Vec<i8> = view.lattice.iter().flat_map(|s| s.iter().copied()).collect();
        assert_eq!(plain_flat, sharded_flat);
        // per-tensor gather agrees with the plain store's tensors
        for (k, &li) in q.lattice_indices().iter().enumerate() {
            let want = q.entries[li].data.as_i8();
            assert_eq!(&*view.lattice_tensor(k), want, "tensor {}", k);
            // plain view takes the borrowed fast path
            let pv = q.params_view();
            assert!(matches!(pv.lattice_tensor(k), Cow::Borrowed(_)));
        }
    }
}
