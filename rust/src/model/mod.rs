//! Parameter store: the Rust-side single source of truth for model weights.
//!
//! The store mirrors the manifest's per-format flat argument layout exactly
//! (same names, same order), so marshalling to PJRT literals is a direct
//! walk. Lattice tensors are held as int8 values on the symmetric grid plus
//! a per-output-channel scale vector; the lattice *range* (INT4 vs INT8) is
//! a property of the run's `Format`, enforced by boundary gating — the same
//! int8 storage and HLO artifact serve both widths, as in DESIGN.md.

pub mod checkpoint;
pub mod init;
pub mod sharded;

use std::collections::BTreeMap;

pub use sharded::{
    AsParams, ParamsView, ShardPlan, ShardedParamStore, Snapshot, DEFAULT_SHARDS, SHARD_ALIGN,
};

use crate::quant::Format;
use crate::runtime::manifest::{Manifest, ParamMeta};

/// Raw tensor payload.
#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>),
    I8(Vec<i8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorData::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            TensorData::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i8(&self) -> &[i8] {
        match self {
            TensorData::I8(v) => v,
            _ => panic!("expected i8 tensor"),
        }
    }

    pub fn as_i8_mut(&mut self) -> &mut [i8] {
        match self {
            TensorData::I8(v) => v,
            _ => panic!("expected i8 tensor"),
        }
    }
}

/// What role a flat argument plays (mirrors manifest "kind").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    /// Always-FP tensor (embeddings, norms).
    Fp,
    /// Integer lattice values of a quantized linear weight.
    LatticeQ,
    /// Per-output-channel scale of a quantized linear weight.
    Scale,
    /// A lattice-eligible weight materialized as f32 (the `fp` format).
    LatticeAsFp,
}

impl ParamKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "fp" => ParamKind::Fp,
            "lattice_q" => ParamKind::LatticeQ,
            "scale" => ParamKind::Scale,
            "lattice_as_fp" => ParamKind::LatticeAsFp,
            other => anyhow::bail!("unknown param kind {:?}", other),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: ParamKind,
    /// (dist, std) init hint from the manifest, for fp-format tensors.
    pub init: Option<(String, f32)>,
    pub data: TensorData,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Ordered parameter collection for one (model size, format).
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub size: String,
    pub format: Format,
    pub entries: Vec<ParamEntry>,
    index: BTreeMap<String, usize>,
    /// Indices of LatticeQ (quant formats) or LatticeAsFp (fp) entries, in
    /// canonical order — the ES parameter space.
    lattice: Vec<usize>,
}

impl ParamStore {
    /// Build a zero-initialized store from the manifest layout.
    pub fn from_manifest(man: &Manifest, size: &str, format: Format) -> anyhow::Result<Self> {
        let metas: &[ParamMeta] = man.params(size, format.artifact_format())?;
        let mut entries = Vec::with_capacity(metas.len());
        for m in metas {
            let numel: usize = m.shape.iter().product();
            let kind = ParamKind::parse(&m.kind)?;
            let data = match m.dtype.as_str() {
                "i8" => TensorData::I8(vec![0i8; numel]),
                "f32" => TensorData::F32(vec![0.0f32; numel]),
                other => anyhow::bail!("unsupported param dtype {:?}", other),
            };
            entries.push(ParamEntry {
                name: m.name.clone(),
                shape: m.shape.clone(),
                kind,
                init: m.init.clone(),
                data,
            });
        }
        let index = entries
            .iter()
            .enumerate()
            .map(|(i, e)| (e.name.clone(), i))
            .collect();
        let lattice = entries
            .iter()
            .enumerate()
            .filter(|(_, e)| matches!(e.kind, ParamKind::LatticeQ | ParamKind::LatticeAsFp))
            .map(|(i, _)| i)
            .collect();
        Ok(ParamStore { size: size.to_string(), format, entries, index, lattice })
    }

    pub fn get(&self, name: &str) -> Option<&ParamEntry> {
        self.index.get(name).map(|&i| &self.entries[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut ParamEntry> {
        let i = *self.index.get(name)?;
        Some(&mut self.entries[i])
    }

    /// Indices of the ES-optimizable (lattice) entries, canonical order.
    pub fn lattice_indices(&self) -> &[usize] {
        &self.lattice
    }

    /// Total lattice dimension d (the ES search-space size).
    pub fn lattice_dim(&self) -> usize {
        self.lattice.iter().map(|&i| self.entries[i].numel()).sum()
    }

    /// Iterate lattice tensors as immutable i8 slices (quant formats only).
    pub fn lattice_i8(&self) -> Vec<&[i8]> {
        self.lattice.iter().map(|&i| self.entries[i].data.as_i8()).collect()
    }

    /// Iterate lattice tensors as mutable i8 slices (quant formats only).
    pub fn lattice_i8_mut(&mut self) -> Vec<&mut [i8]> {
        // split_at_mut dance: collect raw pointers, safe because indices are
        // distinct entries of the same Vec.
        let mut out = Vec::with_capacity(self.lattice.len());
        let base = self.entries.as_mut_ptr();
        for &i in &self.lattice {
            unsafe {
                let e = &mut *base.add(i);
                out.push(e.data.as_i8_mut() as *mut [i8]);
            }
        }
        out.into_iter().map(|p| unsafe { &mut *p }).collect()
    }

    /// Iterate lattice tensors as mutable f32 slices (fp format only —
    /// the MeZO/continuous-baseline parameter space).
    pub fn lattice_f32_mut(&mut self) -> Vec<&mut [f32]> {
        // Same disjoint-entries argument as `lattice_i8_mut`.
        let mut out = Vec::with_capacity(self.lattice.len());
        let base = self.entries.as_mut_ptr();
        for &i in &self.lattice {
            unsafe {
                let e = &mut *base.add(i);
                out.push(e.data.as_f32_mut() as *mut [f32]);
            }
        }
        out.into_iter().map(|p| unsafe { &mut *p }).collect()
    }

    /// Memory footprint of the weights in bytes, using the TRUE packed
    /// lattice width (INT4 packs two values per byte) — Table 8 accounting.
    pub fn weight_bytes(&self) -> u64 {
        let mut total = 0u64;
        for e in &self.entries {
            total += match (&e.data, self.format) {
                (TensorData::I8(v), Format::Int4) => (v.len() as u64 + 1) / 2,
                (TensorData::I8(v), _) => v.len() as u64,
                (TensorData::F32(v), _) => v.len() as u64 * 4,
            };
        }
        total
    }

    /// Quantize an fp-format store onto the lattice (per-channel symmetric
    /// PTQ, or GPTQ when calibration activations are supplied per tensor).
    pub fn quantize_from(
        fp: &ParamStore,
        man: &Manifest,
        format: Format,
        mut calib: Option<&mut dyn FnMut(&str, usize, usize) -> Option<Vec<f32>>>,
    ) -> anyhow::Result<ParamStore> {
        anyhow::ensure!(fp.format == Format::Fp32, "source must be fp32");
        anyhow::ensure!(format != Format::Fp32, "target must be quantized");
        let mut qs = ParamStore::from_manifest(man, &fp.size, format)?;
        let qmax = format.qmax();
        // Walk q-store entries; lattice tensors pull from the fp tensor of
        // the same base name; fp tensors copy through.
        for i in 0..qs.entries.len() {
            let (name, kind, shape) = {
                let e = &qs.entries[i];
                (e.name.clone(), e.kind, e.shape.clone())
            };
            match kind {
                ParamKind::Fp => {
                    let src = fp
                        .get(&name)
                        .ok_or_else(|| anyhow::anyhow!("missing fp param {}", name))?;
                    qs.entries[i].data = TensorData::F32(src.data.as_f32().to_vec());
                }
                ParamKind::LatticeQ => {
                    let base = name.trim_end_matches(".q");
                    let src = fp
                        .get(base)
                        .ok_or_else(|| anyhow::anyhow!("missing fp param {}", base))?;
                    let (rows, cols) = (src.shape[0], src.shape[1]);
                    let w = src.data.as_f32();
                    let qt = match calib.as_mut().and_then(|f| f(base, rows, cols)) {
                        Some(x) => {
                            let ns = x.len() / rows;
                            crate::quant::gptq_quantize(w, rows, cols, qmax, &x, ns, 0.01)?
                        }
                        None => crate::quant::ptq_quantize(w, rows, cols, qmax),
                    };
                    qs.entries[i].data = TensorData::I8(qt.q);
                    // fill the paired scale entry (always follows .q)
                    let sname = format!("{}.s", base);
                    let si = *qs
                        .index
                        .get(&sname)
                        .ok_or_else(|| anyhow::anyhow!("missing scale entry {}", sname))?;
                    qs.entries[si].data = TensorData::F32(qt.scale);
                    let _ = shape;
                }
                ParamKind::Scale => { /* filled together with .q above */ }
                ParamKind::LatticeAsFp => unreachable!("quant store has no lattice_as_fp"),
            }
        }
        Ok(qs)
    }

    /// Dequantize a quant-format store back to an fp-format store (used by
    /// eval tooling and tests).
    pub fn dequantize(&self, man: &Manifest) -> anyhow::Result<ParamStore> {
        anyhow::ensure!(self.format != Format::Fp32, "already fp");
        let mut fp = ParamStore::from_manifest(man, &self.size, Format::Fp32)?;
        for i in 0..fp.entries.len() {
            let (name, kind) = {
                let e = &fp.entries[i];
                (e.name.clone(), e.kind)
            };
            match kind {
                ParamKind::Fp => {
                    let src = self
                        .get(&name)
                        .ok_or_else(|| anyhow::anyhow!("missing param {}", name))?;
                    fp.entries[i].data = TensorData::F32(src.data.as_f32().to_vec());
                }
                ParamKind::LatticeAsFp => {
                    let q = self
                        .get(&format!("{}.q", name))
                        .ok_or_else(|| anyhow::anyhow!("missing {}.q", name))?;
                    let s = self
                        .get(&format!("{}.s", name))
                        .ok_or_else(|| anyhow::anyhow!("missing {}.s", name))?;
                    let cols = s.data.as_f32().len();
                    let qv = q.data.as_i8();
                    let sv = s.data.as_f32();
                    let mut out = vec![0.0f32; qv.len()];
                    for (j, &qj) in qv.iter().enumerate() {
                        out[j] = qj as f32 * sv[j % cols];
                    }
                    fp.entries[i].data = TensorData::F32(out);
                }
                _ => unreachable!(),
            }
        }
        Ok(fp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn manifest() -> Manifest {
        Manifest::load("artifacts/manifest.json").expect("run `make artifacts` first")
    }

    #[test]
    fn store_layout_matches_manifest() {
        let man = manifest();
        let s = ParamStore::from_manifest(&man, "nano", Format::Int4).unwrap();
        // nano: 2 layers x (2 ln + 6 lattice pairs... ) + embeds + lnf
        assert!(s.entries.len() > 10);
        assert_eq!(s.lattice_indices().len(), 2 * 6);
        assert!(s.lattice_dim() > 0);
        let man_cfg = man.config("nano").unwrap();
        assert_eq!(s.lattice_dim(), man_cfg.lattice_params);
    }

    #[test]
    fn quantize_roundtrip_small_error() {
        let man = manifest();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        crate::model::init::init_fp(&mut fp, 42);
        let q8 = ParamStore::quantize_from(&fp, &man, Format::Int8, None).unwrap();
        let back = q8.dequantize(&man).unwrap();
        // INT8 symmetric per-channel: max elementwise error <= scale/2,
        // and scale ~ absmax/127 — so relative recon error is tiny.
        for (&li, _) in fp.lattice_indices().iter().zip(0..) {
            let name = fp.entries[li].name.clone();
            let a = fp.get(&name).unwrap().data.as_f32();
            let b = back.get(&name).unwrap().data.as_f32();
            let maxerr = a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            let absmax = a.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
            assert!(maxerr <= absmax / 127.0 + 1e-6, "{}: {}", name, maxerr);
        }
    }

    #[test]
    fn int4_weight_bytes_half_of_int8() {
        let man = manifest();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        crate::model::init::init_fp(&mut fp, 1);
        let q4 = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        let q8 = ParamStore::quantize_from(&fp, &man, Format::Int8, None).unwrap();
        let d = q4.lattice_dim() as u64;
        assert_eq!(q8.weight_bytes() - q4.weight_bytes(), d / 2);
    }
}
