//! Weight initialization for fp-format stores (pretraining starts here).

use crate::model::{ParamKind, ParamStore, TensorData};
use crate::rng::SplitMix64;

/// Initialize all fp tensors of an fp-format store from the manifest's init
/// hints: ("normal", std) | ("ones",) | ("zeros",). Deterministic in `seed`.
pub fn init_fp(store: &mut ParamStore, seed: u64) {
    let mut rng = SplitMix64::new(seed ^ 0x517c_c1b7_2722_0a95);
    for e in store.entries.iter_mut() {
        debug_assert!(matches!(e.kind, ParamKind::Fp | ParamKind::LatticeAsFp));
        let data = match &mut e.data {
            TensorData::F32(v) => v,
            TensorData::I8(_) => panic!("fp store has i8 tensor {}", e.name),
        };
        match e.init.as_ref().map(|(d, s)| (d.as_str(), *s)) {
            Some(("normal", std)) => {
                for x in data.iter_mut() {
                    *x = rng.normal() * std;
                }
            }
            Some(("ones", _)) => data.fill(1.0),
            Some(("zeros", _)) | None => data.fill(0.0),
            Some((other, _)) => panic!("unknown init dist {:?} for {}", other, e.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Format;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn init_is_deterministic_and_sane() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut a = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        let mut b = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut a, 7);
        init_fp(&mut b, 7);
        for (ea, eb) in a.entries.iter().zip(b.entries.iter()) {
            assert_eq!(ea.data.as_f32(), eb.data.as_f32(), "{}", ea.name);
        }
        // norms start at identity
        let g = a.get("lnf.g").unwrap().data.as_f32();
        assert!(g.iter().all(|&x| x == 1.0));
        // embeddings non-degenerate
        let emb = a.get("tok_emb").unwrap().data.as_f32();
        assert!(crate::util::std_dev(emb) > 0.01);
    }

    #[test]
    fn different_seed_different_weights() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut a = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        let mut b = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut a, 1);
        init_fp(&mut b, 2);
        assert_ne!(
            a.get("tok_emb").unwrap().data.as_f32(),
            b.get("tok_emb").unwrap().data.as_f32()
        );
    }
}
