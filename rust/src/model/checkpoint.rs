//! Checkpoint serialization (own binary format; no serde offline).
//!
//! Layout (little-endian):
//!   magic  b"QESCKPT1"
//!   u32    size-name length, bytes
//!   u32    format-name length, bytes
//!   u32    entry count
//!   per entry:
//!     u32 name length, bytes
//!     u8  kind (0=fp 1=lattice_q 2=scale 3=lattice_as_fp)
//!     u8  dtype (0=f32 1=i8 2=i8-packed-int4)
//!     u32 ndim, u64 dims...
//!     u64 payload byte length, payload
//!
//! INT4 lattices are written nibble-packed (dtype=2), so an INT4 checkpoint
//! on disk really is half the size of the INT8 one — the artifact the
//! paper's Table 8 accounting assumes.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::{ParamKind, ParamStore, TensorData};
use crate::quant::{pack_int4, unpack_int4, Format};
use crate::runtime::manifest::Manifest;

const MAGIC: &[u8; 8] = b"QESCKPT1";

fn kind_byte(k: ParamKind) -> u8 {
    match k {
        ParamKind::Fp => 0,
        ParamKind::LatticeQ => 1,
        ParamKind::Scale => 2,
        ParamKind::LatticeAsFp => 3,
    }
}

pub fn save(store: &ParamStore, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    write_str(&mut w, &store.size)?;
    write_str(&mut w, store.format.name())?;
    w.write_all(&(store.entries.len() as u32).to_le_bytes())?;
    for e in &store.entries {
        write_str(&mut w, &e.name)?;
        w.write_all(&[kind_byte(e.kind)])?;
        let pack4 = store.format == Format::Int4 && e.kind == ParamKind::LatticeQ;
        match (&e.data, pack4) {
            (TensorData::F32(v), _) => {
                w.write_all(&[0u8])?;
                write_dims(&mut w, &e.shape)?;
                let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
                write_payload(&mut w, &bytes)?;
            }
            (TensorData::I8(v), false) => {
                w.write_all(&[1u8])?;
                write_dims(&mut w, &e.shape)?;
                let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                write_payload(&mut w, &bytes)?;
            }
            (TensorData::I8(v), true) => {
                w.write_all(&[2u8])?;
                write_dims(&mut w, &e.shape)?;
                write_payload(&mut w, &pack_int4(v))?;
            }
        }
    }
    Ok(())
}

pub fn load(man: &Manifest, path: &Path) -> anyhow::Result<ParamStore> {
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic in {:?}", path);
    let size = read_str(&mut r)?;
    let fmt = Format::parse(&read_str(&mut r)?)?;
    let n = read_u32(&mut r)? as usize;
    let mut store = ParamStore::from_manifest(man, &size, fmt)?;
    anyhow::ensure!(
        store.entries.len() == n,
        "checkpoint has {} entries, manifest layout has {}",
        n,
        store.entries.len()
    );
    for i in 0..n {
        let name = read_str(&mut r)?;
        anyhow::ensure!(
            store.entries[i].name == name,
            "entry {} name mismatch: ckpt {:?} vs manifest {:?}",
            i,
            name,
            store.entries[i].name
        );
        let mut kd = [0u8; 2];
        r.read_exact(&mut kd)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u64(&mut r)? as usize);
        }
        anyhow::ensure!(dims == store.entries[i].shape, "shape mismatch for {}", name);
        let numel: usize = dims.iter().product();
        let payload = read_payload(&mut r)?;
        store.entries[i].data = match kd[1] {
            0 => {
                anyhow::ensure!(payload.len() == numel * 4, "bad f32 payload for {}", name);
                TensorData::F32(
                    payload
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            1 => {
                anyhow::ensure!(payload.len() == numel, "bad i8 payload for {}", name);
                TensorData::I8(payload.iter().map(|&b| b as i8).collect())
            }
            2 => TensorData::I8(unpack_int4(&payload, numel)),
            other => anyhow::bail!("bad dtype byte {} for {}", other, name),
        };
    }
    Ok(store)
}

fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn write_dims<W: Write>(w: &mut W, dims: &[usize]) -> std::io::Result<()> {
    w.write_all(&(dims.len() as u32).to_le_bytes())?;
    for &d in dims {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn write_payload<W: Write>(w: &mut W, bytes: &[u8]) -> std::io::Result<()> {
    w.write_all(&(bytes.len() as u64).to_le_bytes())?;
    w.write_all(bytes)
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str<R: Read>(r: &mut R) -> anyhow::Result<String> {
    let n = read_u32(r)? as usize;
    anyhow::ensure!(n < 1 << 20, "absurd string length {}", n);
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

fn read_payload<R: Read>(r: &mut R) -> anyhow::Result<Vec<u8>> {
    let n = read_u64(r)? as usize;
    anyhow::ensure!(n < 1 << 33, "absurd payload length {}", n);
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp;

    #[test]
    fn roundtrip_fp_and_int4() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 99);
        let dir = std::env::temp_dir().join("qes_ckpt_test");
        let fp_path = dir.join("fp.ckpt");
        save(&fp, &fp_path).unwrap();
        let fp2 = load(&man, &fp_path).unwrap();
        assert_eq!(
            fp.get("tok_emb").unwrap().data.as_f32(),
            fp2.get("tok_emb").unwrap().data.as_f32()
        );

        let q4 = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        let q4_path = dir.join("int4.ckpt");
        save(&q4, &q4_path).unwrap();
        let q4b = load(&man, &q4_path).unwrap();
        for &li in q4.lattice_indices() {
            let name = q4.entries[li].name.clone();
            assert_eq!(
                q4.get(&name).unwrap().data.as_i8(),
                q4b.get(&name).unwrap().data.as_i8(),
                "{}",
                name
            );
        }
        // INT4 checkpoint should be materially smaller than INT8's.
        let q8 = ParamStore::quantize_from(&fp, &man, Format::Int8, None).unwrap();
        let q8_path = dir.join("int8.ckpt");
        save(&q8, &q8_path).unwrap();
        let s4 = std::fs::metadata(&q4_path).unwrap().len();
        let s8 = std::fs::metadata(&q8_path).unwrap().len();
        assert!(s4 < s8, "int4 ckpt {} >= int8 ckpt {}", s4, s8);
    }

    #[test]
    fn rejects_corrupt_magic() {
        let dir = std::env::temp_dir().join("qes_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"NOTAMAGIC").unwrap();
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        assert!(load(&man, &p).is_err());
    }
}
