//! Checkpoint serialization (own binary format; no serde offline).
//!
//! Layout (little-endian):
//!   magic  b"QESCKPT1"
//!   u32    size-name length, bytes
//!   u32    format-name length, bytes
//!   u32    entry count
//!   per entry:
//!     u32 name length, bytes
//!     u8  kind (0=fp 1=lattice_q 2=scale 3=lattice_as_fp)
//!     u8  dtype (0=f32 1=i8 2=i8-packed-int4)
//!     u32 ndim, u64 dims...
//!     u64 payload byte length, payload
//!
//! INT4 lattices are written nibble-packed (dtype=2), so an INT4 checkpoint
//! on disk really is half the size of the INT8 one — the artifact the
//! paper's Table 8 accounting assumes.
//!
//! All writes are crash-consistent: the payload goes to a temp file in
//! the destination directory, is fsynced, and is atomically renamed
//! over the target — a reader never observes a torn checkpoint, only
//! the old file or the new one.
//!
//! Training checkpoints (`save_train`/`load_train`, magic b"QESTRAIN")
//! embed a param checkpoint plus everything `qes finetune --resume`
//! needs to continue bit-identically: round counter, master RNG seed,
//! variant name and the optimizer's `save_state` blob (residual slabs /
//! replay history / step counters).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::Context;

use crate::model::{ParamKind, ParamStore, TensorData};
use crate::quant::{pack_int4, unpack_int4, Format};
use crate::runtime::manifest::Manifest;

const MAGIC: &[u8; 8] = b"QESCKPT1";
const TRAIN_MAGIC: &[u8; 8] = b"QESTRAIN";
const TRAIN_VERSION: u32 = 1;

fn kind_byte(k: ParamKind) -> u8 {
    match k {
        ParamKind::Fp => 0,
        ParamKind::LatticeQ => 1,
        ParamKind::Scale => 2,
        ParamKind::LatticeAsFp => 3,
    }
}

/// Write `path` via temp-file + fsync + atomic rename: `f` streams the
/// payload into a `.tmp` sibling, which replaces `path` only after its
/// contents are durable. A crash at any point leaves either the old
/// file or the new one — never a torn mix.
fn atomic_write<F>(path: &Path, f: F) -> anyhow::Result<()>
where
    F: FnOnce(&mut std::io::BufWriter<std::fs::File>) -> anyhow::Result<()>,
{
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => {
            std::fs::create_dir_all(d)?;
            Some(d.to_path_buf())
        }
        _ => None,
    };
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow::anyhow!("checkpoint path {:?} has no file name", path))?;
    let tmp = path.with_file_name(format!(".{}.{}.tmp", name, std::process::id()));
    let result = (|| -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(
            std::fs::File::create(&tmp)
                .with_context(|| format!("cannot create temp checkpoint {:?}", tmp))?,
        );
        f(&mut w)?;
        w.flush()?;
        w.get_ref().sync_all()?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("cannot rename {:?} over {:?}", tmp, path))?;
        Ok(())
    })();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
        return result;
    }
    // Make the rename itself durable (best-effort: not every
    // filesystem lets you fsync a directory handle).
    if let Some(d) = dir {
        if let Ok(dh) = std::fs::File::open(&d) {
            let _ = dh.sync_all();
        }
    }
    Ok(())
}

pub fn save(store: &ParamStore, path: &Path) -> anyhow::Result<()> {
    atomic_write(path, |w| write_store(store, w))
}

/// Stream a param checkpoint body (magic through last payload) to `w`.
fn write_store<W: Write>(store: &ParamStore, w: &mut W) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    write_str(&mut w, &store.size)?;
    write_str(&mut w, store.format.name())?;
    w.write_all(&(store.entries.len() as u32).to_le_bytes())?;
    for e in &store.entries {
        write_str(&mut w, &e.name)?;
        w.write_all(&[kind_byte(e.kind)])?;
        let pack4 = store.format == Format::Int4 && e.kind == ParamKind::LatticeQ;
        match (&e.data, pack4) {
            (TensorData::F32(v), _) => {
                w.write_all(&[0u8])?;
                write_dims(&mut w, &e.shape)?;
                let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
                write_payload(&mut w, &bytes)?;
            }
            (TensorData::I8(v), false) => {
                w.write_all(&[1u8])?;
                write_dims(&mut w, &e.shape)?;
                let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                write_payload(&mut w, &bytes)?;
            }
            (TensorData::I8(v), true) => {
                w.write_all(&[2u8])?;
                write_dims(&mut w, &e.shape)?;
                write_payload(&mut w, &pack_int4(v))?;
            }
        }
    }
    Ok(())
}

pub fn load(man: &Manifest, path: &Path) -> anyhow::Result<ParamStore> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("cannot open checkpoint {:?}", path))?,
    );
    read_store(man, &mut r)
        .with_context(|| format!("corrupt or truncated checkpoint {:?}", path))
}

/// Parse a param checkpoint body from `r` (counterpart of
/// `write_store`). Short reads surface as errors from `read_exact` and
/// get the file-level context attached by the callers.
fn read_store<R: Read>(man: &Manifest, mut r: R) -> anyhow::Result<ParamStore> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("short read in checkpoint magic")?;
    anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic");
    let size = read_str(&mut r)?;
    let fmt = Format::parse(&read_str(&mut r)?)?;
    let n = read_u32(&mut r)? as usize;
    let mut store = ParamStore::from_manifest(man, &size, fmt)?;
    anyhow::ensure!(
        store.entries.len() == n,
        "checkpoint has {} entries, manifest layout has {}",
        n,
        store.entries.len()
    );
    for i in 0..n {
        let name = read_str(&mut r)?;
        anyhow::ensure!(
            store.entries[i].name == name,
            "entry {} name mismatch: ckpt {:?} vs manifest {:?}",
            i,
            name,
            store.entries[i].name
        );
        let mut kd = [0u8; 2];
        r.read_exact(&mut kd)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u64(&mut r)? as usize);
        }
        anyhow::ensure!(dims == store.entries[i].shape, "shape mismatch for {}", name);
        let numel: usize = dims.iter().product();
        let payload = read_payload(&mut r)?;
        store.entries[i].data = match kd[1] {
            0 => {
                anyhow::ensure!(payload.len() == numel * 4, "bad f32 payload for {}", name);
                TensorData::F32(
                    payload
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                )
            }
            1 => {
                anyhow::ensure!(payload.len() == numel, "bad i8 payload for {}", name);
                TensorData::I8(payload.iter().map(|&b| b as i8).collect())
            }
            2 => TensorData::I8(unpack_int4(&payload, numel)),
            other => anyhow::bail!("bad dtype byte {} for {}", other, name),
        };
    }
    Ok(store)
}

/// Everything `qes finetune --resume` needs to continue a run
/// bit-identically to an uninterrupted one.
pub struct TrainState {
    /// Generations already committed (the master RNG has drawn exactly
    /// this many gen_seeds).
    pub rounds_done: u64,
    /// The run's master seed — resume validates it against the config.
    pub seed: u64,
    /// Optimizer variant name (`Variant::name()`).
    pub variant: String,
    /// Opaque `LatticeOptimizer::save_state` blob.
    pub opt_state: Vec<u8>,
    /// The committed parameter plane at `rounds_done`.
    pub store: ParamStore,
}

/// Atomically write a training checkpoint: round/RNG counters, variant,
/// optimizer-state blob, then the full param checkpoint embedded.
pub fn save_train(
    path: &Path,
    store: &ParamStore,
    rounds_done: u64,
    seed: u64,
    variant: &str,
    opt_state: &[u8],
) -> anyhow::Result<()> {
    atomic_write(path, |w| {
        w.write_all(TRAIN_MAGIC)?;
        w.write_all(&TRAIN_VERSION.to_le_bytes())?;
        w.write_all(&rounds_done.to_le_bytes())?;
        w.write_all(&seed.to_le_bytes())?;
        write_str(w, variant)?;
        w.write_all(&(opt_state.len() as u64).to_le_bytes())?;
        w.write_all(opt_state)?;
        write_store(store, w)
    })
}

pub fn load_train(man: &Manifest, path: &Path) -> anyhow::Result<TrainState> {
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("cannot open training checkpoint {:?}", path))?,
    );
    (|| -> anyhow::Result<TrainState> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("short read in training checkpoint magic")?;
        anyhow::ensure!(&magic == TRAIN_MAGIC, "bad training checkpoint magic");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(
            version == TRAIN_VERSION,
            "training checkpoint version {} (expected {})",
            version,
            TRAIN_VERSION
        );
        let rounds_done = read_u64(&mut r)?;
        let seed = read_u64(&mut r)?;
        let variant = read_str(&mut r)?;
        let opt_state = read_payload(&mut r)?;
        let store = read_store(man, &mut r)?;
        Ok(TrainState { rounds_done, seed, variant, opt_state, store })
    })()
    .with_context(|| format!("corrupt or truncated training checkpoint {:?}", path))
}

fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn write_dims<W: Write>(w: &mut W, dims: &[usize]) -> std::io::Result<()> {
    w.write_all(&(dims.len() as u32).to_le_bytes())?;
    for &d in dims {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

fn write_payload<W: Write>(w: &mut W, bytes: &[u8]) -> std::io::Result<()> {
    w.write_all(&(bytes.len() as u64).to_le_bytes())?;
    w.write_all(bytes)
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str<R: Read>(r: &mut R) -> anyhow::Result<String> {
    let n = read_u32(r)? as usize;
    anyhow::ensure!(n < 1 << 20, "absurd string length {}", n);
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

fn read_payload<R: Read>(r: &mut R) -> anyhow::Result<Vec<u8>> {
    let n = read_u64(r)? as usize;
    anyhow::ensure!(n < 1 << 33, "absurd payload length {}", n);
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp;

    #[test]
    fn roundtrip_fp_and_int4() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 99);
        let dir = std::env::temp_dir().join("qes_ckpt_test");
        let fp_path = dir.join("fp.ckpt");
        save(&fp, &fp_path).unwrap();
        let fp2 = load(&man, &fp_path).unwrap();
        assert_eq!(
            fp.get("tok_emb").unwrap().data.as_f32(),
            fp2.get("tok_emb").unwrap().data.as_f32()
        );

        let q4 = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        let q4_path = dir.join("int4.ckpt");
        save(&q4, &q4_path).unwrap();
        let q4b = load(&man, &q4_path).unwrap();
        for &li in q4.lattice_indices() {
            let name = q4.entries[li].name.clone();
            assert_eq!(
                q4.get(&name).unwrap().data.as_i8(),
                q4b.get(&name).unwrap().data.as_i8(),
                "{}",
                name
            );
        }
        // INT4 checkpoint should be materially smaller than INT8's.
        let q8 = ParamStore::quantize_from(&fp, &man, Format::Int8, None).unwrap();
        let q8_path = dir.join("int8.ckpt");
        save(&q8, &q8_path).unwrap();
        let s4 = std::fs::metadata(&q4_path).unwrap().len();
        let s8 = std::fs::metadata(&q8_path).unwrap().len();
        assert!(s4 < s8, "int4 ckpt {} >= int8 ckpt {}", s4, s8);
    }

    #[test]
    fn rejects_corrupt_magic() {
        let dir = std::env::temp_dir().join("qes_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, b"NOTAMAGIC").unwrap();
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        assert!(load(&man, &p).is_err());
    }

    #[test]
    fn save_leaves_no_temp_files() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 12);
        let dir = std::env::temp_dir().join("qes_ckpt_atomic_test");
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("fp.ckpt");
        save(&fp, &p).unwrap();
        save(&fp, &p).unwrap(); // overwrite goes through rename too
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "stray temp files: {:?}", leftovers);
        assert!(load(&man, &p).is_ok());
    }

    #[test]
    fn short_read_reports_context() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 13);
        let dir = std::env::temp_dir().join("qes_ckpt_trunc_test");
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("fp.ckpt");
        save(&fp, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let cut = dir.join("cut.ckpt");
        std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
        let err = load(&man, &cut);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("corrupt or truncated"), "no context in: {}", msg);
        assert!(msg.contains("cut.ckpt"), "no path in: {}", msg);
    }

    #[test]
    fn train_checkpoint_roundtrip_and_truncation() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 14);
        let q = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        let dir = std::env::temp_dir().join("qes_ckpt_train_test");
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("run.train.ckpt");
        let blob = vec![3u8, 1, 4, 1, 5, 9, 2, 6];
        save_train(&p, &q, 17, 42, "qes", &blob).unwrap();
        let ts = load_train(&man, &p).unwrap();
        assert_eq!(ts.rounds_done, 17);
        assert_eq!(ts.seed, 42);
        assert_eq!(ts.variant, "qes");
        assert_eq!(ts.opt_state, blob);
        for &li in q.lattice_indices() {
            let name = q.entries[li].name.clone();
            assert_eq!(
                q.get(&name).unwrap().data.as_i8(),
                ts.store.get(&name).unwrap().data.as_i8(),
                "{}",
                name
            );
        }
        // Truncated file errors with context, never panics.
        let bytes = std::fs::read(&p).unwrap();
        let cut = dir.join("cut.train.ckpt");
        std::fs::write(&cut, &bytes[..bytes.len() - 7]).unwrap();
        let err = load_train(&man, &cut);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("corrupt or truncated training checkpoint"), "{}", msg);
        // A param checkpoint is not a training checkpoint.
        let pp = dir.join("plain.ckpt");
        save(&q, &pp).unwrap();
        assert!(load_train(&man, &pp).is_err());
    }
}
