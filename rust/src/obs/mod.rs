//! Unified observability plane: metrics registry + trace spans.
//!
//! Zero-dependency (pure `std::sync::atomic`) telemetry shared by the
//! serving plane and the training plane so there is ONE source of truth
//! for every counter that used to live in an ad-hoc struct
//! (`SchedStats`, `MuxStats`, `RoundOutcome`, the old process-global
//! `sched::telemetry`). Three primitives:
//!
//! * [`Counter`] — monotone `AtomicU64`, relaxed ordering.
//! * [`Gauge`] — last-value or high-water `AtomicU64` (`set`/`max`).
//! * [`Histogram`] — log-linear buckets ({1,2,5}×10^e) with lock-free
//!   `observe` and p50/p90/p99 summaries; the ONE latency definition
//!   used by `/metrics`, `benches/serve.rs`, and the `stats` command.
//!
//! All process-wide metrics register in the global [`registry()`] and
//! render as Prometheus text exposition format 0.0.4 (`GET /metrics`
//! on the HTTP front end), as a JSON snapshot (the `stats` line-protocol
//! command), and as a catalog listing (`qes info`). The well-known
//! handles are pre-registered in [`Metrics`], reachable via [`m()`].
//!
//! The trace side records per-request spans `{request, conn, member,
//! phase, t_start, t_end, tokens}` covering queued → admitted →
//! prefill → decode-step → retired on the serve path and resolve /
//! rollout / update / commit / checkpoint on the train path, into a
//! bounded ring buffer ([`TRACE_CAP`]) behind a single `AtomicBool`
//! gate (`QES_TRACE=1` or `--trace-out`). Disabled, a span site costs
//! one relaxed load.
//!
//! Contract neutrality: nothing in this module feeds back into compute.
//! Wall-clock time is read only to fill observation records, so every
//! equivalence/scheduler/chaos suite passes bit-identically with
//! telemetry and tracing fully enabled.

use std::collections::VecDeque;
use std::io::Write as IoWrite;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

/// Monotonically increasing counter.
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        if n > 0 {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value / high-water gauge.
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    /// High-water update: keep the maximum ever seen.
    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn sub(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-linear-bucket histogram with lock-free observation.
///
/// Bucket upper bounds follow the {1, 2, 5} × 10^e pattern so relative
/// quantile error is bounded (~2.5×) at every scale with ~3 buckets per
/// decade. A value lands in the first bucket whose bound is >= it;
/// values above the top bound land in a dedicated overflow bucket whose
/// reported quantile is the exact maximum observed.
pub struct Histogram {
    bounds: Vec<u64>,
    /// `bounds.len() + 1` slots; the last is the overflow bucket.
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    /// Histogram over explicit bucket upper bounds (strictly increasing).
    pub fn with_bounds(bounds: Vec<u64>) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must be strictly increasing");
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds,
            counts,
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// {1, 2, 5} × 10^e for e in 0..=max_exp.
    pub fn log_linear(max_exp: u32) -> Histogram {
        let mut bounds = Vec::new();
        for e in 0..=max_exp {
            let p = 10u64.pow(e);
            bounds.extend_from_slice(&[p, 2 * p, 5 * p]);
        }
        Histogram::with_bounds(bounds)
    }

    /// The standard latency scale: 1 ns .. 50 s (5×10^10 ns).
    pub fn latency_ns() -> Histogram {
        Histogram::log_linear(10)
    }

    pub fn observe(&self, v: u64) {
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }
    /// Per-bucket counts snapshot (overflow bucket last).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Quantile estimate: the upper bound of the bucket holding the
    /// q-th observation (the exact max for the overflow bucket), so
    /// `exact_q <= quantile(q) <= smallest bound >= exact_q`.
    pub fn quantile(&self, q: f64) -> u64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max.load(Ordering::Relaxed)
                };
            }
        }
        self.max.load(Ordering::Relaxed)
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p90(&self) -> u64 {
        self.quantile(0.90)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

enum Handle {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histogram),
}

impl Handle {
    fn kind(&self) -> &'static str {
        match self {
            Handle::C(_) => "counter",
            Handle::G(_) => "gauge",
            Handle::H(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    help: String,
    labels: Vec<(String, String)>,
    h: Handle,
}

/// Metric registry: named handles plus Prometheus/JSON/catalog views.
///
/// Instantiable for tests; production code uses the process-global
/// [`registry()`]. Handles are `&'static` (leaked once at registration)
/// so hot paths touch plain atomics with no locking; the registry lock
/// is taken only to register and to render.
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

fn labels_eq(a: &[(String, String)], b: &[(&str, &str)]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.0 == y.0 && x.1 == y.1)
}

impl Registry {
    pub fn new() -> Registry {
        Registry { entries: Mutex::new(Vec::new()) }
    }

    pub fn counter(&self, name: &str, help: &str) -> &'static Counter {
        self.counter_labeled(name, help, &[])
    }

    pub fn counter_labeled(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> &'static Counter {
        let mut es = self.entries.lock().unwrap();
        for e in es.iter() {
            if e.name == name && labels_eq(&e.labels, labels) {
                if let Handle::C(c) = e.h {
                    return c;
                }
            }
        }
        let c: &'static Counter = Box::leak(Box::new(Counter::new()));
        es.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            h: Handle::C(c),
        });
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> &'static Gauge {
        self.gauge_labeled(name, help, &[])
    }

    pub fn gauge_labeled(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> &'static Gauge {
        let mut es = self.entries.lock().unwrap();
        for e in es.iter() {
            if e.name == name && labels_eq(&e.labels, labels) {
                if let Handle::G(g) = e.h {
                    return g;
                }
            }
        }
        let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
        es.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            h: Handle::G(g),
        });
        g
    }

    pub fn histogram(&self, name: &str, help: &str, hist: Histogram) -> &'static Histogram {
        self.histogram_labeled(name, help, &[], hist)
    }

    pub fn histogram_labeled(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        hist: Histogram,
    ) -> &'static Histogram {
        let mut es = self.entries.lock().unwrap();
        for e in es.iter() {
            if e.name == name && labels_eq(&e.labels, labels) {
                if let Handle::H(h) = e.h {
                    return h;
                }
            }
        }
        let h: &'static Histogram = Box::leak(Box::new(hist));
        es.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            h: Handle::H(h),
        });
        h
    }

    /// Prometheus text exposition format 0.0.4.
    pub fn render_prometheus(&self) -> String {
        let es = self.entries.lock().unwrap();
        let mut out = String::new();
        let mut seen: Vec<&str> = Vec::new();
        for first in es.iter() {
            if seen.contains(&first.name.as_str()) {
                continue;
            }
            seen.push(&first.name);
            out.push_str(&format!("# HELP {} {}\n", first.name, escape_help(&first.help)));
            out.push_str(&format!("# TYPE {} {}\n", first.name, first.h.kind()));
            for e in es.iter().filter(|e| e.name == first.name) {
                match e.h {
                    Handle::C(c) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            e.name,
                            label_block(&e.labels, None),
                            c.get()
                        ));
                    }
                    Handle::G(g) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            e.name,
                            label_block(&e.labels, None),
                            g.get()
                        ));
                    }
                    Handle::H(h) => {
                        let counts = h.bucket_counts();
                        let mut cum = 0u64;
                        for (i, &b) in h.bounds().iter().enumerate() {
                            cum += counts[i];
                            out.push_str(&format!(
                                "{}_bucket{} {}\n",
                                e.name,
                                label_block(&e.labels, Some(&b.to_string())),
                                cum
                            ));
                        }
                        cum += counts[h.bounds().len()];
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            e.name,
                            label_block(&e.labels, Some("+Inf")),
                            cum
                        ));
                        out.push_str(&format!(
                            "{}_sum{} {}\n",
                            e.name,
                            label_block(&e.labels, None),
                            h.sum()
                        ));
                        out.push_str(&format!(
                            "{}_count{} {}\n",
                            e.name,
                            label_block(&e.labels, None),
                            h.count()
                        ));
                    }
                }
            }
        }
        out
    }

    /// JSON snapshot for the line-protocol `stats` command. Counters and
    /// gauges map to their value; histograms to {count, sum, p50, p90, p99}.
    pub fn snapshot_json(&self) -> Json {
        let es = self.entries.lock().unwrap();
        let mut m = std::collections::BTreeMap::new();
        for e in es.iter() {
            let key = if e.labels.is_empty() {
                e.name.clone()
            } else {
                format!("{}{}", e.name, label_block(&e.labels, None))
            };
            let v = match e.h {
                Handle::C(c) => Json::Num(c.get() as f64),
                Handle::G(g) => Json::Num(g.get() as f64),
                Handle::H(h) => {
                    let mut o = std::collections::BTreeMap::new();
                    o.insert("count".to_string(), Json::Num(h.count() as f64));
                    o.insert("sum".to_string(), Json::Num(h.sum() as f64));
                    o.insert("p50".to_string(), Json::Num(h.p50() as f64));
                    o.insert("p90".to_string(), Json::Num(h.p90() as f64));
                    o.insert("p99".to_string(), Json::Num(h.p99() as f64));
                    Json::Obj(o)
                }
            };
            m.insert(key, v);
        }
        Json::Obj(m)
    }

    /// (name, kind, help) per metric family, registration order.
    pub fn catalog(&self) -> Vec<(String, &'static str, String)> {
        let es = self.entries.lock().unwrap();
        let mut out: Vec<(String, &'static str, String)> = Vec::new();
        for e in es.iter() {
            if !out.iter().any(|(n, _, _)| n == &e.name) {
                out.push((e.name.clone(), e.h.kind(), e.help.clone()));
            }
        }
        out
    }
}

/// Escape a label value per the exposition format: `\` `"` and newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// HELP text escaping: `\` and newline (quotes are legal there).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// `{k="v",...}` (with optional trailing `le`), or "" when empty.
fn label_block(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{}=\"{}\"", k, escape_label(v))).collect();
    if let Some(b) = le {
        parts.push(format!("le=\"{}\"", b));
    }
    format!("{{{}}}", parts.join(","))
}

/// The process-global registry backing `/metrics`, `stats`, and `qes info`.
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

// ---------------------------------------------------------------------------
// Well-known metrics
// ---------------------------------------------------------------------------

/// Every built-in metric, pre-registered in the global registry.
/// Centralizing the handles keeps hot paths to a single `m()` call
/// (OnceLock fast path: one atomic load) + plain atomic ops.
pub struct Metrics {
    // scheduler
    pub sched_steps: &'static Counter,
    pub sched_prefill_rows: &'static Counter,
    pub sched_decode_rows: &'static Counter,
    pub sched_retired: &'static Counter,
    pub sched_tokens: &'static Counter,
    pub sched_resolves: &'static Counter,
    pub sched_slots: &'static Gauge,
    pub sched_max_live: &'static Gauge,
    // paged KV arena
    pub kv_pages_high_water: &'static Gauge,
    pub kv_prefix_hits: &'static Counter,
    pub kv_prefix_misses: &'static Counter,
    pub kv_cow_forks: &'static Counter,
    // serving plane (stdin serve_loop and the connection mux share these)
    pub serve_conns: &'static Counter,
    pub serve_served: &'static Counter,
    pub serve_errors: &'static Counter,
    pub serve_shed: &'static Counter,
    pub serve_cancelled: &'static Counter,
    pub serve_orphaned: &'static Counter,
    pub serve_write_failed: &'static Counter,
    pub serve_active_conns: &'static Gauge,
    pub serve_inflight: &'static Gauge,
    pub serve_conn_queue_depth: &'static Histogram,
    pub serve_latency_ns: &'static Histogram,
    // worker pool
    pub pool_retries: &'static Counter,
    pub pool_redispatches: &'static Counter,
    pub pool_respawns: &'static Counter,
    pub pool_failed_members: &'static Counter,
    // finetune loop
    pub train_rounds: &'static Counter,
    pub train_rollout_ns: &'static Histogram,
    pub train_update_ns: &'static Histogram,
}

/// Built-in metric handles (registered on first use).
pub fn m() -> &'static Metrics {
    static M: OnceLock<Metrics> = OnceLock::new();
    M.get_or_init(|| {
        let r = registry();
        Metrics {
            sched_steps: r.counter("qes_sched_steps_total", "Scheduler steps executed"),
            sched_prefill_rows: r
                .counter("qes_sched_prefill_rows_total", "Prompt rows prefilled"),
            sched_decode_rows: r
                .counter("qes_sched_decode_rows_total", "Decode rows executed (live seqs x steps)"),
            sched_retired: r.counter("qes_sched_retired_total", "Requests retired (EOS or budget)"),
            sched_tokens: r.counter("qes_sched_tokens_total", "Tokens emitted by decode"),
            sched_resolves: r
                .counter("qes_sched_resolves_total", "Weight resolves (scheduler constructions)"),
            sched_slots: r.gauge("qes_sched_slots", "Decode slots of the latest scheduler"),
            sched_max_live: r
                .gauge("qes_sched_max_live", "High-water concurrent live sequences"),
            kv_pages_high_water: r
                .gauge("qes_kv_pages_high_water", "High-water KV pages allocated"),
            kv_prefix_hits: r
                .counter("qes_kv_prefix_hits_total", "Prefix-cache hits (shared-prefix adoptions)"),
            kv_prefix_misses: r.counter("qes_kv_prefix_misses_total", "Prefix-cache misses"),
            kv_cow_forks: r
                .counter("qes_kv_cow_forks_total", "Copy-on-write page forks on divergence"),
            serve_conns: r.counter("qes_serve_conns_total", "Connections accepted"),
            serve_served: r.counter("qes_serve_served_total", "Responses delivered"),
            serve_errors: r.counter("qes_serve_errors_total", "Request errors returned"),
            serve_shed: r.counter("qes_serve_shed_total", "Requests shed by admission control"),
            serve_cancelled: r
                .counter("qes_serve_cancelled_total", "Queued requests cancelled at teardown"),
            serve_orphaned: r
                .counter("qes_serve_orphaned_total", "Finished outputs dropped (conn gone)"),
            serve_write_failed: r
                .counter("qes_serve_write_failed_total", "Connections torn down on failed write"),
            serve_active_conns: r.gauge("qes_serve_active_conns", "Currently open connections"),
            serve_inflight: r
                .gauge("qes_serve_inflight", "Requests in flight (waiting + live)"),
            serve_conn_queue_depth: r.histogram(
                "qes_serve_conn_queue_depth",
                "Per-connection outstanding-request depth at admission",
                Histogram::log_linear(4),
            ),
            serve_latency_ns: r.histogram(
                "qes_serve_latency_ns",
                "Request latency submit -> response delivered (ns)",
                Histogram::latency_ns(),
            ),
            pool_retries: r.counter("qes_pool_retries_total", "Member evals retried in place"),
            pool_redispatches: r
                .counter("qes_pool_redispatches_total", "Member evals redispatched to peers"),
            pool_respawns: r.counter("qes_pool_respawns_total", "Workers respawned after death"),
            pool_failed_members: r
                .counter("qes_pool_failed_members_total", "Members failed after all retries"),
            train_rounds: r.counter("qes_train_rounds_total", "Finetune generations completed"),
            train_rollout_ns: r.histogram(
                "qes_train_rollout_ns",
                "Rollout (population eval) wall time per generation (ns)",
                Histogram::latency_ns(),
            ),
            train_update_ns: r.histogram(
                "qes_train_update_ns",
                "Optimizer update wall time per generation (ns)",
                Histogram::latency_ns(),
            ),
        }
    })
}

// ---------------------------------------------------------------------------
// KV telemetry reader (replaces the old destructive sched::telemetry::take)
// ---------------------------------------------------------------------------

/// Non-destructive per-interval reader over the KV counters.
///
/// The old `sched::telemetry::take()` swapped the process globals to
/// zero, so two readers (serve summary + finetune CSV in one process)
/// silently stole each other's counts. A `KvDelta` snapshots the
/// registry counters at construction and [`KvDelta::delta`] returns
/// what accrued since the previous call — the globals are never reset,
/// and any number of independent readers coexist.
pub struct KvDelta {
    hits: u64,
    misses: u64,
    forks: u64,
}

impl KvDelta {
    pub fn new() -> KvDelta {
        let mm = m();
        KvDelta {
            hits: mm.kv_prefix_hits.get(),
            misses: mm.kv_prefix_misses.get(),
            forks: mm.kv_cow_forks.get(),
        }
    }

    /// `(pages_high_water, prefix_hits, prefix_misses, cow_forks)` —
    /// pages as the process-lifetime high-water gauge, the rest as
    /// deltas since the previous `delta()` (or construction).
    pub fn delta(&mut self) -> (u64, u64, u64, u64) {
        let mm = m();
        let (h, mi, f) =
            (mm.kv_prefix_hits.get(), mm.kv_prefix_misses.get(), mm.kv_cow_forks.get());
        let out = (mm.kv_pages_high_water.get(), h - self.hits, mi - self.misses, f - self.forks);
        self.hits = h;
        self.misses = mi;
        self.forks = f;
        out
    }
}

// ---------------------------------------------------------------------------
// Trace spans
// ---------------------------------------------------------------------------

/// Ring-buffer capacity: oldest spans are dropped (and counted) beyond this.
pub const TRACE_CAP: usize = 1 << 16;

/// Lifecycle phase a span describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    // serve path, per request
    Queued,
    Admitted,
    Retired,
    // serve path, per scheduler step (batch-wide, request = step index)
    Prefill,
    DecodeStep,
    // train path
    Resolve,
    Rollout,
    Update,
    Commit,
    Checkpoint,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Queued => "queued",
            Phase::Admitted => "admitted",
            Phase::Retired => "retired",
            Phase::Prefill => "prefill",
            Phase::DecodeStep => "decode_step",
            Phase::Resolve => "resolve",
            Phase::Rollout => "rollout",
            Phase::Update => "update",
            Phase::Commit => "commit",
            Phase::Checkpoint => "checkpoint",
        }
    }
}

/// One trace event. `request` is the scheduler ticket (serve phases),
/// the step index (batch phases), or the generation (train phases);
/// `conn`/`member` are `None` where not applicable; `tokens` counts
/// rows or emitted tokens depending on phase.
#[derive(Debug, Clone)]
pub struct Span {
    pub request: u64,
    pub conn: Option<u64>,
    pub member: Option<u64>,
    pub phase: Phase,
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    pub tokens: u64,
}

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE_INIT: Once = Once::new();

fn trace_env_default() -> bool {
    std::env::var("QES_TRACE")
        .map(|v| matches!(v.trim(), "1" | "on" | "true"))
        .unwrap_or(false)
}

/// Is span recording on? First call seeds the gate from `QES_TRACE`;
/// after that it is one relaxed load — the full cost at a disabled site.
pub fn trace_enabled() -> bool {
    TRACE_INIT.call_once(|| TRACE_ON.store(trace_env_default(), Ordering::Relaxed));
    TRACE_ON.load(Ordering::Relaxed)
}

/// Force the gate (e.g. `--trace-out`, benches, tests).
pub fn set_trace(on: bool) {
    TRACE_INIT.call_once(|| ()); // claim init so env can't clobber us later
    TRACE_ON.store(on, Ordering::Relaxed);
}

/// Restore the gate to its `QES_TRACE` environment default.
pub fn reset_trace_from_env() {
    set_trace(trace_env_default());
}

/// Monotonic nanoseconds since the first observability call in this
/// process. Only ever written into observation records.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

struct Ring {
    buf: VecDeque<Span>,
    dropped: u64,
}

fn ring() -> &'static Mutex<Ring> {
    static R: OnceLock<Mutex<Ring>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Ring { buf: VecDeque::new(), dropped: 0 }))
}

/// Append a span to the ring (no-op while tracing is off).
pub fn record_span(s: Span) {
    if !trace_enabled() {
        return;
    }
    let mut r = ring().lock().unwrap();
    if r.buf.len() >= TRACE_CAP {
        r.buf.pop_front();
        r.dropped += 1;
    }
    r.buf.push_back(s);
}

/// Take every buffered span, plus how many were dropped to the cap.
pub fn drain_spans() -> (Vec<Span>, u64) {
    let mut r = ring().lock().unwrap();
    let spans = r.buf.drain(..).collect();
    let dropped = std::mem::take(&mut r.dropped);
    (spans, dropped)
}

fn span_json(s: &Span) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("request".to_string(), Json::Num(s.request as f64));
    o.insert("conn".to_string(), s.conn.map(|c| Json::Num(c as f64)).unwrap_or(Json::Null));
    o.insert("member".to_string(), s.member.map(|m| Json::Num(m as f64)).unwrap_or(Json::Null));
    o.insert("phase".to_string(), Json::Str(s.phase.name().to_string()));
    o.insert("t_start_ns".to_string(), Json::Num(s.t_start_ns as f64));
    o.insert("t_end_ns".to_string(), Json::Num(s.t_end_ns as f64));
    o.insert("tokens".to_string(), Json::Num(s.tokens as f64));
    Json::Obj(o)
}

/// Drain the ring to a JSONL file (one span object per line); returns
/// the number of spans written.
pub fn dump_trace_jsonl(path: &std::path::Path) -> std::io::Result<usize> {
    let (spans, dropped) = drain_spans();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for s in &spans {
        writeln!(f, "{}", span_json(s).to_string_compact())?;
    }
    if dropped > 0 {
        eprintln!("[obs] trace ring dropped {} spans (cap {})", dropped, TRACE_CAP);
    }
    f.flush()?;
    Ok(spans.len())
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// SplitMix64 — deterministic value streams for property tests.
    fn splitmix(state: &mut u64) -> u64 {
        *state = state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = *state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    #[test]
    fn histogram_quantiles_bound_exact_reference() {
        // Property: for random value sets across many scales,
        //   exact_q <= hist.quantile(q) <= smallest bound >= exact_q
        // and bucket counts are non-negative with cumulative sums
        // monotone and ending at the total count.
        let mut s = 0x1234_5678u64;
        for trial in 0..20u64 {
            let h = Histogram::latency_ns();
            let n = 1 + (splitmix(&mut s) % 2000) as usize;
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                // span many decades: 1 .. ~1e10
                let e = splitmix(&mut s) % 10;
                let v = 1 + splitmix(&mut s) % 10u64.pow(e as u32 + 1);
                vals.push(v);
                h.observe(v);
            }
            vals.sort_unstable();
            assert_eq!(h.count(), n as u64);
            assert_eq!(h.sum(), vals.iter().sum::<u64>());

            // cumulative monotonicity
            let counts = h.bucket_counts();
            assert_eq!(counts.iter().sum::<u64>(), n as u64);
            let mut cum = 0u64;
            for c in &counts {
                let prev = cum;
                cum += c;
                assert!(cum >= prev);
            }

            for &q in &[0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = vals[rank - 1];
                let got = h.quantile(q);
                let ceil_bound = h
                    .bounds()
                    .iter()
                    .copied()
                    .find(|&b| b >= exact)
                    .unwrap_or(*vals.last().unwrap());
                assert!(
                    got >= exact && got <= ceil_bound,
                    "trial {} q={}: exact {} got {} ceil {}",
                    trial,
                    q,
                    exact,
                    got,
                    ceil_bound
                );
            }
        }
    }

    #[test]
    fn histogram_overflow_bucket_reports_exact_max() {
        let h = Histogram::with_bounds(vec![10, 100]);
        h.observe(5);
        h.observe(12345); // above top bound -> overflow bucket
        assert_eq!(h.quantile(1.0), 12345);
        assert_eq!(h.quantile(0.5), 10);
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let r = Registry::new();
        let c = r.counter("t_requests_total", "Requests seen");
        c.add(7);
        let g = r.gauge_labeled("t_depth", "Queue depth", &[("conn", "a\"b\\c\nd")]);
        g.set(3);
        let h = r.histogram("t_lat_ns", "Latency", Histogram::with_bounds(vec![10, 100]));
        h.observe(5);
        h.observe(50);
        h.observe(500);

        let text = r.render_prometheus();
        // every family has exactly one HELP and one TYPE line, TYPE names a
        // valid metric type, and every sample line belongs to a family
        for fam in ["t_requests_total", "t_depth", "t_lat_ns"] {
            assert_eq!(
                text.lines().filter(|l| *l == format!("# HELP {} {}", fam, match fam {
                    "t_requests_total" => "Requests seen",
                    "t_depth" => "Queue depth",
                    _ => "Latency",
                })).count(),
                1,
                "{}",
                text
            );
            let ty: Vec<&str> = text
                .lines()
                .filter(|l| l.starts_with(&format!("# TYPE {} ", fam)))
                .collect();
            assert_eq!(ty.len(), 1, "{}", text);
            let kind = ty[0].rsplit(' ').next().unwrap();
            assert!(matches!(kind, "counter" | "gauge" | "histogram"), "{}", ty[0]);
        }
        assert!(text.contains("t_requests_total 7\n"), "{}", text);
        // label escaping: backslash, quote, newline
        assert!(text.contains(r#"t_depth{conn="a\"b\\c\nd"} 3"#), "{}", text);
        // histogram series: cumulative buckets, +Inf == count, sum/count lines
        assert!(text.contains("t_lat_ns_bucket{le=\"10\"} 1\n"), "{}", text);
        assert!(text.contains("t_lat_ns_bucket{le=\"100\"} 2\n"), "{}", text);
        assert!(text.contains("t_lat_ns_bucket{le=\"+Inf\"} 3\n"), "{}", text);
        assert!(text.contains("t_lat_ns_sum 555\n"), "{}", text);
        assert!(text.contains("t_lat_ns_count 3\n"), "{}", text);
        // no sample line precedes its family's TYPE line
        let type_pos = text.find("# TYPE t_lat_ns ").unwrap();
        let sample_pos = text.find("t_lat_ns_bucket").unwrap();
        assert!(type_pos < sample_pos);
        // registration is idempotent: same (name, labels) -> same handle
        let c2 = r.counter("t_requests_total", "Requests seen");
        c2.inc();
        assert_eq!(c.get(), 8);
        assert_eq!(
            r.render_prometheus().lines().filter(|l| l.starts_with("# TYPE t_requests")).count(),
            1
        );
    }

    #[test]
    fn snapshot_json_and_catalog_cover_all_families() {
        let r = Registry::new();
        r.counter("s_a_total", "A").add(2);
        let h = r.histogram("s_b_ns", "B", Histogram::with_bounds(vec![10]));
        h.observe(4);
        let j = r.snapshot_json();
        assert_eq!(j.get("s_a_total").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(
            j.get("s_b_ns").and_then(|v| v.get("count")).and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert_eq!(j.get("s_b_ns").and_then(|v| v.get("p50")).and_then(|v| v.as_f64()), Some(10.0));
        let cat = r.catalog();
        assert_eq!(cat.len(), 2);
        assert_eq!(cat[0], ("s_a_total".to_string(), "counter", "A".to_string()));
    }

    #[test]
    fn trace_ring_bounds_and_drains() {
        // local exercise of gate + ring; use set_trace to avoid QES_TRACE
        set_trace(true);
        drain_spans(); // start clean (other tests share the global ring)
        for i in 0..(TRACE_CAP + 10) as u64 {
            record_span(Span {
                request: i,
                conn: Some(0xFFFF_FF00),
                member: None,
                phase: Phase::Queued,
                t_start_ns: i,
                t_end_ns: i + 1,
                tokens: 0,
            });
        }
        let (spans, dropped) = drain_spans();
        let mine: Vec<&Span> = spans.iter().filter(|s| s.conn == Some(0xFFFF_FF00)).collect();
        assert!(mine.len() <= TRACE_CAP);
        assert!(dropped >= 10, "oldest spans dropped and counted, got {}", dropped);
        // oldest were evicted first: the LAST span must have survived
        assert_eq!(mine.last().unwrap().request, (TRACE_CAP + 10) as u64 - 1);
        set_trace(false);
        record_span(Span {
            request: 0,
            conn: Some(0xFFFF_FF00),
            member: None,
            phase: Phase::Queued,
            t_start_ns: 0,
            t_end_ns: 0,
            tokens: 0,
        });
        let (spans, _) = drain_spans();
        assert!(
            !spans.iter().any(|s| s.conn == Some(0xFFFF_FF00)),
            "disabled gate records nothing"
        );
        reset_trace_from_env();
    }

    #[test]
    fn kv_delta_is_non_destructive_across_readers() {
        let mm = m();
        let mut a = KvDelta::new();
        let mut b = KvDelta::new();
        mm.kv_prefix_hits.add(5);
        mm.kv_cow_forks.add(2);
        let (_, ha, _, fa) = a.delta();
        assert_eq!((ha, fa), (5, 2));
        // reader B sees the SAME counts — nothing was stolen
        let (_, hb, _, fb) = b.delta();
        assert_eq!((hb, fb), (5, 2));
        // and each reader's second read is a clean delta
        mm.kv_prefix_hits.add(1);
        assert_eq!(a.delta().1, 1);
        assert_eq!(b.delta().1, 1);
    }
}
