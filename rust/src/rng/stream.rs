//! Per-member noise streams and the discrete perturbation of Eq. (3).
//!
//! A generation `t` has a single 64-bit `gen_seed`. Member `i` of the
//! population derives `member_seed(gen_seed, i)`; its `NoiseStream` then
//! yields one perturbation value per lattice parameter element, in a fixed
//! global order. Antithetic pairs share a seed and differ only by `sign`.
//!
//! The discrete perturbation (paper Eq. 3) stochastically rounds the scaled
//! Gaussian: delta = floor(sigma * eps) + Bernoulli(frac(sigma * eps)).
//! Both the Gaussian and the Bernoulli draw come from the same stream, so
//! replaying the seed reproduces delta exactly — this is what makes
//! Algorithm 2's rematerialization possible.

use super::SplitMix64;

/// Mix a generation seed and member index into an independent stream seed.
#[inline]
pub fn member_seed(gen_seed: u64, member: u64) -> u64 {
    // One SplitMix64 scramble round over the combination; avoids accidental
    // stream overlap between adjacent members.
    let mut z = gen_seed ^ member.wrapping_mul(0xff51_afd7_ed55_8ccd);
    z = (z ^ (z >> 33)).wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    z ^ (z >> 33)
}

/// Raw uniforms consumed per element by the *delta* view of the stream
/// (`next_delta` / `next_pair_deltas`): one Gaussian (2 draws via
/// Box–Muller) plus one shared stochastic-rounding uniform.
pub const DELTA_DRAWS_PER_ELEM: u64 = 3;

/// Raw uniforms consumed per element by the *continuous* view
/// (`next_scaled_gauss`): the Box–Muller pair only.
pub const GAUSS_DRAWS_PER_ELEM: u64 = 2;

/// A deterministic stream of discrete perturbation values.
///
/// Every element consumes a FIXED number of underlying uniforms
/// ([`DELTA_DRAWS_PER_ELEM`] for the delta view, [`GAUSS_DRAWS_PER_ELEM`]
/// for the continuous view), and `SplitMix64` advances its state by a
/// constant per draw — so the stream is *counter-addressable*: [`at`]
/// materializes the stream positioned at any element index in O(1),
/// which is what lets chunk-parallel kernels regenerate arbitrary windows
/// of the noise independently, bit-identical to a sequential walk.
///
/// A stream instance must stick to ONE view (delta or continuous): the two
/// views consume different draw counts per element, so mixing them
/// desynchronizes element indexing.
pub struct NoiseStream {
    rng: SplitMix64,
    sigma: f32,
    sign: f32,
}

impl NoiseStream {
    /// `sign` is +1.0 / -1.0 for the two halves of an antithetic pair.
    pub fn new(seed: u64, sigma: f32, sign: f32) -> Self {
        NoiseStream { rng: SplitMix64::new(seed), sigma, sign }
    }

    /// The delta-view stream positioned at element `elem` in O(1):
    /// equivalent to `new(..)` followed by `elem` calls of `next_delta`
    /// (or `next_pair_deltas`), at constant cost.
    pub fn at(seed: u64, sigma: f32, sign: f32, elem: usize) -> Self {
        let mut rng = SplitMix64::new(seed);
        rng.jump(DELTA_DRAWS_PER_ELEM.wrapping_mul(elem as u64));
        NoiseStream { rng, sigma, sign }
    }

    /// The continuous-view stream positioned at element `elem` in O(1):
    /// equivalent to `new(..)` followed by `elem` calls of
    /// `next_scaled_gauss`.
    pub fn at_gauss(seed: u64, sigma: f32, sign: f32, elem: usize) -> Self {
        let mut rng = SplitMix64::new(seed);
        rng.jump(GAUSS_DRAWS_PER_ELEM.wrapping_mul(elem as u64));
        NoiseStream { rng, sigma, sign }
    }

    /// The continuous scaled-Gaussian value sigma * eps (pre-rounding).
    /// Consumes exactly the same stream positions as `next_delta`'s
    /// Gaussian, so the two views stay aligned element-for-element.
    #[inline]
    pub fn next_scaled_gauss(&mut self) -> f32 {
        self.sign * self.sigma * self.rng.normal()
    }

    /// Both halves of the antithetic pair's discrete perturbation at this
    /// element, sharing one Gaussian and one Bernoulli draw (3 uniforms).
    /// This is the primitive: `next_delta` and the optimizer's paired
    /// gradient accumulation both consume the stream identically, which is
    /// what keeps rollout-time and replay-time perturbations bit-equal.
    #[inline]
    pub fn next_pair_deltas(&mut self) -> (i32, i32) {
        let z = self.rng.normal();
        let xp = self.sigma * z;
        let xm = -xp;
        let u = self.rng.uniform01();
        let fp = xp.floor();
        let fm = xm.floor();
        let dp = fp as i32 + (u < (xp - fp)) as i32;
        let dm = fm as i32 + (u < (xm - fm)) as i32;
        (dp, dm)
    }

    /// The discrete perturbation of Eq. (3):
    /// delta = floor(s*eps) + Bernoulli(s*eps - floor(s*eps)).
    ///
    /// Returns values in {..., -1, 0, 1, ...}; for sigma << 1 almost all
    /// mass is on {-1, 0, 1}.
    #[inline]
    pub fn next_delta(&mut self) -> i32 {
        let (dp, dm) = self.next_pair_deltas();
        if self.sign >= 0.0 {
            dp
        } else {
            dm
        }
    }

    /// Fill `out` with one delta per element (the hot path; kept free of
    /// bounds checks in the inner loop).
    pub fn fill_deltas(&mut self, out: &mut [i32]) {
        for slot in out.iter_mut() {
            *slot = self.next_delta();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_exact() {
        let mut a = NoiseStream::new(77, 0.5, 1.0);
        let first: Vec<i32> = (0..10_000).map(|_| a.next_delta()).collect();
        let mut b = NoiseStream::new(77, 0.5, 1.0);
        let second: Vec<i32> = (0..10_000).map(|_| b.next_delta()).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn at_matches_sequential_delta_stream() {
        let (seed, sigma) = (0x5eed, 0.4f32);
        let mut seq = NoiseStream::new(seed, sigma, 1.0);
        let reference: Vec<(i32, i32)> = (0..5000).map(|_| seq.next_pair_deltas()).collect();
        for &start in &[0usize, 1, 63, 64, 1000, 4095, 4999] {
            let mut s = NoiseStream::at(seed, sigma, 1.0, start);
            for (j, want) in reference.iter().enumerate().skip(start).take(128) {
                assert_eq!(s.next_pair_deltas(), *want, "start={} j={}", start, j);
            }
        }
    }

    #[test]
    fn at_matches_sequential_single_deltas() {
        let mut seq = NoiseStream::new(99, 0.7, -1.0);
        let reference: Vec<i32> = (0..2000).map(|_| seq.next_delta()).collect();
        for &start in &[0usize, 17, 512, 1999] {
            let mut s = NoiseStream::at(99, 0.7, -1.0, start);
            for (j, &want) in reference.iter().enumerate().skip(start).take(64) {
                assert_eq!(s.next_delta(), want, "start={} j={}", start, j);
            }
        }
    }

    #[test]
    fn at_gauss_matches_sequential_gauss_stream() {
        let mut seq = NoiseStream::new(7, 0.3, 1.0);
        let reference: Vec<f32> = (0..2000).map(|_| seq.next_scaled_gauss()).collect();
        for &start in &[0usize, 5, 100, 1536] {
            let mut s = NoiseStream::at_gauss(7, 0.3, 1.0, start);
            for (j, &want) in reference.iter().enumerate().skip(start).take(64) {
                let got = s.next_scaled_gauss();
                assert_eq!(got.to_bits(), want.to_bits(), "start={} j={}", start, j);
            }
        }
    }

    #[test]
    fn antithetic_pairs_mirror_gaussian() {
        let mut p = NoiseStream::new(5, 1.0, 1.0);
        let mut m = NoiseStream::new(5, 1.0, -1.0);
        for _ in 0..1000 {
            let a = p.next_scaled_gauss();
            let b = m.next_scaled_gauss();
            assert!((a + b).abs() < 1e-6);
        }
    }

    #[test]
    fn delta_is_unbiased_estimator_of_scaled_gauss() {
        // E[delta | x] = x, so the empirical mean of deltas approaches the
        // empirical mean of the underlying scaled gaussians (~0).
        let mut s = NoiseStream::new(123, 0.3, 1.0);
        let n = 200_000;
        let mut sum = 0i64;
        for _ in 0..n {
            sum += s.next_delta() as i64;
        }
        let mean = sum as f64 / n as f64;
        assert!(mean.abs() < 0.01, "mean={}", mean);
    }

    #[test]
    fn small_sigma_mostly_zero_deltas() {
        // The stagnation regime: sigma small => deltas almost surely 0/±1.
        let mut s = NoiseStream::new(9, 0.01, 1.0);
        let mut hist = [0usize; 3];
        for _ in 0..100_000 {
            let d = s.next_delta();
            assert!(d.abs() <= 1, "unexpectedly large delta {}", d);
            hist[(d + 1) as usize] += 1;
        }
        assert!(hist[1] > 95_000); // overwhelmingly zero
        assert!(hist[0] > 0 && hist[2] > 0); // but not degenerate
    }

    #[test]
    fn member_seeds_distinct() {
        let g = 0xabcdef;
        let seeds: Vec<u64> = (0..1000).map(|i| member_seed(g, i)).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
    }

    #[test]
    fn stochastic_round_matches_expectation() {
        // For x = 0.3: P(delta=1) = 0.3, P(delta=0) = 0.7. Build a stream
        // with sigma chosen so scaled gauss is irrelevant; test bernoulli
        // fraction directly through next_delta's distribution around a
        // known constant by Monte-Carlo over many streams.
        let mut ones = 0usize;
        let n = 50_000;
        for seed in 0..n {
            let mut s = NoiseStream::new(seed as u64, 1.0, 1.0);
            // Consume one delta; its law is symmetric. Just sanity: finite.
            let _ = s.next_delta();
            let mut r = SplitMix64::new(seed as u64 ^ 0x5555);
            if r.bernoulli(0.3) {
                ones += 1;
            }
        }
        let rate = ones as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02);
    }
}
