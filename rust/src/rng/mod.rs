//! Deterministic RNG substrate.
//!
//! Everything QES does — perturbation generation (Eq. 3), fitness rollout
//! sampling, and the stateless seed replay (Algorithm 2) — must be exactly
//! reproducible from a 64-bit seed. A perturbation is never stored; it is
//! *re-generated* from its seed both at rollout time and again at update /
//! replay time, so the generator here is the true "optimizer state" of the
//! stateless variant.
//!
//! `SplitMix64` is the base generator (tiny state, passes BigCrush for this
//! use, and trivially portable). `NoiseStream` derives a per-(generation,
//! member) stream via seed mixing, giving independence across members
//! without any coordination.

pub mod stream;

pub use stream::{member_seed, NoiseStream};

/// SplitMix64's Weyl-sequence increment. The state after `n` draws from
/// seed `s` is exactly `s + n * GAMMA (mod 2^64)` — the property that makes
/// every stream position O(1)-addressable (see [`SplitMix64::jump`]).
pub const GAMMA: u64 = 0x9e37_79b9_7f4a_7c15;

/// SplitMix64: 64-bit state, one multiply-xorshift round per output.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Advance the stream by `n_draws` outputs in O(1): because the state
    /// is a pure Weyl sequence (`state += GAMMA` per draw) with the mixing
    /// applied on output only, skipping ahead is a single multiply-add.
    /// `jump(n)` followed by a draw produces exactly the `n+1`-th value of
    /// the sequential stream — the counter-addressable property all
    /// chunk-parallel kernels rely on.
    #[inline]
    pub fn jump(&mut self, n_draws: u64) {
        self.state = self.state.wrapping_add(GAMMA.wrapping_mul(n_draws));
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 24 bits of mantissa (exact f32 grid).
    #[inline]
    pub fn uniform01(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one sample; the pair's second half
    /// is discarded to keep the per-element stream position predictable).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        // u1 in (0,1] to keep ln() finite.
        let u1 = 1.0 - self.uniform01();
        let u2 = self.uniform01();
        let r = (-2.0 * (u1 as f64).ln()).sqrt() as f32;
        let theta = 2.0 * std::f32::consts::PI * u2;
        r * theta.cos()
    }

    /// Gumbel(0,1) sample (for softmax sampling: argmax(logits + tau * g)).
    #[inline]
    pub fn gumbel(&mut self) -> f32 {
        let u = (1.0 - self.uniform01()).max(1e-12);
        -(-(u as f64).ln()).ln() as f32
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform01() < p
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is < 2^-40 for the n used here (task sampling).
        self.next_u64() % n
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn jump_matches_sequential_draws() {
        for &(seed, skip) in &[(0u64, 1u64), (42, 7), (0xdead_beef, 1000), (u64::MAX, 123_456)] {
            let mut seq = SplitMix64::new(seed);
            for _ in 0..skip {
                seq.next_u64();
            }
            let mut jumped = SplitMix64::new(seed);
            jumped.jump(skip);
            for _ in 0..100 {
                assert_eq!(seq.next_u64(), jumped.next_u64(), "seed={} skip={}", seed, skip);
            }
        }
    }

    #[test]
    fn jump_composes_additively() {
        let mut a = SplitMix64::new(9);
        a.jump(1000);
        let mut b = SplitMix64::new(9);
        b.jump(400);
        b.jump(600);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = SplitMix64::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform01();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={}", mean);
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={}", mean);
        assert!((var - 1.0).abs() < 0.02, "var={}", var);
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = SplitMix64::new(9);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={}", rate);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = SplitMix64::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(v, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn gumbel_finite() {
        let mut r = SplitMix64::new(13);
        for _ in 0..10_000 {
            assert!(r.gumbel().is_finite());
        }
    }
}
