//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and this runtime. It records, per artifact, the exact positional input
//! and output specs; and per (size, format), the flat parameter layout the
//! `ParamStore` mirrors.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub s_prompt: usize,
    pub t_dec: usize,
    pub s_train: usize,
    pub b_gen: usize,
    pub b_train: usize,
    pub lattice_params: usize,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String, // "i32" | "f32" | "i8"
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
    pub kind: String,
    pub init: Option<(String, f32)>,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub config: String,
    pub format: String, // "wq" | "w8a8" | "fp"
    pub func: String,   // "gen" | "loss" | "cls" | "grad"
    pub data_inputs: Vec<IoSpec>,
    pub n_param_inputs: usize,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ModelConfig>,
    params: BTreeMap<(String, String), Vec<ParamMeta>>,
    artifacts: Vec<ArtifactMeta>,
}

fn shape_of(j: &Json) -> anyhow::Result<Vec<usize>> {
    Ok(j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().unwrap_or(0))
        .collect())
}

fn io_spec(j: &Json) -> anyhow::Result<IoSpec> {
    Ok(IoSpec {
        name: j
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string(),
        dtype: j
            .get("dtype")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("io spec missing dtype"))?
            .to_string(),
        shape: shape_of(j.get("shape").ok_or_else(|| anyhow::anyhow!("io missing shape"))?)?,
    })
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(path: P) -> anyhow::Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {:?} (run `make artifacts`): {}", path, e))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {}", e))?;

        let mut configs = BTreeMap::new();
        for (name, c) in j
            .get("configs")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing configs"))?
        {
            let g = |k: &str| -> anyhow::Result<usize> {
                c.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow::anyhow!("config {} missing {}", name, k))
            };
            configs.insert(
                name.clone(),
                ModelConfig {
                    vocab: g("vocab")?,
                    d_model: g("d_model")?,
                    n_layers: g("n_layers")?,
                    n_heads: g("n_heads")?,
                    d_ff: g("d_ff")?,
                    s_prompt: g("s_prompt")?,
                    t_dec: g("t_dec")?,
                    s_train: g("s_train")?,
                    b_gen: g("b_gen")?,
                    b_train: g("b_train")?,
                    lattice_params: g("lattice_params")?,
                },
            );
        }

        let mut params = BTreeMap::new();
        for (size, fmts) in j
            .get("params")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing params"))?
        {
            for (fmt, list) in fmts
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("params[{}] not an object", size))?
            {
                let mut metas = Vec::new();
                for p in list.as_arr().unwrap_or(&[]) {
                    let init = p.get("init").and_then(|v| v.as_arr()).map(|arr| {
                        let dist = arr
                            .first()
                            .and_then(|d| d.as_str())
                            .unwrap_or("zeros")
                            .to_string();
                        let std = arr.get(1).and_then(|s| s.as_f64()).unwrap_or(0.0) as f32;
                        (dist, std)
                    });
                    metas.push(ParamMeta {
                        name: p
                            .get("name")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| anyhow::anyhow!("param missing name"))?
                            .to_string(),
                        dtype: p
                            .get("dtype")
                            .and_then(|v| v.as_str())
                            .unwrap_or("f32")
                            .to_string(),
                        shape: shape_of(
                            p.get("shape").ok_or_else(|| anyhow::anyhow!("param missing shape"))?,
                        )?,
                        kind: p
                            .get("kind")
                            .and_then(|v| v.as_str())
                            .unwrap_or("fp")
                            .to_string(),
                        init,
                    });
                }
                params.insert((size.clone(), fmt.clone()), metas);
            }
        }

        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
        {
            let data_inputs = a
                .get("data_inputs")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(io_spec)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(io_spec)
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                file: a
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("artifact missing file"))?
                    .to_string(),
                config: a.get("config").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                format: a.get("format").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                func: a.get("fn").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                data_inputs,
                n_param_inputs: a.get("n_param_inputs").and_then(|v| v.as_usize()).unwrap_or(0),
                outputs,
            });
        }

        Ok(Manifest {
            dir: path.parent().unwrap_or(Path::new(".")).to_path_buf(),
            configs,
            params,
            artifacts,
        })
    }

    pub fn config(&self, size: &str) -> anyhow::Result<&ModelConfig> {
        self.configs
            .get(size)
            .ok_or_else(|| anyhow::anyhow!("model size {:?} not in manifest (have: {:?})",
                size, self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn params(&self, size: &str, fmt: &str) -> anyhow::Result<&[ParamMeta]> {
        self.params
            .get(&(size.to_string(), fmt.to_string()))
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("no param layout for ({}, {})", size, fmt))
    }

    pub fn artifact(&self, size: &str, fmt: &str, func: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.config == size && a.format == fmt && a.func == func)
            .ok_or_else(|| {
                anyhow::anyhow!("artifact ({}, {}, {}) not in manifest", size, fmt, func)
            })
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::{ensure, Context, Result};

    // Tests return `Result` with per-step context instead of bare
    // `.unwrap()` chains, so a manifest regression reports WHICH key
    // failed instead of "unwrapped a None somewhere in line N".

    fn load() -> Result<Manifest> {
        Manifest::load("artifacts/manifest.json")
            .context("loading artifacts/manifest.json (run `make artifacts`)")
    }

    #[test]
    fn loads_real_manifest() -> Result<()> {
        let man = load()?;
        ensure!(man.configs.contains_key("nano"), "configs missing 'nano'");
        let cfg = man.config("nano").context("config nano")?;
        ensure!(cfg.vocab == 48, "nano vocab = {}, want 48", cfg.vocab);
        let a = man.artifact("nano", "wq", "gen").context("artifact (nano, wq, gen)")?;
        ensure!(
            a.data_inputs.len() == 4,
            "(nano, wq, gen) has {} data inputs, want 4",
            a.data_inputs.len()
        );
        ensure!(a.outputs.len() == 1, "(nano, wq, gen) has {} outputs, want 1", a.outputs.len());
        ensure!(a.n_param_inputs > 0, "(nano, wq, gen) reports zero param inputs");
        let metas = man.params("nano", "wq").context("param layout (nano, wq)")?;
        ensure!(
            metas.len() == a.n_param_inputs,
            "param list has {} entries but artifact expects {}",
            metas.len(),
            a.n_param_inputs
        );
        Ok(())
    }

    #[test]
    fn lattice_accounting_is_consistent() -> Result<()> {
        let man = load()?;
        for size in ["nano", "micro"] {
            let cfg = man.config(size).with_context(|| format!("config {}", size))?;
            let metas = man.params(size, "wq").with_context(|| format!("params ({}, wq)", size))?;
            let lattice: usize = metas
                .iter()
                .filter(|m| m.kind == "lattice_q")
                .map(|m| m.shape.iter().product::<usize>())
                .sum();
            ensure!(
                lattice == cfg.lattice_params,
                "{}: lattice_q tensors sum to {} but config says {}",
                size,
                lattice,
                cfg.lattice_params
            );
        }
        Ok(())
    }

    #[test]
    fn missing_artifact_errors() -> Result<()> {
        let man = load()?;
        ensure!(
            man.artifact("nano", "wq", "nonexistent").is_err(),
            "bogus artifact lookup must fail"
        );
        ensure!(man.config("giant").is_err(), "bogus config lookup must fail");
        ensure!(man.params("nano", "int7").is_err(), "bogus format lookup must fail");
        Ok(())
    }
}
