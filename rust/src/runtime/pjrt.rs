//! PJRT implementation of [`ForwardBackend`]: compiled AOT HLO artifacts
//! executed on an `xla` client (see `runtime::engine` for the compile /
//! marshalling layer this builds on).
//!
//! The `xla` client is `Rc`-based (not `Send`), so a `PjrtBackend` lives
//! on one thread; pool workers each build their own from the same
//! manifest. Requires a real PJRT runtime — construction fails on the
//! offline stub build (`BackendPolicy::Auto` falls back to the native
//! backend there).

use anyhow::Result;

use crate::model::ParamsView;
use crate::quant::Format;
use crate::runtime::backend::{EngineSet, ForwardBackend};
use crate::runtime::encode::{gumbel_noise, ClsBatch, GenBatch, LmBatch};
use crate::runtime::engine::{self, Engine, HostTensor};
use crate::runtime::manifest::{Manifest, ModelConfig};

/// A set of compiled engines bound to one (model size, weight format) on
/// a thread-local PJRT client.
pub struct PjrtBackend {
    cfg: ModelConfig,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    gen: Option<Engine>,
    loss: Option<Engine>,
    cls: Option<Engine>,
    grad: Option<Engine>,
}

impl PjrtBackend {
    pub fn new(man: &Manifest, size: &str, format: Format, set: EngineSet) -> Result<PjrtBackend> {
        let cfg = man.config(size)?.clone();
        let client = xla::PjRtClient::cpu()?;
        let fmt = format.artifact_format();
        let mk = |want: bool, func: &str| -> Result<Option<Engine>> {
            if !want {
                return Ok(None);
            }
            Ok(Some(Engine::load(&client, man, man.artifact(size, fmt, func)?)?))
        };
        let gen = mk(set.gen, "gen")?;
        let loss = mk(set.loss, "loss")?;
        let cls = mk(set.cls, "cls")?;
        let grad = mk(set.grad, "grad")?;
        Ok(PjrtBackend { cfg, client, gen, loss, cls, grad })
    }

    fn engine<'a>(e: &'a Option<Engine>, what: &str) -> Result<&'a Engine> {
        e.as_ref().ok_or_else(|| anyhow::anyhow!("engine {:?} not compiled for this session", what))
    }

    fn lm_args(
        &self,
        eng: &Engine,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<Vec<xla::Literal>> {
        let d = &eng.meta.data_inputs;
        let mut args = Vec::with_capacity(5 + view.store.entries.len());
        args.push(engine::literal_for(&d[0], &HostTensor::I32(batch.tokens.clone()))?);
        args.push(engine::literal_for(&d[1], &HostTensor::I32(batch.pos_ids.clone()))?);
        args.push(engine::literal_for(&d[2], &HostTensor::F32(batch.mask.clone()))?);
        args.push(engine::literal_for(&d[3], &HostTensor::I32(batch.targets.clone()))?);
        args.push(engine::literal_for(&d[4], &HostTensor::F32(batch.loss_mask.clone()))?);
        args.extend(engine::param_literals_view(view, overrides)?);
        Ok(args)
    }
}

impl ForwardBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn generate(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &GenBatch,
        tau: f32,
        gumbel_seed: Option<u64>,
    ) -> Result<Vec<i32>> {
        let eng = Self::engine(&self.gen, "gen")?;
        let cfg = &self.cfg;
        let mut args = Vec::with_capacity(4 + view.store.entries.len());
        args.push(engine::literal_for(
            &eng.meta.data_inputs[0],
            &HostTensor::I32(batch.prompt.clone()),
        )?);
        args.push(engine::literal_for(
            &eng.meta.data_inputs[1],
            &HostTensor::I32(batch.lens.clone()),
        )?);
        args.push(xla::Literal::scalar(tau));
        args.push(engine::literal_for(
            &eng.meta.data_inputs[3],
            &HostTensor::F32(gumbel_noise(cfg, gumbel_seed)),
        )?);
        args.extend(engine::param_literals_view(view, overrides)?);
        let outs = eng.run(&args)?;
        engine::to_i32_vec(&outs[0])
    }

    fn cls_scores(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &ClsBatch,
    ) -> Result<Vec<f32>> {
        let eng = Self::engine(&self.cls, "cls")?;
        let d = &eng.meta.data_inputs;
        let mut args = Vec::with_capacity(6 + view.store.entries.len());
        args.push(engine::literal_for(&d[0], &HostTensor::I32(batch.tokens.clone()))?);
        args.push(engine::literal_for(&d[1], &HostTensor::I32(batch.pos_ids.clone()))?);
        args.push(engine::literal_for(&d[2], &HostTensor::F32(batch.mask.clone()))?);
        args.push(engine::literal_for(&d[3], &HostTensor::I32(batch.cls_pos.clone()))?);
        args.push(engine::literal_for(&d[4], &HostTensor::I32(batch.class_ids.clone()))?);
        args.push(engine::literal_for(&d[5], &HostTensor::I32(batch.labels.clone()))?);
        args.extend(engine::param_literals_view(view, overrides)?);
        let outs = eng.run(&args)?;
        // outputs: (sum_ce, n_correct, scores) — the host recomputes
        // real-row stats from the scores, so only they are returned.
        engine::to_f32_vec(&outs[2])
    }

    fn lm_loss(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<(f32, f32, f32)> {
        let eng = Self::engine(&self.loss, "loss")?;
        let outs = eng.run(&self.lm_args(eng, view, overrides, batch)?)?;
        Ok((
            engine::to_f32_scalar(&outs[0])?,
            engine::to_f32_scalar(&outs[1])?,
            engine::to_f32_scalar(&outs[2])?,
        ))
    }

    fn lm_grads(&self, view: &ParamsView<'_>, batch: &LmBatch) -> Result<(f32, Vec<Vec<f32>>)> {
        let eng = Self::engine(&self.grad, "grad")?;
        let outs = eng.run(&self.lm_args(eng, view, None, batch)?)?;
        let loss = engine::to_f32_scalar(&outs[0])?;
        let grads = outs[1..].iter().map(engine::to_f32_vec).collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }
}
