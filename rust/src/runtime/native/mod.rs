//! Native pure-Rust forward backend: interprets the manifest's
//! `ModelConfig` directly — embedding, layernorm, attention, MLP, logits
//! — with a fused dequant-GEMM ([`gemm`]) that reads the packed INT4
//! nibbles / int8 slabs of the lattice without ever materializing f32
//! weights. Runs everywhere, including the offline build, which is what
//! lights up rollout/eval end-to-end without a PJRT machine.
//!
//! Semantics mirror `python/compile/model.py` operation-for-operation
//! (left-padded prompts, explicit `pos_ids`/key `mask`, additive -1e9
//! attention bias, tanh-approximate GELU, KV-cached decode writing slot
//! `s_prompt + t` for every row). Cross-backend agreement with the PJRT
//! engines is tolerance-checked in `tests/integration.rs` when a real
//! runtime is linked.
//!
//! # Determinism
//!
//! Forward results are bit-identical for any thread count: the GEMM
//! assigns each output element to exactly one thread and accumulates in
//! K-index order, and everything else is elementwise or sequential — the
//! same contract the update kernels obey (`opt::kernels`).

pub mod autograd;
pub mod gemm;

use std::borrow::Cow;

use anyhow::{Context, Result};

use crate::kernel::{self, DotKernel};
use crate::model::{ParamStore, ParamsView};
use crate::quant::Format;
use crate::runtime::backend::{EngineSet, ForwardBackend};
use crate::runtime::encode::{gumbel_noise, ClsBatch, GenBatch, LmBatch};
use crate::runtime::manifest::{Manifest, ModelConfig};
use crate::util::parallel;

use autograd::LayerCache;
use gemm::Lin;

/// Matches model.py's additive attention-bias constant.
pub(crate) const NEG_INF: f32 = -1e9;
/// LayerNorm epsilon (model.py `_layernorm`).
pub(crate) const LN_EPS: f32 = 1e-5;

/// Tanh-approximate GELU — `jax.nn.gelu`'s default form.
#[inline]
pub(crate) fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// The pure-Rust [`ForwardBackend`]: stateless apart from the model
/// config and a thread knob, so it is cheap to construct per worker.
pub struct NativeBackend {
    cfg: ModelConfig,
    format: Format,
    threads: usize,
    /// Which graphs callers declared they need. The interpreter could
    /// serve all of them, but enforcing the declaration keeps the
    /// contract identical to the PJRT path — code that under-declares
    /// fails here too, not only on a machine with a real runtime.
    set: EngineSet,
}

impl NativeBackend {
    /// All graphs enabled — direct/raw use (tests, benches, parity).
    pub fn new(man: &Manifest, size: &str, format: Format) -> Result<NativeBackend> {
        NativeBackend::with_engine_set(man, size, format, EngineSet::all())
    }

    /// Serve only the declared graphs, mirroring `PjrtBackend::new` —
    /// what `Session::with_policy` uses.
    pub fn with_engine_set(
        man: &Manifest,
        size: &str,
        format: Format,
        set: EngineSet,
    ) -> Result<NativeBackend> {
        let cfg = man.config(size)?.clone();
        // same layout contract the engines check at compile time
        man.params(size, format.artifact_format())
            .with_context(|| format!("no param layout for ({}, {})", size, format.name()))?;
        Ok(NativeBackend { cfg, format, threads: parallel::default_threads(), set })
    }

    /// Override the GEMM thread count (results are invariant to it — the
    /// determinism contract; this is pure wall-clock tuning).
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.threads = threads.max(1);
        self
    }

    fn want(&self, enabled: bool, what: &str) -> Result<()> {
        anyhow::ensure!(enabled, "engine {:?} not compiled for this session", what);
        Ok(())
    }

    /// The run's weight format.
    pub fn format(&self) -> Format {
        self.format
    }

    /// The configured GEMM thread fan-out (results are invariant to it).
    pub fn gemm_threads(&self) -> usize {
        self.threads
    }

    /// Resolve the full model against a view for direct stepping (the
    /// generation scheduler): optional member overrides, optional shared
    /// head operand, optional K-major decode packs.
    pub(crate) fn resolve_params<'v>(
        &self,
        view: &ParamsView<'v>,
        overrides: Option<&'v [Vec<i8>]>,
        emb_t: Option<&'v [f32]>,
        decode_pack: bool,
    ) -> Result<NativeParams<'v>> {
        resolve(&self.cfg, self.format, view, overrides, emb_t, decode_pack)
    }

    /// ONE resolve pass serving a whole population: every member's
    /// lattice overrides against the same snapshot view, shared fp
    /// tensors (embeddings, LN, scales, head operand) borrowed once.
    /// Never builds K-major decode packs — grouping is the contracted
    /// training form and the reassociating pack stays serving-only.
    pub(crate) fn resolve_params_grouped<'v>(
        &self,
        view: &ParamsView<'v>,
        member_overrides: &'v [Vec<Vec<i8>>],
        emb_t: Option<&'v [f32]>,
    ) -> Result<Vec<NativeParams<'v>>> {
        resolve_grouped(&self.cfg, self.format, view, member_overrides, emb_t)
    }

    fn forward_full(
        &self,
        p: &NativeParams<'_>,
        tokens: &[i32],
        pos_ids: &[i32],
        mask: &[f32],
        b: usize,
        s: usize,
        want_kv: bool,
    ) -> Forward {
        forward_full(
            &self.cfg,
            self.threads,
            kernel::active_kernel(),
            p,
            tokens,
            pos_ids,
            mask,
            b,
            s,
            want_kv,
            None,
        )
    }

    fn head_rows(&self, p: &NativeParams<'_>, h: &[f32], rows: &[usize], out: &mut [f32]) {
        head_rows(&self.cfg, self.threads, kernel::active_kernel(), p, h, rows, out);
    }
}

/// ONE full-sequence pass of the layer stack — the single source of truth
/// for the forward op sequence, shared by every consumer: the backend's
/// gen/cls/loss graphs (`capture: None`), the generation scheduler's
/// batched prefill, and the autograd backward (`capture: Some`, which
/// additionally records every per-layer intermediate — layernorm
/// statistics, attention probabilities, pre-GELU activations — the
/// backward pass needs). Capture changes WHERE results are written, never
/// what is computed: both modes execute the identical float op sequence,
/// so captured and plain forwards agree bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_full(
    cfg: &ModelConfig,
    threads: usize,
    kr: &dyn DotKernel,
    p: &NativeParams<'_>,
    tokens: &[i32],
    pos_ids: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    want_kv: bool,
    mut capture: Option<&mut Vec<LayerCache>>,
) -> Forward {
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let dh = d / heads;
    let rows = b * s;
    let mut h = vec![0.0f32; rows * d];
    for r in 0..rows {
        let tok = tokens[r] as usize;
        let pos = pos_ids[r] as usize;
        for j in 0..d {
            h[r * d + j] = p.tok_emb[tok * d + j] + p.pos_emb[pos * d + j];
        }
    }
    let mut x = vec![0.0f32; rows * d];
    let mut qb = vec![0.0f32; rows * d];
    let mut kb = vec![0.0f32; rows * d];
    let mut vb = vec![0.0f32; rows * d];
    let mut ab = vec![0.0f32; rows * d];
    let mut pj = vec![0.0f32; rows * d];
    let mut ff = vec![0.0f32; rows * cfg.d_ff];
    let mut ff2 = vec![0.0f32; rows * d];
    let mut kvs = Vec::new();
    for layer in &p.layers {
        match &mut capture {
            None => {
                layernorm(&h, d, layer.ln1_g, layer.ln1_b, &mut x);
                gemm::matmul_with(&x, rows, &layer.wq, &mut qb, threads, kr);
                gemm::matmul_with(&x, rows, &layer.wk, &mut kb, threads, kr);
                gemm::matmul_with(&x, rows, &layer.wv, &mut vb, threads, kr);
                attend_full(b, s, heads, dh, &qb, &kb, &vb, mask, None, &mut ab);
                gemm::matmul_with(&ab, rows, &layer.wo, &mut pj, threads, kr);
                for i in 0..rows * d {
                    h[i] += pj[i];
                }
                layernorm(&h, d, layer.ln2_g, layer.ln2_b, &mut x);
                gemm::matmul_with(&x, rows, &layer.w1, &mut ff, threads, kr);
                for fv in ff.iter_mut() {
                    *fv = gelu(*fv);
                }
                gemm::matmul_with(&ff, rows, &layer.w2, &mut ff2, threads, kr);
                for i in 0..rows * d {
                    h[i] += ff2[i];
                }
                if want_kv {
                    kvs.push((kb.clone(), vb.clone()));
                }
            }
            Some(caches) => {
                let mut c = LayerCache::new(rows, d, cfg.d_ff, b, heads, s);
                layernorm_stats(
                    &h,
                    d,
                    layer.ln1_g,
                    layer.ln1_b,
                    &mut c.x1,
                    Some((&mut c.xhat1, &mut c.rstd1)),
                );
                gemm::matmul_with(&c.x1, rows, &layer.wq, &mut c.q, threads, kr);
                gemm::matmul_with(&c.x1, rows, &layer.wk, &mut c.k, threads, kr);
                gemm::matmul_with(&c.x1, rows, &layer.wv, &mut c.v, threads, kr);
                attend_full(
                    b,
                    s,
                    heads,
                    dh,
                    &c.q,
                    &c.k,
                    &c.v,
                    mask,
                    Some(&mut c.att),
                    &mut c.amerge,
                );
                gemm::matmul_with(&c.amerge, rows, &layer.wo, &mut pj, threads, kr);
                for i in 0..rows * d {
                    h[i] += pj[i];
                }
                layernorm_stats(
                    &h,
                    d,
                    layer.ln2_g,
                    layer.ln2_b,
                    &mut c.x2,
                    Some((&mut c.xhat2, &mut c.rstd2)),
                );
                gemm::matmul_with(&c.x2, rows, &layer.w1, &mut c.u, threads, kr);
                for (gv, &uv) in c.gu.iter_mut().zip(c.u.iter()) {
                    *gv = gelu(uv);
                }
                gemm::matmul_with(&c.gu, rows, &layer.w2, &mut ff2, threads, kr);
                for i in 0..rows * d {
                    h[i] += ff2[i];
                }
                if want_kv {
                    kvs.push((c.k.clone(), c.v.clone()));
                }
                caches.push(c);
            }
        }
    }
    Forward { h, kvs }
}

/// Cross-member grouped full-sequence pass: one walk over the layer
/// stack serving every population member at once. `assign[bi]` names the
/// member whose weights sequence `bi` runs under; the six lattice
/// matmuls per layer go through [`gemm::matmul_grouped_with`] so each
/// weight set is applied only to its own member's rows, while the shared
/// fp32 tensors (embeddings, layernorm gains/biases) are read from
/// `ps[0]` — [`resolve_grouped`] guarantees they are the same store
/// slices for every member.
///
/// # Determinism
///
/// Per-sequence ops (embedding, layernorm, attention, residuals, GELU)
/// are independent across rows, and the grouped GEMM computes each row
/// with its member's weights in the identical K-order op sequence — so
/// outputs are bit-identical to running [`forward_full`] per member over
/// that member's sequences, for any member count, thread count or kernel
/// backend.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_full_grouped(
    cfg: &ModelConfig,
    threads: usize,
    kr: &dyn DotKernel,
    ps: &[NativeParams<'_>],
    assign: &[usize],
    tokens: &[i32],
    pos_ids: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    want_kv: bool,
) -> Forward {
    assert!(!ps.is_empty(), "grouped forward: no members");
    assert_eq!(assign.len(), b, "grouped forward: assign len {} != b {}", assign.len(), b);
    assert!(assign.iter().all(|&a| a < ps.len()), "grouped forward: member id out of range");
    let p0 = &ps[0];
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let dh = d / heads;
    let rows = b * s;
    let row_assign: Vec<usize> = (0..rows).map(|r| assign[r / s]).collect();
    let mut h = vec![0.0f32; rows * d];
    for r in 0..rows {
        let tok = tokens[r] as usize;
        let pos = pos_ids[r] as usize;
        for j in 0..d {
            h[r * d + j] = p0.tok_emb[tok * d + j] + p0.pos_emb[pos * d + j];
        }
    }
    let mut x = vec![0.0f32; rows * d];
    let mut qb = vec![0.0f32; rows * d];
    let mut kb = vec![0.0f32; rows * d];
    let mut vb = vec![0.0f32; rows * d];
    let mut ab = vec![0.0f32; rows * d];
    let mut pj = vec![0.0f32; rows * d];
    let mut ff = vec![0.0f32; rows * cfg.d_ff];
    let mut ff2 = vec![0.0f32; rows * d];
    let mut kvs = Vec::new();
    for li in 0..p0.layers.len() {
        // ONE pass over each weight matrix's member set per layer
        macro_rules! mm_grouped {
            ($field:ident, $x:expr, $out:expr) => {{
                let lins: Vec<&Lin> = ps.iter().map(|p| &p.layers[li].$field).collect();
                gemm::matmul_grouped_with($x, rows, &lins, &row_assign, $out, threads, kr);
            }};
        }
        let layer = &p0.layers[li];
        layernorm(&h, d, layer.ln1_g, layer.ln1_b, &mut x);
        mm_grouped!(wq, &x, &mut qb);
        mm_grouped!(wk, &x, &mut kb);
        mm_grouped!(wv, &x, &mut vb);
        attend_full(b, s, heads, dh, &qb, &kb, &vb, mask, None, &mut ab);
        mm_grouped!(wo, &ab, &mut pj);
        for i in 0..rows * d {
            h[i] += pj[i];
        }
        layernorm(&h, d, layer.ln2_g, layer.ln2_b, &mut x);
        mm_grouped!(w1, &x, &mut ff);
        for fv in ff.iter_mut() {
            *fv = gelu(*fv);
        }
        mm_grouped!(w2, &ff, &mut ff2);
        for i in 0..rows * d {
            h[i] += ff2[i];
        }
        if want_kv {
            kvs.push((kb.clone(), vb.clone()));
        }
    }
    Forward { h, kvs }
}

/// Final layernorm + weight-tied LM head over the selected rows of `h`:
/// `out[[i], :] = lnf(h[rows[i]]) @ tok_emb^T`.
pub(crate) fn head_rows(
    cfg: &ModelConfig,
    threads: usize,
    kr: &dyn DotKernel,
    p: &NativeParams<'_>,
    h: &[f32],
    rows: &[usize],
    out: &mut [f32],
) {
    let d = cfg.d_model;
    let v = cfg.vocab;
    let mut hf = vec![0.0f32; rows.len() * d];
    for (ri, &r) in rows.iter().enumerate() {
        layernorm(&h[r * d..(r + 1) * d], d, p.lnf_g, p.lnf_b, &mut hf[ri * d..(ri + 1) * d]);
    }
    let lin = Lin::Fp { w: p.emb_t.as_ref(), rows: d, cols: v };
    gemm::matmul_with(&hf, rows.len(), &lin, out, threads, kr);
}

/// One layer's read-only view of a sequence's cached prefix K/V rows,
/// resolved through the scheduler's paged arena: logical position `pos`
/// (< `len`) lives at `(table[pos / page] * page + pos % page) * d` in
/// `k`/`v`. Keeps the suffix forward free of any arena dependency.
pub(crate) struct PrefixKv<'a> {
    pub(crate) k: &'a [f32],
    pub(crate) v: &'a [f32],
    pub(crate) table: &'a [u32],
    pub(crate) page: usize,
    pub(crate) len: usize,
}

/// A suffix-only prefill pass: final hidden states and per-layer k/v
/// rows for positions `lc..prompt.len()` only.
pub(crate) struct SuffixForward {
    pub(crate) h: Vec<f32>,
    pub(crate) kvs: Vec<(Vec<f32>, Vec<f32>)>,
}

/// Prefill continuation for a prefix-cache hit: compute ONLY rows
/// `lc..prompt.len()` of one sequence, attending to the `lc` cached
/// prefix rows through `prefix` (one [`PrefixKv`] per layer) and to the
/// locally-computed suffix rows.
///
/// # Bit-identity with the cold batched prefill
///
/// Every op a suffix row runs here is the op [`forward_full`] runs for
/// that row: the GEMMs go through the same `matmul_with` (each output
/// row accumulated independently in K order, so the row set in the call
/// doesn't matter), layernorm/GELU/residuals are row-wise, and the
/// attention walks keys in the same logical order with the same
/// scale/softmax/V-accumulate sequence — the left-pad and causal-future
/// positions the cold path biases to `NEG_INF` contribute EXACT zeros
/// there (`exp` underflows to +0.0, and adding ±0.0 never changes an
/// accumulator that starts at +0.0), so simply omitting them is
/// bit-identical. Cached prefix rows are bit-identical to a cold
/// recompute because a causal row depends only on the tokens at and
/// before its logical position. The one exception is W8A8, whose
/// per-call activation grid spans all rows of a call — the scheduler
/// disables prefix adoption for that format.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_suffix(
    cfg: &ModelConfig,
    threads: usize,
    kr: &dyn DotKernel,
    p: &NativeParams<'_>,
    prompt: &[u8],
    lc: usize,
    prefix: &[PrefixKv<'_>],
) -> SuffixForward {
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let dh = d / heads;
    let l = prompt.len();
    debug_assert!(lc < l, "suffix forward needs at least one live row");
    debug_assert_eq!(prefix.len(), p.layers.len());
    let rows = l - lc;
    let mut h = vec![0.0f32; rows * d];
    for r in 0..rows {
        let tok = prompt[lc + r] as usize;
        let pos = lc + r;
        for j in 0..d {
            h[r * d + j] = p.tok_emb[tok * d + j] + p.pos_emb[pos * d + j];
        }
    }
    let mut x = vec![0.0f32; rows * d];
    let mut qb = vec![0.0f32; rows * d];
    let mut kb = vec![0.0f32; rows * d];
    let mut vb = vec![0.0f32; rows * d];
    let mut ab = vec![0.0f32; rows * d];
    let mut pj = vec![0.0f32; rows * d];
    let mut ff = vec![0.0f32; rows * cfg.d_ff];
    let mut ff2 = vec![0.0f32; rows * d];
    let mut att = vec![0.0f32; l];
    let scale = 1.0 / (dh as f32).sqrt();
    let mut kvs = Vec::with_capacity(p.layers.len());
    for (li, layer) in p.layers.iter().enumerate() {
        layernorm(&h, d, layer.ln1_g, layer.ln1_b, &mut x);
        gemm::matmul_with(&x, rows, &layer.wq, &mut qb, threads, kr);
        gemm::matmul_with(&x, rows, &layer.wk, &mut kb, threads, kr);
        gemm::matmul_with(&x, rows, &layer.wv, &mut vb, threads, kr);
        let px = &prefix[li];
        debug_assert_eq!(px.len, lc);
        ab.fill(0.0);
        for sq in 0..rows {
            for hh in 0..heads {
                let qo = sq * d + hh * dh;
                // keys: cached prefix rows through the page table, then
                // the local suffix rows, in logical order
                for sk in 0..lc {
                    let pid = px.table[sk / px.page] as usize;
                    let ko = (pid * px.page + sk % px.page) * d + hh * dh;
                    let mut dot = 0.0f32;
                    for j in 0..dh {
                        dot += qb[qo + j] * px.k[ko + j];
                    }
                    att[sk] = dot * scale;
                }
                for sk in lc..=lc + sq {
                    let ko = (sk - lc) * d + hh * dh;
                    let mut dot = 0.0f32;
                    for j in 0..dh {
                        dot += qb[qo + j] * kb[ko + j];
                    }
                    att[sk] = dot * scale;
                }
                let st = lc + sq + 1;
                softmax_inplace(&mut att[..st]);
                let oo = sq * d + hh * dh;
                for sk in 0..lc {
                    let w = att[sk];
                    let pid = px.table[sk / px.page] as usize;
                    let vo = (pid * px.page + sk % px.page) * d + hh * dh;
                    for j in 0..dh {
                        ab[oo + j] += w * px.v[vo + j];
                    }
                }
                for sk in lc..st {
                    let w = att[sk];
                    let vo = (sk - lc) * d + hh * dh;
                    for j in 0..dh {
                        ab[oo + j] += w * vb[vo + j];
                    }
                }
            }
        }
        gemm::matmul_with(&ab, rows, &layer.wo, &mut pj, threads, kr);
        for i in 0..rows * d {
            h[i] += pj[i];
        }
        layernorm(&h, d, layer.ln2_g, layer.ln2_b, &mut x);
        gemm::matmul_with(&x, rows, &layer.w1, &mut ff, threads, kr);
        for fv in ff.iter_mut() {
            *fv = gelu(*fv);
        }
        gemm::matmul_with(&ff, rows, &layer.w2, &mut ff2, threads, kr);
        for i in 0..rows * d {
            h[i] += ff2[i];
        }
        kvs.push((kb.clone(), vb.clone()));
    }
    SuffixForward { h, kvs }
}

impl ForwardBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn as_native(&self) -> Option<&NativeBackend> {
        Some(self)
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    fn generate(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &GenBatch,
        tau: f32,
        gumbel_seed: Option<u64>,
    ) -> Result<Vec<i32>> {
        self.want(self.set.gen, "gen")?;
        let p = resolve(&self.cfg, self.format, view, overrides, None, false)?;
        let cfg = &self.cfg;
        let (b, sp, t_dec) = (cfg.b_gen, cfg.s_prompt, cfg.t_dec);
        let st = sp + t_dec;
        let d = cfg.d_model;
        let v = cfg.vocab;
        let n_heads = cfg.n_heads;

        // left-padding geometry (model.py gen_fn prologue)
        let mut mask = vec![0.0f32; b * sp];
        let mut pos_ids = vec![0i32; b * sp];
        for bi in 0..b {
            let len = batch.lens[bi] as usize;
            let pad = sp - len;
            for s0 in pad..sp {
                mask[bi * sp + s0] = 1.0;
                pos_ids[bi * sp + s0] = (s0 - pad) as i32;
            }
        }
        let fw = self.forward_full(&p, &batch.prompt, &pos_ids, &mask, b, sp, true);
        let last_rows: Vec<usize> = (0..b).map(|bi| bi * sp + sp - 1).collect();
        let mut last = vec![0.0f32; b * v];
        self.head_rows(&p, &fw.h, &last_rows, &mut last);

        // KV caches [b, s_total, d] per layer, prompt slots primed
        let mut kc = vec![vec![0.0f32; b * st * d]; cfg.n_layers];
        let mut vc = vec![vec![0.0f32; b * st * d]; cfg.n_layers];
        for li in 0..cfg.n_layers {
            let (kf, vf) = &fw.kvs[li];
            for bi in 0..b {
                for s0 in 0..sp {
                    let src = (bi * sp + s0) * d;
                    let dst = (bi * st + s0) * d;
                    kc[li][dst..dst + d].copy_from_slice(&kf[src..src + d]);
                    vc[li][dst..dst + d].copy_from_slice(&vf[src..src + d]);
                }
            }
        }
        let mut keymask = vec![0.0f32; b * st];
        for bi in 0..b {
            keymask[bi * st..bi * st + sp].copy_from_slice(&mask[bi * sp..(bi + 1) * sp]);
        }

        let gumbel = gumbel_seed.map(|seed| gumbel_noise(cfg, Some(seed)));
        let mut out = vec![0i32; b * t_dec];
        let mut h = vec![0.0f32; b * d];
        let mut x = vec![0.0f32; b * d];
        let mut qb = vec![0.0f32; b * d];
        let mut kb = vec![0.0f32; b * d];
        let mut vb = vec![0.0f32; b * d];
        let mut ab = vec![0.0f32; b * d];
        let mut pj = vec![0.0f32; b * d];
        let mut ff = vec![0.0f32; b * cfg.d_ff];
        let mut ff2 = vec![0.0f32; b * d];

        for t in 0..t_dec {
            // next token: argmax(last + tau * gumbel_t), first max like
            // jnp.argmax; greedy when no seed was given
            for bi in 0..b {
                let row = &last[bi * v..(bi + 1) * v];
                let mut best = 0usize;
                let mut bestv = f32::NEG_INFINITY;
                for c in 0..v {
                    let g = match &gumbel {
                        Some(gv) => gv[(bi * t_dec + t) * v + c],
                        None => 0.0,
                    };
                    let val = row[c] + tau * g;
                    if val > bestv {
                        bestv = val;
                        best = c;
                    }
                }
                out[bi * t_dec + t] = best as i32;
            }
            if t + 1 == t_dec {
                break; // the scan's final block only feeds logits nobody reads
            }
            let slot = sp + t;
            for bi in 0..b {
                let tok = out[bi * t_dec + t] as usize;
                let pos = batch.lens[bi] as usize + t;
                for j in 0..d {
                    h[bi * d + j] = p.tok_emb[tok * d + j] + p.pos_emb[pos * d + j];
                }
                keymask[bi * st + slot] = 1.0;
            }
            for (li, layer) in p.layers.iter().enumerate() {
                layernorm(&h, d, layer.ln1_g, layer.ln1_b, &mut x);
                gemm::matmul(&x, b, &layer.wq, &mut qb, self.threads);
                gemm::matmul(&x, b, &layer.wk, &mut kb, self.threads);
                gemm::matmul(&x, b, &layer.wv, &mut vb, self.threads);
                for bi in 0..b {
                    let dst = (bi * st + slot) * d;
                    kc[li][dst..dst + d].copy_from_slice(&kb[bi * d..(bi + 1) * d]);
                    vc[li][dst..dst + d].copy_from_slice(&vb[bi * d..(bi + 1) * d]);
                }
                let dh = d / n_heads;
                attend_decode(b, st, n_heads, dh, &qb, &kc[li], &vc[li], &keymask, &mut ab);
                gemm::matmul(&ab, b, &layer.wo, &mut pj, self.threads);
                for i in 0..b * d {
                    h[i] += pj[i];
                }
                layernorm(&h, d, layer.ln2_g, layer.ln2_b, &mut x);
                gemm::matmul(&x, b, &layer.w1, &mut ff, self.threads);
                for fv in ff.iter_mut() {
                    *fv = gelu(*fv);
                }
                gemm::matmul(&ff, b, &layer.w2, &mut ff2, self.threads);
                for i in 0..b * d {
                    h[i] += ff2[i];
                }
            }
            let rows: Vec<usize> = (0..b).collect();
            self.head_rows(&p, &h, &rows, &mut last);
        }
        Ok(out)
    }

    fn cls_scores(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &ClsBatch,
    ) -> Result<Vec<f32>> {
        self.want(self.set.cls, "cls")?;
        let p = resolve(&self.cfg, self.format, view, overrides, None, false)?;
        let cfg = &self.cfg;
        let (b, s) = (cfg.b_train, cfg.s_train);
        let v = cfg.vocab;
        let fw = self.forward_full(&p, &batch.tokens, &batch.pos_ids, &batch.mask, b, s, false);
        let rows: Vec<usize> = (0..b).map(|bi| bi * s + batch.cls_pos[bi] as usize).collect();
        let mut at = vec![0.0f32; b * v];
        self.head_rows(&p, &fw.h, &rows, &mut at);
        let c = batch.class_ids.len();
        let mut scores = vec![0.0f32; b * c];
        for bi in 0..b {
            for (ci, &cid) in batch.class_ids.iter().enumerate() {
                scores[bi * c + ci] = at[bi * v + cid as usize];
            }
        }
        Ok(scores)
    }

    fn lm_loss(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<(f32, f32, f32)> {
        self.want(self.set.loss, "loss")?;
        let p = resolve(&self.cfg, self.format, view, overrides, None, false)?;
        let cfg = &self.cfg;
        let (b, s) = (cfg.b_train, cfg.s_train);
        let v = cfg.vocab;
        let fw = self.forward_full(&p, &batch.tokens, &batch.pos_ids, &batch.mask, b, s, false);
        let rows: Vec<usize> = (0..b * s).collect();
        let mut logits = vec![0.0f32; b * s * v];
        self.head_rows(&p, &fw.h, &rows, &mut logits);
        let mut sum_ce = 0.0f32;
        let mut n_tok = 0.0f32;
        let mut n_correct = 0.0f32;
        for r in 0..b * s {
            let lm = batch.loss_mask[r];
            n_tok += lm;
            if lm == 0.0 {
                continue;
            }
            let row = &logits[r * v..(r + 1) * v];
            let target = batch.targets[r] as usize;
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logz = m + row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln();
            sum_ce += (logz - row[target]) * lm;
            let mut best = 0usize;
            let mut bestv = f32::NEG_INFINITY;
            for (c, &l) in row.iter().enumerate() {
                if l > bestv {
                    bestv = l;
                    best = c;
                }
            }
            if best == target {
                n_correct += lm;
            }
        }
        Ok((sum_ce, n_tok, n_correct))
    }

    fn lm_grads(&self, view: &ParamsView<'_>, batch: &LmBatch) -> Result<(f32, Vec<Vec<f32>>)> {
        self.want(self.set.grad, "grad")?;
        anyhow::ensure!(
            view.store.format == Format::Fp32,
            "lm_grads needs an fp-format store (got {})",
            view.store.format.name()
        );
        autograd::lm_grads(&self.cfg, view.store, batch)
    }
}

/// One full-sequence pass: final hidden states plus (optionally) each
/// layer's k/v rows for cache priming.
pub(crate) struct Forward {
    pub(crate) h: Vec<f32>,
    pub(crate) kvs: Vec<(Vec<f32>, Vec<f32>)>,
}

/// Weights of one transformer block, resolved to slices/GEMM operands.
pub(crate) struct LayerParams<'v> {
    pub(crate) ln1_g: &'v [f32],
    pub(crate) ln1_b: &'v [f32],
    pub(crate) ln2_g: &'v [f32],
    pub(crate) ln2_b: &'v [f32],
    pub(crate) wq: Lin<'v>,
    pub(crate) wk: Lin<'v>,
    pub(crate) wv: Lin<'v>,
    pub(crate) wo: Lin<'v>,
    pub(crate) w1: Lin<'v>,
    pub(crate) w2: Lin<'v>,
}

/// The full model resolved against one parameter view (+ optional member
/// overrides). Lives for one backend call — or for a whole scheduler
/// round, which is the point: the resolve+pack cost is paid once per
/// member per round instead of once per generate call.
pub(crate) struct NativeParams<'v> {
    pub(crate) tok_emb: &'v [f32],
    pub(crate) pos_emb: &'v [f32],
    pub(crate) lnf_g: &'v [f32],
    pub(crate) lnf_b: &'v [f32],
    pub(crate) layers: Vec<LayerParams<'v>>,
    /// `tok_emb` transposed to `[d_model, vocab]` for the weight-tied LM
    /// head GEMM: materialized per resolve, or borrowed from a caller's
    /// cache (`tok_emb` never changes during ES fine-tuning, so one
    /// transpose can serve every member and round — see [`build_emb_t`]).
    pub(crate) emb_t: Cow<'v, [f32]>,
}

/// Materialize the weight-tied LM head operand (`tok_emb` transposed to
/// `[d_model, vocab]`) for sharing across [`resolve`] calls.
pub fn build_emb_t(store: &ParamStore) -> Result<Vec<f32>> {
    let e = store
        .get("tok_emb")
        .ok_or_else(|| anyhow::anyhow!("param \"tok_emb\" missing from store"))?;
    Ok(transpose_emb(e.data.as_f32(), e.shape[0], e.shape[1]))
}

/// `[vocab, d]` -> `[d, vocab]` — the ONE transpose loop behind both
/// [`build_emb_t`] and [`resolve`]'s uncached path.
fn transpose_emb(tok_emb: &[f32], v: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; d * v];
    for vi in 0..v {
        for j in 0..d {
            out[j * v + vi] = tok_emb[vi * d + j];
        }
    }
    out
}

fn fp_slice<'v>(store: &'v ParamStore, name: &str) -> Result<&'v [f32]> {
    Ok(store
        .get(name)
        .ok_or_else(|| anyhow::anyhow!("param {:?} missing from store", name))?
        .data
        .as_f32())
}

/// Resolve the lattice tensor named `<base>.q` through the view (shard
/// slabs gathered per tensor) or the member's override buffer, paired
/// with its `.s` scales, into a GEMM operand. `decode_pack` additionally
/// builds the K-major transposed pack for the decode-step GEMM (INT4
/// only; see [`Lin::with_decode_pack`]).
fn lattice_lin<'v>(
    view: &ParamsView<'v>,
    overrides: Option<&'v [Vec<i8>]>,
    base: &str,
    format: Format,
    decode_pack: bool,
) -> Result<Lin<'v>> {
    let store = view.store;
    if format == Format::Fp32 {
        let e = store
            .get(base)
            .ok_or_else(|| anyhow::anyhow!("param {:?} missing from store", base))?;
        return Ok(Lin::Fp { w: e.data.as_f32(), rows: e.shape[0], cols: e.shape[1] });
    }
    let qname = format!("{}.q", base);
    let idx = store
        .entries
        .iter()
        .position(|e| e.name == qname)
        .ok_or_else(|| anyhow::anyhow!("lattice tensor {:?} missing from store", qname))?;
    let k = store
        .lattice_indices()
        .iter()
        .position(|&i| i == idx)
        .ok_or_else(|| anyhow::anyhow!("{:?} is not a lattice entry", qname))?;
    let e = &store.entries[idx];
    let q: Cow<'v, [i8]> = match overrides {
        Some(ovs) => Cow::Borrowed(ovs[k].as_slice()),
        None => view.lattice_tensor(k),
    };
    anyhow::ensure!(
        q.len() == e.numel(),
        "{}: lattice view has {} elems, want {}",
        qname,
        q.len(),
        e.numel()
    );
    let scale = fp_slice(store, &format!("{}.s", base))?;
    let lin = Lin::from_lattice(q, scale, e.shape[0], e.shape[1], format);
    Ok(if decode_pack { lin.with_decode_pack() } else { lin })
}

pub(crate) fn resolve<'v>(
    cfg: &ModelConfig,
    format: Format,
    view: &ParamsView<'v>,
    overrides: Option<&'v [Vec<i8>]>,
    emb_t: Option<&'v [f32]>,
    decode_pack: bool,
) -> Result<NativeParams<'v>> {
    let store = view.store;
    anyhow::ensure!(
        store.format == format,
        "store format {} does not match backend format {}",
        store.format.name(),
        format.name()
    );
    if let Some(ovs) = overrides {
        anyhow::ensure!(format != Format::Fp32, "i8 overrides passed for fp-format store");
        anyhow::ensure!(
            ovs.len() == store.lattice_indices().len(),
            "got {} override tensors for {} lattice tensors",
            ovs.len(),
            store.lattice_indices().len()
        );
    }
    let tok_emb = fp_slice(store, "tok_emb")?;
    let pos_emb = fp_slice(store, "pos_emb")?;
    let emb = store.get("tok_emb").expect("checked above");
    let (v, d) = (emb.shape[0], emb.shape[1]);
    let emb_t: Cow<'v, [f32]> = match emb_t {
        Some(t) => {
            anyhow::ensure!(
                t.len() == d * v,
                "shared emb_t cache has {} elems, want {}",
                t.len(),
                d * v
            );
            Cow::Borrowed(t)
        }
        None => Cow::Owned(transpose_emb(tok_emb, v, d)),
    };
    // cfg drives the layer count; a store missing a layer surfaces as a
    // descriptive missing-param error from fp_slice/lattice_lin below
    // instead of an index panic in the KV-priming loop.
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let pre = format!("layers.{}.", i);
        layers.push(LayerParams {
            ln1_g: fp_slice(store, &format!("{}ln1.g", pre))?,
            ln1_b: fp_slice(store, &format!("{}ln1.b", pre))?,
            ln2_g: fp_slice(store, &format!("{}ln2.g", pre))?,
            ln2_b: fp_slice(store, &format!("{}ln2.b", pre))?,
            wq: lattice_lin(view, overrides, &format!("{}attn.wq", pre), format, decode_pack)?,
            wk: lattice_lin(view, overrides, &format!("{}attn.wk", pre), format, decode_pack)?,
            wv: lattice_lin(view, overrides, &format!("{}attn.wv", pre), format, decode_pack)?,
            wo: lattice_lin(view, overrides, &format!("{}attn.wo", pre), format, decode_pack)?,
            w1: lattice_lin(view, overrides, &format!("{}mlp.w1", pre), format, decode_pack)?,
            w2: lattice_lin(view, overrides, &format!("{}mlp.w2", pre), format, decode_pack)?,
        });
    }
    Ok(NativeParams {
        tok_emb,
        pos_emb,
        lnf_g: fp_slice(store, "lnf.g")?,
        lnf_b: fp_slice(store, "lnf.b")?,
        layers,
        emb_t,
    })
}

/// Resolve a whole population against ONE snapshot view: member `j` gets
/// the base model with its own lattice overrides. Shared fp32 tensors
/// (embeddings, layernorms, scales) resolve to the SAME store slices for
/// every member — only the 6 lattice matrices per layer differ — which
/// is what lets [`forward_full_grouped`] read them from `ps[0]`. Each
/// member's lattice slabs still pack individually (their weights differ
/// elementwise); the amortization is one resolve PASS per round instead
/// of one per member, plus everything downstream of it (one scheduler,
/// one weight-stream walk per layer per step).
pub(crate) fn resolve_grouped<'v>(
    cfg: &ModelConfig,
    format: Format,
    view: &ParamsView<'v>,
    member_overrides: &'v [Vec<Vec<i8>>],
    emb_t: Option<&'v [f32]>,
) -> Result<Vec<NativeParams<'v>>> {
    anyhow::ensure!(!member_overrides.is_empty(), "grouped resolve: zero members");
    member_overrides
        .iter()
        .map(|ov| resolve(cfg, format, view, Some(ov), emb_t, false))
        .collect()
}

/// Grouped Cls scoring: ONE resolve pass + ONE grouped forward per batch
/// serve every member — each member's copy of the batch rows runs under
/// its own weights in the same op sequence as a per-member
/// [`ForwardBackend::cls_scores`] call, so the returned
/// `[member][batch][b*c]` scores are bit-identical to the sequential
/// path (the W8A8 activation grid is per member: a member's grouped row
/// set IS the full per-call tensor the sequential path quantizes over).
pub(crate) fn cls_scores_grouped(
    backend: &NativeBackend,
    view: &ParamsView<'_>,
    member_overrides: &[Vec<Vec<i8>>],
    emb_t: Option<&[f32]>,
    batches: &[ClsBatch],
) -> Result<Vec<Vec<Vec<f32>>>> {
    backend.want(backend.set.cls, "cls")?;
    let cfg = &backend.cfg;
    let ps = resolve_grouped(cfg, backend.format, view, member_overrides, emb_t)?;
    let n_members = ps.len();
    let (b, s) = (cfg.b_train, cfg.s_train);
    let v = cfg.vocab;
    let kr = kernel::active_kernel();
    let assign: Vec<usize> = (0..n_members * b).map(|i| i / b).collect();
    let mut out = vec![Vec::with_capacity(batches.len()); n_members];
    let mut tokens = Vec::with_capacity(n_members * b * s);
    let mut pos_ids = Vec::with_capacity(n_members * b * s);
    let mut mask = Vec::with_capacity(n_members * b * s);
    for batch in batches {
        tokens.clear();
        pos_ids.clear();
        mask.clear();
        for _ in 0..n_members {
            tokens.extend_from_slice(&batch.tokens);
            pos_ids.extend_from_slice(&batch.pos_ids);
            mask.extend_from_slice(&batch.mask);
        }
        let fw = forward_full_grouped(
            cfg,
            backend.threads,
            kr,
            &ps,
            &assign,
            &tokens,
            &pos_ids,
            &mask,
            n_members * b,
            s,
            false,
        );
        let rows: Vec<usize> = (0..n_members * b)
            .map(|i| i * s + batch.cls_pos[i % b] as usize)
            .collect();
        let mut at = vec![0.0f32; n_members * b * v];
        head_rows(cfg, backend.threads, kr, &ps[0], &fw.h, &rows, &mut at);
        let c = batch.class_ids.len();
        for (j, member_out) in out.iter_mut().enumerate() {
            let mut scores = vec![0.0f32; b * c];
            for bi in 0..b {
                for (ci, &cid) in batch.class_ids.iter().enumerate() {
                    scores[bi * c + ci] = at[(j * b + bi) * v + cid as usize];
                }
            }
            member_out.push(scores);
        }
    }
    Ok(out)
}

/// Row-wise layernorm over `[rows, d]`.
pub(crate) fn layernorm(x: &[f32], d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    layernorm_stats(x, d, g, b, out, None);
}

/// [`layernorm`] that optionally records per-row normalization state
/// (`xhat`, `rstd`) for the backward pass. The float op sequence is
/// identical with and without capture.
pub(crate) fn layernorm_stats(
    x: &[f32],
    d: usize,
    g: &[f32],
    b: &[f32],
    out: &mut [f32],
    mut stats: Option<(&mut [f32], &mut [f32])>,
) {
    for (r, (xr, or)) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)).enumerate() {
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let rstd = 1.0 / (var + LN_EPS).sqrt();
        for j in 0..d {
            let xh = (xr[j] - mu) * rstd;
            or[j] = xh * g[j] + b[j];
            if let Some((xhat, _)) = &mut stats {
                xhat[r * d + j] = xh;
            }
        }
        if let Some((_, rs)) = &mut stats {
            rs[r] = rstd;
        }
    }
}

pub(crate) fn softmax_inplace(l: &mut [f32]) {
    let m = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in l.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in l.iter_mut() {
        *v *= inv;
    }
}

/// Full-sequence multi-head attention with causal + key masking. `q`,
/// `k`, `v`, `out` are `[b, s, heads*dh]` row-major; `mask` is `[b, s]`
/// (1 = real key). Matches model.py `_attend` + the `_block_full` bias.
/// When `att` is `Some` (`[b, heads, s, s]`), the softmax probabilities
/// are computed in place there — the backward pass's cache — with the
/// identical op sequence.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_full(
    b: usize,
    s: usize,
    heads: usize,
    dh: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    mut att: Option<&mut [f32]>,
    out: &mut [f32],
) {
    let d = heads * dh;
    out.fill(0.0);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut local = vec![0.0f32; s];
    for bi in 0..b {
        for h in 0..heads {
            for sq in 0..s {
                let qo = (bi * s + sq) * d + h * dh;
                let logits: &mut [f32] = match &mut att {
                    Some(a) => {
                        let base = ((bi * heads + h) * s + sq) * s;
                        &mut a[base..base + s]
                    }
                    None => &mut local,
                };
                for sk in 0..s {
                    let bias =
                        if sk <= sq && mask[bi * s + sk] > 0.0 { 0.0 } else { NEG_INF };
                    let ko = (bi * s + sk) * d + h * dh;
                    let mut dot = 0.0f32;
                    for i in 0..dh {
                        dot += q[qo + i] * k[ko + i];
                    }
                    logits[sk] = dot * scale + bias;
                }
                softmax_inplace(logits);
                let oo = (bi * s + sq) * d + h * dh;
                for sk in 0..s {
                    let w = logits[sk];
                    let vo = (bi * s + sk) * d + h * dh;
                    for i in 0..dh {
                        out[oo + i] += w * v[vo + i];
                    }
                }
            }
        }
    }
}

/// Single-position attention against a KV cache: `q`/`out` are `[b, d]`
/// (one decode token per row), `kc`/`vc` are `[b, st, d]`, `keymask` is
/// `[b, st]` with the current slot already enabled.
pub(crate) fn attend_decode(
    b: usize,
    st: usize,
    heads: usize,
    dh: usize,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    keymask: &[f32],
    out: &mut [f32],
) {
    let d = heads * dh;
    out.fill(0.0);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut logits = vec![0.0f32; st];
    for bi in 0..b {
        for h in 0..heads {
            let qo = bi * d + h * dh;
            for sk in 0..st {
                let bias = if keymask[bi * st + sk] > 0.0 { 0.0 } else { NEG_INF };
                let ko = (bi * st + sk) * d + h * dh;
                let mut dot = 0.0f32;
                for i in 0..dh {
                    dot += q[qo + i] * kc[ko + i];
                }
                logits[sk] = dot * scale + bias;
            }
            softmax_inplace(&mut logits);
            let oo = bi * d + h * dh;
            for sk in 0..st {
                let w = logits[sk];
                let vo = (bi * st + sk) * d + h * dh;
                for i in 0..dh {
                    out[oo + i] += w * vc[vo + i];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp;
    use crate::model::AsParams;
    use crate::tasks::gen_task;

    fn manifest() -> Manifest {
        Manifest::load("artifacts/manifest.json").expect("run `make artifacts` first")
    }

    fn stores() -> (Manifest, ParamStore, ParamStore) {
        let man = manifest();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 77);
        let q = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        (man, fp, q)
    }

    #[test]
    fn generate_bit_identical_across_thread_counts() {
        let (man, _fp, q) = stores();
        let cfg = man.config("nano").unwrap().clone();
        let task = gen_task("countdown", cfg.s_prompt, cfg.t_dec).unwrap();
        let mut rng = crate::rng::SplitMix64::new(4);
        let problems: Vec<_> = (0..cfg.b_gen).map(|_| task.sample(&mut rng)).collect();
        let batch = GenBatch::build(&cfg, problems);
        let view = q.params_view();
        let base = NativeBackend::new(&man, "nano", Format::Int4)
            .unwrap()
            .with_threads(1)
            .generate(&view, None, &batch, 0.7, Some(9))
            .unwrap();
        for threads in [2usize, 8] {
            let got = NativeBackend::new(&man, "nano", Format::Int4)
                .unwrap()
                .with_threads(threads)
                .generate(&view, None, &batch, 0.7, Some(9))
                .unwrap();
            assert_eq!(base, got, "threads={}", threads);
        }
    }

    #[test]
    fn quantized_loss_tracks_fp_loss() {
        // INT8 dequant forward must land near the fp forward on the same
        // weights — the native analog of the PJRT quantization test.
        let (man, fp, _q4) = stores();
        let q8 = ParamStore::quantize_from(&fp, &man, Format::Int8, None).unwrap();
        let cfg = man.config("nano").unwrap().clone();
        let task = gen_task("countdown", cfg.s_prompt, cfg.t_dec).unwrap();
        let mut rng = crate::rng::SplitMix64::new(6);
        let pairs: Vec<(String, String)> =
            (0..cfg.b_train).map(|_| task.supervised(&mut rng)).collect();
        let batch = LmBatch::build(&cfg, &pairs);
        let nb_fp = NativeBackend::new(&man, "nano", Format::Fp32).unwrap();
        let (ce_fp, nt, _) = nb_fp.lm_loss(&fp.params_view(), None, &batch).unwrap();
        let nb_q = NativeBackend::new(&man, "nano", Format::Int8).unwrap();
        let (ce_q, nt_q, _) = nb_q.lm_loss(&q8.params_view(), None, &batch).unwrap();
        assert_eq!(nt, nt_q);
        let (l_fp, l_q) = (ce_fp / nt, ce_q / nt_q);
        assert!((l_fp - l_q).abs() < 0.2, "fp {} vs int8 {}", l_fp, l_q);
        // random init: CE should sit near ln(vocab)
        assert!((l_fp - (cfg.vocab as f32).ln()).abs() < 1.0, "loss {}", l_fp);
    }

    #[test]
    fn format_mismatch_and_bad_overrides_error() {
        let (man, fp, q) = stores();
        let nb = NativeBackend::new(&man, "nano", Format::Int4).unwrap();
        let cfg = nb.cfg().clone();
        let task = gen_task("countdown", cfg.s_prompt, cfg.t_dec).unwrap();
        let mut rng = crate::rng::SplitMix64::new(2);
        let batch = GenBatch::build(&cfg, vec![task.sample(&mut rng)]);
        // fp store into an int4 backend
        assert!(nb.generate(&fp.params_view(), None, &batch, 0.0, None).is_err());
        // wrong override arity
        let bad = vec![vec![0i8; 4]];
        assert!(nb.generate(&q.params_view(), Some(&bad), &batch, 0.0, None).is_err());
    }

    #[test]
    fn undeclared_graphs_error_like_pjrt() {
        // The EngineSet declaration is enforced on the native path too,
        // so under-declaring can't pass CI natively and then explode on
        // a PJRT machine.
        let (man, _fp, q) = stores();
        let nb = NativeBackend::with_engine_set(
            &man,
            "nano",
            Format::Int4,
            EngineSet::gen_only(),
        )
        .unwrap();
        let cfg = nb.cfg().clone();
        let ct = crate::tasks::cls_task("snli").unwrap();
        let mut rng = crate::rng::SplitMix64::new(3);
        let exs: Vec<_> = (0..cfg.b_train).map(|_| ct.sample(&mut rng, true)).collect();
        let cb = ClsBatch::build(&cfg, &exs, &ct.verbalizers());
        let err = nb.cls_scores(&q.params_view(), None, &cb).unwrap_err();
        assert!(format!("{}", err).contains("not compiled"), "{}", err);
    }
}
