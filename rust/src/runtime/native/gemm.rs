//! Fused dequant-GEMM: the native backend's linear-layer hot path.
//!
//! Computes `y[M,N] = x[M,K] @ dequant(W[K,N])` reading the quantized
//! weights directly — int8 lattice slabs as stored by the parameter
//! plane, or nibble-packed INT4 (packed once per forward call, then read
//! two weights per byte in the inner loop). The per-output-channel scale
//! is applied once per accumulator after the K-loop ("in-register"), so
//! no f32 weight tensor is ever materialized — the historical
//! dequant-then-matmul path exists only as [`dequant_then_matmul`], the
//! benchmark baseline and property-test reference.
//!
//! # Determinism
//!
//! Output rows are distributed over threads (`util::parallel`), but every
//! output element is accumulated by exactly one thread, sequentially in
//! K-index order — so results are bit-identical for any thread count,
//! the same contract the update kernels in `opt::kernels` obey. The
//! inner loops dispatch to the SIMD microkernels (`crate::kernel`),
//! which vectorize along the N (output-column) axis with unfused
//! mul+add — each element's op sequence is unchanged, so results are
//! additionally bit-identical across kernel backends (scalar/AVX2/NEON,
//! i.e. `QES_KERNEL` never changes a forward output).

use std::borrow::Cow;

use crate::kernel::{self, DotKernel};
use crate::quant::pack::{pack_int4, unpack_int4_row};
use crate::quant::Format;
use crate::util::parallel;

/// INT8 activation grid for W8A8 (symmetric, per-tensor, dynamic) —
/// mirrors `python/compile/kernels/ref.py`.
pub const A8_QMAX: f32 = 127.0;

/// Below this many multiply-accumulates a GEMM runs inline on the caller
/// thread: thread spawns would dominate (determinism is unaffected — the
/// per-element op order is the same for any thread count).
const PAR_THRESHOLD: usize = 1 << 15;

/// Quantized weight payload of a linear layer.
pub enum QData<'v> {
    /// int8 lattice values, read straight from the store / plane slabs.
    I8(Cow<'v, [i8]>),
    /// Nibble-packed INT4: two lattice values per byte, unpacked row-wise
    /// in the inner loop (half the weight memory traffic of the i8 path).
    PackedInt4(Vec<u8>),
}

/// One linear layer's weights, layout `[rows=K, cols=N]` row-major with
/// one scale per column (output channel) for the quantized forms.
pub enum Lin<'v> {
    Fp {
        w: &'v [f32],
        rows: usize,
        cols: usize,
    },
    Quant {
        q: QData<'v>,
        scale: &'v [f32],
        rows: usize,
        cols: usize,
        /// W8A8: additionally quantize activations to INT8 per tensor.
        a8: bool,
        /// K-major (transposed, `[cols, rows]`) nibble pack for the
        /// decode path: one cache-resident `dot_packed_int4` per output
        /// channel instead of streaming N-sized axpy rows K times. Built
        /// on demand ([`Lin::with_decode_pack`]); INT4 only.
        kmajor: Option<Vec<u8>>,
    },
}

impl<'v> Lin<'v> {
    /// Build from lattice values + scales per the run format: INT4 packs
    /// the nibbles once here (O(K·N/2), amortized over the whole forward
    /// call); INT8/W8A8 keep the i8 slab as-is (zero-copy when borrowed).
    pub fn from_lattice(
        q: Cow<'v, [i8]>,
        scale: &'v [f32],
        rows: usize,
        cols: usize,
        format: Format,
    ) -> Lin<'v> {
        debug_assert_eq!(q.len(), rows * cols);
        debug_assert_eq!(scale.len(), cols);
        let qd = match format {
            Format::Int4 => QData::PackedInt4(pack_int4(&q)),
            _ => QData::I8(q),
        };
        Lin::Quant { q: qd, scale, rows, cols, a8: format == Format::W8A8, kmajor: None }
    }

    /// Additionally build the K-major decode pack (INT4 only; a no-op for
    /// every other layout). Costs one extra transpose + pack, O(K·N/2)
    /// bytes — callers that run many decode steps against the same
    /// weights (the generation scheduler) amortize it; one-shot forwards
    /// should skip it.
    pub fn with_decode_pack(mut self) -> Lin<'v> {
        if let Lin::Quant { q: QData::PackedInt4(bytes), rows, cols, kmajor, .. } = &mut self {
            if kmajor.is_none() {
                // unpack row-wise (the packed bytes are the source of
                // truth), transpose to [N, K], repack
                let (k, n) = (*rows, *cols);
                let mut row = vec![0i8; n];
                let mut qt = vec![0i8; k * n];
                for r in 0..k {
                    unpack_int4_row(bytes, r * n, &mut row);
                    for c in 0..n {
                        qt[c * k + r] = row[c];
                    }
                }
                *kmajor = Some(pack_int4(&qt));
            }
        }
        self
    }

    /// Does this layout carry the K-major decode pack?
    pub fn has_decode_pack(&self) -> bool {
        matches!(self, Lin::Quant { kmajor: Some(_), .. })
    }

    pub fn rows(&self) -> usize {
        match self {
            Lin::Fp { rows, .. } | Lin::Quant { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Lin::Fp { cols, .. } | Lin::Quant { cols, .. } => *cols,
        }
    }
}

/// `out[M,N] = x[M,K] @ W` with fused dequantization, on the
/// process-wide dispatched microkernel. Bit-identical for any `threads`
/// and any kernel backend (see module docs).
pub fn matmul(x: &[f32], m: usize, lin: &Lin<'_>, out: &mut [f32], threads: usize) {
    matmul_with(x, m, lin, out, threads, kernel::active_kernel());
}

/// [`matmul`] on an explicit microkernel backend — what the conformance
/// tests and benches use to pin scalar vs SIMD against each other.
pub fn matmul_with(
    x: &[f32],
    m: usize,
    lin: &Lin<'_>,
    out: &mut [f32],
    threads: usize,
    kr: &dyn DotKernel,
) {
    let (k, n) = (lin.rows(), lin.cols());
    assert_eq!(x.len(), m * k, "gemm: x is {} elems, want {}x{}", x.len(), m, k);
    assert_eq!(out.len(), m * n, "gemm: out is {} elems, want {}x{}", out.len(), m, n);
    if m == 0 {
        return;
    }
    match lin {
        Lin::Fp { w, .. } => {
            par_rows(x, m, k, n, out, threads, 0, |xr, or, _| fp_row(kr, xr, w, n, or));
        }
        Lin::Quant { q, scale, a8: false, .. } => match q {
            QData::I8(qv) => par_rows(x, m, k, n, out, threads, 0, |xr, or, _| {
                i8_row(kr, xr, qv, n, or);
                apply_scale(or, scale, 1.0);
            }),
            QData::PackedInt4(bytes) => par_rows(x, m, k, n, out, threads, n, |xr, or, sc| {
                packed_row(kr, xr, bytes, n, or, sc);
                apply_scale(or, scale, 1.0);
            }),
        },
        Lin::Quant { q, scale, a8: true, .. } => {
            // dynamic per-tensor INT8 activation grid; integer products
            // accumulate exactly in f32 (|xq·q| <= 127·127 << 2^24)
            let (xq, xs) = quantize_act(x);
            match q {
                QData::I8(qv) => par_rows(&xq, m, k, n, out, threads, 0, |xr, or, _| {
                    i8_row(kr, xr, qv, n, or);
                    apply_scale(or, scale, xs);
                }),
                QData::PackedInt4(bytes) => {
                    par_rows(&xq, m, k, n, out, threads, n, |xr, or, sc| {
                        packed_row(kr, xr, bytes, n, or, sc);
                        apply_scale(or, scale, xs);
                    })
                }
            }
        }
    }
}

/// Cross-member grouped GEMM: `out[r] = x[r] @ lins[assign[r]]` — ONE
/// call serves every population member's rows, so per-call overheads
/// (dispatch, thread-block setup, activation-grid scan) and the
/// resolve/pack that produced `lins` are paid once per round instead of
/// once per member. All `lins` must share shape and layout (they come
/// from one [`Lin::from_lattice`] resolve over the same snapshot).
///
/// # Determinism
///
/// Bit-identical to the per-member sequential path BY CONSTRUCTION: each
/// output row is computed from its own input row and its own member's
/// weights through the very same row helpers (`fp_row`/`i8_row`/
/// `packed_row` + `apply_scale`) in the same K order, on one thread. The
/// W8A8 activation grid is computed PER MEMBER over exactly that
/// member's rows (f32 absmax is order-independent), so even the a8 form
/// matches the per-member call whenever the member's row set matches.
/// K-major decode packs are deliberately ignored here: grouping is the
/// contracted training form, and the reassociating K-major fast form
/// stays serving-only (single-member `matmul_decode`).
pub fn matmul_grouped_with(
    x: &[f32],
    m: usize,
    lins: &[&Lin<'_>],
    assign: &[usize],
    out: &mut [f32],
    threads: usize,
    kr: &dyn DotKernel,
) {
    assert!(!lins.is_empty(), "grouped gemm: no members");
    let (k, n) = (lins[0].rows(), lins[0].cols());
    assert_eq!(x.len(), m * k, "grouped gemm: x is {} elems, want {}x{}", x.len(), m, k);
    assert_eq!(out.len(), m * n, "grouped gemm: out is {} elems, want {}x{}", out.len(), m, n);
    assert_eq!(assign.len(), m, "grouped gemm: assign len {} != m {}", assign.len(), m);
    for lin in lins {
        assert_eq!((lin.rows(), lin.cols()), (k, n), "grouped gemm: mixed member shapes");
    }
    assert!(assign.iter().all(|&a| a < lins.len()), "grouped gemm: member id out of range");
    if m == 0 {
        return;
    }
    if lins.len() == 1 {
        // degenerate population: exactly the single-member path
        return matmul_with(x, m, lins[0], out, threads, kr);
    }
    let a8 = matches!(lins[0], Lin::Quant { a8: true, .. });
    // per-member dynamic activation grids (identity extras when !a8)
    let (xq, extras) = if a8 {
        quantize_act_grouped(x, m, k, assign, lins.len())
    } else {
        (Vec::new(), vec![1.0f32; lins.len()])
    };
    let xa = if a8 { xq.as_slice() } else { x };
    match lins[0] {
        Lin::Fp { .. } => par_rows_idx(x, m, k, n, out, threads, 0, |r, xr, or, _| {
            let Lin::Fp { w, .. } = lins[assign[r]] else {
                unreachable!("grouped gemm: mixed member layouts")
            };
            fp_row(kr, xr, w, n, or);
        }),
        Lin::Quant { q: QData::I8(_), .. } => {
            par_rows_idx(xa, m, k, n, out, threads, 0, |r, xr, or, _| {
                let mi = assign[r];
                let Lin::Quant { q: QData::I8(qv), scale, .. } = lins[mi] else {
                    unreachable!("grouped gemm: mixed member layouts")
                };
                i8_row(kr, xr, qv, n, or);
                apply_scale(or, scale, extras[mi]);
            })
        }
        Lin::Quant { q: QData::PackedInt4(_), .. } => {
            par_rows_idx(xa, m, k, n, out, threads, n, |r, xr, or, sc| {
                let mi = assign[r];
                let Lin::Quant { q: QData::PackedInt4(bytes), scale, .. } = lins[mi] else {
                    unreachable!("grouped gemm: mixed member layouts")
                };
                packed_row(kr, xr, bytes, n, or, sc);
                apply_scale(or, scale, extras[mi]);
            })
        }
    }
}

/// Per-member W8A8 activation grids for the grouped path: member `j`'s
/// scale is computed from the absmax over exactly the rows assigned to
/// `j`, so each member's grid matches what the per-member sequential
/// call would have produced over the same rows (f32 max is
/// order-independent, `round_ties_even` is element-local).
fn quantize_act_grouped(
    x: &[f32],
    m: usize,
    k: usize,
    assign: &[usize],
    n_members: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut absmax = vec![0.0f32; n_members];
    for r in 0..m {
        let am = &mut absmax[assign[r]];
        *am = x[r * k..(r + 1) * k].iter().fold(*am, |a, &v| a.max(v.abs()));
    }
    let scales: Vec<f32> = absmax.iter().map(|&am| am.max(1e-8) / A8_QMAX).collect();
    let mut q = vec![0.0f32; m * k];
    for r in 0..m {
        let s = scales[assign[r]];
        for (qv, &v) in q[r * k..(r + 1) * k].iter_mut().zip(&x[r * k..(r + 1) * k]) {
            *qv = round_ties_even(v / s).clamp(-A8_QMAX, A8_QMAX);
        }
    }
    (q, scales)
}

/// Decode-step GEMM: [`matmul_with`] that routes INT4 layouts carrying a
/// K-major pack ([`Lin::with_decode_pack`]) through
/// [`DotKernel::dot_packed_int4`] — one cache-resident dot per output
/// channel instead of K streaming passes over N-sized axpy rows, which is
/// the right shape for the small-M decode step (M = live sequences, often
/// 1). Layouts without a decode pack fall back to the axpy form.
///
/// # Determinism
///
/// Every output element is still computed by exactly one thread from its
/// own input row and the fixed weight bytes, so results are bit-identical
/// for any `m`, row order and thread count. Across KERNEL backends this
/// path is tolerance-close, not bit-identical: `dot_packed_int4` is the
/// one reassociating primitive (SIMD reduces K in the pinned 8-lane FMA
/// layout; the scalar backend keeps the sequential order, which makes
/// scalar decode bit-identical to the axpy form). The generation
/// scheduler's batch-invariance contract is therefore stated on output
/// TOKENS, which the conformance suite pins across kernels.
pub fn matmul_decode(
    x: &[f32],
    m: usize,
    lin: &Lin<'_>,
    out: &mut [f32],
    threads: usize,
    kr: &dyn DotKernel,
) {
    let (k, n) = (lin.rows(), lin.cols());
    if let Lin::Quant { kmajor: Some(bytes_t), scale, a8, .. } = lin {
        assert_eq!(x.len(), m * k, "decode gemm: x is {} elems, want {}x{}", x.len(), m, k);
        assert_eq!(out.len(), m * n, "decode gemm: out is {} elems, want {}x{}", out.len(), m, n);
        if m == 0 {
            return;
        }
        let (xq, xs) = if *a8 { quantize_act(x) } else { (Vec::new(), 1.0) };
        let xr = if *a8 { xq.as_slice() } else { x };
        par_rows(xr, m, k, n, out, threads, 0, |xrow, orow, _| {
            for (c, o) in orow.iter_mut().enumerate() {
                *o = kr.dot_packed_int4(bytes_t, c * k, xrow) * (scale[c] * xs);
            }
        });
    } else {
        matmul_with(x, m, lin, out, threads, kr);
    }
}

/// The historical per-member cost the fused path eliminates: materialize
/// the f32 weight tensor (dequantizing when quantized), then a plain f32
/// matmul. Benchmark baseline + property-test reference; weight-only
/// formats (W8A8's activation grid has its own oracle in the tests).
pub fn dequant_then_matmul(x: &[f32], m: usize, lin: &Lin<'_>, out: &mut [f32]) {
    let (k, n) = (lin.rows(), lin.cols());
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m * n);
    // follows the SAME dispatched microkernel as the fused path, so the
    // long-tracked dequant-vs-fused BENCH speedup keeps measuring fusion
    // alone (the ISA dimension has its own forward_gemm/simd records);
    // as the property-test reference this is equally valid on any
    // backend — axpy is bit-identical across them by contract
    let kr = kernel::active_kernel();
    match lin {
        Lin::Fp { w, .. } => {
            par_rows(x, m, k, n, out, 1, 0, |xr, or, _| fp_row(kr, xr, w, n, or));
        }
        Lin::Quant { q, scale, rows, cols, a8, .. } => {
            assert!(!a8, "dequant_then_matmul is the weight-only reference");
            let wf = dequant_full(q, scale, *rows, *cols);
            par_rows(x, m, k, n, out, 1, 0, |xr, or, _| fp_row(kr, xr, &wf, n, or));
        }
    }
}

/// Materialize the full f32 weight tensor (reference path only).
pub fn dequant_full(q: &QData<'_>, scale: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut w = vec![0.0f32; rows * cols];
    match q {
        QData::I8(qv) => {
            for r in 0..rows {
                for c in 0..cols {
                    w[r * cols + c] = qv[r * cols + c] as f32 * scale[c];
                }
            }
        }
        QData::PackedInt4(bytes) => {
            let mut row = vec![0i8; cols];
            for r in 0..rows {
                unpack_int4_row(bytes, r * cols, &mut row);
                for c in 0..cols {
                    w[r * cols + c] = row[c] as f32 * scale[c];
                }
            }
        }
    }
    w
}

/// Round half-to-even (banker's rounding) — `jnp.round`'s tie rule, so
/// W8A8 activation grids agree with the PJRT kernels at exact .5 grid
/// points (`f32::round` rounds ties away from zero).
#[inline]
fn round_ties_even(x: f32) -> f32 {
    let r = x.round();
    if (r - x).abs() == 0.5 && r % 2.0 != 0.0 {
        r - (r - x).signum() // tie landed on an odd integer: step to the even one
    } else {
        r
    }
}

/// Dynamic symmetric per-tensor INT8 activation quantization:
/// `q = clip(round(x/s), ±127)`, `s = max(absmax, 1e-8)/127`. The
/// quantized values are exact small integers held in f32; rounding is
/// half-to-even to match `ref.py`'s `jnp.round`.
pub fn quantize_act(x: &[f32]) -> (Vec<f32>, f32) {
    let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let s = absmax.max(1e-8) / A8_QMAX;
    let q = x.iter().map(|&v| round_ties_even(v / s).clamp(-A8_QMAX, A8_QMAX)).collect();
    (q, s)
}

/// Distribute output rows over threads in contiguous blocks; each block
/// gets one `scratch_len`-sized i8 scratch (the packed path's row
/// buffer). Falls back to inline execution for small problems.
fn par_rows<F>(
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
    scratch_len: usize,
    f: F,
) where
    F: Fn(&[f32], &mut [f32], &mut [i8]) + Sync,
{
    par_rows_idx(x, m, k, n, out, threads, scratch_len, |_, xr, or, sc| f(xr, or, sc));
}

/// [`par_rows`] whose closure additionally receives the global row index
/// — the grouped path uses it to look up the row's member assignment.
/// Same blocking, same per-row op order, same thread-count invariance.
#[allow(clippy::too_many_arguments)]
fn par_rows_idx<F>(
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
    scratch_len: usize,
    f: F,
) where
    F: Fn(usize, &[f32], &mut [f32], &mut [i8]) + Sync,
{
    let threads = if m * k * n < PAR_THRESHOLD { 1 } else { threads.clamp(1, m) };
    if threads <= 1 {
        let mut scratch = vec![0i8; scratch_len];
        for r in 0..m {
            f(r, &x[r * k..(r + 1) * k], &mut out[r * n..(r + 1) * n], &mut scratch);
        }
        return;
    }
    let block = (m + threads - 1) / threads;
    let tasks: Vec<(usize, &mut [f32])> = out.chunks_mut(block * n).enumerate().collect();
    let fref = &f;
    parallel::map_tasks(tasks, threads, move |(bi, oblk)| {
        let mut scratch = vec![0i8; scratch_len];
        let r0 = bi * block;
        for (ri, orow) in oblk.chunks_mut(n).enumerate() {
            let r = r0 + ri;
            fref(r, &x[r * k..(r + 1) * k], orow, &mut scratch);
        }
    });
}

fn fp_row(kr: &dyn DotKernel, xrow: &[f32], w: &[f32], n: usize, orow: &mut [f32]) {
    orow.fill(0.0);
    for (r, &xv) in xrow.iter().enumerate() {
        kr.axpy_f32(orow, xv, &w[r * n..(r + 1) * n]);
    }
}

fn i8_row(kr: &dyn DotKernel, xrow: &[f32], q: &[i8], n: usize, orow: &mut [f32]) {
    orow.fill(0.0);
    for (r, &xv) in xrow.iter().enumerate() {
        kr.axpy_i8(orow, xv, &q[r * n..(r + 1) * n]);
    }
}

fn packed_row(
    kr: &dyn DotKernel,
    xrow: &[f32],
    bytes: &[u8],
    n: usize,
    orow: &mut [f32],
    scratch: &mut [i8],
) {
    orow.fill(0.0);
    for (r, &xv) in xrow.iter().enumerate() {
        kr.unpack_int4_row(bytes, r * n, scratch);
        kr.axpy_i8(orow, xv, scratch);
    }
}

#[inline]
fn apply_scale(orow: &mut [f32], scale: &[f32], extra: f32) {
    for (o, &s) in orow.iter_mut().zip(scale.iter()) {
        *o *= s * extra;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelKind;
    use crate::util::prop::{prop_check, Gen};

    fn rand_quant(g: &mut Gen, rows: usize, cols: usize, qmax: i8) -> (Vec<i8>, Vec<f32>) {
        let q = g.vec_i8(rows * cols, -qmax, qmax);
        let scale: Vec<f32> = g.vec_f32(cols, 0.001, 0.1);
        (q, scale)
    }

    #[test]
    fn fused_matches_dequant_reference() {
        prop_check("fused gemm vs dequant-then-matmul", 40, |g| {
            let m = g.usize_in(1, 9);
            let k = g.usize_in(1, 40);
            let n = g.usize_in(1, 40);
            let x = g.vec_f32(m * k, -1.0, 1.0);
            for fmt in [Format::Int4, Format::Int8] {
                let (q, scale) = rand_quant(g, k, n, fmt.qmax());
                let lin = Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, fmt);
                let mut fused = vec![0.0f32; m * n];
                let mut reference = vec![0.0f32; m * n];
                matmul(&x, m, &lin, &mut fused, 4);
                dequant_then_matmul(&x, m, &lin, &mut reference);
                for i in 0..m * n {
                    let err = (fused[i] - reference[i]).abs();
                    let tol = 1e-4 * reference[i].abs().max(1.0);
                    if err > tol {
                        return Err(format!(
                            "{:?} elem {}: fused {} vs ref {}",
                            fmt, i, fused[i], reference[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fused_fp_matches_reference_exactly() {
        let mut g = Gen::from_seed(7);
        let (m, k, n) = (5, 23, 31);
        let x = g.vec_f32(m * k, -1.0, 1.0);
        let w = g.vec_f32(k * n, -0.5, 0.5);
        let lin = Lin::Fp { w: &w, rows: k, cols: n };
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m * n];
        matmul(&x, m, &lin, &mut a, 8);
        dequant_then_matmul(&x, m, &lin, &mut b);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut g = Gen::from_seed(11);
        // large enough to clear PAR_THRESHOLD so threading actually kicks in
        let (m, k, n) = (64, 48, 96);
        let x = g.vec_f32(m * k, -2.0, 2.0);
        for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
            let (q, scale) = rand_quant(&mut g, k, n, fmt.qmax());
            let lin = Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, fmt);
            let mut base = vec![0.0f32; m * n];
            matmul(&x, m, &lin, &mut base, 1);
            for threads in [2usize, 8] {
                let mut out = vec![0.0f32; m * n];
                matmul(&x, m, &lin, &mut out, threads);
                assert_eq!(
                    base.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{:?} threads={}",
                    fmt,
                    threads
                );
            }
        }
    }

    #[test]
    fn bit_identical_across_kernel_backends() {
        // The SIMD extension of the determinism contract: every detected
        // microkernel backend must produce the very same forward bits as
        // the scalar one, for every format, at lane-unaligned geometry
        // (tails shorter than 8) and under threading. m*k*n must clear
        // PAR_THRESHOLD so the threads=2 leg really runs the row-block
        // scheduling path, not the inline fallback.
        let mut g = Gen::from_seed(23);
        let (m, k, n) = (24, 37, 53);
        assert!(m * k * n >= PAR_THRESHOLD);
        let x = g.vec_f32(m * k, -2.0, 2.0);
        let scalar = kernel::by_kind(KernelKind::Scalar);
        for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
            let (q, scale) = rand_quant(&mut g, k, n, fmt.qmax());
            let lin = Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, fmt);
            let mut base = vec![0.0f32; m * n];
            matmul_with(&x, m, &lin, &mut base, 1, scalar);
            for kind in kernel::available() {
                for threads in [1usize, 2] {
                    let mut out = vec![0.0f32; m * n];
                    matmul_with(&x, m, &lin, &mut out, threads, kernel::by_kind(kind));
                    assert_eq!(
                        base.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{:?} kernel={} threads={}",
                        fmt,
                        kind.name(),
                        threads
                    );
                }
            }
        }
        let w = g.vec_f32(k * n, -0.5, 0.5);
        let lin = Lin::Fp { w: &w, rows: k, cols: n };
        let mut base = vec![0.0f32; m * n];
        matmul_with(&x, m, &lin, &mut base, 1, scalar);
        for kind in kernel::available() {
            let mut out = vec![0.0f32; m * n];
            matmul_with(&x, m, &lin, &mut out, 2, kernel::by_kind(kind));
            assert_eq!(
                base.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fp kernel={}",
                kind.name()
            );
        }
    }

    #[test]
    fn decode_pack_matches_axpy_form() {
        // K-major decode GEMM vs the row-major axpy form: the scalar
        // kernel's dot IS the sequential K-order accumulation, i.e. the
        // exact op sequence of the axpy form — bit-identical. SIMD
        // backends reduce in the pinned 8-lane layout and must land
        // within reassociation tolerance.
        prop_check("kmajor decode gemm vs axpy", 40, |g| {
            let m = g.usize_in(1, 5);
            let k = g.usize_in(1, 60);
            let n = g.usize_in(1, 40);
            let x = g.vec_f32(m * k, -1.0, 1.0);
            let (q, scale) = rand_quant(g, k, n, 7);
            let lin = Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, Format::Int4)
                .with_decode_pack();
            assert!(lin.has_decode_pack());
            let mut axpy = vec![0.0f32; m * n];
            matmul_with(&x, m, &lin, &mut axpy, 1, kernel::by_kind(KernelKind::Scalar));
            let mut dec = vec![0.0f32; m * n];
            matmul_decode(&x, m, &lin, &mut dec, 1, kernel::by_kind(KernelKind::Scalar));
            for i in 0..m * n {
                if dec[i].to_bits() != axpy[i].to_bits() {
                    return Err(format!(
                        "scalar kmajor != axpy at {}: {} vs {}",
                        i, dec[i], axpy[i]
                    ));
                }
            }
            for kind in kernel::available() {
                for threads in [1usize, 2] {
                    let mut out = vec![0.0f32; m * n];
                    matmul_decode(&x, m, &lin, &mut out, threads, kernel::by_kind(kind));
                    for i in 0..m * n {
                        let tol = 1e-4 * axpy[i].abs().max(1.0);
                        if (out[i] - axpy[i]).abs() > tol {
                            return Err(format!(
                                "{} threads={} elem {}: {} vs {}",
                                kind.name(),
                                threads,
                                i,
                                out[i],
                                axpy[i]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn decode_pack_thread_invariant_and_fallbacks() {
        let mut g = Gen::from_seed(17);
        let (m, k, n) = (8usize, 96, 80);
        let x = g.vec_f32(m * k, -2.0, 2.0);
        let (q, scale) = rand_quant(&mut g, k, n, 7);
        let lin =
            Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, Format::Int4).with_decode_pack();
        let kr = kernel::active_kernel();
        let mut base = vec![0.0f32; m * n];
        matmul_decode(&x, m, &lin, &mut base, 1, kr);
        for threads in [2usize, 8] {
            let mut out = vec![0.0f32; m * n];
            matmul_decode(&x, m, &lin, &mut out, threads, kr);
            assert_eq!(
                base.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={}",
                threads
            );
        }
        // non-int4 layouts: with_decode_pack is a no-op and matmul_decode
        // falls back to the axpy form bit-for-bit
        for fmt in [Format::Int8, Format::W8A8] {
            let (q8, s8) = rand_quant(&mut g, k, n, fmt.qmax());
            let lin8 =
                Lin::from_lattice(Cow::Borrowed(&q8), &s8, k, n, fmt).with_decode_pack();
            assert!(!lin8.has_decode_pack());
            let mut a = vec![0.0f32; m * n];
            let mut b = vec![0.0f32; m * n];
            matmul_decode(&x, m, &lin8, &mut a, 1, kr);
            matmul_with(&x, m, &lin8, &mut b, 1, kr);
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn w8a8_matches_integer_grid_oracle() {
        prop_check("w8a8 gemm vs ref.py oracle", 30, |g| {
            let m = g.usize_in(1, 6);
            let k = g.usize_in(1, 24);
            let n = g.usize_in(1, 24);
            let x = g.vec_f32(m * k, -1.0, 1.0);
            let (q, scale) = rand_quant(g, k, n, 127);
            let lin = Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, Format::W8A8);
            let mut fused = vec![0.0f32; m * n];
            matmul(&x, m, &lin, &mut fused, 2);
            // oracle: quantize acts, integer matmul, dequantize (ref.py)
            let (xq, xs) = quantize_act(&x);
            for r in 0..m {
                for c in 0..n {
                    let mut acc = 0.0f32;
                    for j in 0..k {
                        acc += xq[r * k + j] * q[j * n + c] as f32;
                    }
                    let want = acc * xs * scale[c];
                    let got = fused[r * n + c];
                    if (want - got).abs() > 1e-4 * want.abs().max(1.0) {
                        return Err(format!("({},{}): {} vs {}", r, c, got, want));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rounding_is_half_to_even() {
        // jnp.round semantics: ties go to the even integer
        for (x, want) in [
            (0.5f32, 0.0f32),
            (-0.5, 0.0),
            (1.5, 2.0),
            (2.5, 2.0),
            (-2.5, -2.0),
            (3.5, 4.0),
            (0.4999, 0.0),
            (0.5001, 1.0),
            (-1.2, -1.0),
        ] {
            assert_eq!(round_ties_even(x), want, "x={}", x);
        }
    }

    /// Per-member reference for the grouped entry: gather each member's
    /// rows, run the single-member path on them, scatter back.
    fn per_member_reference(
        x: &[f32],
        m: usize,
        lins: &[&Lin<'_>],
        assign: &[usize],
        kr: &dyn DotKernel,
    ) -> Vec<f32> {
        let (k, n) = (lins[0].rows(), lins[0].cols());
        let mut out = vec![0.0f32; m * n];
        for (mi, lin) in lins.iter().enumerate() {
            let rows: Vec<usize> = (0..m).filter(|&r| assign[r] == mi).collect();
            let mut xm = Vec::with_capacity(rows.len() * k);
            for &r in &rows {
                xm.extend_from_slice(&x[r * k..(r + 1) * k]);
            }
            let mut om = vec![0.0f32; rows.len() * n];
            matmul_with(&xm, rows.len(), lin, &mut om, 1, kr);
            for (i, &r) in rows.iter().enumerate() {
                out[r * n..(r + 1) * n].copy_from_slice(&om[i * n..(i + 1) * n]);
            }
        }
        out
    }

    #[test]
    fn grouped_matches_per_member_reference() {
        // The tentpole equivalence: ONE grouped call over every member's
        // rows must reproduce the per-member sequential calls bit-for-bit
        // — every format (incl. the per-member W8A8 activation grids),
        // every kernel backend, any thread count, odd shapes, uneven and
        // empty member row sets.
        prop_check("grouped gemm vs per-member sequential", 25, |g| {
            let members = g.usize_in(1, 5);
            let m = g.usize_in(1, 13);
            let k = g.usize_in(1, 40);
            let n = g.usize_in(1, 40);
            let x = g.vec_f32(m * k, -1.0, 1.0);
            // random assignment: some members may own zero rows
            let assign: Vec<usize> = (0..m).map(|_| g.usize_in(0, members - 1)).collect();
            let scalar = kernel::by_kind(KernelKind::Scalar);
            for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
                let qs: Vec<(Vec<i8>, Vec<f32>)> =
                    (0..members).map(|_| rand_quant(g, k, n, fmt.qmax())).collect();
                let lins: Vec<Lin> = qs
                    .iter()
                    .map(|(q, s)| Lin::from_lattice(Cow::Borrowed(q), s, k, n, fmt))
                    .collect();
                let refs: Vec<&Lin> = lins.iter().collect();
                let want = per_member_reference(&x, m, &refs, &assign, scalar);
                for kind in kernel::available() {
                    for threads in [1usize, 3] {
                        let mut got = vec![0.0f32; m * n];
                        matmul_grouped_with(
                            &x,
                            m,
                            &refs,
                            &assign,
                            &mut got,
                            threads,
                            kernel::by_kind(kind),
                        );
                        for i in 0..m * n {
                            if got[i].to_bits() != want[i].to_bits() {
                                return Err(format!(
                                    "{:?} kernel={} threads={} members={} elem {}: {} vs {}",
                                    fmt,
                                    kind.name(),
                                    threads,
                                    members,
                                    i,
                                    got[i],
                                    want[i]
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_bit_identical_above_par_threshold() {
        // Same equivalence at a geometry that clears PAR_THRESHOLD, so
        // the threaded row-block scheduling path really runs, plus the
        // fp32 layout (shared LN/embedding tensors go through it).
        let mut g = Gen::from_seed(31);
        let (members, m, k, n) = (3usize, 24usize, 37usize, 53usize);
        assert!(m * k * n >= PAR_THRESHOLD);
        let x = g.vec_f32(m * k, -2.0, 2.0);
        let assign: Vec<usize> = (0..m).map(|r| r % members).collect();
        let scalar = kernel::by_kind(KernelKind::Scalar);
        for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
            let qs: Vec<(Vec<i8>, Vec<f32>)> =
                (0..members).map(|_| rand_quant(&mut g, k, n, fmt.qmax())).collect();
            let lins: Vec<Lin> = qs
                .iter()
                .map(|(q, s)| Lin::from_lattice(Cow::Borrowed(q), s, k, n, fmt))
                .collect();
            let refs: Vec<&Lin> = lins.iter().collect();
            let want = per_member_reference(&x, m, &refs, &assign, scalar);
            for kind in kernel::available() {
                for threads in [1usize, 2, 8] {
                    let mut got = vec![0.0f32; m * n];
                    matmul_grouped_with(&x, m, &refs, &assign, &mut got, threads, kernel::by_kind(kind));
                    assert_eq!(
                        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{:?} kernel={} threads={}",
                        fmt,
                        kind.name(),
                        threads
                    );
                }
            }
        }
        // fp32 members (grouped LN-adjacent layers share one fp tensor,
        // but the entry must still honor per-member fp weights)
        let ws: Vec<Vec<f32>> = (0..members).map(|_| g.vec_f32(k * n, -0.5, 0.5)).collect();
        let lins: Vec<Lin> = ws.iter().map(|w| Lin::Fp { w, rows: k, cols: n }).collect();
        let refs: Vec<&Lin> = lins.iter().collect();
        let want = per_member_reference(&x, m, &refs, &assign, scalar);
        for kind in kernel::available() {
            let mut got = vec![0.0f32; m * n];
            matmul_grouped_with(&x, m, &refs, &assign, &mut got, 2, kernel::by_kind(kind));
            assert_eq!(
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fp kernel={}",
                kind.name()
            );
        }
    }

    #[test]
    fn grouped_single_member_is_exactly_matmul_with() {
        let mut g = Gen::from_seed(41);
        let (m, k, n) = (6usize, 33, 29);
        let x = g.vec_f32(m * k, -1.0, 1.0);
        let (q, scale) = rand_quant(&mut g, k, n, 7);
        let lin = Lin::from_lattice(Cow::Borrowed(&q), &scale, k, n, Format::Int4);
        let assign = vec![0usize; m];
        let mut a = vec![0.0f32; m * n];
        let mut b = vec![0.0f32; m * n];
        let kr = kernel::active_kernel();
        matmul_grouped_with(&x, m, &[&lin], &assign, &mut a, 2, kr);
        matmul_with(&x, m, &lin, &mut b, 2, kr);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quantize_act_grid_properties() {
        let (q, s) = quantize_act(&[0.0, 0.5, -1.0, 0.25]);
        assert!((s - 1.0 / 127.0).abs() < 1e-9);
        assert_eq!(q[2], -127.0);
        assert_eq!(q[0], 0.0);
        // all values are integers on the grid
        assert!(q.iter().all(|&v| v == v.round() && v.abs() <= 127.0));
        // all-zero tensor hits the epsilon floor, no NaNs
        let (qz, sz) = quantize_act(&[0.0; 8]);
        assert!(sz > 0.0 && qz.iter().all(|&v| v == 0.0));
    }
}
