//! Reverse-mode gradients for the fp-format forward pass — the native
//! equivalent of the AOT `grad` artifact (`jax.value_and_grad` over
//! model.py's `mean_loss`). Powers pretraining and the FO/STE baselines
//! on the offline build.
//!
//! The forward IS [`super::forward_full`] in cache-capture mode — one
//! source of truth for the op sequence; this module adds only the head
//! (whose layernorm statistics and full logits the backward consumes)
//! and the hand-derived backward itself. Gradients come back in
//! store-entry order, ready for `opt::Adam::step`. Single-threaded: the
//! pretraining sizes are tiny and grad determinism needs no tuning knob.

use anyhow::Result;

use crate::kernel;
use crate::model::{AsParams, ParamStore};
use crate::quant::Format;
use crate::runtime::encode::LmBatch;
use crate::runtime::manifest::ModelConfig;

use super::gemm::{self, Lin};

/// `(mean loss, per-entry gradients)` for a teacher-forced LM batch.
pub fn lm_grads(
    cfg: &ModelConfig,
    store: &ParamStore,
    batch: &LmBatch,
) -> Result<(f32, Vec<Vec<f32>>)> {
    let refs = ModelRefs::resolve(cfg, store)?;
    let (b, s) = (cfg.b_train, cfg.s_train);
    let d = cfg.d_model;
    let f = cfg.d_ff;
    let v = cfg.vocab;
    let heads = cfg.n_heads;
    let dh = d / heads;
    let rows = b * s;
    let w = |i: usize| store.entries[i].data.as_f32();

    // ---- forward: the shared layer stack in cache-capture mode ---------
    let view = store.params_view();
    let p = super::resolve(cfg, Format::Fp32, &view, None, None, false)?;
    let mut caches: Vec<LayerCache> = Vec::with_capacity(refs.layers.len());
    let fw = super::forward_full(
        cfg,
        1,
        kernel::active_kernel(),
        &p,
        &batch.tokens,
        &batch.pos_ids,
        &batch.mask,
        b,
        s,
        false,
        Some(&mut caches),
    );
    let h = fw.h;
    let tok_emb = w(refs.tok_emb);
    // final norm (statistics captured for the backward) + weight-tied head
    let mut hf = vec![0.0f32; rows * d];
    let mut xhatf = vec![0.0f32; rows * d];
    let mut rstdf = vec![0.0f32; rows];
    super::layernorm_stats(
        &h,
        d,
        w(refs.lnf_g),
        w(refs.lnf_b),
        &mut hf,
        Some((&mut xhatf, &mut rstdf)),
    );
    // weight-tied head on the resolved emb_t operand, through the
    // dispatched GEMM (lnf statistics were captured above, so this is
    // logits only — same hf bits the backward consumes)
    let mut logits = vec![0.0f32; rows * v];
    let head = Lin::Fp { w: p.emb_t.as_ref(), rows: d, cols: v };
    gemm::matmul_with(&hf, rows, &head, &mut logits, 1, kernel::active_kernel());
    // masked CE + dlogits in one pass
    let n_tok: f32 = batch.loss_mask.iter().sum();
    let n_tok = n_tok.max(1.0);
    let mut sum_ce = 0.0f32;
    let mut dlogits = vec![0.0f32; rows * v];
    for r in 0..rows {
        let lm = batch.loss_mask[r];
        if lm == 0.0 {
            continue;
        }
        let row = &logits[r * v..(r + 1) * v];
        let target = batch.targets[r] as usize;
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &l in row {
            sum += (l - m).exp();
        }
        let logz = m + sum.ln();
        sum_ce += (logz - row[target]) * lm;
        let gscale = lm / n_tok;
        for c in 0..v {
            let p = (row[c] - logz).exp();
            dlogits[r * v + c] =
                gscale * (p - if c == target { 1.0 } else { 0.0 });
        }
    }
    let loss = sum_ce / n_tok;

    // ---- backward -------------------------------------------------------
    let mut grads: Vec<Vec<f32>> =
        store.entries.iter().map(|e| vec![0.0f32; e.numel()]).collect();

    // head: dhf = dlogits @ E; dE += dlogits^T @ hf (weight tying)
    let mut dhf = vec![0.0f32; rows * d];
    matmul_ab(&dlogits, tok_emb, rows, v, d, &mut dhf);
    matmul_at_b(&dlogits, &hf, rows, v, d, &mut grads[refs.tok_emb]);
    // lnf
    let mut dhid = vec![0.0f32; rows * d];
    {
        let (dg, db) = two_grads(&mut grads, refs.lnf_g, refs.lnf_b);
        layernorm_bwd(&dhf, &xhatf, &rstdf, w(refs.lnf_g), d, dg, db, &mut dhid);
    }

    for (lr, c) in refs.layers.iter().zip(caches.iter()).rev() {
        // MLP block: h_out = h_mid + gelu(x2 @ W1) @ W2
        matmul_at_b(&c.gu, &dhid, rows, f, d, &mut grads[lr.w2]);
        let mut dgu = vec![0.0f32; rows * f];
        matmul_a_bt(&dhid, w(lr.w2), rows, f, d, &mut dgu);
        let mut du = dgu;
        for i in 0..rows * f {
            du[i] *= gelu_grad(c.u[i]);
        }
        matmul_at_b(&c.x2, &du, rows, d, f, &mut grads[lr.w1]);
        let mut dx2 = vec![0.0f32; rows * d];
        matmul_a_bt(&du, w(lr.w1), rows, d, f, &mut dx2);
        // ln2: residual grad + norm backward into dh_mid
        let mut dh_mid = dhid.clone();
        {
            let (dg, db) = two_grads(&mut grads, lr.ln2_g, lr.ln2_b);
            layernorm_bwd(&dx2, &c.xhat2, &c.rstd2, w(lr.ln2_g), d, dg, db, &mut dh_mid);
        }
        // attention output projection
        matmul_at_b(&c.amerge, &dh_mid, rows, d, d, &mut grads[lr.wo]);
        let mut da = vec![0.0f32; rows * d];
        matmul_a_bt(&dh_mid, w(lr.wo), rows, d, d, &mut da);
        // softmax-attention backward per (batch, head)
        let mut dq = vec![0.0f32; rows * d];
        let mut dk = vec![0.0f32; rows * d];
        let mut dv = vec![0.0f32; rows * d];
        let scale = 1.0 / (dh as f32).sqrt();
        let mut datt = vec![0.0f32; s * s];
        let mut dlog = vec![0.0f32; s * s];
        for bi in 0..b {
            for hd in 0..heads {
                let att = &c.att[((bi * heads + hd) * s) * s..((bi * heads + hd) * s + s) * s];
                let off = |sq: usize| (bi * s + sq) * d + hd * dh;
                for sq in 0..s {
                    for sk in 0..s {
                        let mut acc = 0.0f32;
                        let (ao, vo) = (off(sq), off(sk));
                        for i in 0..dh {
                            acc += da[ao + i] * c.v[vo + i];
                        }
                        datt[sq * s + sk] = acc;
                    }
                }
                // dv[sk] += att^T @ da
                for sk in 0..s {
                    let vo = off(sk);
                    for sq in 0..s {
                        let a = att[sq * s + sk];
                        if a == 0.0 {
                            continue;
                        }
                        let ao = off(sq);
                        for i in 0..dh {
                            dv[vo + i] += a * da[ao + i];
                        }
                    }
                }
                // softmax: dlog = att * (datt - rowsum(datt * att))
                for sq in 0..s {
                    let mut dot = 0.0f32;
                    for sk in 0..s {
                        dot += datt[sq * s + sk] * att[sq * s + sk];
                    }
                    for sk in 0..s {
                        dlog[sq * s + sk] = att[sq * s + sk] * (datt[sq * s + sk] - dot);
                    }
                }
                // dq = dlog @ k * scale; dk = dlog^T @ q * scale
                for sq in 0..s {
                    let qo = off(sq);
                    for sk in 0..s {
                        let g = dlog[sq * s + sk] * scale;
                        if g == 0.0 {
                            continue;
                        }
                        let ko = off(sk);
                        for i in 0..dh {
                            dq[qo + i] += g * c.k[ko + i];
                            dk[ko + i] += g * c.q[qo + i];
                        }
                    }
                }
            }
        }
        // projections into x1
        matmul_at_b(&c.x1, &dq, rows, d, d, &mut grads[lr.wq]);
        matmul_at_b(&c.x1, &dk, rows, d, d, &mut grads[lr.wk]);
        matmul_at_b(&c.x1, &dv, rows, d, d, &mut grads[lr.wv]);
        let mut dx1 = vec![0.0f32; rows * d];
        let mut tmp = vec![0.0f32; rows * d];
        matmul_a_bt(&dq, w(lr.wq), rows, d, d, &mut dx1);
        matmul_a_bt(&dk, w(lr.wk), rows, d, d, &mut tmp);
        for i in 0..rows * d {
            dx1[i] += tmp[i];
        }
        matmul_a_bt(&dv, w(lr.wv), rows, d, d, &mut tmp);
        for i in 0..rows * d {
            dx1[i] += tmp[i];
        }
        // ln1: residual grad + norm backward into dh_in
        let mut dh_in = dh_mid;
        {
            let (dg, db) = two_grads(&mut grads, lr.ln1_g, lr.ln1_b);
            layernorm_bwd(&dx1, &c.xhat1, &c.rstd1, w(lr.ln1_g), d, dg, db, &mut dh_in);
        }
        dhid = dh_in;
    }
    // embeddings
    for r in 0..rows {
        let tok = batch.tokens[r] as usize;
        let pos = batch.pos_ids[r] as usize;
        for j in 0..d {
            grads[refs.tok_emb][tok * d + j] += dhid[r * d + j];
            grads[refs.pos_emb][pos * d + j] += dhid[r * d + j];
        }
    }
    Ok((loss, grads))
}

/// GELU' for the tanh approximation used in the forward.
#[inline]
fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    const A: f32 = 0.044715;
    let t = (C * (x + A * x * x * x)).tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// Entry indices of every parameter, resolved once per call.
struct ModelRefs {
    tok_emb: usize,
    pos_emb: usize,
    lnf_g: usize,
    lnf_b: usize,
    layers: Vec<LayerRefs>,
}

struct LayerRefs {
    ln1_g: usize,
    ln1_b: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln2_g: usize,
    ln2_b: usize,
    w1: usize,
    w2: usize,
}

impl ModelRefs {
    fn resolve(cfg: &ModelConfig, store: &ParamStore) -> Result<ModelRefs> {
        let idx = |name: String| -> Result<usize> {
            store
                .entries
                .iter()
                .position(|e| e.name == name)
                .ok_or_else(|| anyhow::anyhow!("param {:?} missing from fp store", name))
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{}.", i);
            layers.push(LayerRefs {
                ln1_g: idx(format!("{}ln1.g", p))?,
                ln1_b: idx(format!("{}ln1.b", p))?,
                wq: idx(format!("{}attn.wq", p))?,
                wk: idx(format!("{}attn.wk", p))?,
                wv: idx(format!("{}attn.wv", p))?,
                wo: idx(format!("{}attn.wo", p))?,
                ln2_g: idx(format!("{}ln2.g", p))?,
                ln2_b: idx(format!("{}ln2.b", p))?,
                w1: idx(format!("{}mlp.w1", p))?,
                w2: idx(format!("{}mlp.w2", p))?,
            });
        }
        Ok(ModelRefs {
            tok_emb: idx("tok_emb".to_string())?,
            pos_emb: idx("pos_emb".to_string())?,
            lnf_g: idx("lnf.g".to_string())?,
            lnf_b: idx("lnf.b".to_string())?,
            layers,
        })
    }
}

/// Per-layer forward intermediates the backward pass consumes, filled by
/// [`super::forward_full`] in cache-capture mode.
pub(crate) struct LayerCache {
    pub(crate) xhat1: Vec<f32>,
    pub(crate) rstd1: Vec<f32>,
    pub(crate) x1: Vec<f32>,
    pub(crate) q: Vec<f32>,
    pub(crate) k: Vec<f32>,
    pub(crate) v: Vec<f32>,
    pub(crate) att: Vec<f32>,
    pub(crate) amerge: Vec<f32>,
    pub(crate) xhat2: Vec<f32>,
    pub(crate) rstd2: Vec<f32>,
    pub(crate) x2: Vec<f32>,
    pub(crate) u: Vec<f32>,
    pub(crate) gu: Vec<f32>,
}

impl LayerCache {
    pub(crate) fn new(
        rows: usize,
        d: usize,
        f: usize,
        b: usize,
        heads: usize,
        s: usize,
    ) -> LayerCache {
        LayerCache {
            xhat1: vec![0.0; rows * d],
            rstd1: vec![0.0; rows],
            x1: vec![0.0; rows * d],
            q: vec![0.0; rows * d],
            k: vec![0.0; rows * d],
            v: vec![0.0; rows * d],
            att: vec![0.0; b * heads * s * s],
            amerge: vec![0.0; rows * d],
            xhat2: vec![0.0; rows * d],
            rstd2: vec![0.0; rows],
            x2: vec![0.0; rows * d],
            u: vec![0.0; rows * f],
            gu: vec![0.0; rows * f],
        }
    }
}

/// Two disjoint gradient buffers out of the per-entry vec (split_at_mut
/// dance keyed by entry index order).
fn two_grads(grads: &mut [Vec<f32>], a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = grads.split_at_mut(b);
        (lo[a].as_mut_slice(), hi[0].as_mut_slice())
    } else {
        let (lo, hi) = grads.split_at_mut(a);
        (hi[0].as_mut_slice(), lo[b].as_mut_slice())
    }
}

/// `out = x[M,K] @ w[K,N]` (overwrite).
fn matmul_ab(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let kr = crate::kernel::active_kernel();
    for r in 0..m {
        let orow = &mut out[r * n..(r + 1) * n];
        orow.fill(0.0);
        for kk in 0..k {
            let xv = x[r * k + kk];
            if xv == 0.0 {
                continue;
            }
            kr.axpy_f32(orow, xv, &w[kk * n..(kk + 1) * n]);
        }
    }
}

/// `dx[M,K] = dy[M,N] @ w[K,N]^T` (overwrite).
fn matmul_a_bt(dy: &[f32], w: &[f32], m: usize, k: usize, n: usize, dx: &mut [f32]) {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(dx.len(), m * k);
    for r in 0..m {
        let dyr = &dy[r * n..(r + 1) * n];
        let dxr = &mut dx[r * k..(r + 1) * k];
        for kk in 0..k {
            let wr = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for c in 0..n {
                acc += dyr[c] * wr[c];
            }
            dxr[kk] = acc;
        }
    }
}

/// `dw[K,N] += x[M,K]^T @ y[M,N]` (accumulate).
fn matmul_at_b(x: &[f32], y: &[f32], m: usize, k: usize, n: usize, dw: &mut [f32]) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(y.len(), m * n);
    debug_assert_eq!(dw.len(), k * n);
    let kr = crate::kernel::active_kernel();
    for r in 0..m {
        let yr = &y[r * n..(r + 1) * n];
        for kk in 0..k {
            let xv = x[r * k + kk];
            if xv == 0.0 {
                continue;
            }
            kr.axpy_f32(&mut dw[kk * n..(kk + 1) * n], xv, yr);
        }
    }
}

/// Layernorm backward: `dg`/`db` accumulate, `dx` accumulates (residual
/// paths add into an existing gradient).
fn layernorm_bwd(
    dy: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    g: &[f32],
    d: usize,
    dg: &mut [f32],
    db: &mut [f32],
    dx: &mut [f32],
) {
    let rows = rstd.len();
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &xhat[r * d..(r + 1) * d];
        let mut m1 = 0.0f32;
        let mut m2 = 0.0f32;
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            m1 += dxh;
            m2 += dxh * xhr[j];
            dg[j] += dyr[j] * xhr[j];
            db[j] += dyr[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let rs = rstd[r];
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dx[r * d + j] += rs * (dxh - m1 - xhr[j] * m2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp;
    use crate::quant::Format;
    use crate::runtime::manifest::Manifest;

    fn setup() -> (ModelConfig, ParamStore, LmBatch) {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let cfg = man.config("nano").unwrap().clone();
        let mut store = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut store, 21);
        let task = crate::tasks::gen_task("countdown", cfg.s_prompt, cfg.t_dec).unwrap();
        let mut rng = crate::rng::SplitMix64::new(13);
        let pairs: Vec<(String, String)> =
            (0..cfg.b_train).map(|_| task.supervised(&mut rng)).collect();
        let batch = LmBatch::build(&cfg, &pairs);
        (cfg, store, batch)
    }

    /// Central-difference check of a handful of parameters spread across
    /// every tensor family — the strongest correctness evidence a
    /// hand-written backward can carry.
    #[test]
    fn grads_match_finite_differences() {
        let (cfg, mut store, batch) = setup();
        let (_, grads) = lm_grads(&cfg, &store, &batch).unwrap();
        // (entry, element): embeddings, a norm gain, each weight kind.
        // Probe each tensor's largest-|grad| element so the loss delta
        // clears f32 resolution of the ~ln(48) loss.
        let names = [
            "tok_emb",
            "pos_emb",
            "layers.0.ln1.g",
            "layers.0.attn.wq",
            "layers.0.attn.wo",
            "layers.1.mlp.w1",
            "layers.1.mlp.w2",
            "lnf.b",
        ];
        let probes: Vec<(usize, usize)> = names
            .iter()
            .map(|n| {
                let i = store.entries.iter().position(|e| e.name == *n).unwrap();
                let j = grads[i]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap()
                    .0;
                (i, j)
            })
            .collect();
        let eps = 1e-2f32;
        for (ei, j) in probes {
            let orig = store.entries[ei].data.as_f32()[j];
            store.entries[ei].data.as_f32_mut()[j] = orig + eps;
            let (lp, _) = lm_grads(&cfg, &store, &batch).unwrap();
            store.entries[ei].data.as_f32_mut()[j] = orig - eps;
            let (lms, _) = lm_grads(&cfg, &store, &batch).unwrap();
            store.entries[ei].data.as_f32_mut()[j] = orig;
            let fd = (lp - lms) / (2.0 * eps);
            let an = grads[ei][j];
            let name = &store.entries[ei].name;
            // f32 central differences are noisy; accept 10% + abs floor
            assert!(
                (fd - an).abs() <= 0.1 * fd.abs().max(an.abs()).max(0.02),
                "{}[{}]: analytic {} vs finite-diff {}",
                name,
                j,
                an,
                fd
            );
        }
    }

    #[test]
    fn loss_matches_forward_backend() {
        use crate::model::AsParams;
        use crate::runtime::backend::ForwardBackend;
        let (cfg, store, batch) = setup();
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let nb = super::super::NativeBackend::new(&man, "nano", Format::Fp32).unwrap();
        let (sum_ce, n_tok, _) = nb.lm_loss(&store.params_view(), None, &batch).unwrap();
        let (loss, grads) = lm_grads(&cfg, &store, &batch).unwrap();
        assert!((loss - sum_ce / n_tok.max(1.0)).abs() < 1e-4, "{} vs {}", loss, sum_ce / n_tok);
        assert_eq!(grads.len(), store.entries.len());
        // gradient of a masked-out padding position's token must be finite
        assert!(grads.iter().flatten().all(|g| g.is_finite()));
    }
}
