//! Runtime layer: every way the model's forward graphs can execute.
//!
//! The execution contract is the [`ForwardBackend`] trait (`backend.rs`):
//! compile/load per the `Manifest`, run gen/cls/loss/grad over a
//! [`crate::model::ParamsView`]. Two implementations ship:
//!
//! * [`PjrtBackend`] (`pjrt.rs` over `engine.rs`) — AOT-compiled HLO
//!   artifacts on a PJRT client (see DESIGN.md §7 and
//!   python/compile/aot.py); requires the real `xla` bindings.
//! * [`NativeBackend`] (`native/`) — a pure-Rust interpreter of the
//!   manifest's `ModelConfig` with a fused dequant-GEMM over the packed
//!   lattice; runs everywhere, including the offline stub build.
//!
//! `encode.rs` holds the host-side batch encoders both backends consume.

pub mod backend;
pub mod encode;
pub mod engine;
pub mod manifest;
pub mod native;
pub mod pjrt;

/// Whether a real PJRT runtime backs the `xla` dependency. The offline
/// build links a stub (`rust/vendor/xla`) and reports `false`;
/// [`BackendPolicy::Auto`] falls back to the native backend there, and
/// PJRT-only assertions (cross-backend parity) gate on this instead of
/// failing deep inside engine construction.
pub fn backend_available() -> bool {
    xla::available()
}

pub use backend::{BackendPolicy, EngineSet, ForwardBackend};
pub use encode::{gumbel_noise, ClsBatch, GenBatch, LmBatch};
pub use engine::{
    f32_literal, i8_literal, literal_for, param_literals, param_literals_view, to_f32_scalar,
    to_f32_vec, to_i32_vec, Engine, HostTensor,
};
pub use manifest::{ArtifactMeta, IoSpec, Manifest, ModelConfig, ParamMeta};
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;
