//! PJRT runtime: manifest-driven artifact loading and execution.
//!
//! Layer-3's bridge to the AOT-compiled Layer-2/1 compute. HLO text is the
//! interchange format (see DESIGN.md §7 and python/compile/aot.py).

pub mod engine;
pub mod manifest;

/// Whether a real PJRT runtime backs the `xla` dependency. The offline
/// build links a stub (`rust/vendor/xla`) and reports `false`; engine-bound
/// tests and tools gate themselves on this instead of failing deep inside
/// `Session` construction.
pub fn backend_available() -> bool {
    xla::available()
}

pub use engine::{
    f32_literal, i8_literal, literal_for, param_literals, param_literals_view, to_f32_scalar,
    to_f32_vec, to_i32_vec, Engine, HostTensor,
};
pub use manifest::{ArtifactMeta, IoSpec, Manifest, ModelConfig, ParamMeta};
