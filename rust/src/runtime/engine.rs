//! PJRT engine: load an HLO-text artifact, compile once, execute many.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so an `Engine`
//! lives on one thread; worker threads each build their own `Engine` from
//! the same artifact file (see `coordinator::pool`). Compilation is ~1s per
//! artifact on this testbed and happens once per worker at startup.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::{AsParams, ParamStore, ParamsView, TensorData};
use crate::runtime::manifest::{ArtifactMeta, IoSpec, Manifest};

/// Host-side input value handed to `Engine::run`.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
    ScalarF32(f32),
}

/// Element types with a defined little-endian byte image — what the PJRT
/// untyped-data constructor expects. The safe replacement for the
/// `slice::from_raw_parts` byte reinterpretations the f32/i32/i8 literal
/// arms used to duplicate. Costs one pre-sized buffer per literal (the
/// price of safety without a cast crate); i8 lowers to a straight byte
/// copy, and the runtime copies the bytes again on ingestion either way.
trait ToLeBytes: Copy {
    fn extend_le(v: &[Self], out: &mut Vec<u8>);
}

impl ToLeBytes for f32 {
    fn extend_le(v: &[Self], out: &mut Vec<u8>) {
        for x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

impl ToLeBytes for i32 {
    fn extend_le(v: &[Self], out: &mut Vec<u8>) {
        for x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

impl ToLeBytes for i8 {
    fn extend_le(v: &[Self], out: &mut Vec<u8>) {
        out.extend(v.iter().map(|&b| b as u8));
    }
}

/// Little-endian byte image of a numeric slice.
fn le_bytes<T: ToLeBytes>(v: &[T]) -> Vec<u8> {
    let mut out = Vec::with_capacity(std::mem::size_of_val(v));
    T::extend_le(v, &mut out);
    out
}

/// One shared literal constructor for every dtype arm.
fn typed_literal<T: ToLeBytes>(
    ty: xla::ElementType,
    shape: &[usize],
    v: &[T],
) -> Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(ty, shape, &le_bytes(v))?)
}

/// Build an xla literal matching an IoSpec.
pub fn literal_for(spec: &IoSpec, t: &HostTensor) -> Result<xla::Literal> {
    let numel: usize = spec.shape.iter().product();
    let check = |len: usize| -> Result<()> {
        anyhow::ensure!(len == numel, "{}: got {} elems want {}", spec.name, len, numel);
        Ok(())
    };
    match (spec.dtype.as_str(), t) {
        ("f32", HostTensor::ScalarF32(v)) => {
            anyhow::ensure!(spec.shape.is_empty(), "{}: scalar for non-scalar spec", spec.name);
            Ok(xla::Literal::scalar(*v))
        }
        ("f32", HostTensor::F32(v)) => {
            check(v.len())?;
            typed_literal(xla::ElementType::F32, &spec.shape, v)
        }
        ("i32", HostTensor::I32(v)) => {
            check(v.len())?;
            typed_literal(xla::ElementType::S32, &spec.shape, v)
        }
        ("i8", HostTensor::I8(v)) => {
            check(v.len())?;
            typed_literal(xla::ElementType::S8, &spec.shape, v)
        }
        (dt, ht) => anyhow::bail!("{}: dtype {} incompatible with {:?}", spec.name, dt, ht),
    }
}

/// Build a literal directly from a slice of i8 (lattice hot path).
pub fn i8_literal(shape: &[usize], v: &[i8]) -> Result<xla::Literal> {
    typed_literal(xla::ElementType::S8, shape, v)
}

/// Build a literal directly from a slice of f32.
pub fn f32_literal(shape: &[usize], v: &[f32]) -> Result<xla::Literal> {
    typed_literal(xla::ElementType::F32, shape, v)
}

/// A compiled artifact bound to a (thread-local) PJRT client.
pub struct Engine {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Load + compile `artifacts/<file>` on the given client.
    pub fn load(client: &xla::PjRtClient, man: &Manifest, meta: &ArtifactMeta) -> Result<Engine> {
        let path = man.dir.join(&meta.file);
        Self::load_path(client, &path, meta.clone())
    }

    pub fn load_path(
        client: &xla::PjRtClient,
        path: &Path,
        meta: ArtifactMeta,
    ) -> Result<Engine> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Engine { meta, exe })
    }

    /// Execute with pre-built literals (data inputs followed by params).
    /// Returns the flattened output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let expected = self.meta.data_inputs.len() + self.meta.n_param_inputs;
        anyhow::ensure!(
            args.len() == expected,
            "{}: got {} args, want {} ({} data + {} params)",
            self.meta.file,
            args.len(),
            expected,
            self.meta.data_inputs.len(),
            self.meta.n_param_inputs
        );
        let buffers = self.exe.execute::<xla::Literal>(args)?;
        let result = buffers[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(
            outs.len() == self.meta.outputs.len(),
            "{}: got {} outputs, manifest says {}",
            self.meta.file,
            outs.len(),
            self.meta.outputs.len()
        );
        Ok(outs)
    }
}

/// Convert a parameter view's entries to literals, in manifest order,
/// with an optional override for lattice tensors (the per-member
/// perturbed values).
///
/// `overrides[i]` corresponds to `store.lattice_indices()[i]`. Without
/// overrides, lattice values come from the view's flat segments —
/// zero-copy for per-tensor views, gathered per tensor for shard-backed
/// views (snapshots / the leader plane), whose base entries are empty.
pub fn param_literals_view(
    view: &ParamsView<'_>,
    overrides: Option<&[Vec<i8>]>,
) -> Result<Vec<xla::Literal>> {
    let store = view.store;
    let lat = store.lattice_indices();
    let mut lat_pos = 0usize;
    let mut out = Vec::with_capacity(store.entries.len());
    for (i, e) in store.entries.iter().enumerate() {
        let is_lattice = lat_pos < lat.len() && lat[lat_pos] == i;
        match &e.data {
            TensorData::I8(v) => {
                if is_lattice {
                    match overrides {
                        Some(ovs) => out.push(i8_literal(&e.shape, &ovs[lat_pos])?),
                        None => {
                            let vals = view.lattice_tensor(lat_pos);
                            out.push(i8_literal(&e.shape, &vals)?);
                        }
                    }
                } else {
                    out.push(i8_literal(&e.shape, v)?);
                }
            }
            TensorData::F32(v) => {
                if is_lattice {
                    // fp-format lattice tensors can't be overridden with i8
                    anyhow::ensure!(
                        overrides.is_none(),
                        "i8 overrides passed for fp-format store"
                    );
                }
                out.push(f32_literal(&e.shape, v)?);
            }
        }
        if is_lattice {
            lat_pos += 1;
        }
    }
    Ok(out)
}

/// [`param_literals_view`] over a plain store (convenience wrapper kept
/// for tooling and benches).
pub fn param_literals(
    store: &ParamStore,
    overrides: Option<&[Vec<i8>]>,
) -> Result<Vec<xla::Literal>> {
    param_literals_view(&store.params_view(), overrides)
}

/// Extract a Vec<f32> from an output literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a Vec<i32> from an output literal.
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Extract a scalar f32 from an output literal.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn le_bytes_covers_every_literal_dtype() {
        assert_eq!(le_bytes(&[1.5f32]), 1.5f32.to_le_bytes().to_vec());
        assert_eq!(
            le_bytes(&[-2i32, 3]),
            [(-2i32).to_le_bytes(), 3i32.to_le_bytes()].concat()
        );
        assert_eq!(le_bytes(&[-1i8, 7]), vec![0xff, 0x07]);
        assert!(le_bytes::<f32>(&[]).is_empty());
        // 4-byte dtypes produce 4 bytes per element, i8 one
        assert_eq!(le_bytes(&[0f32; 3]).len(), 12);
        assert_eq!(le_bytes(&[0i8; 3]).len(), 3);
    }
}
