//! The `ForwardBackend` trait: one contract for executing the model's
//! forward graphs (gen / cls / loss / grad), implemented by every runtime.
//!
//! Two backends ship today:
//!
//! * [`crate::runtime::pjrt::PjrtBackend`] — the AOT-compiled HLO path
//!   over a PJRT client (requires the real `xla` bindings);
//! * [`crate::runtime::native::NativeBackend`] — a pure-Rust interpreter
//!   of the manifest's `ModelConfig` with a fused dequant-GEMM that reads
//!   the packed lattice directly (runs everywhere, including the offline
//!   build).
//!
//! Both consume the same inputs the artifacts define: batches from
//! [`crate::runtime::encode`] plus a [`ParamsView`] of the weights (plain
//! store, sharded plane, or snapshot) with optional per-member lattice
//! overrides. The coordinator (`Session`, the worker pool, workloads) is
//! generic over this trait and picks an impl via [`BackendPolicy`].

use anyhow::Result;

use crate::model::ParamsView;
use crate::runtime::encode::{ClsBatch, GenBatch, LmBatch};
use crate::runtime::manifest::ModelConfig;

/// Which backend a `Session` (or pool worker) should execute on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendPolicy {
    /// Native by default; PJRT when a real runtime backs the `xla` crate.
    #[default]
    Auto,
    /// Force the pure-Rust interpreter (works everywhere).
    Native,
    /// Force the PJRT engine path (errors on the offline stub build).
    Pjrt,
}

impl BackendPolicy {
    pub fn parse(s: &str) -> Result<BackendPolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => BackendPolicy::Auto,
            "native" => BackendPolicy::Native,
            "pjrt" | "xla" => BackendPolicy::Pjrt,
            other => anyhow::bail!("unknown backend {:?} (auto|native|pjrt)", other),
        })
    }

    /// Resolve `Auto` against the linked `xla` runtime.
    pub fn use_pjrt(self) -> bool {
        match self {
            BackendPolicy::Auto => xla::available(),
            BackendPolicy::Native => false,
            BackendPolicy::Pjrt => true,
        }
    }
}

/// Which graphs a session uses. Backend-neutral: the PJRT path compiles
/// exactly these (compilation is ~1s each; pay only for what the run
/// uses), and the native interpreter enforces the same declaration, so
/// under-declaring fails identically on every backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineSet {
    pub gen: bool,
    pub loss: bool,
    pub cls: bool,
    pub grad: bool,
}

impl EngineSet {
    pub fn gen_only() -> Self {
        EngineSet { gen: true, ..Default::default() }
    }
    pub fn cls_only() -> Self {
        EngineSet { cls: true, ..Default::default() }
    }
    pub fn pretrain() -> Self {
        EngineSet { grad: true, loss: true, gen: true, ..Default::default() }
    }
    /// Every graph — raw/direct backend use (tests, benches, parity).
    pub fn all() -> Self {
        EngineSet { gen: true, loss: true, cls: true, grad: true }
    }
}

/// A runtime able to execute the model's forward graphs over a parameter
/// view. Implementations may be thread-local (the PJRT client is
/// `Rc`-based); the worker pool builds one per thread.
///
/// `overrides[k]` (when present) replaces lattice tensor `k` of
/// `view.store.lattice_indices()` — a population member's perturbed
/// weights. Quantized formats only; fp views must pass `None`.
pub trait ForwardBackend {
    fn name(&self) -> &'static str;

    fn cfg(&self) -> &ModelConfig;

    /// Downcast to the native interpreter when this backend is one. The
    /// generation scheduler (`crate::sched`) steps the model directly and
    /// so only runs natively; callers holding a `dyn ForwardBackend` use
    /// this to pick between the scheduler and the per-call graph path.
    fn as_native(&self) -> Option<&crate::runtime::native::NativeBackend> {
        None
    }

    /// Cap the backend's INTERNAL parallelism (the native GEMM's thread
    /// fan-out). Results are invariant to it — the determinism contract
    /// — so this is pure topology tuning: callers that are themselves
    /// one of many parallel workers should set 1 to avoid nesting
    /// thread pools. Default: no-op (the PJRT path has no host-side
    /// threading to cap).
    fn set_threads(&mut self, _threads: usize) {}

    /// Batched autoregressive generation (the `gen` graph): returns the
    /// decoded token ids, `i32[b_gen * t_dec]` row-major. `gumbel_seed =
    /// None` decodes greedily.
    fn generate(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &GenBatch,
        tau: f32,
        gumbel_seed: Option<u64>,
    ) -> Result<Vec<i32>>;

    /// Verbalizer-classification scores (the `cls` graph): class-token
    /// logits `f32[b_train * 8]` row-major, per example.
    fn cls_scores(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &ClsBatch,
    ) -> Result<Vec<f32>>;

    /// Teacher-forced LM loss sums (the `loss` graph):
    /// `(sum_ce, n_tokens, n_correct)` over the loss-masked positions.
    fn lm_loss(
        &self,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<(f32, f32, f32)>;

    /// Mean loss + gradients for every parameter in store-entry order
    /// (the `grad` graph; fp-format views only).
    fn lm_grads(&self, view: &ParamsView<'_>, batch: &LmBatch) -> Result<(f32, Vec<Vec<f32>>)>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_and_resolves() {
        assert_eq!(BackendPolicy::parse("native").unwrap(), BackendPolicy::Native);
        assert_eq!(BackendPolicy::parse("PJRT").unwrap(), BackendPolicy::Pjrt);
        assert_eq!(BackendPolicy::parse("auto").unwrap(), BackendPolicy::Auto);
        assert!(BackendPolicy::parse("tpu").is_err());
        assert!(!BackendPolicy::Native.use_pjrt());
        assert!(BackendPolicy::Pjrt.use_pjrt());
        // Auto follows the linked runtime (the offline stub reports false).
        assert_eq!(BackendPolicy::Auto.use_pjrt(), xla::available());
    }
}
