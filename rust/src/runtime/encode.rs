//! Host-side batch encoding: task text -> the exact tensors the AOT
//! artifacts expect.
//!
//! Conventions (mirrors python/compile/model.py):
//! * generation prompts are LEFT-padded to `s_prompt` (uniform cache slots);
//! * loss/cls sequences are RIGHT-padded to `s_train` with explicit
//!   `pos_ids` and key `mask`, so padding never leaks into attention.

use crate::rng::SplitMix64;
use crate::runtime::manifest::ModelConfig;
use crate::tasks::{tokenizer, ClsExample, GenProblem};

/// A generation rollout batch, padded to exactly `b_gen` rows.
#[derive(Debug, Clone)]
pub struct GenBatch {
    pub prompt: Vec<i32>,   // [b_gen, s_prompt], left-padded
    pub lens: Vec<i32>,     // [b_gen]
    pub problems: Vec<GenProblem>,
    /// Rows beyond this are padding duplicates and must not be scored.
    pub n_real: usize,
}

impl GenBatch {
    /// Build from up to `b_gen` problems (panics if a prompt exceeds the
    /// budget — task constructors are sized to prevent that).
    pub fn build(cfg: &ModelConfig, problems: Vec<GenProblem>) -> GenBatch {
        assert!(!problems.is_empty() && problems.len() <= cfg.b_gen);
        let n_real = problems.len();
        let mut padded = problems.clone();
        while padded.len() < cfg.b_gen {
            padded.push(problems[0].clone());
        }
        let (b, sp) = (cfg.b_gen, cfg.s_prompt);
        let mut prompt = vec![tokenizer::PAD as i32; b * sp];
        let mut lens = vec![0i32; b];
        for (i, p) in padded.iter().enumerate() {
            let ids = tokenizer::encode(&p.prompt);
            assert!(
                ids.len() <= sp,
                "prompt {:?} ({} tokens) exceeds s_prompt {}",
                p.prompt,
                ids.len(),
                sp
            );
            let off = sp - ids.len();
            for (j, &t) in ids.iter().enumerate() {
                prompt[i * sp + off + j] = t as i32;
            }
            lens[i] = ids.len() as i32;
        }
        GenBatch { prompt, lens, problems: padded, n_real }
    }
}

/// Gumbel noise tensor for sampled decoding, derived from a seed;
/// all-zeros (greedy) when `tau == 0` callers pass `None`.
pub fn gumbel_noise(cfg: &ModelConfig, seed: Option<u64>) -> Vec<f32> {
    let n = cfg.b_gen * cfg.t_dec * cfg.vocab;
    match seed {
        None => vec![0.0; n],
        Some(s) => {
            let mut rng = SplitMix64::new(s ^ 0x6775_6d62_656c_2121);
            (0..n).map(|_| rng.gumbel()).collect()
        }
    }
}

/// A classification batch, padded to exactly `b_train` rows.
#[derive(Debug, Clone)]
pub struct ClsBatch {
    pub tokens: Vec<i32>,    // [b_train, s_train], right-padded
    pub pos_ids: Vec<i32>,   // [b_train, s_train]
    pub mask: Vec<f32>,      // [b_train, s_train]
    pub cls_pos: Vec<i32>,   // [b_train]
    pub class_ids: Vec<i32>, // [8] (artifact-fixed width; unused slots repeat)
    pub labels: Vec<i32>,    // [b_train]
    pub n_real: usize,
}

impl ClsBatch {
    pub fn build(cfg: &ModelConfig, examples: &[ClsExample], verbalizers: &[u8]) -> ClsBatch {
        assert!(!examples.is_empty() && examples.len() <= cfg.b_train);
        let n_real = examples.len();
        let (b, st) = (cfg.b_train, cfg.s_train);
        let mut tokens = vec![tokenizer::PAD as i32; b * st];
        let mut pos_ids = vec![0i32; b * st];
        let mut mask = vec![0.0f32; b * st];
        let mut cls_pos = vec![0i32; b];
        let mut labels = vec![0i32; b];
        for i in 0..b {
            let ex = &examples[i.min(n_real - 1)];
            let ids = tokenizer::encode(&ex.text);
            assert!(ids.len() <= st, "cls text {:?} exceeds s_train {}", ex.text, st);
            for (j, &t) in ids.iter().enumerate() {
                tokens[i * st + j] = t as i32;
                pos_ids[i * st + j] = j as i32;
                mask[i * st + j] = 1.0;
            }
            cls_pos[i] = (ids.len() - 1) as i32; // the '>' position
            labels[i] = ex.label as i32;
        }
        // artifact takes a fixed 8-wide class-id vector
        let mut class_ids = vec![verbalizers[0] as i32; 8];
        for (c, &v) in verbalizers.iter().enumerate().take(8) {
            class_ids[c] = v as i32;
        }
        ClsBatch { tokens, pos_ids, mask, cls_pos, class_ids, labels, n_real }
    }
}

/// A teacher-forced LM batch (pretraining / loss eval).
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>,
    pub pos_ids: Vec<i32>,
    pub mask: Vec<f32>,
    pub targets: Vec<i32>,
    pub loss_mask: Vec<f32>,
}

impl LmBatch {
    /// Build from (prompt, completion) pairs: loss on completion tokens
    /// only (the usual SFT masking).
    pub fn build(cfg: &ModelConfig, pairs: &[(String, String)]) -> LmBatch {
        assert!(!pairs.is_empty() && pairs.len() <= cfg.b_train);
        let (b, st) = (cfg.b_train, cfg.s_train);
        let mut tokens = vec![tokenizer::PAD as i32; b * st];
        let mut pos_ids = vec![0i32; b * st];
        let mut mask = vec![0.0f32; b * st];
        let mut targets = vec![tokenizer::PAD as i32; b * st];
        let mut loss_mask = vec![0.0f32; b * st];
        for i in 0..b {
            let (p, c) = &pairs[i.min(pairs.len() - 1)];
            let pids = tokenizer::encode(p);
            let cids = tokenizer::encode(c);
            let full: Vec<u8> = pids.iter().chain(cids.iter()).copied().collect();
            assert!(full.len() <= st, "sequence {:?}{:?} exceeds s_train {}", p, c, st);
            for (j, &t) in full.iter().enumerate() {
                tokens[i * st + j] = t as i32;
                pos_ids[i * st + j] = j as i32;
                mask[i * st + j] = 1.0;
            }
            // targets[j] = tokens[j+1]; loss only where the TARGET is a
            // completion token.
            for j in 0..full.len().saturating_sub(1) {
                targets[i * st + j] = full[j + 1] as i32;
                if j + 1 >= pids.len() {
                    loss_mask[i * st + j] = 1.0;
                }
            }
        }
        LmBatch { tokens, pos_ids, mask, targets, loss_mask }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::tasks::{gen_task, ProblemKey};

    fn cfg() -> ModelConfig {
        Manifest::load("artifacts/manifest.json").unwrap().config("nano").unwrap().clone()
    }

    #[test]
    fn gen_batch_left_padded() {
        let cfg = cfg();
        let t = gen_task("countdown", cfg.s_prompt, cfg.t_dec).unwrap();
        let mut rng = SplitMix64::new(1);
        let problems: Vec<_> = (0..3).map(|_| t.sample(&mut rng)).collect();
        let b = GenBatch::build(&cfg, problems);
        assert_eq!(b.n_real, 3);
        assert_eq!(b.prompt.len(), cfg.b_gen * cfg.s_prompt);
        // row 0: leading pads then prompt; last token is ':'
        let row0 = &b.prompt[..cfg.s_prompt];
        let len0 = b.lens[0] as usize;
        assert!(row0[..cfg.s_prompt - len0].iter().all(|&t| t == 0));
        assert_eq!(row0[cfg.s_prompt - 1], tokenizer::tok(':') as i32);
        // padding rows duplicate problem 0
        if let (ProblemKey::Countdown { target: t0, .. }, ProblemKey::Countdown { target: tp, .. }) =
            (&b.problems[0].key, &b.problems[cfg.b_gen - 1].key)
        {
            assert_eq!(t0, tp);
        }
    }

    #[test]
    fn gumbel_deterministic_and_greedy_zero() {
        let cfg = cfg();
        assert!(gumbel_noise(&cfg, None).iter().all(|&x| x == 0.0));
        let a = gumbel_noise(&cfg, Some(5));
        let b = gumbel_noise(&cfg, Some(5));
        assert_eq!(a, b);
        assert!(gumbel_noise(&cfg, Some(6)) != a);
    }

    #[test]
    fn cls_batch_positions() {
        let cfg = cfg();
        let task = crate::tasks::cls_task("snli").unwrap();
        let mut rng = SplitMix64::new(2);
        let exs: Vec<_> = (0..4).map(|_| task.sample(&mut rng, true)).collect();
        let b = ClsBatch::build(&cfg, &exs, &task.verbalizers());
        assert_eq!(b.n_real, 4);
        for i in 0..4 {
            let cp = b.cls_pos[i] as usize;
            assert_eq!(b.tokens[i * cfg.s_train + cp], tokenizer::tok('>') as i32);
            assert_eq!(b.mask[i * cfg.s_train + cp], 1.0);
            if cp + 1 < cfg.s_train {
                assert_eq!(b.mask[i * cfg.s_train + cp + 1], 0.0);
            }
        }
    }

    #[test]
    fn lm_batch_masks_prompt() {
        let cfg = cfg();
        let pairs = vec![("3,4,5=17:".to_string(), "3*4+5;".to_string())];
        let b = LmBatch::build(&cfg, &pairs);
        let st = cfg.s_train;
        let plen = 9;
        // loss mask zero where target is still inside the prompt
        for j in 0..plen - 1 {
            assert_eq!(b.loss_mask[j], 0.0, "j={}", j);
        }
        // and one on the completion region
        let full_len = plen + 6;
        for j in plen - 1..full_len - 1 {
            assert_eq!(b.loss_mask[j], 1.0, "j={}", j);
            assert_eq!(b.targets[j], b.tokens[j + 1]);
        }
        // nothing beyond the sequence
        for j in full_len..st {
            assert_eq!(b.loss_mask.get(j).copied().unwrap_or(0.0), 0.0);
        }
    }
}
