//! Algorithm 2 — Stateless QES Update with Seed Replay.
//!
//! The headline memory mechanism: instead of persisting the FP16 residual
//! `e in R^d`, keep only the last K generations' `(gen_seed, fitness)`
//! tuples and *rematerialize* a proxy residual by re-simulating the update
//! dynamics from an assumed-zero state at t-K. Because gamma^K ~ 0, the
//! truncated history's contribution vanishes; because boundary gating is
//! checked against the CURRENT weights W_t instead of the historical W_tau
//! (paper §4.5), the reconstruction is approximate exactly when an active
//! update coincides with a lattice boundary — measured to be ~1e-5 rare.
//!
//! Persistent state: K * (8 bytes seed + 4 bytes * population fitness) —
//! kilobytes, independent of d (Table 8). The rematerialized proxy
//! residual is transient scratch, tiled per lattice shard so it lives
//! alongside the COW plane's slabs with no layout translation.

use std::collections::VecDeque;

use crate::model::{ShardPlan, ShardedParamStore};
use crate::opt::kernels::{self, ReplayStep};
use crate::opt::{EsHyper, KernelPolicy, LatticeOptimizer, PopulationSpec, StepStats};

#[derive(Debug, Clone)]
struct HistoryStep {
    gen_seed: u64,
    fitness: Vec<f32>,
    sigma: f32,
    alpha: f32,
}

pub struct SeedReplayQes {
    pub hyper: EsHyper,
    /// Kernel execution policy (chunk size / threads). Never affects the
    /// produced lattice or residual — only wall-clock.
    pub policy: KernelPolicy,
    history: VecDeque<HistoryStep>,
    /// Rematerialized proxy residual (transient scratch, not state — kept
    /// for diagnostics and the adaptive-K controller), one tile per
    /// lattice shard.
    e_proxy: Vec<Vec<f32>>,
    d: usize,
    qmax: i8,
}

impl SeedReplayQes {
    pub fn new(d: usize, qmax: i8, hyper: EsHyper) -> Self {
        SeedReplayQes {
            history: VecDeque::with_capacity(hyper.k_window + 1),
            hyper,
            policy: KernelPolicy::default(),
            e_proxy: Vec::new(),
            d,
            qmax,
        }
    }

    /// Shape the per-shard proxy tiles to the store's plan. The proxy is
    /// rebuilt from zero every update, so reshaping is always safe.
    fn ensure_shards(&mut self, plan: &ShardPlan) {
        let ok = self.e_proxy.len() == plan.n_shards
            && (0..plan.n_shards).all(|s| self.e_proxy[s].len() == plan.bounds(s).1);
        if !ok {
            self.e_proxy =
                (0..plan.n_shards).map(|s| vec![0.0f32; plan.bounds(s).1]).collect();
        }
    }

    /// The rematerialized proxy residual from the last update, flattened
    /// to canonical order (diagnostics).
    pub fn proxy_residual(&self) -> Vec<f32> {
        self.e_proxy.iter().flat_map(|s| s.iter().copied()).collect()
    }

    /// Mean |e_proxy| without materializing the flat vector (the
    /// adaptive-K controller's truncation-pressure signal).
    pub fn mean_abs_proxy(&self) -> f32 {
        let n: usize = self.e_proxy.iter().map(|s| s.len()).sum();
        if n == 0 {
            return 0.0;
        }
        let sum: f32 = self.e_proxy.iter().flat_map(|s| s.iter()).map(|x| x.abs()).sum();
        sum / n as f32
    }

    pub fn history_len(&self) -> usize {
        self.history.len()
    }
}

impl LatticeOptimizer for SeedReplayQes {
    fn update(
        &mut self,
        store: &mut ShardedParamStore,
        spec: &PopulationSpec,
        fitness: &[f32],
    ) -> anyhow::Result<StepStats> {
        anyhow::ensure!(
            store.lattice_dim() == self.d,
            "lattice dim {} != optimizer dim {}",
            store.lattice_dim(),
            self.d
        );
        anyhow::ensure!(fitness.len() == spec.n_members());
        self.ensure_shards(store.plan());

        // Describe the replay window by BORROWING the history — the fused
        // kernel walks `(spec, &fitness, alpha)` views; no fitness vector
        // is cloned per update.
        let steps: Vec<ReplayStep<'_>> = self
            .history
            .iter()
            .map(|h| ReplayStep {
                spec: PopulationSpec {
                    gen_seed: h.gen_seed,
                    pairs: h.fitness.len() / 2,
                    sigma: h.sigma,
                },
                fitness: &h.fitness,
                alpha: h.alpha,
            })
            .collect();
        let current = ReplayStep { spec: spec.clone(), fitness, alpha: self.hyper.alpha };

        // Fused K-deep tile over the read-only shard slabs: per chunk, the
        // proxy residual is rematerialized across ALL history steps while
        // cache-resident, then the current step commits — one pass over d
        // instead of the scalar path's K+1 full-lattice sweeps. Weight
        // changes come back sparse and COW-commit per shard.
        let (gamma, qmax, policy) = (self.hyper.gamma, self.qmax, self.policy);
        let e_segs: Vec<&mut [f32]> =
            self.e_proxy.iter_mut().map(|v| v.as_mut_slice()).collect();
        let (stats, deltas) = kernels::fused_seed_replay(
            store.lattice_segments(),
            e_segs,
            &steps,
            &current,
            gamma,
            qmax,
            policy,
        );
        drop(steps);
        store.apply_deltas(&deltas);

        // Record this generation; trim the window.
        let alpha = self.hyper.alpha;
        self.history.push_back(HistoryStep {
            gen_seed: spec.gen_seed,
            fitness: fitness.to_vec(),
            sigma: spec.sigma,
            alpha,
        });
        while self.history.len() > self.hyper.k_window {
            self.history.pop_front();
        }
        Ok(stats)
    }

    fn state_bytes(&self) -> u64 {
        // (seed u64 + sigma f32 + alpha f32 + fitness f32 * pop) per step
        self.history
            .iter()
            .map(|h| 8 + 4 + 4 + 4 * h.fitness.len() as u64)
            .sum()
    }

    fn name(&self) -> &'static str {
        "qes-seed-replay"
    }

    /// State = the replay window itself: `(gen_seed, sigma, alpha,
    /// fitness[])` per step. The proxy residual is NOT saved — it is
    /// rematerialized from the history on the next update, which is the
    /// whole point of Algorithm 2 (and what makes this checkpoint
    /// kilobytes, independent of d).
    fn save_state(&self, w: &mut dyn std::io::Write) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        write_u8(w, crate::opt::state_tag::SEED_REPLAY)?;
        write_u32(w, self.history.len() as u32)?;
        for h in &self.history {
            write_u64(w, h.gen_seed)?;
            write_f32(w, h.sigma)?;
            write_f32(w, h.alpha)?;
            write_u32(w, h.fitness.len() as u32)?;
            for &f in &h.fitness {
                write_f32(w, f)?;
            }
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut dyn std::io::Read) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        expect_tag(r, crate::opt::state_tag::SEED_REPLAY, "qes-seed-replay")?;
        let n = read_u32(r)? as usize;
        anyhow::ensure!(n <= 1 << 20, "absurd replay history length {}", n);
        let mut history = VecDeque::with_capacity(n);
        for _ in 0..n {
            let gen_seed = read_u64(r)?;
            let sigma = read_f32(r)?;
            let alpha = read_f32(r)?;
            let len = read_u32(r)? as usize;
            anyhow::ensure!(len <= 1 << 24, "absurd fitness length {}", len);
            let mut fitness = Vec::with_capacity(len);
            for _ in 0..len {
                fitness.push(read_f32(r)?);
            }
            history.push_back(HistoryStep { gen_seed, fitness, sigma, alpha });
        }
        self.history = history;
        // Proxy tiles are transient scratch; drop any stale shape.
        self.e_proxy.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init::init_fp, ParamStore};
    use crate::opt::QesFullResidual;
    use crate::quant::Format;
    use crate::runtime::manifest::Manifest;

    fn store(fmt: Format, seed: u64) -> ShardedParamStore {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, seed);
        let q = ParamStore::quantize_from(&fp, &man, fmt, None).unwrap();
        ShardedParamStore::with_default_shards(q).unwrap()
    }

    fn clone_plane(s: &ShardedParamStore) -> ShardedParamStore {
        ShardedParamStore::with_default_shards(s.materialize()).unwrap()
    }

    fn flat(s: &ShardedParamStore) -> Vec<i8> {
        s.lattice_segments().iter().flat_map(|t| t.iter().copied()).collect()
    }

    fn run_steps(
        opt: &mut dyn LatticeOptimizer,
        s: &mut ShardedParamStore,
        gens: usize,
        seed: u64,
        pairs: usize,
    ) {
        let mut rng = crate::rng::SplitMix64::new(seed);
        for _ in 0..gens {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs, sigma: 0.5 };
            let raw: Vec<f32> = (0..2 * pairs).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            opt.update(s, &spec, &fitness).unwrap();
        }
    }

    #[test]
    fn tracks_full_residual_oracle_when_window_covers_history() {
        // With K >= T and gamma=1 and no gating pressure (INT8), replay is
        // EXACT vs. an f32-residual oracle: same seeds, same fitness =>
        // identical weight trajectories (f16 storage in the oracle is the
        // only divergence source, kept below rounding threshold here).
        let hyper = EsHyper { sigma: 0.5, alpha: 0.4, gamma: 0.9, pairs: 4, k_window: 64 };
        let mut s_replay = store(Format::Int8, 21);
        let mut s_oracle = clone_plane(&s_replay);
        let d = s_replay.lattice_dim();
        let mut replay = SeedReplayQes::new(d, 127, hyper.clone());
        let mut oracle = QesFullResidual::new(d, 127, hyper.clone());
        let mut rng = crate::rng::SplitMix64::new(100);
        for _ in 0..12 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            replay.update(&mut s_replay, &spec, &fitness).unwrap();
            oracle.update(&mut s_oracle, &spec, &fitness).unwrap();
        }
        let a = flat(&s_replay);
        let b = flat(&s_oracle);
        let diff = a.iter().zip(b.iter()).filter(|(x, y)| x != y).count();
        // f16-vs-f32 residual rounding can flip a handful of borderline
        // elements; fidelity must still be near-perfect (paper §4.5).
        assert!(diff < d / 500 + 1, "replay diverged on {}/{} elements", diff, d);
    }

    #[test]
    fn state_is_kilobytes_and_independent_of_d() {
        let hyper = EsHyper { k_window: 50, pairs: 25, ..Default::default() };
        let mut s = store(Format::Int4, 4);
        let d = s.lattice_dim();
        let mut opt = SeedReplayQes::new(d, 7, hyper);
        run_steps(&mut opt, &mut s, 60, 7, 25);
        let bytes = opt.state_bytes();
        // 50 steps x (16 + 4*50) = 10.8 KB — the paper's "~29.7 KB" regime
        assert!(bytes < 32_000, "state {} bytes", bytes);
        assert!(bytes > 5_000);
        assert_eq!(opt.history_len(), 50);
    }

    #[test]
    fn window_truncation_with_decay_is_graceful() {
        // Fixed gamma = 0.9, K=6 vs K=12: trajectories stay close (Table 7
        // "fixed decay" regime) — compare number of diverging elements.
        let mk = |k: usize| EsHyper {
            sigma: 0.5,
            alpha: 0.4,
            gamma: 0.9,
            pairs: 4,
            k_window: k,
        };
        let mut s_a = store(Format::Int4, 9);
        let mut s_b = clone_plane(&s_a);
        let d = s_a.lattice_dim();
        let mut a = SeedReplayQes::new(d, 7, mk(6));
        let mut b = SeedReplayQes::new(d, 7, mk(12));
        let mut rng = crate::rng::SplitMix64::new(55);
        for _ in 0..20 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            a.update(&mut s_a, &spec, &fitness).unwrap();
            b.update(&mut s_b, &spec, &fitness).unwrap();
        }
        let xa = flat(&s_a);
        let xb = flat(&s_b);
        let diff = xa.iter().zip(xb.iter()).filter(|(x, y)| x != y).count();
        assert!(diff < d / 20, "K=6 vs K=12 diverged on {}/{} elements", diff, d);
    }

    #[test]
    fn lattice_in_range_under_stress() {
        let hyper = EsHyper { sigma: 1.0, alpha: 3.0, gamma: 0.95, pairs: 2, k_window: 5 };
        let mut s = store(Format::Int4, 2);
        let d = s.lattice_dim();
        let mut opt = SeedReplayQes::new(d, 7, hyper);
        run_steps(&mut opt, &mut s, 15, 3, 2);
        assert!(flat(&s).iter().all(|&v| (-7..=7).contains(&v)));
    }

    #[test]
    fn history_caps_at_k() {
        let hyper = EsHyper { k_window: 3, ..Default::default() };
        let mut s = store(Format::Int4, 6);
        let d = s.lattice_dim();
        let mut opt = SeedReplayQes::new(d, 7, hyper);
        run_steps(&mut opt, &mut s, 10, 11, 2);
        assert_eq!(opt.history_len(), 3);
    }

    /// A restored optimizer must continue the weight trajectory
    /// bit-identically: run T+T' steps straight vs. T steps, save/load
    /// into a fresh optimizer, then T' more with the same seeds.
    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        let hyper = EsHyper { sigma: 0.5, alpha: 0.4, gamma: 0.9, pairs: 4, k_window: 4 };
        let mut s_full = store(Format::Int4, 31);
        let mut s_resume = clone_plane(&s_full);
        let d = s_full.lattice_dim();
        let mut full = SeedReplayQes::new(d, 7, hyper.clone());
        run_steps(&mut full, &mut s_full, 10, 77, 4);

        let mut first = SeedReplayQes::new(d, 7, hyper.clone());
        // run_steps re-derives specs from the seed, so splitting 10 into
        // 6+4 needs the same rng position: replay the first 6 manually.
        let mut rng = crate::rng::SplitMix64::new(77);
        let mut step = |opt: &mut SeedReplayQes, s: &mut ShardedParamStore| {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            opt.update(s, &spec, &fitness).unwrap();
        };
        for _ in 0..6 {
            step(&mut first, &mut s_resume);
        }
        let mut blob = Vec::new();
        first.save_state(&mut blob).unwrap();
        let mut resumed = SeedReplayQes::new(d, 7, hyper);
        resumed.load_state(&mut blob.as_slice()).unwrap();
        assert_eq!(resumed.history_len(), first.history_len());
        for _ in 0..4 {
            step(&mut resumed, &mut s_resume);
        }
        assert_eq!(flat(&s_full), flat(&s_resume), "resume diverged from straight run");
    }

    #[test]
    fn state_tag_mismatch_is_rejected() {
        let hyper = EsHyper::default();
        let opt = SeedReplayQes::new(64, 7, hyper.clone());
        let mut blob = Vec::new();
        opt.save_state(&mut blob).unwrap();
        let mut wrong = QesFullResidual::new(64, 7, hyper);
        let err = wrong.load_state(&mut blob.as_slice());
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("tag mismatch"));
    }
}
