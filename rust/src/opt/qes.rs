//! Algorithm 1 — QES with Accumulated Error Feedback (Full Residual).
//!
//! The oracle variant: the high-precision error state `e` is stored
//! explicitly (FP16, as in the paper — see `util::f16`), giving the exact
//! Delta-Sigma dynamics:
//!
//!   u_t      = alpha * g_hat_t + gamma * e_{t-1}        (Eq. 6)
//!   dW_t     = Round(u_t)                               (Eq. 7)
//!   e_t      = u_t - dW_t                               (Eq. 8)
//!
//! with boundary gating (Eq. 4) folded in: a gated element contributes its
//! whole u back to the residual, so signal is deferred, never lost.
//!
//! The residual lives as one FP16 slab per lattice shard, aligned with the
//! store's `ShardPlan`, so the fused kernel dispatches weights and residual
//! with identical flat-space layout and the COW plane commits only the
//! shards the update actually changed.
//!
//! The §5 temporal-equivalence invariant — Theta_t = W_t + e_t evolves by
//! pure gradient ascent and ||e_t||_inf <= 1/2 wherever the gate is
//! inactive — is enforced by the property tests below.

use crate::model::{ShardPlan, ShardedParamStore};
use crate::opt::{kernels, EsHyper, KernelPolicy, LatticeOptimizer, PopulationSpec, StepStats};
use crate::util::f16::f16_bits_to_f32;

pub struct QesFullResidual {
    pub hyper: EsHyper,
    /// Kernel execution policy (chunk size / threads). Never affects the
    /// produced lattice or residual — only wall-clock.
    pub policy: KernelPolicy,
    /// FP16-stored residual (paper Alg. 1 line 3: "Residuals e_0 (FP16)"),
    /// one slab per lattice shard; shaped on first update from the store's
    /// shard plan.
    e: Vec<Vec<u16>>,
    d: usize,
    qmax: i8,
}

impl QesFullResidual {
    pub fn new(d: usize, qmax: i8, hyper: EsHyper) -> Self {
        QesFullResidual { hyper, policy: KernelPolicy::default(), e: Vec::new(), d, qmax }
    }

    /// Shape the per-shard residual slabs to the store's plan. The
    /// residual is persistent state, so the plan may not change once the
    /// first update has run.
    fn ensure_shards(&mut self, plan: &ShardPlan) -> anyhow::Result<()> {
        anyhow::ensure!(
            plan.d == self.d,
            "lattice dim {} != residual dim {}",
            plan.d,
            self.d
        );
        if self.e.is_empty() {
            self.e = (0..plan.n_shards).map(|s| vec![0u16; plan.bounds(s).1]).collect();
        }
        anyhow::ensure!(
            self.e.len() == plan.n_shards
                && (0..plan.n_shards).all(|s| self.e[s].len() == plan.bounds(s).1),
            "store shard plan changed mid-run"
        );
        Ok(())
    }

    /// Residual snapshot as flat f32 (tests / diagnostics).
    pub fn residual(&self) -> Vec<f32> {
        self.e.iter().flat_map(|s| s.iter().map(|&h| f16_bits_to_f32(h))).collect()
    }
}

impl LatticeOptimizer for QesFullResidual {
    fn update(
        &mut self,
        store: &mut ShardedParamStore,
        spec: &PopulationSpec,
        fitness: &[f32],
    ) -> anyhow::Result<StepStats> {
        anyhow::ensure!(fitness.len() == spec.n_members());
        self.ensure_shards(store.plan())?;
        let (alpha, gamma, qmax, policy) =
            (self.hyper.alpha, self.hyper.gamma, self.qmax, self.policy);
        let e_segs: Vec<&mut [u16]> = self.e.iter_mut().map(|v| v.as_mut_slice()).collect();
        // Fused chunk-parallel kernel over the read-only shard slabs:
        // gradient regeneration, error feedback and gating in one pass —
        // no d-sized gradient buffer, no eager unsharing.
        let (stats, deltas) = kernels::fused_full_residual(
            store.lattice_segments(),
            e_segs,
            spec,
            fitness,
            alpha,
            gamma,
            qmax,
            policy,
        );
        store.apply_deltas(&deltas);
        Ok(stats)
    }

    fn state_bytes(&self) -> u64 {
        // persistent optimizer state: the FP16 residual only (the fused
        // kernel's transient scratch is one chunk, not d-sized).
        (self.d * 2) as u64
    }

    fn name(&self) -> &'static str {
        "qes-full-residual"
    }

    /// State = the FP16 residual slabs, raw bits, one slab per shard.
    /// An unshaped optimizer (no update run yet) writes zero shards.
    fn save_state(&self, w: &mut dyn std::io::Write) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        write_u8(w, crate::opt::state_tag::FULL_RESIDUAL)?;
        write_u32(w, self.e.len() as u32)?;
        for slab in &self.e {
            write_u64(w, slab.len() as u64)?;
            for &h in slab {
                w.write_all(&h.to_le_bytes())?;
            }
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut dyn std::io::Read) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        expect_tag(r, crate::opt::state_tag::FULL_RESIDUAL, "qes-full-residual")?;
        let n_shards = read_u32(r)? as usize;
        anyhow::ensure!(n_shards <= 1 << 20, "absurd residual shard count {}", n_shards);
        let mut e = Vec::with_capacity(n_shards);
        let mut total = 0usize;
        for _ in 0..n_shards {
            let len = read_u64(r)? as usize;
            total = total
                .checked_add(len)
                .filter(|&t| t <= self.d)
                .ok_or_else(|| anyhow::anyhow!("residual slabs exceed lattice dim {}", self.d))?;
            let mut slab = vec![0u16; len];
            let mut buf = [0u8; 2];
            for h in slab.iter_mut() {
                r.read_exact(&mut buf)?;
                *h = u16::from_le_bytes(buf);
            }
            e.push(slab);
        }
        anyhow::ensure!(
            e.is_empty() || total == self.d,
            "residual covers {}/{} lattice elements",
            total,
            self.d
        );
        // `ensure_shards` on the next update re-validates the slab
        // shapes against the live store's plan.
        self.e = e;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init::init_fp, ParamStore};
    use crate::opt::accumulate_grad;
    use crate::quant::Format;
    use crate::runtime::manifest::Manifest;

    fn store(fmt: Format) -> ShardedParamStore {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 8);
        let q = ParamStore::quantize_from(&fp, &man, fmt, None).unwrap();
        ShardedParamStore::with_default_shards(q).unwrap()
    }

    fn flat(s: &ShardedParamStore) -> Vec<i8> {
        s.lattice_segments().iter().flat_map(|t| t.iter().copied()).collect()
    }

    fn hyper() -> EsHyper {
        EsHyper { sigma: 0.5, alpha: 0.3, gamma: 0.9, pairs: 4, k_window: 8 }
    }

    #[test]
    fn residual_bounded_by_half_when_ungated() {
        // §5: ||e_T||_inf <= 1/2 (+ f16 rounding eps) wherever the gate
        // didn't fire. Gated elements may exceed 1/2 by design (deferred
        // signal), so use small alpha to keep gating rare and check the
        // overwhelming majority.
        let mut s = store(Format::Int8); // wide lattice: gate almost never fires
        let d = s.lattice_dim();
        let mut opt = QesFullResidual::new(d, 127, hyper());
        let mut rng = crate::rng::SplitMix64::new(77);
        for gen in 0..20 {
            let spec = PopulationSpec { gen_seed: rng.next_u64() ^ gen, pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            opt.update(&mut s, &spec, &fitness).unwrap();
        }
        let e = opt.residual();
        let violations = e.iter().filter(|x| x.abs() > 0.5 + 1e-3).count();
        assert!(
            violations < d / 1000 + 1,
            "{} of {} residuals exceed 1/2",
            violations,
            d
        );
    }

    #[test]
    fn temporal_equivalence_virtual_trajectory() {
        // Theta_t = W_t + e_t must equal W_0 + sum(alpha * g_hat) exactly
        // (up to f16 rounding) on ungated elements — Eq. 12/13.
        let mut s = store(Format::Int8);
        let d = s.lattice_dim();
        let h = EsHyper { gamma: 1.0, ..hyper() }; // gamma=1: exact integration
        let mut opt = QesFullResidual::new(d, 127, h.clone());
        let w0 = flat(&s);

        let mut ideal = vec![0.0f64; d]; // sum of alpha * g_hat
        let mut g = vec![0.0f32; d];
        let mut rng = crate::rng::SplitMix64::new(5);
        for _ in 0..10 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            accumulate_grad(&spec, &fitness, &mut g);
            for (acc, &gj) in ideal.iter_mut().zip(g.iter()) {
                *acc += (h.alpha * gj) as f64;
            }
            opt.update(&mut s, &spec, &fitness).unwrap();
        }
        let e = opt.residual();
        let wt = flat(&s);
        let mut max_dev = 0.0f64;
        for j in 0..d {
            let theta = wt[j] as f64 + e[j] as f64;
            let want = w0[j] as f64 + ideal[j];
            max_dev = max_dev.max((theta - want).abs());
        }
        // f16 residual storage injects <= 2^-11 per step; 10 steps ~ 5e-3.
        assert!(max_dev < 0.01, "virtual trajectory deviates by {}", max_dev);
    }

    #[test]
    fn stagnation_is_defeated() {
        // The signature QES behaviour: with alpha*g far below the rounding
        // threshold, naive rounding would never move; error feedback must
        // accumulate until weights change.
        let mut s = store(Format::Int4);
        let d = s.lattice_dim();
        let h = EsHyper { alpha: 0.2, gamma: 1.0, sigma: 0.5, pairs: 2, k_window: 0 };
        let mut opt = QesFullResidual::new(d, 7, h);
        // identical fitness pattern every generation -> consistent drift
        let spec0 = PopulationSpec { gen_seed: 999, pairs: 2, sigma: 0.5 };
        let fitness = vec![0.5, -0.5, 0.25, -0.25];
        let mut total_changed = 0u64;
        let mut first_changed = 0u64;
        for t in 0..8 {
            let st = opt.update(&mut s, &spec0, &fitness).unwrap();
            if t == 0 {
                first_changed = st.n_changed;
            }
            total_changed += st.n_changed;
        }
        // same seed every step => same g_hat each step; alpha|g| may be sub-
        // threshold at t=0 for most elements but must cross it eventually.
        assert!(total_changed > first_changed * 2, "no accumulation effect");
        assert!(total_changed > 0);
    }

    #[test]
    fn zero_fitness_changes_nothing() {
        let mut s = store(Format::Int4);
        let before = flat(&s);
        let d = s.lattice_dim();
        let mut opt = QesFullResidual::new(d, 7, hyper());
        let spec = PopulationSpec { gen_seed: 1, pairs: 4, sigma: 0.5 };
        opt.update(&mut s, &spec, &vec![0.0; 8]).unwrap();
        assert_eq!(before, flat(&s));
        assert_eq!(s.dirty_shards(), 0, "no-op update dirtied shards");
    }

    #[test]
    fn lattice_never_leaves_range() {
        let mut s = store(Format::Int4);
        let d = s.lattice_dim();
        let h = EsHyper { alpha: 5.0, gamma: 0.95, sigma: 1.0, pairs: 2, k_window: 0 };
        let mut opt = QesFullResidual::new(d, 7, h);
        let mut rng = crate::rng::SplitMix64::new(3);
        for _ in 0..15 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 2, sigma: 1.0 };
            let raw: Vec<f32> = (0..4).map(|_| rng.uniform01() * 10.0).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            opt.update(&mut s, &spec, &fitness).unwrap();
        }
        assert!(flat(&s).iter().all(|&v| (-7..=7).contains(&v)));
    }

    #[test]
    fn state_bytes_is_2d() {
        let s = store(Format::Int4);
        let d = s.lattice_dim();
        let opt = QesFullResidual::new(d, 7, hyper());
        assert_eq!(opt.state_bytes(), 2 * d as u64);
    }
}
