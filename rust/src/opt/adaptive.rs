//! Adaptive replay window — the paper's §6 future-work extension:
//! "an adaptive algorithm could automatically tune K and the decay rate
//! gamma based on real-time convergence stability".
//!
//! `AdaptiveReplayQes` wraps `SeedReplayQes` and adjusts K between updates
//! from two live signals:
//!
//! * **truncation pressure** — the magnitude the proxy residual would still
//!   have at the window edge, estimated as `gamma^K * mean|e|`: if the
//!   truncated tail is non-negligible, K grows (reconstruction is being
//!   cut off too early);
//! * **stability headroom** — if fitness variance has been low (converged
//!   plateau) and the tail is negligible, K shrinks to save reconstruction
//!   compute (the Table 7/9 trade-off, automated).
//!
//! K stays in [k_min, k_max]; history beyond the current K is dropped
//! lazily by the inner optimizer.

use crate::model::ShardedParamStore;
use crate::opt::{
    EsHyper, KernelPolicy, LatticeOptimizer, PopulationSpec, SeedReplayQes, StepStats,
};

pub struct AdaptiveReplayQes {
    inner: SeedReplayQes,
    pub k_min: usize,
    pub k_max: usize,
    /// Truncation tolerance: grow K while gamma^K * mean|e| exceeds this.
    pub tail_tol: f32,
    /// Recent fitness spreads (max - min), for the stability signal.
    recent_spread: Vec<f32>,
    adjust_every: usize,
    step: usize,
}

impl AdaptiveReplayQes {
    pub fn new(d: usize, qmax: i8, hyper: EsHyper, k_min: usize, k_max: usize) -> Self {
        let k0 = hyper.k_window.clamp(k_min, k_max);
        let mut hyper = hyper;
        hyper.k_window = k0;
        AdaptiveReplayQes {
            inner: SeedReplayQes::new(d, qmax, hyper),
            k_min,
            k_max,
            tail_tol: 0.02,
            recent_spread: Vec::new(),
            adjust_every: 5,
            step: 0,
        }
    }

    pub fn current_k(&self) -> usize {
        self.inner.hyper.k_window
    }

    /// Set the inner replay kernel's execution policy (chunk size /
    /// threads). Results are invariant to it; only wall-clock changes.
    pub fn set_policy(&mut self, policy: KernelPolicy) {
        self.inner.policy = policy;
    }

    fn mean_abs_residual(&self) -> f32 {
        self.inner.mean_abs_proxy()
    }

    fn adjust(&mut self) {
        let gamma = self.inner.hyper.gamma;
        let k = self.inner.hyper.k_window;
        let tail = gamma.powi(k as i32) * self.mean_abs_residual();
        let spread = crate::util::mean(&self.recent_spread);
        self.recent_spread.clear();
        let new_k = if tail > self.tail_tol {
            // truncation is biting: widen the window
            (k + k / 2 + 1).min(self.k_max)
        } else if spread < 1e-3 && tail < self.tail_tol * 0.1 {
            // converged plateau with negligible tail: save compute
            (k.saturating_sub(k / 4).max(1)).max(self.k_min)
        } else {
            k
        };
        self.inner.hyper.k_window = new_k;
    }
}

impl LatticeOptimizer for AdaptiveReplayQes {
    fn update(
        &mut self,
        store: &mut ShardedParamStore,
        spec: &PopulationSpec,
        fitness: &[f32],
    ) -> anyhow::Result<StepStats> {
        let spread = fitness.iter().cloned().fold(f32::MIN, f32::max)
            - fitness.iter().cloned().fold(f32::MAX, f32::min);
        self.recent_spread.push(spread.max(0.0));
        let stats = self.inner.update(store, spec, fitness)?;
        self.step += 1;
        if self.step % self.adjust_every == 0 {
            self.adjust();
        }
        Ok(stats)
    }

    fn state_bytes(&self) -> u64 {
        self.inner.state_bytes()
    }

    fn name(&self) -> &'static str {
        "qes-adaptive-k"
    }

    /// Inner replay state plus the controller's own evolution: the
    /// current K, the step counter (phase of the adjust cadence) and
    /// the pending spread window — all of it feeds future K decisions,
    /// so a resumed run must see the same controller trajectory.
    fn save_state(&self, w: &mut dyn std::io::Write) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        write_u8(w, crate::opt::state_tag::ADAPTIVE)?;
        self.inner.save_state(w)?;
        write_u32(w, self.inner.hyper.k_window as u32)?;
        write_u64(w, self.step as u64)?;
        write_u32(w, self.recent_spread.len() as u32)?;
        for &s in &self.recent_spread {
            write_f32(w, s)?;
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut dyn std::io::Read) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        expect_tag(r, crate::opt::state_tag::ADAPTIVE, "qes-adaptive-k")?;
        self.inner.load_state(r)?;
        let k = read_u32(r)? as usize;
        anyhow::ensure!(
            (self.k_min..=self.k_max).contains(&k),
            "restored K={} outside [{}, {}]",
            k,
            self.k_min,
            self.k_max
        );
        self.inner.hyper.k_window = k;
        self.step = read_u64(r)? as usize;
        let n = read_u32(r)? as usize;
        anyhow::ensure!(n <= 1 << 16, "absurd spread window length {}", n);
        self.recent_spread.clear();
        for _ in 0..n {
            self.recent_spread.push(read_f32(r)?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init::init_fp, ParamStore};
    use crate::quant::Format;
    use crate::rng::SplitMix64;
    use crate::runtime::manifest::Manifest;

    fn store() -> ShardedParamStore {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 5);
        let q = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        ShardedParamStore::with_default_shards(q).unwrap()
    }

    fn hyper(k: usize) -> EsHyper {
        EsHyper { sigma: 0.5, alpha: 0.4, gamma: 0.95, pairs: 4, k_window: k }
    }

    #[test]
    fn k_stays_within_bounds() {
        let mut s = store();
        let d = s.lattice_dim();
        let mut opt = AdaptiveReplayQes::new(d, 7, hyper(4), 2, 12);
        let mut rng = SplitMix64::new(1);
        for _ in 0..40 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            opt.update(&mut s, &spec, &fitness).unwrap();
            assert!((2..=12).contains(&opt.current_k()), "K={}", opt.current_k());
        }
    }

    #[test]
    fn k_shrinks_on_plateau() {
        // zero fitness spread for many generations => plateau => K shrinks
        let mut s = store();
        let d = s.lattice_dim();
        let mut opt = AdaptiveReplayQes::new(d, 7, hyper(8), 2, 16);
        let mut rng = SplitMix64::new(2);
        for _ in 0..30 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            opt.update(&mut s, &spec, &vec![0.0; 8]).unwrap();
        }
        assert!(opt.current_k() < 8, "K did not shrink: {}", opt.current_k());
    }

    #[test]
    fn k_grows_under_truncation_pressure() {
        // strong persistent signal + high gamma keeps residuals large at
        // the window edge => K grows
        let mut s = store();
        let d = s.lattice_dim();
        let mut h = hyper(2);
        h.gamma = 0.99;
        h.alpha = 0.3;
        let mut opt = AdaptiveReplayQes::new(d, 7, h, 2, 16);
        opt.tail_tol = 1e-4;
        let spec = PopulationSpec { gen_seed: 9, pairs: 4, sigma: 0.5 };
        let fitness = vec![0.5, -0.5, 0.25, -0.25, 0.1, -0.1, 0.05, -0.05];
        for _ in 0..20 {
            opt.update(&mut s, &spec, &fitness).unwrap();
        }
        assert!(opt.current_k() > 2, "K did not grow: {}", opt.current_k());
    }

    #[test]
    fn state_stays_kilobytes() {
        let mut s = store();
        let d = s.lattice_dim();
        let mut opt = AdaptiveReplayQes::new(d, 7, hyper(8), 2, 64);
        let mut rng = SplitMix64::new(3);
        for _ in 0..70 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 4, sigma: 0.5 };
            let raw: Vec<f32> = (0..8).map(|_| rng.uniform01()).collect();
            opt.update(&mut s, &spec, &crate::opt::normalize_fitness(&raw)).unwrap();
        }
        assert!(opt.state_bytes() < 8192);
    }
}
