//! Shared ES machinery: perturbation application (rollout side) and
//! gradient-estimate accumulation (update side). Both regenerate the same
//! discrete noise from seeds — nothing d-sized is ever stored between them.
//!
//! The sequential `accumulate_grad` here is the REFERENCE implementation
//! the chunk-parallel kernels (`opt::kernels`) are verified against
//! bit-for-bit; the optimizers' hot paths run the fused kernels instead.

use crate::model::{AsParams, ParamsView};
use crate::opt::kernels::{self, KernelPolicy};
use crate::opt::PopulationSpec;
use crate::rng::NoiseStream;

/// Materialize member `m`'s perturbed lattice tensors (Eq. 3 + Eq. 4
/// boundary gating), leaving the parameters untouched. Output is aligned
/// with `store.lattice_indices()` — ready for `runtime::param_literals`.
/// Accepts any parameter source (plain store, sharded plane, snapshot).
///
/// Allocates fresh buffers per call; rollout loops that evaluate many
/// members should hold a scratch `Vec<Vec<i8>>` and call
/// [`apply_perturbation_into`] instead.
pub fn apply_perturbation<P: AsParams + ?Sized>(
    params: &P,
    spec: &PopulationSpec,
    member: usize,
    qmax: i8,
) -> Vec<Vec<i8>> {
    let mut out: Vec<Vec<i8>> = Vec::new();
    apply_perturbation_into(params, spec, member, qmax, &mut out, KernelPolicy::default());
    out
}

/// [`apply_perturbation`] into caller-owned buffers: `out` is resized to
/// mirror the lattice tensor shapes on first use and reused verbatim after
/// that, so a rollout loop allocates once per worker instead of once per
/// member. Chunk-parallel per `policy`; output is bit-identical to the
/// sequential walk for any policy AND any source segmentation (per-tensor
/// or per-shard — the chunk plan covers the same flat element space).
pub fn apply_perturbation_into<P: AsParams + ?Sized>(
    params: &P,
    spec: &PopulationSpec,
    member: usize,
    qmax: i8,
    out: &mut Vec<Vec<i8>>,
    policy: KernelPolicy,
) {
    let ParamsView { store, lattice } = params.params_view();
    let lat = store.lattice_indices();
    if out.len() != lat.len() {
        out.resize_with(lat.len(), Vec::new);
    }
    for (o, &i) in out.iter_mut().zip(lat.iter()) {
        o.resize(store.entries[i].numel(), 0);
    }
    let dst: Vec<&mut [i8]> = out.iter_mut().map(|v| v.as_mut_slice()).collect();
    kernels::fill_perturbation(lattice, dst, spec, member, qmax, policy);
}

/// Materialize perturbed lattices for a whole member subset at once —
/// the grouped rollout's regeneration hook. `outs[j]` receives member
/// `members[j]`'s override tensors, each filled by the same
/// [`apply_perturbation_into`] walk the sequential path uses, so every
/// member's slab is bit-identical to its per-member materialization.
/// Outer and inner buffers are reused across rounds like the per-member
/// scratch.
pub fn apply_population_into<P: AsParams + ?Sized>(
    params: &P,
    spec: &PopulationSpec,
    members: &[usize],
    qmax: i8,
    outs: &mut Vec<Vec<Vec<i8>>>,
    policy: KernelPolicy,
) {
    if outs.len() < members.len() {
        outs.resize_with(members.len(), Vec::new);
    }
    outs.truncate(members.len());
    for (out, &m) in outs.iter_mut().zip(members.iter()) {
        apply_perturbation_into(params, spec, m, qmax, out, policy);
    }
}

/// Accumulate the ES gradient estimate (Eq. 5):
///   g_hat = 1 / (N * sigma) * sum_i F_i * delta_i
/// over all 2*pairs members, into `out` (length = lattice dim d).
///
/// Antithetic pairs share RNG draws via `next_pair_deltas`, halving the
/// regeneration cost. This is the sequential reference path; the fused
/// chunk-parallel equivalent is `kernels::accumulate_grad_chunked` (and
/// the optimizers fuse it straight into their update loops).
pub fn accumulate_grad(spec: &PopulationSpec, fitness: &[f32], out: &mut [f32]) {
    assert_eq!(fitness.len(), spec.n_members());
    out.fill(0.0);
    let n = spec.n_members() as f32;
    let inv = 1.0 / (n * spec.sigma);
    for pair in 0..spec.pairs {
        let (seed, _) = spec.member(2 * pair);
        let fp = fitness[2 * pair] * inv;
        let fm = fitness[2 * pair + 1] * inv;
        if fp == 0.0 && fm == 0.0 {
            // Rank-normalized fitness can zero a pair; still must consume
            // nothing — stream positions are per-pair, not global, so a
            // skipped pair costs nothing and changes nothing.
            continue;
        }
        let mut stream = NoiseStream::new(seed, spec.sigma, 1.0);
        for g in out.iter_mut() {
            let (dp, dm) = stream.next_pair_deltas();
            *g += fp * dp as f32 + fm * dm as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init::init_fp, ParamStore};
    use crate::quant::Format;
    use crate::runtime::manifest::Manifest;

    fn quant_store() -> (Manifest, ParamStore) {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 3);
        let q = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        (man, q)
    }

    #[test]
    fn perturbation_is_reproducible_and_in_range() {
        let (_man, store) = quant_store();
        let spec = PopulationSpec { gen_seed: 11, pairs: 2, sigma: 0.8 };
        let a = apply_perturbation(&store, &spec, 0, 7);
        let b = apply_perturbation(&store, &spec, 0, 7);
        assert_eq!(a, b);
        for t in &a {
            assert!(t.iter().all(|&v| (-7..=7).contains(&v)));
        }
        // with sigma=0.8 a decent share of elements must actually move
        let moved: usize = a
            .iter()
            .zip(store.lattice_i8())
            .map(|(p, o)| p.iter().zip(o.iter()).filter(|(x, y)| x != y).count())
            .sum();
        assert!(moved > 0);
    }

    #[test]
    fn perturbation_into_reuses_buffers_and_matches() {
        let (_man, store) = quant_store();
        let spec = PopulationSpec { gen_seed: 31, pairs: 2, sigma: 0.6 };
        let fresh = apply_perturbation(&store, &spec, 1, 7);
        let mut scratch: Vec<Vec<i8>> = Vec::new();
        // fill twice with different members; the second overwrite must be
        // indistinguishable from a fresh allocation
        apply_perturbation_into(&store, &spec, 0, 7, &mut scratch, KernelPolicy::scalar());
        apply_perturbation_into(&store, &spec, 1, 7, &mut scratch, KernelPolicy::default());
        assert_eq!(scratch, fresh);
    }

    #[test]
    fn population_matches_per_member_application() {
        let (_man, store) = quant_store();
        let spec = PopulationSpec { gen_seed: 19, pairs: 2, sigma: 0.6 };
        let members = [3usize, 0, 2];
        let mut outs: Vec<Vec<Vec<i8>>> = Vec::new();
        apply_population_into(&store, &spec, &members, 7, &mut outs, KernelPolicy::default());
        assert_eq!(outs.len(), members.len());
        for (out, &m) in outs.iter().zip(members.iter()) {
            assert_eq!(*out, apply_perturbation(&store, &spec, m, 7));
        }
        // shrink: buffers truncate to the subset (retry singletons)
        apply_population_into(&store, &spec, &[1], 7, &mut outs, KernelPolicy::scalar());
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0], apply_perturbation(&store, &spec, 1, 7));
    }

    #[test]
    fn antithetic_members_differ() {
        let (_man, store) = quant_store();
        let spec = PopulationSpec { gen_seed: 5, pairs: 1, sigma: 1.0 };
        let p = apply_perturbation(&store, &spec, 0, 7);
        let m = apply_perturbation(&store, &spec, 1, 7);
        assert_ne!(p, m);
    }

    #[test]
    fn grad_zero_for_zero_fitness() {
        let (_man, store) = quant_store();
        let d = store.lattice_dim();
        let spec = PopulationSpec { gen_seed: 2, pairs: 4, sigma: 0.5 };
        let mut g = vec![1.0f32; d];
        accumulate_grad(&spec, &vec![0.0; 8], &mut g);
        assert!(g.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn grad_points_toward_rewarded_member() {
        // Reward only the + member of pair 0: g must equal F * delta+ / (N sigma)
        let (_man, store) = quant_store();
        let d = store.lattice_dim();
        let spec = PopulationSpec { gen_seed: 17, pairs: 2, sigma: 0.7 };
        let mut fitness = vec![0.0f32; 4];
        fitness[0] = 0.5;
        let mut g = vec![0.0f32; d];
        accumulate_grad(&spec, &fitness, &mut g);
        // regenerate delta+ of pair 0 and check proportionality
        let (seed, _) = spec.member(0);
        let mut stream = NoiseStream::new(seed, spec.sigma, 1.0);
        let inv = 0.5 / (4.0 * spec.sigma);
        for gj in g.iter().take(1000) {
            let (dp, _) = stream.next_pair_deltas();
            assert!((gj - inv * dp as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_matches_paired_delta_regeneration_exactly() {
        // g[j] must equal inv * (F+ * dp_j + F- * dm_j) with the deltas
        // regenerated from the same stream — the identity Algorithm 2's
        // replay depends on.
        let (_man, store) = quant_store();
        let d = store.lattice_dim();
        let spec = PopulationSpec { gen_seed: 23, pairs: 1, sigma: 0.5 };
        let (f_pos, f_neg) = (0.3f32, -0.1f32);
        let mut g = vec![0.0f32; d];
        accumulate_grad(&spec, &[f_pos, f_neg], &mut g);
        let (seed, _) = spec.member(0);
        let mut stream = NoiseStream::new(seed, spec.sigma, 1.0);
        let inv = 1.0 / (2.0 * spec.sigma);
        for (j, &gj) in g.iter().enumerate() {
            let (dp, dm) = stream.next_pair_deltas();
            let want = inv * (f_pos * dp as f32 + f_neg * dm as f32);
            assert!((gj - want).abs() < 1e-6, "elem {}: {} vs {}", j, gj, want);
        }
        // and the paired deltas themselves are unbiased mirrors on average
        let mean: f32 = g.iter().sum::<f32>() / d as f32;
        assert!(mean.abs() < 0.05, "mean={}", mean);
    }
}
