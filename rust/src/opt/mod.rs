//! Optimizer suite: QES (Algorithms 1 & 2) and every baseline the paper
//! compares against (QuZO, MeZO, first-order Adam ± STE).
//!
//! All ES-family optimizers share the population protocol:
//!
//! 1. the leader draws one `gen_seed` per generation;
//! 2. pair `p` of the population derives `member_seed(gen_seed, p)`; its two
//!    antithetic members perturb the lattice with `±` discrete noise
//!    (Eq. 3) regenerated from that seed — never stored;
//! 3. after rollouts, raw rewards are rank-normalized into fitness;
//! 4. the update rule consumes `(gen_seed, fitness)` only — which is
//!    exactly why Algorithm 2 can rematerialize optimizer state from a
//!    K-deep history of those tuples.

pub mod adam;
pub mod adaptive;
pub mod baselines;
pub mod grad;
pub mod kernels;
pub mod qes;
pub mod replay;

pub use adam::{Adam, AdamConfig};
pub use adaptive::AdaptiveReplayQes;
pub use baselines::{MezoOptimizer, QuzoOptimizer};
pub use grad::{
    accumulate_grad, apply_perturbation, apply_perturbation_into, apply_population_into,
};
pub use kernels::{accumulate_grad_chunked, KernelPolicy, WeightDeltas, DEFAULT_CHUNK};
pub use qes::QesFullResidual;
pub use replay::SeedReplayQes;

use crate::model::ShardedParamStore;

/// Hyperparameters shared by the ES-family optimizers (paper §A.1/§A.3).
#[derive(Debug, Clone)]
pub struct EsHyper {
    /// Perturbation scale sigma.
    pub sigma: f32,
    /// Learning rate alpha.
    pub alpha: f32,
    /// Residual decay gamma in (0, 1].
    pub gamma: f32,
    /// Antithetic pairs per generation (population = 2 * pairs).
    pub pairs: usize,
    /// Seed-replay window K (Algorithm 2 only).
    pub k_window: usize,
}

impl Default for EsHyper {
    fn default() -> Self {
        EsHyper { sigma: 1e-2, alpha: 5e-4, gamma: 0.9, pairs: 8, k_window: 8 }
    }
}

/// One generation's population description. Member `2p` is the `+` half of
/// pair `p`, member `2p+1` the `-` half.
#[derive(Debug, Clone)]
pub struct PopulationSpec {
    pub gen_seed: u64,
    pub pairs: usize,
    pub sigma: f32,
}

impl PopulationSpec {
    pub fn n_members(&self) -> usize {
        self.pairs * 2
    }

    /// (stream seed, sign) of member `m`.
    pub fn member(&self, m: usize) -> (u64, f32) {
        let pair = (m / 2) as u64;
        let sign = if m % 2 == 0 { 1.0 } else { -1.0 };
        (crate::rng::member_seed(self.gen_seed, pair), sign)
    }
}

/// Centered-rank fitness normalization (Salimans et al. 2017): maps raw
/// rewards to [-0.5, 0.5] by rank; constant populations map to all-zero
/// (no update when there is no signal).
pub fn normalize_fitness(raw: &[f32]) -> Vec<f32> {
    let n = raw.len();
    if n <= 1 {
        return vec![0.0; n];
    }
    let first = raw[0];
    if raw.iter().all(|&r| r == first) {
        return vec![0.0; n];
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| raw[a].partial_cmp(&raw[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut fit = vec![0.0f32; n];
    // average ranks over ties so equal rewards get equal fitness
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && raw[idx[j + 1]] == raw[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f32 / 2.0;
        for &k in &idx[i..=j] {
            fit[k] = avg_rank / (n - 1) as f32 - 0.5;
        }
        i = j + 1;
    }
    fit
}

/// Degraded-round fitness (fault-tolerant rollout plane): rank-normalize
/// over the members actually scored. `rewards[m] = None` marks a member
/// that permanently failed scoring; an antithetic pair counts only when
/// BOTH halves scored (a surviving half alone would bias the gradient
/// estimate, so incomplete pairs contribute exactly zero). Scored
/// members are rank-normalized among themselves and rescaled by
/// `n / n_scored`, which turns the update rule's fixed `1/(n·σ)`
/// normalization into an effective `1/(n_scored·σ)`.
///
/// Determinism: the output is a pure function of the failed-member SET
/// and the scored rewards (themselves pure functions of seeds), so a
/// degraded round commits bit-identical deltas regardless of which
/// worker, retry attempt, or arrival order produced the survivors. A
/// fully-scored round returns exactly [`normalize_fitness`].
///
/// Errors when fewer than `ceil(min_quorum * pairs)` pairs scored.
pub fn quorum_fitness(rewards: &[Option<f32>], min_quorum: f32) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(rewards.len() % 2 == 0, "population size must be even");
    let pairs = rewards.len() / 2;
    if pairs == 0 {
        return Ok(Vec::new());
    }
    let complete: Vec<usize> = (0..pairs)
        .filter(|&p| rewards[2 * p].is_some() && rewards[2 * p + 1].is_some())
        .collect();
    anyhow::ensure!(
        complete.len() as f32 + 1e-6 >= min_quorum * pairs as f32,
        "round below quorum: {}/{} antithetic pairs scored (min quorum {:.2})",
        complete.len(),
        pairs,
        min_quorum
    );
    if complete.len() == pairs {
        let raw: Vec<f32> = rewards.iter().map(|r| r.expect("all pairs complete")).collect();
        return Ok(normalize_fitness(&raw));
    }
    let scored: Vec<f32> = complete
        .iter()
        .flat_map(|&p| {
            [
                rewards[2 * p].expect("pair checked complete"),
                rewards[2 * p + 1].expect("pair checked complete"),
            ]
        })
        .collect();
    let norm = normalize_fitness(&scored);
    let scale = rewards.len() as f32 / scored.len() as f32;
    let mut out = vec![0.0f32; rewards.len()];
    for (i, &p) in complete.iter().enumerate() {
        out[2 * p] = norm[2 * i] * scale;
        out[2 * p + 1] = norm[2 * i + 1] * scale;
    }
    Ok(out)
}

/// Per-step update statistics (paper Table 7 bottom: update ratio and
/// boundary-hit ratio rho).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepStats {
    /// Lattice elements whose value changed this step.
    pub n_changed: u64,
    /// Changed elements that landed exactly on the lattice boundary ±qmax.
    pub n_boundary: u64,
    /// Update deltas suppressed by the gate (would have left the lattice).
    pub n_gated: u64,
    /// Total lattice dimension d.
    pub d: u64,
}

impl StepStats {
    pub fn update_ratio(&self) -> f64 {
        if self.d == 0 {
            0.0
        } else {
            self.n_changed as f64 / self.d as f64
        }
    }

    pub fn boundary_hit_ratio(&self) -> f64 {
        if self.n_changed == 0 {
            0.0
        } else {
            self.n_boundary as f64 / self.n_changed as f64
        }
    }
}

/// The interface the coordinator drives. `update` consumes the generation's
/// seeds (via the spec) and normalized fitness, and commits the resulting
/// sparse weight deltas onto the store's copy-on-write shard plane.
pub trait LatticeOptimizer {
    fn update(
        &mut self,
        store: &mut ShardedParamStore,
        spec: &PopulationSpec,
        fitness: &[f32],
    ) -> anyhow::Result<StepStats>;

    /// Persistent optimizer-state footprint in bytes (Table 8).
    fn state_bytes(&self) -> u64;

    fn name(&self) -> &'static str;

    /// Serialize the optimizer's mutable state (residual slabs, replay
    /// history, step counters — everything `update` evolves) for the
    /// crash-consistent training checkpoint. Hyperparameters are NOT
    /// included: a resumed run reconstructs the optimizer from config
    /// and then restores state on top.
    fn save_state(&self, w: &mut dyn std::io::Write) -> anyhow::Result<()>;

    /// Restore state written by `save_state` of the same optimizer
    /// type. Errors (rather than corrupting the run) on a tag or shape
    /// mismatch.
    fn load_state(&mut self, r: &mut dyn std::io::Read) -> anyhow::Result<()>;
}

/// One-byte discriminants guarding `save_state`/`load_state` blobs
/// against cross-optimizer restores.
pub(crate) mod state_tag {
    pub const QUZO: u8 = 1;
    pub const FULL_RESIDUAL: u8 = 2;
    pub const SEED_REPLAY: u8 = 3;
    pub const ADAPTIVE: u8 = 4;
}

/// Little-endian primitives for optimizer-state blobs. Deliberately
/// minimal: the blob is embedded inside the training checkpoint, whose
/// framing (magic, lengths, atomicity) lives in `model::checkpoint`.
pub(crate) mod state_io {
    use std::io::{Read, Write};

    pub fn write_u8(w: &mut dyn Write, v: u8) -> anyhow::Result<()> {
        w.write_all(&[v])?;
        Ok(())
    }

    pub fn write_u32(w: &mut dyn Write, v: u32) -> anyhow::Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn write_u64(w: &mut dyn Write, v: u64) -> anyhow::Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn write_f32(w: &mut dyn Write, v: f32) -> anyhow::Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn read_u8(r: &mut dyn Read) -> anyhow::Result<u8> {
        let mut b = [0u8; 1];
        r.read_exact(&mut b)?;
        Ok(b[0])
    }

    pub fn read_u32(r: &mut dyn Read) -> anyhow::Result<u32> {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn read_u64(r: &mut dyn Read) -> anyhow::Result<u64> {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn read_f32(r: &mut dyn Read) -> anyhow::Result<f32> {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }

    pub fn expect_tag(r: &mut dyn Read, want: u8, name: &str) -> anyhow::Result<()> {
        let got = read_u8(r)?;
        anyhow::ensure!(
            got == want,
            "optimizer state tag mismatch: expected {} ({}), found {}",
            want,
            name,
            got
        );
        Ok(())
    }
}

/// Evaluate the boundary gate for one lattice element without mutating it.
/// Returns (applied delta, landed_on_boundary) — the pure core shared by
/// [`gate_apply`] and the delta-emitting kernels.
#[inline]
pub fn gate_eval(w: i8, dw: i32, qmax: i8) -> (i32, bool) {
    if dw == 0 {
        return (0, false);
    }
    let next = w as i32 + dw;
    if next < -(qmax as i32) || next > qmax as i32 {
        (0, false) // gated: Eq. (4)
    } else {
        (dw, next.unsigned_abs() == qmax as u32)
    }
}

/// Gate + apply a discrete update to one lattice element.
/// Returns (applied delta, landed_on_boundary).
#[inline]
pub fn gate_apply(w: &mut i8, dw: i32, qmax: i8) -> (i32, bool) {
    let (applied, boundary) = gate_eval(*w, dw, qmax);
    if applied != 0 {
        *w = (*w as i32 + applied) as i8;
    }
    (applied, boundary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitness_is_centered_and_bounded() {
        let f = normalize_fitness(&[3.0, 1.0, 2.0, 0.0]);
        let sum: f32 = f.iter().sum();
        assert!(sum.abs() < 1e-6);
        assert_eq!(f[0], 0.5); // highest reward
        assert_eq!(f[3], -0.5); // lowest
        assert!(f.iter().all(|&x| (-0.5..=0.5).contains(&x)));
    }

    #[test]
    fn constant_rewards_zero_fitness() {
        let f = normalize_fitness(&[0.25; 10]);
        assert!(f.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn ties_share_fitness() {
        let f = normalize_fitness(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(f[1], f[2]);
        assert!(f[3] > f[1] && f[1] > f[0]);
    }

    #[test]
    fn member_spec_antithetic() {
        let spec = PopulationSpec { gen_seed: 9, pairs: 4, sigma: 0.1 };
        assert_eq!(spec.n_members(), 8);
        let (s0, g0) = spec.member(0);
        let (s1, g1) = spec.member(1);
        assert_eq!(s0, s1);
        assert_eq!(g0, 1.0);
        assert_eq!(g1, -1.0);
        let (s2, _) = spec.member(2);
        assert_ne!(s0, s2);
    }

    #[test]
    fn gate_blocks_out_of_range() {
        let mut w = 7i8;
        let (applied, _) = gate_apply(&mut w, 1, 7);
        assert_eq!(applied, 0);
        assert_eq!(w, 7);
        let (applied, boundary) = gate_apply(&mut w, -1, 7);
        assert_eq!(applied, -1);
        assert_eq!(w, 6);
        assert!(!boundary);
        let mut w = 6i8;
        let (_, boundary) = gate_apply(&mut w, 1, 7);
        assert!(boundary);
        assert_eq!(w, 7);
    }

    #[test]
    fn quorum_full_round_matches_normalize() {
        let raw = [3.0f32, 1.0, 2.0, 0.0, 5.0, 4.0];
        let wrapped: Vec<Option<f32>> = raw.iter().map(|&r| Some(r)).collect();
        let q = quorum_fitness(&wrapped, 0.5).unwrap();
        assert_eq!(q, normalize_fitness(&raw), "fault-free path must be bit-identical");
    }

    #[test]
    fn quorum_degraded_zeroes_incomplete_pairs_and_rescales() {
        // pair 1 lost one half -> whole pair contributes zero
        let rewards = vec![Some(3.0), Some(1.0), None, Some(9.0), Some(2.0), Some(0.0)];
        let q = quorum_fitness(&rewards, 0.5).unwrap();
        assert_eq!(q[2], 0.0);
        assert_eq!(q[3], 0.0);
        // scored members: ranks over [3,1,2,0] scaled by 6/4
        let expect = normalize_fitness(&[3.0, 1.0, 2.0, 0.0]);
        let scale = 6.0 / 4.0;
        assert_eq!(q[0], expect[0] * scale);
        assert_eq!(q[1], expect[1] * scale);
        assert_eq!(q[4], expect[2] * scale);
        assert_eq!(q[5], expect[3] * scale);
        // degraded fitness still sums to ~0 (centered ranks)
        assert!(q.iter().sum::<f32>().abs() < 1e-5);
    }

    #[test]
    fn quorum_is_a_function_of_the_failed_set() {
        // Same failed set, different hypothetical arrival stories — the
        // input is the same, so this documents that nothing else (order,
        // retries, workers) can influence the result.
        let a = vec![Some(1.0), Some(2.0), None, None, Some(5.0), Some(3.0)];
        let b = a.clone();
        assert_eq!(quorum_fitness(&a, 0.5).unwrap(), quorum_fitness(&b, 0.5).unwrap());
    }

    #[test]
    fn quorum_violation_errors() {
        let rewards = vec![Some(1.0), Some(2.0), None, None, None, Some(3.0)];
        // only 1/3 pairs complete; quorum 0.5 -> error
        let err = quorum_fitness(&rewards, 0.5);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("quorum"));
        // quorum 1/3 passes
        assert!(quorum_fitness(&rewards, 0.33).is_ok());
        // odd population rejected
        assert!(quorum_fitness(&[Some(1.0)], 0.0).is_err());
    }

    #[test]
    fn stats_ratios() {
        let s = StepStats { n_changed: 10, n_boundary: 2, n_gated: 1, d: 1000 };
        assert!((s.update_ratio() - 0.01).abs() < 1e-12);
        assert!((s.boundary_hit_ratio() - 0.2).abs() < 1e-12);
    }
}
