//! Fused, chunk-parallel ES update kernels over counter-addressable noise.
//!
//! The scalar update path costs `(K+1) * pairs * d` sequential RNG calls
//! plus `K+1` full d-sized sweeps per seed-replay update. These kernels
//! restructure all of it around two ideas:
//!
//! 1. **Counter-addressable noise.** `NoiseStream::at(seed, j)` positions a
//!    stream at any element in O(1) (`rng::SplitMix64::jump`), so any chunk
//!    of the noise is independently materializable. Chunks go to worker
//!    threads (`util::parallel`), each regenerating exactly the window it
//!    owns.
//! 2. **Fusion + K-deep tiling.** Per chunk, the kernel regenerates all
//!    pairs' deltas, forms the gradient estimate, applies error feedback
//!    and boundary gating in one pass — no d-sized scratch gradient ever
//!    exists. For seed replay, the chunk's proxy residual stays resident
//!    across ALL K history steps (one pass over d with a K-deep inner tile
//!    instead of K+1 full-lattice passes), cutting memory traffic ~K-fold.
//!
//! # Determinism contract
//!
//! Every kernel produces results **bit-identical to the sequential scalar
//! path, for any chunk size, any thread count and any ISA microkernel
//! backend** (`KernelPolicy::kernel`, `crate::kernel`). The contract
//! holds because (a) stream jumps reproduce exact sequential stream
//! positions, (b) each element's f32 operations happen in the same order
//! as the scalar path (pair-major per element) — the SIMD backends
//! vectorize ACROSS elements with unfused mul+add, never within an
//! element's op sequence, and (c) chunks own disjoint slices, so thread
//! scheduling can never reorder arithmetic. Seed-replay correctness
//! (paper Algorithm 2) depends on this: a lattice evolved on 8 threads
//! with AVX2 microkernels must be re-materializable on 1 scalar thread.
//! `tests/equivalence.rs` enforces the contract across chunk sizes
//! {1, 64, 4096} × thread counts {1, 2, 8} × every detected microkernel.

use crate::kernel::{self, DotKernel, KernelKind};
use crate::opt::{gate_eval, PopulationSpec, StepStats};
use crate::rng::{NoiseStream, SplitMix64};
use crate::util::parallel;

/// Default chunk size: 8 Ki elements keeps the working set (chunk of
/// weights + gradient + residual) around 64 KB — L1/L2-resident on the
/// target cores — while leaving enough chunks to spread across threads
/// even for the nano lattice. Defined as the shard alignment of the COW
/// parameter plane, so default-policy chunks never straddle a shard
/// boundary and per-shard state segments line up with chunk windows.
pub const DEFAULT_CHUNK: usize = crate::model::SHARD_ALIGN;

/// Sparse weight writes produced by an update kernel: `(global flat
/// index, new lattice value)`, ascending by index. The caller commits
/// them through `ShardedParamStore::apply_deltas`, which copy-on-write
/// unshares only the shards that actually changed — update kernels
/// therefore never need mutable access to the (possibly published)
/// weight slabs.
pub type WeightDeltas = Vec<(usize, i8)>;

/// How a kernel splits and schedules its work — and which ISA microkernel
/// backend services the vectorizable inner loops. Never affects results —
/// only wall-clock (see the module-level determinism contract; the SIMD
/// backends keep every element's op sequence, `crate::kernel` docs).
#[derive(Debug, Clone, Copy)]
pub struct KernelPolicy {
    /// Elements per chunk (clamped to [1, d]).
    pub chunk_size: usize,
    /// Worker threads (1 = run inline on the caller's thread).
    pub threads: usize,
    /// ISA microkernel backend; `None` follows the process-wide dispatch
    /// (`QES_KERNEL` / `--kernel` / auto-detection).
    pub kernel: Option<KernelKind>,
}

impl Default for KernelPolicy {
    fn default() -> Self {
        KernelPolicy {
            chunk_size: DEFAULT_CHUNK,
            threads: parallel::default_threads(),
            kernel: None,
        }
    }
}

impl KernelPolicy {
    pub fn new(chunk_size: usize, threads: usize) -> Self {
        KernelPolicy { chunk_size, threads, kernel: None }
    }

    /// The sequential reference policy: one chunk, one thread — executes
    /// the exact op sequence of the historical scalar implementation.
    /// Deliberately topology-only (`kernel: None`, the process-wide
    /// dispatch): microkernel backends are bit-identical on these paths,
    /// and keeping both legs of the scalar-vs-chunked BENCH records on
    /// the SAME backend keeps that trajectory measuring chunk
    /// parallelism alone (the ISA dimension has its own `update_chunk`
    /// records). Pin explicitly with [`KernelPolicy::with_kernel`] when
    /// the backend itself is the variable under test.
    pub fn scalar() -> Self {
        KernelPolicy { chunk_size: usize::MAX, threads: 1, kernel: None }
    }

    /// Pin (or unpin) the ISA microkernel backend.
    pub fn with_kernel(mut self, kernel: Option<KernelKind>) -> Self {
        self.kernel = kernel;
        self
    }

    /// Resolve the microkernel this policy executes on.
    pub fn microkernel(&self) -> &'static dyn DotKernel {
        match self.kernel {
            Some(k) => kernel::by_kind(k),
            None => kernel::active_kernel(),
        }
    }

    /// Name of the resolved microkernel (logs, BENCH records, test
    /// failure messages).
    pub fn kernel_name(&self) -> &'static str {
        self.microkernel().name()
    }
}

/// A chunk's view of the lattice: the (possibly several) tensor segments
/// covering global elements `[start, start + len)`, in canonical order.
pub struct SegChunkMut<'a, T> {
    pub start: usize,
    pub len: usize,
    pub segs: Vec<&'a mut [T]>,
}

/// Immutable counterpart of [`SegChunkMut`].
pub struct SegChunk<'a, T> {
    pub start: usize,
    pub len: usize,
    pub segs: Vec<&'a [T]>,
}

/// The single source of truth for chunk boundaries: per-chunk
/// `(start, len)` over a flat space of `total` elements. Both splitters
/// below slice tensors against this plan, so mutable and immutable
/// chunkings of equal-length tensor lists agree on boundaries by
/// construction (fill_perturbation zips them).
fn chunk_plan(total: usize, chunk_size: usize) -> Vec<(usize, usize)> {
    let chunk_size = chunk_size.clamp(1, total.max(1));
    let mut plan = Vec::with_capacity(total / chunk_size + 1);
    let mut start = 0usize;
    while start < total {
        let len = chunk_size.min(total - start);
        plan.push((start, len));
        start += len;
    }
    plan
}

/// Split a canonical-order tensor list into fixed-size chunks of the flat
/// element space (per [`chunk_plan`]). Chunk boundaries ignore tensor
/// boundaries: a chunk may span several tensors and a tensor may span
/// several chunks.
pub fn chunk_segments_mut<T>(tensors: Vec<&mut [T]>, chunk_size: usize) -> Vec<SegChunkMut<'_, T>> {
    let total: usize = tensors.iter().map(|t| t.len()).sum();
    let mut chunks: Vec<SegChunkMut<'_, T>> = chunk_plan(total, chunk_size)
        .into_iter()
        .map(|(start, len)| SegChunkMut { start, len, segs: Vec::new() })
        .collect();
    let mut ci = 0usize; // chunk being filled
    let mut filled = 0usize; // elements already placed into chunk ci
    for t in tensors {
        let mut rest = t;
        while !rest.is_empty() {
            let take = (chunks[ci].len - filled).min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            chunks[ci].segs.push(head);
            filled += take;
            rest = tail;
            if filled == chunks[ci].len {
                ci += 1;
                filled = 0;
            }
        }
    }
    chunks
}

/// Immutable twin of [`chunk_segments_mut`], slicing against the same
/// [`chunk_plan`].
pub fn chunk_segments<T>(tensors: Vec<&[T]>, chunk_size: usize) -> Vec<SegChunk<'_, T>> {
    let total: usize = tensors.iter().map(|t| t.len()).sum();
    let mut chunks: Vec<SegChunk<'_, T>> = chunk_plan(total, chunk_size)
        .into_iter()
        .map(|(start, len)| SegChunk { start, len, segs: Vec::new() })
        .collect();
    let mut ci = 0usize;
    let mut filled = 0usize;
    for t in tensors {
        let mut rest = t;
        while !rest.is_empty() {
            let take = (chunks[ci].len - filled).min(rest.len());
            let (head, tail) = rest.split_at(take);
            chunks[ci].segs.push(head);
            filled += take;
            rest = tail;
            if filled == chunks[ci].len {
                ci += 1;
                filled = 0;
            }
        }
    }
    chunks
}

/// Accumulate the ES gradient estimate (Eq. 5) for the window of elements
/// `[start, start + g.len())` into `g` — bit-identical to the same window
/// of the sequential `opt::accumulate_grad` (same per-element pair order,
/// same f32 operation sequence).
pub fn grad_chunk(spec: &PopulationSpec, fitness: &[f32], start: usize, g: &mut [f32]) {
    debug_assert_eq!(fitness.len(), spec.n_members());
    g.fill(0.0);
    let n = spec.n_members() as f32;
    let inv = 1.0 / (n * spec.sigma);
    for pair in 0..spec.pairs {
        let fp = fitness[2 * pair] * inv;
        let fm = fitness[2 * pair + 1] * inv;
        if fp == 0.0 && fm == 0.0 {
            // Rank-normalized fitness can zero a pair; skipping costs and
            // changes nothing (stream positions are per-pair).
            continue;
        }
        let (seed, _) = spec.member(2 * pair);
        let mut stream = NoiseStream::at(seed, spec.sigma, 1.0, start);
        for gj in g.iter_mut() {
            let (dp, dm) = stream.next_pair_deltas();
            *gj += fp * dp as f32 + fm * dm as f32;
        }
    }
}

/// Chunk-parallel gradient accumulation into a full d-sized buffer.
/// (The fused optimizer kernels below never materialize this buffer; this
/// entry point exists for diagnostics, tests and benches.)
pub fn accumulate_grad_chunked(
    spec: &PopulationSpec,
    fitness: &[f32],
    out: &mut [f32],
    policy: KernelPolicy,
) {
    assert_eq!(fitness.len(), spec.n_members());
    let chunks = chunk_segments_mut(vec![out], policy.chunk_size);
    parallel::map_tasks(chunks, policy.threads, |mut c| {
        let mut off = c.start;
        for seg in c.segs.iter_mut() {
            grad_chunk(spec, fitness, off, seg);
            off += seg.len();
        }
    });
}

fn reduce_stats(d: usize, partials: Vec<(StepStats, WeightDeltas)>) -> (StepStats, WeightDeltas) {
    let mut total = StepStats { d: d as u64, ..Default::default() };
    let n: usize = partials.iter().map(|(_, dv)| dv.len()).sum();
    let mut deltas = Vec::with_capacity(n);
    // map_tasks returns partials in chunk order, and in-chunk indices are
    // ascending, so the concatenation is globally index-sorted.
    for (p, dv) in partials {
        total.n_changed += p.n_changed;
        total.n_boundary += p.n_boundary;
        total.n_gated += p.n_gated;
        deltas.extend(dv);
    }
    (total, deltas)
}

/// Fused QES Full-Residual update (Algorithm 1): per chunk, regenerate all
/// pairs' deltas, form the gradient, apply error feedback (f16 residual)
/// and boundary gating in a single pass. No d-sized gradient buffer.
///
/// `weights` is the current lattice as read-only canonical-flat-order
/// segments (any segmentation — per-tensor or per-shard); `e` is the
/// persistent residual, segmented per shard alongside the weights. Weight
/// changes come back as sparse [`WeightDeltas`] for COW commit.
#[allow(clippy::too_many_arguments)]
pub fn fused_full_residual(
    weights: Vec<&[i8]>,
    e: Vec<&mut [u16]>,
    spec: &PopulationSpec,
    fitness: &[f32],
    alpha: f32,
    gamma: f32,
    qmax: i8,
    policy: KernelPolicy,
) -> (StepStats, WeightDeltas) {
    let d: usize = weights.iter().map(|t| t.len()).sum();
    let de: usize = e.iter().map(|t| t.len()).sum();
    assert_eq!(d, de, "lattice dim {} != residual dim {}", d, de);
    assert_eq!(fitness.len(), spec.n_members());
    let kr = policy.microkernel();
    let w_chunks = chunk_segments(weights, policy.chunk_size);
    let e_chunks = chunk_segments_mut(e, policy.chunk_size);
    let tasks: Vec<_> = w_chunks.into_iter().zip(e_chunks).collect();
    let partials = parallel::map_tasks(tasks, policy.threads, |(wc, mut ec)| {
        let mut g = vec![0.0f32; wc.len];
        grad_chunk(spec, fitness, wc.start, &mut g);
        // gather the chunk's residual (it may span several shard segments)
        let mut u = vec![0.0f32; wc.len];
        let mut pos = 0usize;
        for seg in ec.segs.iter() {
            let n = seg.len();
            kr.f16_decode(&seg[..n], &mut u[pos..pos + n]);
            pos += n;
        }
        // u <- alpha * g + gamma * e: the vectorizable half of Eq. 6,
        // elementwise and unfused, so every backend matches the scalar
        // op sequence bit-for-bit
        kr.axpby(alpha, &g, gamma, &mut u);
        let mut stats = StepStats::default();
        let mut deltas: WeightDeltas = Vec::new();
        let mut k = 0usize;
        for seg in wc.segs.iter() {
            for &w in seg.iter() {
                let uv = u[k];
                let dw = uv.round() as i32;
                let (applied, boundary) = gate_eval(w, dw, qmax);
                if applied != 0 {
                    stats.n_changed += 1;
                    if boundary {
                        stats.n_boundary += 1;
                    }
                    deltas.push((wc.start + k, (w as i32 + applied) as i8));
                } else if dw != 0 {
                    stats.n_gated += 1;
                }
                u[k] = uv - applied as f32;
                k += 1;
            }
        }
        let mut pos = 0usize;
        for seg in ec.segs.iter_mut() {
            let n = seg.len();
            kr.f16_encode(&u[pos..pos + n], &mut seg[..n]);
            pos += n;
        }
        (stats, deltas)
    });
    reduce_stats(d, partials)
}

/// One step of replayable history, borrowed from the optimizer's window —
/// no fitness vectors are cloned to build a replay pass.
pub struct ReplayStep<'a> {
    pub spec: PopulationSpec,
    pub fitness: &'a [f32],
    pub alpha: f32,
}

/// Fused stateless seed-replay update (Algorithm 2), K-deep tiled.
///
/// Per chunk: zero the chunk's proxy residual, run ALL `history` steps
/// over just this chunk (gradient regeneration + simulated gating against
/// the *current* weights, per paper §4.5), then apply the `current` step
/// for real. The chunk's residual and weights stay cache-resident across
/// the whole K-step tile — the scalar path instead made K+1 full-lattice
/// passes.
///
/// `weights` are read-only (the replay only ever simulates against the
/// current lattice; the final commit comes back as [`WeightDeltas`]);
/// `e_proxy` is per-shard diagnostic scratch the kernel rebuilds from
/// zero and leaves holding the post-update proxy residual.
pub fn fused_seed_replay(
    weights: Vec<&[i8]>,
    e_proxy: Vec<&mut [f32]>,
    history: &[ReplayStep<'_>],
    current: &ReplayStep<'_>,
    gamma: f32,
    qmax: i8,
    policy: KernelPolicy,
) -> (StepStats, WeightDeltas) {
    let d: usize = weights.iter().map(|t| t.len()).sum();
    let de: usize = e_proxy.iter().map(|t| t.len()).sum();
    assert_eq!(d, de, "lattice dim {} != proxy dim {}", d, de);
    assert_eq!(current.fitness.len(), current.spec.n_members());
    let qmax_i = qmax as i32;
    let kr = policy.microkernel();
    let w_chunks = chunk_segments(weights, policy.chunk_size);
    let e_chunks = chunk_segments_mut(e_proxy, policy.chunk_size);
    let tasks: Vec<_> = w_chunks.into_iter().zip(e_chunks).collect();
    let partials = parallel::map_tasks(tasks, policy.threads, |(wc, mut ec)| {
        let mut ep = vec![0.0f32; wc.len];
        let mut g = vec![0.0f32; wc.len];
        // --- K-deep replay tile: rematerialize e_proxy for this chunk ---
        for h in history {
            grad_chunk(&h.spec, h.fitness, wc.start, &mut g);
            // ep <- h.alpha * g + gamma * ep (Eq. 6, vectorized, unfused
            // — bit-identical to the scalar sweep on every backend)
            kr.axpby(h.alpha, &g, gamma, &mut ep);
            let mut k = 0usize;
            for seg in wc.segs.iter() {
                for &w in seg.iter() {
                    let u = ep[k];
                    let dw = u.round() as i32;
                    // simulate the gate against current W, do not mutate
                    let next = w as i32 + dw;
                    let applied =
                        if dw != 0 && (-qmax_i..=qmax_i).contains(&next) { dw } else { 0 };
                    ep[k] = u - applied as f32;
                    k += 1;
                }
            }
        }
        // --- current step: the rematerialized error feeds the real update ---
        grad_chunk(&current.spec, current.fitness, wc.start, &mut g);
        kr.axpby(current.alpha, &g, gamma, &mut ep);
        let mut stats = StepStats::default();
        let mut deltas: WeightDeltas = Vec::new();
        let mut k = 0usize;
        for seg in wc.segs.iter() {
            for &w in seg.iter() {
                let u = ep[k];
                let dw = u.round() as i32;
                let (applied, boundary) = gate_eval(w, dw, qmax);
                if applied != 0 {
                    stats.n_changed += 1;
                    if boundary {
                        stats.n_boundary += 1;
                    }
                    deltas.push((wc.start + k, (w as i32 + applied) as i8));
                } else if dw != 0 {
                    stats.n_gated += 1;
                }
                ep[k] = u - applied as f32;
                k += 1;
            }
        }
        // scatter the rebuilt proxy back into its per-shard segments
        let mut pos = 0usize;
        for seg in ec.segs.iter_mut() {
            let n = seg.len();
            seg.copy_from_slice(&ep[pos..pos + n]);
            pos += n;
        }
        (stats, deltas)
    });
    reduce_stats(d, partials)
}

/// Raw uniforms the QuZO update-rounding stream consumes per element.
pub const QUZO_ROUND_DRAWS_PER_ELEM: u64 = 1;

/// Fused QuZO update: gradient regeneration + stochastic rounding + gating
/// in one chunk-parallel pass over read-only weights. `round_seed` is the
/// per-step salted seed of the rounding stream (1 uniform per element,
/// counter-addressable). Changes come back as sparse [`WeightDeltas`].
pub fn fused_quzo(
    weights: Vec<&[i8]>,
    spec: &PopulationSpec,
    fitness: &[f32],
    alpha: f32,
    qmax: i8,
    round_seed: u64,
    policy: KernelPolicy,
) -> (StepStats, WeightDeltas) {
    let d: usize = weights.iter().map(|t| t.len()).sum();
    assert_eq!(fitness.len(), spec.n_members());
    let chunks = chunk_segments(weights, policy.chunk_size);
    let partials = parallel::map_tasks(chunks, policy.threads, |wc| {
        let mut g = vec![0.0f32; wc.len];
        grad_chunk(spec, fitness, wc.start, &mut g);
        let mut rounder = SplitMix64::new(round_seed);
        rounder.jump(QUZO_ROUND_DRAWS_PER_ELEM.wrapping_mul(wc.start as u64));
        let mut stats = StepStats::default();
        let mut deltas: WeightDeltas = Vec::new();
        let mut k = 0usize;
        for seg in wc.segs.iter() {
            for &w in seg.iter() {
                let u = alpha * g[k];
                // stochastic rounding: unbiased, variance ~ Delta^2
                let f = u.floor();
                let dw = f as i32 + rounder.bernoulli(u - f) as i32;
                let (applied, boundary) = gate_eval(w, dw, qmax);
                if applied != 0 {
                    stats.n_changed += 1;
                    if boundary {
                        stats.n_boundary += 1;
                    }
                    deltas.push((wc.start + k, (w as i32 + applied) as i8));
                } else if dw != 0 {
                    stats.n_gated += 1;
                }
                k += 1;
            }
        }
        (stats, deltas)
    });
    reduce_stats(d, partials)
}

/// Chunk-parallel MeZO SPSA update on continuous (fp32) lattice tensors:
/// `w += sum_p coeff_p * eps_p`, with per-element adds in pair order —
/// bit-identical to the sequential pair-by-pair sweep.
/// `coeffs[p] == 0.0` skips pair `p` entirely (matching the scalar path).
pub fn fused_mezo_update(
    tensors: Vec<&mut [f32]>,
    spec: &PopulationSpec,
    coeffs: &[f32],
    policy: KernelPolicy,
) {
    assert_eq!(coeffs.len(), spec.pairs);
    let chunks = chunk_segments_mut(tensors, policy.chunk_size);
    parallel::map_tasks(chunks, policy.threads, |mut wc| {
        for (pair, &coeff) in coeffs.iter().enumerate() {
            if coeff == 0.0 {
                continue;
            }
            let (seed, _) = spec.member(2 * pair);
            let mut stream = NoiseStream::at_gauss(seed, spec.sigma, 1.0, wc.start);
            for seg in wc.segs.iter_mut() {
                for w in seg.iter_mut() {
                    // next_scaled_gauss = sigma * eps; divide back out so
                    // stream consumption matches perturb_fp exactly.
                    let se = stream.next_scaled_gauss();
                    *w += coeff * (se / spec.sigma);
                }
            }
        }
    });
}

/// Chunk-parallel perturbation materialization (rollout side, Eq. 3 + 4):
/// fill `dst` with member `member`'s perturbed lattice, reading the
/// unperturbed values from `src`. `src` and `dst` must have identical
/// tensor lengths (they describe the same lattice).
pub fn fill_perturbation(
    src: Vec<&[i8]>,
    dst: Vec<&mut [i8]>,
    spec: &PopulationSpec,
    member: usize,
    qmax: i8,
    policy: KernelPolicy,
) {
    // Hard assert: a src/dst total mismatch would make the two chunk
    // plans disagree and the zip below silently truncate, leaving stale
    // dst elements — fail loudly instead (cost is two length sums).
    assert_eq!(
        src.iter().map(|t| t.len()).sum::<usize>(),
        dst.iter().map(|t| t.len()).sum::<usize>(),
        "src/dst lattice dims differ"
    );
    let (seed, sign) = spec.member(member);
    let qmax_i = qmax as i32;
    let s_chunks = chunk_segments(src, policy.chunk_size);
    let d_chunks = chunk_segments_mut(dst, policy.chunk_size);
    let tasks: Vec<_> = s_chunks.into_iter().zip(d_chunks).collect();
    parallel::map_tasks(tasks, policy.threads, |(sc, mut dc)| {
        let mut stream = NoiseStream::at(seed, spec.sigma, sign, sc.start);
        let mut src_it = sc.segs.iter().flat_map(|s| s.iter());
        for seg in dc.segs.iter_mut() {
            for out in seg.iter_mut() {
                let w = *src_it.next().expect("src/dst chunk length mismatch");
                let delta = stream.next_delta();
                let cand = w as i32 + delta;
                // boundary gating: invalid updates are masked (Eq. 4)
                *out = if (-qmax_i..=qmax_i).contains(&cand) { cand as i8 } else { w };
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking_covers_every_element_once() {
        let mut a = vec![0u8; 100];
        let mut b = vec![0u8; 1];
        let mut c = vec![0u8; 257];
        for chunk in [1usize, 7, 64, 1000, usize::MAX] {
            let tensors: Vec<&mut [u8]> =
                vec![a.as_mut_slice(), b.as_mut_slice(), c.as_mut_slice()];
            let chunks = chunk_segments_mut(tensors, chunk);
            let mut next_start = 0usize;
            let mut total = 0usize;
            for ch in &chunks {
                assert_eq!(ch.start, next_start);
                assert_eq!(ch.len, ch.segs.iter().map(|s| s.len()).sum::<usize>());
                assert!(ch.len >= 1);
                next_start += ch.len;
                total += ch.len;
            }
            assert_eq!(total, 100 + 1 + 257, "chunk={}", chunk);
        }
    }

    #[test]
    fn immutable_and_mutable_chunking_agree() {
        let a = vec![0i8; 123];
        let b = vec![0i8; 456];
        let mut am = a.clone();
        let mut bm = b.clone();
        let ic = chunk_segments(vec![a.as_slice(), b.as_slice()], 100);
        let mc = chunk_segments_mut(vec![am.as_mut_slice(), bm.as_mut_slice()], 100);
        assert_eq!(ic.len(), mc.len());
        for (i, m) in ic.iter().zip(mc.iter()) {
            assert_eq!(i.start, m.start);
            assert_eq!(i.len, m.len);
            assert_eq!(i.segs.len(), m.segs.len());
        }
    }

    #[test]
    fn grad_chunk_windows_tile_the_scalar_gradient() {
        let spec = PopulationSpec { gen_seed: 77, pairs: 3, sigma: 0.4 };
        let fitness = [0.5f32, -0.5, 0.25, -0.25, 0.0, 0.1];
        let d = 1000;
        let mut full = vec![0.0f32; d];
        crate::opt::accumulate_grad(&spec, &fitness, &mut full);
        for (start, len) in [(0usize, 1usize), (1, 64), (999, 1), (500, 500), (0, 1000)] {
            let mut g = vec![0.0f32; len];
            grad_chunk(&spec, &fitness, start, &mut g);
            for j in 0..len {
                assert_eq!(
                    g[j].to_bits(),
                    full[start + j].to_bits(),
                    "window ({}, {}) elem {}",
                    start,
                    len,
                    j
                );
            }
        }
    }

    #[test]
    fn accumulate_grad_chunked_matches_scalar_bitwise() {
        let spec = PopulationSpec { gen_seed: 3, pairs: 4, sigma: 0.02 };
        let fitness: Vec<f32> = (0..8).map(|i| (i as f32 - 3.5) / 8.0).collect();
        let d = 9973; // prime: exercises ragged chunk tails
        let mut scalar = vec![0.0f32; d];
        crate::opt::accumulate_grad(&spec, &fitness, &mut scalar);
        for chunk in [1usize, 64, 4096] {
            for threads in [1usize, 2, 8] {
                let mut g = vec![0.0f32; d];
                accumulate_grad_chunked(&spec, &fitness, &mut g, KernelPolicy::new(chunk, threads));
                let same = g
                    .iter()
                    .zip(scalar.iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "chunk={} threads={}", chunk, threads);
            }
        }
    }
}
