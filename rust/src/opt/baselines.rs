//! Baselines: QuZO (quantized zeroth-order with stochastic rounding) and
//! MeZO (full-precision zeroth-order SPSA).

use crate::model::{ParamKind, ParamStore, ShardedParamStore};
use crate::opt::{
    kernels, EsHyper, KernelPolicy, LatticeOptimizer, PopulationSpec, StepStats,
};
use crate::rng::NoiseStream;

/// QuZO (Zhou et al. 2025): the primary quantized baseline. Same discrete
/// perturbations as QES (Eq. 3's stochastic rounding — their "double
/// quantization"), but the update is applied STATELESSLY: the scaled
/// gradient step is stochastically rounded onto the lattice and any
/// rounding error is discarded. Unbiased, but §5 shows the errors
/// accumulate as a random walk (variance explosion) or — when alpha*g is
/// sub-threshold and rounding is deterministic — vanish entirely
/// (stagnation). This is the failure mode QES exists to fix.
pub struct QuzoOptimizer {
    pub hyper: EsHyper,
    /// Kernel execution policy (chunk size / threads); never affects the
    /// produced lattice.
    pub policy: KernelPolicy,
    d: usize,
    qmax: i8,
    step: u64,
}

impl QuzoOptimizer {
    pub fn new(d: usize, qmax: i8, hyper: EsHyper) -> Self {
        QuzoOptimizer { hyper, policy: KernelPolicy::default(), d, qmax, step: 0 }
    }
}

impl LatticeOptimizer for QuzoOptimizer {
    fn update(
        &mut self,
        store: &mut ShardedParamStore,
        spec: &PopulationSpec,
        fitness: &[f32],
    ) -> anyhow::Result<StepStats> {
        let d = store.lattice_dim();
        anyhow::ensure!(d == self.d);
        anyhow::ensure!(fitness.len() == spec.n_members());
        // Per-step rounding stream: decorrelated from the perturbation
        // streams but still deterministic given the generation seed.
        // Salted with the step counter so repeated generation seeds still
        // get fresh rounding randomness (unbiasedness needs independence).
        // One uniform per element, so it is counter-addressable and the
        // fused kernel can jump each chunk to its own window.
        let round_seed = spec.gen_seed ^ Q_ROUND_SALT ^ self.step.wrapping_mul(0x9e37);
        let (stats, deltas) = kernels::fused_quzo(
            store.lattice_segments(),
            spec,
            fitness,
            self.hyper.alpha,
            self.qmax,
            round_seed,
            self.policy,
        );
        store.apply_deltas(&deltas);
        self.step += 1;
        Ok(stats)
    }

    fn state_bytes(&self) -> u64 {
        0 // stateless — its defining property
    }

    fn name(&self) -> &'static str {
        "quzo"
    }

    /// "Stateless" refers to the d-sized residual; the step counter
    /// still salts the rounding stream and must survive resume for the
    /// continued run to be bit-identical.
    fn save_state(&self, w: &mut dyn std::io::Write) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        write_u8(w, crate::opt::state_tag::QUZO)?;
        write_u64(w, self.step)?;
        Ok(())
    }

    fn load_state(&mut self, r: &mut dyn std::io::Read) -> anyhow::Result<()> {
        use crate::opt::state_io::*;
        expect_tag(r, crate::opt::state_tag::QUZO, "quzo")?;
        self.step = read_u64(r)?;
        Ok(())
    }
}

/// Salt decorrelating QuZO's update-rounding stream from perturbation
/// streams derived from the same generation seed.
const Q_ROUND_SALT: u64 = 0x51ed_270b_9d2f_ff2f;

/// MeZO (Malladi et al. 2024): zeroth-order SPSA on CONTINUOUS (fp32)
/// weights — not applicable to quantized stores; it is the full-precision
/// reference point in Table 1. Perturbs the lattice-eligible (linear)
/// weights with sigma * eps and updates
///   w <- w + alpha * mean_p [ (F+_p - F-_p) / (2 sigma) * eps_p ]
/// with eps regenerated from seeds (memory-free, like the original).
pub struct MezoOptimizer {
    pub hyper: EsHyper,
    /// Kernel execution policy (chunk size / threads); never affects the
    /// produced weights.
    pub policy: KernelPolicy,
}

impl MezoOptimizer {
    pub fn new(hyper: EsHyper) -> Self {
        MezoOptimizer { hyper, policy: KernelPolicy::default() }
    }

    /// Materialize member `m`'s perturbed fp weights for rollout: one
    /// f32 vector per lattice-eligible tensor, aligned with
    /// `store.lattice_indices()`.
    pub fn perturb_fp(
        store: &ParamStore,
        spec: &PopulationSpec,
        member: usize,
    ) -> Vec<Vec<f32>> {
        let (seed, sign) = spec.member(member);
        let mut stream = NoiseStream::new(seed, spec.sigma, sign);
        store
            .lattice_indices()
            .iter()
            .map(|&i| {
                let e = &store.entries[i];
                debug_assert_eq!(e.kind, ParamKind::LatticeAsFp);
                e.data
                    .as_f32()
                    .iter()
                    .map(|&w| w + stream.next_scaled_gauss())
                    .collect()
            })
            .collect()
    }

    /// SPSA update from the pair fitnesses. Chunk-parallel: each chunk
    /// jumps every pair's Gaussian stream to its own window
    /// (`NoiseStream::at_gauss`); per-element adds stay in pair order, so
    /// the result is bit-identical to the sequential pair-by-pair sweep.
    pub fn update_fp(
        &mut self,
        store: &mut ParamStore,
        spec: &PopulationSpec,
        fitness: &[f32],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(fitness.len() == spec.n_members());
        let alpha = self.hyper.alpha;
        let coeffs: Vec<f32> = (0..spec.pairs)
            .map(|pair| {
                alpha * (fitness[2 * pair] - fitness[2 * pair + 1])
                    / (2.0 * spec.sigma * spec.pairs as f32)
            })
            .collect();
        kernels::fused_mezo_update(store.lattice_f32_mut(), spec, &coeffs, self.policy);
        Ok(())
    }

    pub fn state_bytes(&self) -> u64 {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init::init_fp, ParamStore};
    use crate::opt::accumulate_grad;
    use crate::quant::Format;
    use crate::rng::SplitMix64;
    use crate::runtime::manifest::Manifest;

    fn stores() -> (ParamStore, ParamStore) {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 12);
        let q = ParamStore::quantize_from(&fp, &man, Format::Int4, None).unwrap();
        (fp, q)
    }

    fn sharded(q: &ParamStore) -> ShardedParamStore {
        ShardedParamStore::with_default_shards(q.clone()).unwrap()
    }

    fn flat(s: &ShardedParamStore) -> Vec<i8> {
        s.lattice_segments().iter().flat_map(|t| t.iter().copied()).collect()
    }

    #[test]
    fn quzo_noise_dominates_where_qes_tracks_signal() {
        // §5's dichotomy, measured as cosine alignment between the realized
        // drift (W_T - W_0) and the ideal continuous update sum(alpha*g).
        // QES's temporal equivalence keeps it within half a grid step of
        // the ideal trajectory (high alignment); QuZO's stochastic rounding
        // is unbiased but its per-step variance ~Delta^2 swamps the tiny
        // signal (alignment near zero).
        let (_fp, s0) = stores();
        let d = s0.lattice_dim();
        let hyper = EsHyper { sigma: 0.5, alpha: 0.2, gamma: 1.0, pairs: 2, k_window: 0 };
        let mut s_quzo = sharded(&s0);
        let mut s_qes = sharded(&s0);
        let mut quzo = QuzoOptimizer::new(d, 7, hyper.clone());
        let mut qes = crate::opt::QesFullResidual::new(d, 7, hyper.clone());
        let w0: Vec<i8> = s0.lattice_i8().iter().flat_map(|t| t.iter().copied()).collect();

        // A PERSISTENT fine-tuning signal: the same population and fitness
        // every generation (the regime where sub-threshold updates must
        // integrate over time — fine-tuning's steady gradient direction).
        let spec = PopulationSpec { gen_seed: 31, pairs: 2, sigma: 0.5 };
        let fitness = vec![0.5f32, -0.5, 0.25, -0.25];
        let mut ideal = vec![0.0f64; d];
        let mut g = vec![0.0f32; d];
        for _ in 0..30 {
            accumulate_grad(&spec, &fitness, &mut g);
            for (a, &gj) in ideal.iter_mut().zip(g.iter()) {
                *a += (hyper.alpha * gj) as f64;
            }
            quzo.update(&mut s_quzo, &spec, &fitness).unwrap();
            qes.update(&mut s_qes, &spec, &fitness).unwrap();
        }
        let cos = |s: &ShardedParamStore| -> f64 {
            let wt: Vec<i8> = flat(s);
            let mut dot = 0.0f64;
            let mut na = 0.0f64;
            let mut nb = 0.0f64;
            for j in 0..d {
                let drift = (wt[j] - w0[j]) as f64;
                dot += drift * ideal[j];
                na += drift * drift;
                nb += ideal[j] * ideal[j];
            }
            if na == 0.0 || nb == 0.0 {
                0.0
            } else {
                dot / (na.sqrt() * nb.sqrt())
            }
        };
        let cos_qes = cos(&s_qes);
        let cos_quzo = cos(&s_quzo);
        // QES's temporal equivalence ==> near-perfect tracking; QuZO's
        // stochastic rounding injects Delta-scale noise that measurably
        // degrades alignment at the same alpha.
        assert!(cos_qes > 0.9, "qes alignment only {}", cos_qes);
        assert!(
            cos_qes > cos_quzo + 0.05,
            "alignment: qes {} vs quzo {}",
            cos_qes,
            cos_quzo
        );
    }

    #[test]
    fn quzo_respects_lattice_range() {
        let (_fp, q) = stores();
        let mut s = sharded(&q);
        let d = s.lattice_dim();
        let hyper = EsHyper { sigma: 1.0, alpha: 10.0, gamma: 1.0, pairs: 2, k_window: 0 };
        let mut quzo = QuzoOptimizer::new(d, 7, hyper);
        let mut rng = SplitMix64::new(8);
        for _ in 0..10 {
            let spec = PopulationSpec { gen_seed: rng.next_u64(), pairs: 2, sigma: 1.0 };
            let raw: Vec<f32> = (0..4).map(|_| rng.uniform01()).collect();
            let fitness = crate::opt::normalize_fitness(&raw);
            quzo.update(&mut s, &spec, &fitness).unwrap();
        }
        assert!(flat(&s).iter().all(|&v| (-7..=7).contains(&v)));
    }

    #[test]
    fn mezo_perturb_update_consistency() {
        // The update must walk the stream exactly as the perturbation did:
        // perturbing then updating with F+=1, F-=0 moves w toward +eps.
        let (mut fp, _q) = stores();
        let spec = PopulationSpec { gen_seed: 71, pairs: 1, sigma: 0.01 };
        let perturbed = MezoOptimizer::perturb_fp(&fp, &spec, 0);
        let li0 = fp.lattice_indices()[0];
        let name = fp.entries[li0].name.clone();
        let before = fp.get(&name).unwrap().data.as_f32().to_vec();
        let mut opt = MezoOptimizer::new(EsHyper { alpha: 1.0, ..Default::default() });
        opt.update_fp(&mut fp, &spec, &[1.0, 0.0]).unwrap();
        let after = fp.get(&name).unwrap().data.as_f32();
        // direction of movement == direction of positive perturbation
        let mut agree = 0usize;
        let mut total = 0usize;
        for j in 0..before.len() {
            let eps_dir = perturbed[0][j] - before[j];
            let move_dir = after[j] - before[j];
            if eps_dir.abs() > 1e-9 {
                total += 1;
                if (eps_dir > 0.0) == (move_dir > 0.0) {
                    agree += 1;
                }
            }
        }
        assert_eq!(agree, total, "update direction disagrees with eps");
    }

    #[test]
    fn quzo_state_is_zero_bytes() {
        let (_fp, s) = stores();
        let q = QuzoOptimizer::new(s.lattice_dim(), 7, EsHyper::default());
        assert_eq!(q.state_bytes(), 0);
    }
}
