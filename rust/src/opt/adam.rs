//! First-order optimization: Adam over fp-format stores, with an optional
//! post-step STE snap onto a fixed quantization grid.
//!
//! Powers (a) the in-repo pretraining pipeline that produces base models,
//! (b) the FP32 first-order upper bound of Table 1, and (c) the "First-
//! Order + STE (W8)" baseline: weights are snapped onto the W8 grid after
//! each `step()` while gradients pass through unchanged — the paper's
//! post-step straight-through estimator (§A.2).

use crate::model::{ParamKind, ParamStore};

#[derive(Debug, Clone)]
pub struct AdamConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Snap lattice-eligible tensors to a fixed per-channel grid after each
    /// step (STE baseline). None = plain Adam.
    pub ste_qmax: Option<i8>,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, ste_qmax: None }
    }
}

pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Fixed per-channel grids for the STE snap, captured on first step.
    grids: Option<Vec<Vec<f32>>>,
    t: u64,
}

impl Adam {
    pub fn new(store: &ParamStore, cfg: AdamConfig) -> Self {
        let m = store.entries.iter().map(|e| vec![0.0f32; e.numel()]).collect();
        let v = store.entries.iter().map(|e| vec![0.0f32; e.numel()]).collect();
        Adam { cfg, m, v, grids: None, t: 0 }
    }

    /// One Adam step. `grads` must align with `store.entries` (the grad
    /// artifact returns them in exactly that order).
    pub fn step(&mut self, store: &mut ParamStore, grads: &[Vec<f32>]) -> anyhow::Result<()> {
        anyhow::ensure!(
            grads.len() == store.entries.len(),
            "got {} grads for {} params",
            grads.len(),
            store.entries.len()
        );
        self.t += 1;
        let b1t = 1.0 - self.cfg.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.cfg.beta2.powi(self.t as i32);
        for (i, e) in store.entries.iter_mut().enumerate() {
            let w = e.data.as_f32_mut();
            anyhow::ensure!(grads[i].len() == w.len(), "grad {} shape mismatch", i);
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            for j in 0..w.len() {
                let g = grads[i][j];
                m[j] = self.cfg.beta1 * m[j] + (1.0 - self.cfg.beta1) * g;
                v[j] = self.cfg.beta2 * v[j] + (1.0 - self.cfg.beta2) * g * g;
                let mhat = m[j] / b1t;
                let vhat = v[j] / b2t;
                // gradient DESCENT on the loss
                w[j] -= self.cfg.lr * mhat / (vhat.sqrt() + self.cfg.eps);
            }
        }
        if let Some(qmax) = self.cfg.ste_qmax {
            self.snap(store, qmax);
        }
        Ok(())
    }

    /// Snap lattice-eligible tensors onto the FIXED per-channel grid (scales
    /// captured from the weights at the first snap — the grid QES would
    /// inherit, not a moving target).
    fn snap(&mut self, store: &mut ParamStore, qmax: i8) {
        let lat: Vec<usize> = store.lattice_indices().to_vec();
        if self.grids.is_none() {
            let mut grids = Vec::with_capacity(lat.len());
            for &i in &lat {
                let e = &store.entries[i];
                let cols = e.shape[1];
                let rows = e.shape[0];
                let w = e.data.as_f32();
                let mut scale = vec![0.0f32; cols];
                for c in 0..cols {
                    let mut a = 0.0f32;
                    for r in 0..rows {
                        a = a.max(w[r * cols + c].abs());
                    }
                    scale[c] = if a > 0.0 { a / qmax as f32 } else { 1.0 };
                }
                grids.push(scale);
            }
            self.grids = Some(grids);
        }
        let grids = self.grids.as_ref().unwrap();
        for (gi, &i) in lat.iter().enumerate() {
            let e = &mut store.entries[i];
            debug_assert_eq!(e.kind, ParamKind::LatticeAsFp);
            let cols = e.shape[1];
            let w = e.data.as_f32_mut();
            let scale = &grids[gi];
            let qmaxf = qmax as f32;
            for (j, wj) in w.iter_mut().enumerate() {
                let s = scale[j % cols];
                let q = (*wj / s).round().clamp(-qmaxf, qmaxf);
                *wj = q * s;
            }
        }
    }

    pub fn state_bytes(&self) -> u64 {
        let n: usize = self.m.iter().map(|v| v.len()).sum();
        (n * 8) as u64 // m + v, f32 each
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_fp;
    use crate::quant::Format;
    use crate::runtime::manifest::Manifest;

    fn fp_store() -> ParamStore {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32).unwrap();
        init_fp(&mut fp, 33);
        fp
    }

    fn fake_grads(store: &ParamStore, toward: f32) -> Vec<Vec<f32>> {
        store.entries.iter().map(|e| vec![toward; e.numel()]).collect()
    }

    #[test]
    fn adam_descends_constant_gradient() {
        let mut s = fp_store();
        let w0 = s.get("tok_emb").unwrap().data.as_f32()[0];
        let mut adam = Adam::new(&s, AdamConfig { lr: 0.01, ..Default::default() });
        for _ in 0..10 {
            let g = fake_grads(&s, 1.0);
            adam.step(&mut s, &g).unwrap();
        }
        let w1 = s.get("tok_emb").unwrap().data.as_f32()[0];
        assert!(w1 < w0, "positive grad must decrease weight: {} -> {}", w0, w1);
    }

    #[test]
    fn ste_snap_puts_lattice_tensors_on_grid() {
        let mut s = fp_store();
        let mut adam = Adam::new(
            &s,
            AdamConfig { lr: 1e-3, ste_qmax: Some(127), ..Default::default() },
        );
        let g = fake_grads(&s, 0.5);
        adam.step(&mut s, &g).unwrap();
        // every lattice weight must be an integer multiple of its channel scale
        let grids = adam.grids.as_ref().unwrap();
        for (gi, &i) in s.lattice_indices().to_vec().iter().enumerate() {
            let e = &s.entries[i];
            let cols = e.shape[1];
            for (j, &w) in e.data.as_f32().iter().enumerate() {
                let sc = grids[gi][j % cols];
                let q = w / sc;
                assert!(
                    (q - q.round()).abs() < 1e-4,
                    "{}[{}] = {} not on grid (scale {})",
                    e.name,
                    j,
                    w,
                    sc
                );
            }
        }
        // non-lattice tensors must NOT be snapped
        let emb = s.get("tok_emb").unwrap().data.as_f32();
        assert!(emb.iter().any(|&x| (x * 1000.0).fract().abs() > 1e-6));
    }

    #[test]
    fn grad_shape_mismatch_errors() {
        let mut s = fp_store();
        let mut adam = Adam::new(&s, AdamConfig::default());
        let bad = vec![vec![0.0f32; 3]; 2];
        assert!(adam.step(&mut s, &bad).is_err());
    }
}
