//! Minimal HTTP/1.1 front end for the connection mux — just enough
//! protocol to put an OpenAI-compatible `POST /v1/completions` surface
//! over the shared scheduler so standard load-generation tooling works
//! against `qes serve --http`.
//!
//! ```text
//! POST /v1/completions
//! {"model": "qes", "prompt": "3,4,5=17:", "max_tokens": 12,
//!  "temperature": 0.0, "seed": 7}
//!
//! 200 OK
//! {"id": "cmpl-0", "object": "text_completion", "model": "qes",
//!  "choices": [{"index": 0, "text": "3*4+5", "finish_reason": "stop"}],
//!  "usage": {"prompt_tokens": 9, "completion_tokens": 6, "total_tokens": 15}}
//! ```
//!
//! Also served: `GET /health` and `GET /v1/models`. Errors come back as
//! `{"error": {"message": ..., "type": ...}}` with 400/404/429.
//! Connections are keep-alive by default; `Connection: close` is
//! honored after the response to the request that carried it. Requests
//! on one connection are answered in request order (the mux stashes
//! out-of-order completions), while different connections never gate
//! each other.
//!
//! The reader ([`read_request`]) supports exactly what the surface
//! needs: request line + headers + `Content-Length` body. No chunked
//! encoding, no continuations — anything else is a 400.

use std::collections::BTreeMap;
use std::io::{BufRead, Read};

use anyhow::{Context, Result};

use crate::sched::serve::{parse_max_new, parse_seed, parse_tau};
use crate::sched::{GenOutput, GenRequest};
use crate::tasks::tokenizer;
use crate::util::json::Json;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpReq {
    pub method: String,
    pub path: String,
    /// Header names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpReq {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    /// Did the client ask to end the connection after this exchange?
    pub fn close_requested(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false)
    }
}

/// What one read attempt produced.
#[derive(Debug)]
pub enum ReadOutcome {
    Req(HttpReq),
    /// Clean EOF at a request boundary.
    Eof,
    /// Malformed request on the wire (answer 400 and stop reading).
    Bad(String),
    /// Read error (deadline / reset) mid-request.
    IoErr,
}

/// Read one HTTP/1.1 request. `max_head` bounds the request line plus
/// headers, `max_body` bounds `Content-Length` — both reject with
/// [`ReadOutcome::Bad`] instead of buffering unboundedly.
pub fn read_request<R: BufRead>(r: &mut R, max_head: usize, max_body: usize) -> ReadOutcome {
    let line = match read_crlf_line(r, max_head) {
        LineOutcome::Line(l) => l,
        LineOutcome::Eof => return ReadOutcome::Eof,
        LineOutcome::TooLong => return ReadOutcome::Bad("request line too long".into()),
        LineOutcome::IoErr => return ReadOutcome::IoErr,
    };
    if line.is_empty() {
        // tolerate a stray blank line between pipelined requests
        return read_request(r, max_head, max_body);
    }
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if parts.next().is_none() => (m, p, v),
        _ => return ReadOutcome::Bad(format!("malformed request line {:?}", line)),
    };
    if !version.starts_with("HTTP/1.") {
        return ReadOutcome::Bad(format!("unsupported version {:?}", version));
    }
    let method = method.to_string();
    let path = path.to_string();
    let mut headers: Vec<(String, String)> = Vec::new();
    let mut head_bytes = line.len();
    loop {
        let line = match read_crlf_line(r, max_head) {
            LineOutcome::Line(l) => l,
            LineOutcome::Eof => return ReadOutcome::Bad("eof inside headers".into()),
            LineOutcome::TooLong => return ReadOutcome::Bad("header line too long".into()),
            LineOutcome::IoErr => return ReadOutcome::IoErr,
        };
        if line.is_empty() {
            break;
        }
        head_bytes += line.len();
        if head_bytes > max_head {
            return ReadOutcome::Bad("headers too large".into());
        }
        let Some((name, value)) = line.split_once(':') else {
            return ReadOutcome::Bad(format!("malformed header {:?}", line));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let len = match headers.iter().find(|(n, _)| n == "content-length") {
        None => 0usize,
        Some((_, v)) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => return ReadOutcome::Bad(format!("bad content-length {:?}", v)),
        },
    };
    if len > max_body {
        return ReadOutcome::Bad(format!("body exceeds {} bytes", max_body));
    }
    let chunked = headers
        .iter()
        .any(|(n, v)| n == "transfer-encoding" && !v.eq_ignore_ascii_case("identity"));
    if chunked {
        return ReadOutcome::Bad("chunked transfer encoding unsupported".into());
    }
    let mut body = vec![0u8; len];
    if len > 0 && r.read_exact(&mut body).is_err() {
        return ReadOutcome::IoErr;
    }
    ReadOutcome::Req(HttpReq { method, path, headers, body })
}

enum LineOutcome {
    Line(String),
    Eof,
    TooLong,
    IoErr,
}

/// Read one `\r\n`- (or `\n`-) terminated line, bounded by `cap`.
fn read_crlf_line<R: BufRead>(r: &mut R, cap: usize) -> LineOutcome {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                return if buf.is_empty() { LineOutcome::Eof } else { LineOutcome::IoErr };
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    return LineOutcome::Line(String::from_utf8_lossy(&buf).into_owned());
                }
                if buf.len() >= cap {
                    return LineOutcome::TooLong;
                }
                buf.push(byte[0]);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return LineOutcome::IoErr,
        }
    }
}

/// Parse an OpenAI-style completions body into a [`GenRequest`].
/// `prompt` is required; `max_tokens` defaults to the scheduler's
/// decode budget; `temperature`/`seed` default to greedy and go through
/// the same validation as the line protocol (exact integer seed, finite
/// non-negative temperature).
pub fn parse_completions(body: &str, default_max_new: usize) -> Result<GenRequest> {
    let j = Json::parse(body).map_err(|e| anyhow::anyhow!("bad json body: {}", e))?;
    let prompt_text =
        j.get("prompt").and_then(Json::as_str).context("body needs a string \"prompt\"")?;
    let prompt = tokenizer::try_encode(prompt_text)
        .map_err(|c| anyhow::anyhow!("prompt char {:?} not in the vocabulary", c))?;
    let max_new = parse_max_new(j.get("max_tokens"), default_max_new, "max_tokens")?;
    let tau = parse_tau(j.get("temperature"), "temperature")?;
    let seed = parse_seed(j.get("seed"))?;
    Ok(GenRequest { prompt, max_new, tau, seed })
}

/// `finish_reason` for a completion: `"stop"` when the sequence emitted
/// EOS inside its budget, `"length"` when the decode budget cut it off.
pub fn finish_reason(out: &GenOutput) -> &'static str {
    if out.tokens.last() == Some(&(tokenizer::EOS as i32)) {
        "stop"
    } else {
        "length"
    }
}

/// OpenAI-compatible `text_completion` response body.
pub fn completion_body(id: &str, model: &str, out: &GenOutput, prompt_tokens: usize) -> String {
    let mut choice = BTreeMap::new();
    choice.insert("index".to_string(), Json::Num(0.0));
    choice.insert("text".to_string(), Json::Str(out.text.clone()));
    choice.insert("finish_reason".to_string(), Json::Str(finish_reason(out).to_string()));
    let completion_tokens = out.tokens.len();
    let mut usage = BTreeMap::new();
    usage.insert("prompt_tokens".to_string(), Json::Num(prompt_tokens as f64));
    usage.insert("completion_tokens".to_string(), Json::Num(completion_tokens as f64));
    usage.insert("total_tokens".to_string(), Json::Num((prompt_tokens + completion_tokens) as f64));
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Str(id.to_string()));
    m.insert("object".to_string(), Json::Str("text_completion".to_string()));
    m.insert("model".to_string(), Json::Str(model.to_string()));
    m.insert("choices".to_string(), Json::Arr(vec![Json::Obj(choice)]));
    m.insert("usage".to_string(), Json::Obj(usage));
    Json::Obj(m).to_string_compact()
}

/// OpenAI-compatible error body: `{"error": {"message", "type"}}`.
pub fn error_body(message: &str, etype: &str) -> String {
    let mut e = BTreeMap::new();
    e.insert("message".to_string(), Json::Str(message.to_string()));
    e.insert("type".to_string(), Json::Str(etype.to_string()));
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::Obj(e));
    Json::Obj(m).to_string_compact()
}

/// `GET /v1/models` body: the one model this server resolves.
pub fn models_body(model: &str) -> String {
    let mut entry = BTreeMap::new();
    entry.insert("id".to_string(), Json::Str(model.to_string()));
    entry.insert("object".to_string(), Json::Str("model".to_string()));
    entry.insert("owned_by".to_string(), Json::Str("qes".to_string()));
    let mut m = BTreeMap::new();
    m.insert("object".to_string(), Json::Str("list".to_string()));
    m.insert("data".to_string(), Json::Arr(vec![Json::Obj(entry)]));
    Json::Obj(m).to_string_compact()
}

/// Frame a full HTTP/1.1 response (status line + headers + JSON body).
pub fn response(status: u16, reason: &str, body: &str, close: bool) -> Vec<u8> {
    response_typed(status, reason, "application/json", body, close)
}

/// [`response`] with an explicit Content-Type (the `/metrics` route
/// serves Prometheus text exposition, not JSON).
pub fn response_typed(
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    close: bool,
) -> Vec<u8> {
    let conn = if close { "close" } else { "keep-alive" };
    format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{}",
        status,
        reason,
        content_type,
        body.len(),
        conn,
        body,
    )
    .into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn read_request_parses_and_rejects() {
        let wire = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcdGET /health HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(&wire[..]);
        let ReadOutcome::Req(req) = read_request(&mut r, 4096, 1 << 16) else {
            panic!("expected request")
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
        assert!(!req.close_requested());
        // pipelined second request on the same buffer
        let ReadOutcome::Req(req) = read_request(&mut r, 4096, 1 << 16) else {
            panic!("expected request")
        };
        assert_eq!((req.method.as_str(), req.path.as_str()), ("GET", "/health"));
        assert!(req.body.is_empty());
        assert!(matches!(read_request(&mut r, 4096, 1 << 16), ReadOutcome::Eof));

        // bare-\n framing and Connection: close
        let wire = b"GET /health HTTP/1.1\nConnection: close\n\n";
        let mut r = BufReader::new(&wire[..]);
        let ReadOutcome::Req(req) = read_request(&mut r, 4096, 1 << 16) else {
            panic!("expected request")
        };
        assert!(req.close_requested());

        // malformed request line / oversized body / chunked → Bad
        let mut r = BufReader::new(&b"NOT-HTTP\r\n\r\n"[..]);
        assert!(matches!(read_request(&mut r, 4096, 16), ReadOutcome::Bad(_)));
        let mut r = BufReader::new(&b"POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\n"[..]);
        assert!(matches!(read_request(&mut r, 4096, 16), ReadOutcome::Bad(_)));
        let mut r =
            BufReader::new(&b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"[..]);
        assert!(matches!(read_request(&mut r, 4096, 1 << 16), ReadOutcome::Bad(_)));
        // truncated mid-headers → Bad (eof inside headers)
        let mut r = BufReader::new(&b"GET / HTTP/1.1\r\nHost"[..]);
        let got = read_request(&mut r, 4096, 1 << 16);
        assert!(matches!(got, ReadOutcome::IoErr | ReadOutcome::Bad(_)));
    }

    #[test]
    fn parse_completions_validates_like_line_protocol() {
        let g = parse_completions(r#"{"prompt": "1+2=", "max_tokens": 4}"#, 12).unwrap();
        assert_eq!(g.prompt, tokenizer::encode("1+2="));
        assert_eq!(g.max_new, 4);
        assert_eq!(g.tau, 0.0);
        assert_eq!(g.seed, None);
        let g = parse_completions(r#"{"prompt": "1", "temperature": 0.5, "seed": 9}"#, 12).unwrap();
        assert!((g.tau - 0.5).abs() < 1e-6);
        assert_eq!(g.seed, Some(9));
        assert_eq!(g.max_new, 12);
        // same validation failures as the line protocol
        assert!(parse_completions(r#"{"prompt": "1", "seed": -1}"#, 12).is_err());
        assert!(parse_completions(r#"{"prompt": "1", "temperature": -0.5}"#, 12).is_err());
        assert!(parse_completions(r#"{"prompt": "1", "max_tokens": -3}"#, 12).is_err());
        assert!(parse_completions(r#"{"max_tokens": 3}"#, 12).is_err());
        assert!(parse_completions("nope", 12).is_err());
    }

    #[test]
    fn bodies_and_framing_roundtrip() {
        let out = GenOutput { tokens: vec![3, 4, 20], text: "12".into(), cached: 0 };
        let body = completion_body("cmpl-7", "qes-s", &out, 5);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("id").unwrap().as_str(), Some("cmpl-7"));
        assert_eq!(j.get("object").unwrap().as_str(), Some("text_completion"));
        let choice = j.get("choices").unwrap().idx(0).unwrap();
        assert_eq!(choice.get("text").unwrap().as_str(), Some("12"));
        assert_eq!(choice.get("finish_reason").unwrap().as_str(), Some("stop"));
        let usage = j.get("usage").unwrap();
        assert_eq!(usage.get("prompt_tokens").unwrap().as_usize(), Some(5));
        assert_eq!(usage.get("completion_tokens").unwrap().as_usize(), Some(3));
        assert_eq!(usage.get("total_tokens").unwrap().as_usize(), Some(8));

        // budget-capped sequence (no EOS) reports "length"
        let out = GenOutput { tokens: vec![3, 4], text: "12".into(), cached: 0 };
        assert_eq!(finish_reason(&out), "length");

        let body = error_body("overloaded", "overloaded_error");
        let bytes = response(429, "Too Many Requests", &body, false);
        let s = String::from_utf8(bytes).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{}", s);
        assert!(s.contains("Connection: keep-alive"));
        let body_at = s.find("\r\n\r\n").unwrap() + 4;
        let j = Json::parse(&s[body_at..]).unwrap();
        assert_eq!(j.get("error").unwrap().get("message").unwrap().as_str(), Some("overloaded"));
        assert_eq!(s[body_at..].len().to_string(), {
            let cl = s.lines().find(|l| l.starts_with("Content-Length:")).unwrap();
            cl.split(':').nth(1).unwrap().trim().to_string()
        });
    }
}
