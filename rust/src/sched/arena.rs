//! Slot-based KV arena: the scheduler's cache memory.
//!
//! Per layer, one `[slots, s_max, d]` f32 slab for keys and one for
//! values, plus a `[slots, s_max]` key mask — the same `[b, st, d]`
//! geometry `NativeBackend::generate` allocates per call, except the
//! slots outlive any single request: a free-list hands them to admitted
//! sequences and recycles them the moment a sequence retires, so a
//! long-running scheduler serves an unbounded request stream from a
//! fixed-size arena (`bytes_per_slot` = `n_layers · 2 · s_max · d · 4`).
//!
//! Recycling never needs to zero the K/V rows: allocation clears only
//! the slot's key mask, and the scheduler attends exclusively to
//! positions it has written for the CURRENT occupant (masked positions
//! contribute exactly zero attention weight), so stale rows from a
//! previous occupant are unreachable — the aliasing property the unit
//! tests pin.

/// Fixed-size slot arena holding per-layer KV slabs and key masks.
pub struct KvArena {
    n_layers: usize,
    slots: usize,
    s_max: usize,
    d: usize,
    /// Per layer: `[slots * s_max * d]` keys.
    k: Vec<Vec<f32>>,
    /// Per layer: `[slots * s_max * d]` values.
    v: Vec<Vec<f32>>,
    /// `[slots * s_max]`, 1.0 = attendable position of the current
    /// occupant (left-pad positions inside the prompt stay 0).
    keymask: Vec<f32>,
    /// LIFO free-list (lowest slot ids surface first from a fresh arena).
    free: Vec<usize>,
    live: Vec<bool>,
    high_water: usize,
}

impl KvArena {
    pub fn new(n_layers: usize, slots: usize, s_max: usize, d: usize) -> KvArena {
        assert!(n_layers > 0 && slots > 0 && s_max > 0 && d > 0, "degenerate arena geometry");
        KvArena {
            n_layers,
            slots,
            s_max,
            d,
            k: (0..n_layers).map(|_| vec![0.0f32; slots * s_max * d]).collect(),
            v: (0..n_layers).map(|_| vec![0.0f32; slots * s_max * d]).collect(),
            keymask: vec![0.0f32; slots * s_max],
            free: (0..slots).rev().collect(),
            live: vec![false; slots],
            high_water: 0,
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn s_max(&self) -> usize {
        self.s_max
    }

    pub fn live_count(&self) -> usize {
        self.slots - self.free.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Most slots ever simultaneously live (telemetry; tests use it to
    /// prove exhaustion queues rather than over-allocating).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn is_live(&self, slot: usize) -> bool {
        self.live[slot]
    }

    /// Claim a slot for a new sequence, clearing its key mask. `None`
    /// when every slot is occupied — callers queue the request rather
    /// than erroring; a later [`KvArena::release`] unblocks it.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(!self.live[slot], "free-list handed out a live slot");
        self.live[slot] = true;
        self.keymask[slot * self.s_max..(slot + 1) * self.s_max].fill(0.0);
        self.high_water = self.high_water.max(self.live_count());
        Some(slot)
    }

    /// Recycle a finished sequence's slot back onto the free list.
    pub fn release(&mut self, slot: usize) {
        assert!(self.live[slot], "released slot {} is not live", slot);
        self.live[slot] = false;
        self.free.push(slot);
    }

    /// Write one position's key/value rows for `slot` at layer `layer`.
    pub fn write_kv(&mut self, layer: usize, slot: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(pos < self.s_max, "position {} outside s_max {}", pos, self.s_max);
        debug_assert!(self.live[slot], "write into a slot that is not live");
        let d = self.d;
        let off = (slot * self.s_max + pos) * d;
        self.k[layer][off..off + d].copy_from_slice(krow);
        self.v[layer][off..off + d].copy_from_slice(vrow);
    }

    pub fn set_mask(&mut self, slot: usize, pos: usize, m: f32) {
        self.keymask[slot * self.s_max + pos] = m;
    }

    pub fn k_slab(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }

    pub fn v_slab(&self, layer: usize) -> &[f32] {
        &self.v[layer]
    }

    pub fn keymask(&self) -> &[f32] {
        &self.keymask
    }

    /// Cache bytes one slot pins across all layers (K + V).
    pub fn bytes_per_slot(&self) -> usize {
        self.n_layers * 2 * self.s_max * self.d * 4
    }

    /// Total arena footprint (slabs + key masks).
    pub fn bytes(&self) -> usize {
        self.slots * self.bytes_per_slot() + self.keymask.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn fill_slot(a: &mut KvArena, slot: usize, tag: f32) {
        for layer in 0..a.n_layers {
            for pos in 0..a.s_max {
                let row: Vec<f32> = (0..a.d).map(|j| tag + j as f32).collect();
                a.write_kv(layer, slot, pos, &row, &row);
                a.set_mask(slot, pos, 1.0);
            }
        }
    }

    fn slot_tag_intact(a: &KvArena, slot: usize, tag: f32) -> bool {
        (0..a.n_layers).all(|layer| {
            let base = slot * a.s_max * a.d;
            a.k_slab(layer)[base] == tag && a.v_slab(layer)[base] == tag
        })
    }

    #[test]
    fn alloc_exhausts_then_queues_and_release_unblocks() {
        let mut a = KvArena::new(2, 4, 8, 4);
        let got: Vec<usize> = (0..4).map(|_| a.alloc().expect("4 slots")).collect();
        assert_eq!(a.live_count(), 4);
        assert!(a.alloc().is_none(), "exhausted arena must return None, not panic");
        assert!(a.alloc().is_none(), "exhaustion is stable");
        a.release(got[2]);
        assert_eq!(a.alloc(), Some(got[2]), "released slot is reusable");
        assert_eq!(a.high_water(), 4);
    }

    #[test]
    fn alloc_never_returns_a_live_slot() {
        // random alloc/release storm: the free list must never hand out a
        // slot that is currently live, and ids stay in range
        let mut a = KvArena::new(1, 8, 4, 2);
        let mut rng = SplitMix64::new(9);
        let mut held: Vec<usize> = Vec::new();
        for _ in 0..500 {
            if !held.is_empty() && rng.below(2) == 0 {
                let i = rng.below(held.len() as u64) as usize;
                let s = held.swap_remove(i);
                a.release(s);
            } else if let Some(s) = a.alloc() {
                assert!(s < a.slots());
                assert!(!held.contains(&s), "slot {} double-allocated", s);
                held.push(s);
            }
            assert_eq!(a.live_count(), held.len());
        }
    }

    #[test]
    fn recycling_never_aliases_live_sequences() {
        // fill every slot with a distinguishable pattern, retire half,
        // overwrite the recycled slots — survivors must be untouched
        let mut a = KvArena::new(2, 6, 5, 3);
        let slots: Vec<usize> = (0..6).map(|_| a.alloc().unwrap()).collect();
        for (i, &s) in slots.iter().enumerate() {
            fill_slot(&mut a, s, 100.0 * (i + 1) as f32);
        }
        for &s in slots.iter().step_by(2) {
            a.release(s);
        }
        let recycled: Vec<usize> = (0..3).map(|_| a.alloc().unwrap()).collect();
        for &s in &recycled {
            assert!(slots.iter().step_by(2).any(|&r| r == s), "recycled {} was never freed", s);
            fill_slot(&mut a, s, 9999.0);
        }
        for (i, &s) in slots.iter().enumerate().skip(1).step_by(2) {
            assert!(
                slot_tag_intact(&a, s, 100.0 * (i + 1) as f32),
                "live slot {} clobbered by recycling",
                s
            );
        }
    }

    #[test]
    fn alloc_clears_keymask_but_not_kv() {
        let mut a = KvArena::new(1, 2, 4, 2);
        let s = a.alloc().unwrap();
        fill_slot(&mut a, s, 7.0);
        a.release(s);
        let s2 = a.alloc().unwrap();
        assert_eq!(s2, s);
        let base = s * a.s_max();
        assert!(a.keymask()[base..base + a.s_max()].iter().all(|&m| m == 0.0));
        // K/V intentionally keeps stale data — masked out by contract
        assert!(slot_tag_intact(&a, s, 7.0));
    }

    #[test]
    fn memory_model_identities() {
        let a = KvArena::new(3, 4, 10, 8);
        assert_eq!(a.bytes_per_slot(), 3 * 2 * 10 * 8 * 4);
        assert_eq!(a.bytes(), 4 * a.bytes_per_slot() + 4 * 10 * 4);
    }
}
