//! Paged KV arena: the scheduler's cache memory.
//!
//! Storage is a pool of fixed-size PAGES (`page` rows × `d` floats, K and
//! V across all layers) handed out on demand, with a per-slot PAGE TABLE
//! mapping logical positions to pages: `pid = table[pos / page]`, row
//! offset `(pid * page + pos % page) * d`. A slot no longer reserves the
//! worst-case `s_max` rows — pages materialize as a sequence grows and
//! return to the pool the moment it retires, so [`KvArena::bytes`] tracks
//! ACTUAL occupancy instead of `slots × s_max` (the dense model survives
//! as [`KvArena::bytes_per_slot`], the worst-case bound one slot can
//! reach).
//!
//! Rows live at their LOGICAL positions (prompt token `j` at row `j`,
//! decode step `t` at row `len + t`): no left-pad rows are stored and no
//! key mask exists — the scheduler attends to exactly the `0..st` rows it
//! wrote for the current occupant, so recycled pages never need zeroing
//! (stale rows are unreachable; the property tests pin this at page
//! granularity). Logical addressing is also what makes a prefix row's
//! CONTENT independent of the total prompt length, the invariant behind:
//!
//! # Shared-prefix caching (copy-on-write)
//!
//! [`KvArena::publish_prefix`] pins a primed prompt's full pages into a
//! small FIFO cache (refcount +1 per page, keyed by `(member, tokens)` —
//! sharing never crosses perturbed members). A later request whose token
//! prefix matches ([`KvArena::adopt_prefix`]) maps its leading page-table
//! entries to the SAME pages and skips recomputing those rows; the first
//! write into a page whose refcount exceeds 1 forks a private copy at the
//! divergence point ([`KvArena::write_kv`]) — the identical copy-on-write
//! discipline `model/sharded.rs` applies to parameter shards. Shared
//! pages are therefore read-only for as long as they are shared
//! (fork-before-write, property-tested), and eviction/release simply
//! decrement refcounts, freeing a page only when its last reader drops.

/// Sentinel for an unmapped page-table entry.
pub const PAGE_NONE: u32 = u32::MAX;

/// One published prefix: the prompt that primed it and the full pages
/// (refcounted) covering its leading `pages.len() * page` rows.
struct PrefixEntry {
    member: usize,
    tokens: Vec<u8>,
    pages: Vec<u32>,
}

/// Paged slot arena: page pool + per-slot page tables + prefix cache.
pub struct KvArena {
    n_layers: usize,
    slots: usize,
    s_max: usize,
    d: usize,
    /// Rows per page (1..=s_max; s_max = dense-equivalent one-page slots).
    page: usize,
    /// Page-table entries per slot: `ceil(s_max / page)`.
    pages_per_slot: usize,
    /// Per layer: `[n_pages * page * d]` keys, grown on demand.
    k: Vec<Vec<f32>>,
    /// Per layer: `[n_pages * page * d]` values.
    v: Vec<Vec<f32>>,
    /// Pages materialized in the pool (slab rows exist for all of them).
    n_pages: usize,
    /// Readers per page (slot tables + prefix-cache entries). 0 = free.
    refcount: Vec<u32>,
    /// LIFO pool of materialized-but-free pages.
    free_pages: Vec<u32>,
    /// `[slots * pages_per_slot]` page table, `PAGE_NONE` = unmapped.
    table: Vec<u32>,
    /// LIFO slot free-list (lowest ids surface first from a fresh arena).
    free: Vec<usize>,
    live: Vec<bool>,
    high_water: usize,
    pages_high_water: usize,
    /// FIFO prefix cache (capacity `prefix_cap`; 0 disables caching).
    prefix: Vec<PrefixEntry>,
    prefix_cap: usize,
    prefix_hits: u64,
    prefix_misses: u64,
    cow_forks: u64,
}

impl KvArena {
    /// `page` is clamped to `[1, s_max]`; `prefix_cap` = max cached
    /// prefixes (0 = caching off).
    pub fn new(
        n_layers: usize,
        slots: usize,
        s_max: usize,
        d: usize,
        page: usize,
        prefix_cap: usize,
    ) -> KvArena {
        assert!(n_layers > 0 && slots > 0 && s_max > 0 && d > 0, "degenerate arena geometry");
        let page = page.clamp(1, s_max);
        let pages_per_slot = (s_max + page - 1) / page;
        KvArena {
            n_layers,
            slots,
            s_max,
            d,
            page,
            pages_per_slot,
            k: (0..n_layers).map(|_| Vec::new()).collect(),
            v: (0..n_layers).map(|_| Vec::new()).collect(),
            n_pages: 0,
            refcount: Vec::new(),
            free_pages: Vec::new(),
            table: vec![PAGE_NONE; slots * pages_per_slot],
            free: (0..slots).rev().collect(),
            live: vec![false; slots],
            high_water: 0,
            pages_high_water: 0,
            prefix: Vec::new(),
            prefix_cap,
            prefix_hits: 0,
            prefix_misses: 0,
            cow_forks: 0,
        }
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn s_max(&self) -> usize {
        self.s_max
    }

    /// Rows per page (after clamping).
    pub fn page(&self) -> usize {
        self.page
    }

    pub fn pages_per_slot(&self) -> usize {
        self.pages_per_slot
    }

    pub fn live_count(&self) -> usize {
        self.slots - self.free.len()
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Most slots ever simultaneously live (telemetry; tests use it to
    /// prove exhaustion queues rather than over-allocating).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Pages currently pinned by slot tables or the prefix cache.
    pub fn pages_in_use(&self) -> usize {
        self.n_pages - self.free_pages.len()
    }

    /// Most pages ever simultaneously in use.
    pub fn pages_high_water(&self) -> usize {
        self.pages_high_water
    }

    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    pub fn prefix_misses(&self) -> u64 {
        self.prefix_misses
    }

    /// Copy-on-write page forks performed (first write into a shared page).
    pub fn cow_forks(&self) -> u64 {
        self.cow_forks
    }

    /// Is prefix caching configured on this arena?
    pub fn prefix_enabled(&self) -> bool {
        self.prefix_cap > 0
    }

    pub fn is_live(&self, slot: usize) -> bool {
        self.live[slot]
    }

    /// Claim a slot for a new sequence. Its page table starts unmapped —
    /// pages materialize on first write per position range. `None` when
    /// every slot is occupied — callers queue the request rather than
    /// erroring; a later [`KvArena::release`] unblocks it.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(!self.live[slot], "free-list handed out a live slot");
        debug_assert!(
            self.table_of(slot).iter().all(|&p| p == PAGE_NONE),
            "freed slot kept mapped pages"
        );
        self.live[slot] = true;
        self.high_water = self.high_water.max(self.live_count());
        Some(slot)
    }

    /// Retire a finished sequence: unmap its pages (each returns to the
    /// pool when its LAST reader drops — pages shared with the prefix
    /// cache or other slots survive) and recycle the slot.
    pub fn release(&mut self, slot: usize) {
        assert!(self.live[slot], "released slot {} is not live", slot);
        for ti in slot * self.pages_per_slot..(slot + 1) * self.pages_per_slot {
            let pid = self.table[ti];
            if pid != PAGE_NONE {
                self.table[ti] = PAGE_NONE;
                self.decref(pid);
            }
        }
        self.live[slot] = false;
        self.free.push(slot);
    }

    /// Write one logical position's key/value rows for `slot` at layer
    /// `layer`. Unmapped position ranges get a page from the pool;
    /// writing into a SHARED page (refcount > 1) first forks a private
    /// copy across all layers — adopted prefix pages are never written
    /// through while shared.
    pub fn write_kv(&mut self, layer: usize, slot: usize, pos: usize, krow: &[f32], vrow: &[f32]) {
        debug_assert!(pos < self.s_max, "position {} outside s_max {}", pos, self.s_max);
        debug_assert!(self.live[slot], "write into a slot that is not live");
        let ti = slot * self.pages_per_slot + pos / self.page;
        let mut pid = self.table[ti];
        if pid == PAGE_NONE {
            pid = self.alloc_page();
            self.table[ti] = pid;
        } else if self.refcount[pid as usize] > 1 {
            pid = self.fork_page(pid);
            self.table[ti] = pid;
        }
        let d = self.d;
        let off = (pid as usize * self.page + pos % self.page) * d;
        self.k[layer][off..off + d].copy_from_slice(krow);
        self.v[layer][off..off + d].copy_from_slice(vrow);
    }

    /// This slot's page table (`pages_per_slot` entries, `PAGE_NONE` =
    /// unmapped). The attention gather walks it: position `pos` lives in
    /// page `table[pos / page]` at in-page row `pos % page`.
    pub fn table_of(&self, slot: usize) -> &[u32] {
        &self.table[slot * self.pages_per_slot..(slot + 1) * self.pages_per_slot]
    }

    /// Layer `layer`'s pooled key slab (`[n_pages * page * d]`).
    pub fn k_slab(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }

    /// Layer `layer`'s pooled value slab.
    pub fn v_slab(&self, layer: usize) -> &[f32] {
        &self.v[layer]
    }

    /// Find the best cached prefix for `(member, prompt)` and map this
    /// slot's leading page-table entries onto its pages (refcount +1
    /// each). Returns the number of leading rows the slot can REUSE —
    /// capped at `prompt.len() - 1` so at least one suffix row is always
    /// computed live (its logits feed the first sampled token). The
    /// caller computes rows `lc..len` and writes them via
    /// [`KvArena::write_kv`], which forks the last adopted page at the
    /// divergence point if the match ends mid-page.
    pub fn adopt_prefix(&mut self, slot: usize, member: usize, prompt: &[u8]) -> usize {
        if self.prefix_cap == 0 {
            return 0;
        }
        debug_assert!(self.live[slot], "adopt into a slot that is not live");
        let mut best: Option<(usize, usize)> = None; // (entry, reusable rows)
        for (ei, e) in self.prefix.iter().enumerate() {
            if e.member != member {
                continue;
            }
            let m = e
                .tokens
                .iter()
                .zip(prompt.iter())
                .take_while(|(a, b)| a == b)
                .count();
            let lc = m.min(prompt.len().saturating_sub(1)).min(e.pages.len() * self.page);
            if lc > best.map_or(0, |(_, b)| b) {
                best = Some((ei, lc));
            }
        }
        let Some((ei, lc)) = best else {
            self.prefix_misses += 1;
            return 0;
        };
        if lc == 0 {
            self.prefix_misses += 1;
            return 0;
        }
        let n_adopt = (lc + self.page - 1) / self.page;
        let pages: Vec<u32> = self.prefix[ei].pages[..n_adopt].to_vec();
        for (pi, &pid) in pages.iter().enumerate() {
            self.refcount[pid as usize] += 1;
            self.table[slot * self.pages_per_slot + pi] = pid;
        }
        self.prefix_hits += 1;
        lc
    }

    /// Pin this slot's fully-covered prompt pages (`prompt.len() / page`
    /// of them) into the prefix cache under `(member, prompt)`. No-op if
    /// caching is off, the prompt spans no full page, or an identical
    /// entry exists. At capacity the OLDEST entry is evicted first
    /// (refcounts drop; its pages free once unshared). Call only after
    /// every layer's rows `0..prompt.len()` are written.
    pub fn publish_prefix(&mut self, slot: usize, member: usize, prompt: &[u8]) {
        if self.prefix_cap == 0 {
            return;
        }
        let n = prompt.len() / self.page;
        if n == 0 {
            return;
        }
        if self.prefix.iter().any(|e| e.member == member && e.tokens == prompt) {
            return;
        }
        let base = slot * self.pages_per_slot;
        let pages: Vec<u32> = self.table[base..base + n].to_vec();
        debug_assert!(
            pages.iter().all(|&p| p != PAGE_NONE),
            "publishing a prompt whose pages are not all written"
        );
        for &pid in &pages {
            self.refcount[pid as usize] += 1;
        }
        if self.prefix.len() == self.prefix_cap {
            let evicted = self.prefix.remove(0);
            for pid in evicted.pages {
                self.decref(pid);
            }
        }
        self.prefix.push(PrefixEntry { member, tokens: prompt.to_vec(), pages });
    }

    /// Cached prefix entries currently pinned.
    pub fn prefix_len(&self) -> usize {
        self.prefix.len()
    }

    fn decref(&mut self, pid: u32) {
        let rc = &mut self.refcount[pid as usize];
        debug_assert!(*rc > 0, "decref on a free page");
        *rc -= 1;
        if *rc == 0 {
            // back to the pool UNZEROED: stale rows are unreachable (the
            // scheduler attends only to rows written for the occupant)
            self.free_pages.push(pid);
        }
    }

    /// Hand out a free page, materializing a new one when the pool is dry
    /// (the slabs grow; arena bytes track the high-water page count).
    fn alloc_page(&mut self) -> u32 {
        let pid = match self.free_pages.pop() {
            Some(p) => p,
            None => {
                let pid = self.n_pages as u32;
                self.n_pages += 1;
                let pd = self.page * self.d;
                for l in 0..self.n_layers {
                    self.k[l].resize(self.n_pages * pd, 0.0);
                    self.v[l].resize(self.n_pages * pd, 0.0);
                }
                self.refcount.push(0);
                pid
            }
        };
        debug_assert_eq!(self.refcount[pid as usize], 0, "pool handed out a pinned page");
        self.refcount[pid as usize] = 1;
        self.pages_high_water = self.pages_high_water.max(self.pages_in_use());
        pid
    }

    /// Copy-on-write: clone `pid`'s rows (all layers, K and V) into a
    /// fresh page for the writer, dropping one reference to the shared
    /// original. Rows before the divergence point stay valid in the copy;
    /// the shared page is never touched.
    fn fork_page(&mut self, pid: u32) -> u32 {
        let npid = self.alloc_page();
        let pd = self.page * self.d;
        let (src, dst) = (pid as usize * pd, npid as usize * pd);
        for l in 0..self.n_layers {
            self.k[l].copy_within(src..src + pd, dst);
            self.v[l].copy_within(src..src + pd, dst);
        }
        self.decref(pid);
        self.cow_forks += 1;
        npid
    }

    /// Bytes one page pins across all layers (K + V).
    pub fn bytes_per_page(&self) -> usize {
        self.n_layers * 2 * self.page * self.d * 4
    }

    /// The DENSE worst-case bound: bytes one slot would pin if it grew to
    /// `s_max` rows with no sharing — the pre-paging per-slot model,
    /// reported next to the paged numbers by `qes info` / `qes serve`.
    pub fn bytes_per_slot(&self) -> usize {
        self.n_layers * 2 * self.s_max * self.d * 4
    }

    /// Total arena footprint: materialized page slabs PLUS bookkeeping —
    /// page tables, refcounts, both free-lists and the prefix cache
    /// (entry prompts + page lists) — so the number callers see is what
    /// the arena actually holds, not just the f32 payload.
    pub fn bytes(&self) -> usize {
        let slabs = self.n_pages * self.bytes_per_page();
        let meta = self.table.len() * 4
            + self.refcount.len() * 4
            + self.free_pages.len() * 4
            + self.free.len() * 8
            + self.live.len();
        let cache: usize =
            self.prefix.iter().map(|e| e.tokens.len() + e.pages.len() * 4).sum();
        slabs + meta + cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    /// Write `len` rows of a recognizable per-position pattern.
    fn fill_rows(a: &mut KvArena, slot: usize, len: usize, tag: f32) {
        for layer in 0..a.n_layers {
            for pos in 0..len {
                let row: Vec<f32> = (0..a.d).map(|j| tag + pos as f32 + j as f32).collect();
                a.write_kv(layer, slot, pos, &row, &row);
            }
        }
    }

    fn read_row(a: &KvArena, layer: usize, slot: usize, pos: usize) -> Vec<f32> {
        let pid = a.table_of(slot)[pos / a.page()] as usize;
        let off = (pid * a.page() + pos % a.page()) * a.d;
        a.k_slab(layer)[off..off + a.d].to_vec()
    }

    fn rows_intact(a: &KvArena, slot: usize, len: usize, tag: f32) -> bool {
        (0..a.n_layers).all(|layer| {
            (0..len).all(|pos| read_row(a, layer, slot, pos)[0] == tag + pos as f32)
        })
    }

    #[test]
    fn alloc_exhausts_then_queues_and_release_unblocks() {
        let mut a = KvArena::new(2, 4, 8, 4, 4, 0);
        let got: Vec<usize> = (0..4).map(|_| a.alloc().expect("4 slots")).collect();
        assert_eq!(a.live_count(), 4);
        assert!(a.alloc().is_none(), "exhausted arena must return None, not panic");
        assert!(a.alloc().is_none(), "exhaustion is stable");
        a.release(got[2]);
        assert_eq!(a.alloc(), Some(got[2]), "released slot is reusable");
        assert_eq!(a.high_water(), 4);
    }

    #[test]
    fn alloc_never_returns_a_live_slot() {
        // random alloc/release storm: the free list must never hand out a
        // slot that is currently live, and ids stay in range
        let mut a = KvArena::new(1, 8, 4, 2, 2, 0);
        let mut rng = SplitMix64::new(9);
        let mut held: Vec<usize> = Vec::new();
        for _ in 0..500 {
            if !held.is_empty() && rng.below(2) == 0 {
                let i = rng.below(held.len() as u64) as usize;
                let s = held.swap_remove(i);
                a.release(s);
            } else if let Some(s) = a.alloc() {
                assert!(s < a.slots());
                assert!(!held.contains(&s), "slot {} double-allocated", s);
                fill_rows(&mut a, s, a.s_max(), 100.0);
                held.push(s);
            }
            assert_eq!(a.live_count(), held.len());
        }
    }

    #[test]
    fn page_recycling_never_aliases_live_sequences() {
        // the page-granular extension of the old keymask non-aliasing
        // pin: fill every slot, retire half (their pages return to the
        // pool), regrow into recycled pages — survivors' rows must be
        // bit-intact even though pages recycle unzeroed
        let mut a = KvArena::new(2, 6, 6, 3, 2, 0);
        let slots: Vec<usize> = (0..6).map(|_| a.alloc().unwrap()).collect();
        for (i, &s) in slots.iter().enumerate() {
            fill_rows(&mut a, s, 6, 100.0 * (i + 1) as f32);
        }
        let full = a.pages_in_use();
        for &s in slots.iter().step_by(2) {
            a.release(s);
        }
        assert_eq!(a.pages_in_use(), full / 2, "released pages return to the pool");
        let recycled: Vec<usize> = (0..3).map(|_| a.alloc().unwrap()).collect();
        for &s in &recycled {
            assert!(slots.iter().step_by(2).any(|&r| r == s), "recycled {} was never freed", s);
            fill_rows(&mut a, s, 6, 9999.0);
        }
        assert_eq!(a.pages_in_use(), full, "regrow reuses pooled pages, no net growth");
        assert_eq!(a.pages_high_water(), full);
        for (i, &s) in slots.iter().enumerate().skip(1).step_by(2) {
            assert!(
                rows_intact(&a, s, 6, 100.0 * (i + 1) as f32),
                "live slot {} clobbered by page recycling",
                s
            );
        }
    }

    #[test]
    fn pages_materialize_on_demand_and_bytes_track_occupancy() {
        let mut a = KvArena::new(3, 4, 10, 8, 2, 0);
        assert_eq!(a.bytes_per_page(), 3 * 2 * 2 * 8 * 4);
        assert_eq!(a.bytes_per_slot(), 3 * 2 * 10 * 8 * 4);
        assert_eq!(a.pages_per_slot(), 5);
        let empty = a.bytes();
        assert!(empty < a.bytes_per_page(), "empty arena holds metadata only");
        let s = a.alloc().unwrap();
        let base = a.bytes();
        fill_rows(&mut a, s, 3, 5.0); // 3 rows @ page=2 -> 2 pages
        assert_eq!(a.pages_in_use(), 2);
        // each materialized page costs its slab bytes + one refcount cell
        assert_eq!(a.bytes(), base + 2 * (a.bytes_per_page() + 4));
        // growing to s_max costs exactly the dense bound in slab bytes
        fill_rows(&mut a, s, 10, 5.0);
        assert_eq!(a.pages_in_use(), 5);
        assert_eq!(a.bytes(), base + 5 * (a.bytes_per_page() + 4));
        assert_eq!(5 * a.bytes_per_page(), a.bytes_per_slot());
    }

    #[test]
    fn prefix_adoption_shares_pages_and_forks_before_write() {
        // the fork-before-write pin: adopted prefix pages are read-only
        // while shared — the adopter's first write forks a private copy
        // and the publisher's rows stay bit-intact
        let mut a = KvArena::new(2, 4, 8, 3, 4, 8);
        let owner = a.alloc().unwrap();
        let prompt: Vec<u8> = vec![1, 2, 3, 4, 5, 6];
        fill_rows(&mut a, owner, 6, 100.0);
        a.publish_prefix(owner, 0, &prompt);
        assert_eq!(a.prefix_len(), 1);
        let before = a.pages_in_use();

        // same member, shared 5-token prefix, divergent tail
        let adopter = a.alloc().unwrap();
        let p2: Vec<u8> = vec![1, 2, 3, 4, 5, 9];
        let lc = a.adopt_prefix(adopter, 0, &p2);
        assert_eq!(lc, 4, "match 5 rows, capped to the published 1 full page (4 rows)");
        assert_eq!(a.prefix_hits(), 1);
        assert_eq!(a.pages_in_use(), before, "adoption maps pages, allocates none");
        assert_eq!(a.table_of(adopter)[0], a.table_of(owner)[0], "page is shared");
        // the adopter computes + writes rows lc.. — page 1 is fresh here
        // (lc == page boundary), but overwriting a SHARED row must fork
        let forks0 = a.cow_forks();
        fill_rows(&mut a, adopter, 6, 200.0);
        assert!(a.cow_forks() > forks0, "write into a shared page must fork");
        assert_ne!(a.table_of(adopter)[0], a.table_of(owner)[0], "fork unshared the page");
        assert!(rows_intact(&a, owner, 6, 100.0), "publisher rows written through the share");
        assert!(rows_intact(&a, adopter, 6, 200.0), "fork lost the adopter's writes");

        // different member must NEVER share (perturbed weights)
        let other = a.alloc().unwrap();
        assert_eq!(a.adopt_prefix(other, 1, &prompt), 0);
        assert_eq!(a.prefix_misses(), 1);
    }

    #[test]
    fn cached_pages_survive_owner_release_and_evict_fifo() {
        let mut a = KvArena::new(1, 2, 8, 2, 2, 2);
        let s = a.alloc().unwrap();
        let prompt: Vec<u8> = vec![7, 7, 7, 7];
        fill_rows(&mut a, s, 4, 10.0);
        a.publish_prefix(s, 0, &prompt);
        a.release(s);
        assert_eq!(a.pages_in_use(), 2, "cache pins pages past the owner's retirement");
        // a new request still adopts from the cache
        let s2 = a.alloc().unwrap();
        assert_eq!(a.adopt_prefix(s2, 0, &prompt), 3, "capped at len-1 rows");
        // publishing identical (member, prompt) again is a no-op
        fill_rows(&mut a, s2, 4, 11.0);
        a.publish_prefix(s2, 0, &prompt);
        assert_eq!(a.prefix_len(), 1);
        a.release(s2);
        // FIFO eviction at capacity drops the oldest entry's pins
        let s3 = a.alloc().unwrap();
        fill_rows(&mut a, s3, 4, 12.0);
        a.publish_prefix(s3, 0, &[1, 1, 1, 1]);
        a.release(s3);
        let s4 = a.alloc().unwrap();
        fill_rows(&mut a, s4, 4, 13.0);
        a.publish_prefix(s4, 0, &[2, 2, 2, 2]);
        a.release(s4);
        assert_eq!(a.prefix_len(), 2, "capacity holds");
        let s5 = a.alloc().unwrap();
        assert_eq!(a.adopt_prefix(s5, 0, &prompt), 0, "oldest entry was evicted first");
    }

    #[test]
    fn memory_model_identities() {
        let a = KvArena::new(3, 4, 10, 8, 0, 0); // page=0 clamps to 1
        assert_eq!(a.page(), 1);
        let b = KvArena::new(3, 4, 10, 8, 99, 0); // page>s_max clamps to s_max
        assert_eq!(b.page(), 10);
        assert_eq!(b.pages_per_slot(), 1);
        assert_eq!(b.bytes_per_page(), b.bytes_per_slot(), "full-page slots are dense");
    }
}
