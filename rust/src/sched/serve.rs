//! `qes serve` — a line-delimited JSON front end over the
//! continuous-batching scheduler: one request object per input line, one
//! response object per completed generation, emitted the moment the
//! sequence retires (admission order never gates emission).
//!
//! ```text
//! request:  {"prompt": "3,4,5=17:", "max_new": 12, "tau": 0.7, "seed": 9, "id": "r1"}
//! response: {"id": "r1", "text": "3*4+5", "tokens": 6}
//! error:    {"id": "r1", "error": "..."}
//! ```
//!
//! `prompt` is required; `max_new` defaults to the scheduler's decode
//! budget, `tau`/`seed` default to greedy, `id` (string or number)
//! defaults to the submission index. Malformed lines and oversized
//! prompts produce an error RESPONSE, never a dead server.
//!
//! The pump ([`serve_loop`]) interleaves intake with decoding: it drains
//! whatever lines are already queued, steps the scheduler once, writes
//! finished responses, and only blocks on input when nothing is in
//! flight — so a request arriving mid-batch joins the next admission
//! wave instead of waiting for a drain. The CLI (`qes serve`) feeds it
//! from stdin through an mpsc channel; `--tcp`/`--http` serve MANY
//! concurrent connections against one scheduler through the connection
//! mux ([`mux`](crate::sched::mux)), which reuses this module's parse /
//! response / pump machinery per connection (the OpenAI-compatible
//! `POST /v1/completions` surface in [`http`](crate::sched::http)
//! validates through the same `parse_max_new`/`parse_tau`/`parse_seed`).

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use anyhow::{Context, Result};

use crate::obs;
use crate::sched::{GenOutput, GenRequest, Scheduler};
use crate::tasks::tokenizer;
use crate::util::json::Json;

/// A decoded request line: the request plus its response id.
pub struct ParsedRequest {
    pub id: String,
    pub req: GenRequest,
}

/// Validate a decode budget field (`max_new` / OpenAI `max_tokens`):
/// absent or `null` takes the default; anything else must be an exact
/// non-negative integer (a negative number must NOT saturate to 0 —
/// that silently turns a malformed request into an instant empty
/// completion).
pub fn parse_max_new(v: Option<&Json>, default_max_new: usize, field: &str) -> Result<usize> {
    match v {
        None | Some(Json::Null) => Ok(default_max_new),
        Some(j) => j
            .as_usize()
            .with_context(|| format!("\"{}\" must be a non-negative integer", field)),
    }
}

/// Validate a sampling temperature (`tau` / OpenAI `temperature`):
/// absent or `null` decodes greedily; negative, NaN or infinite values
/// are rejected instead of flowing into sampled decode (a NaN tau makes
/// every gumbel-perturbed logit NaN and argmax degenerates to token 0).
pub fn parse_tau(v: Option<&Json>, field: &str) -> Result<f32> {
    match v {
        None | Some(Json::Null) => Ok(0.0),
        Some(j) => {
            let t = j.as_f64().with_context(|| format!("\"{}\" must be a number", field))?;
            anyhow::ensure!(
                t.is_finite() && t >= 0.0,
                "\"{}\" must be a finite non-negative number",
                field
            );
            Ok(t as f32)
        }
    }
}

/// Validate a sampling seed: absent or `null` means none; anything else
/// must be an exact non-negative integer below 2^53. The old path went
/// through `as_f64() as u64`, so `{"seed": -1}` silently saturated to
/// seed 0 and integer seeds at/above 2^53 lost precision — both now get
/// an error response instead.
pub fn parse_seed(v: Option<&Json>) -> Result<Option<u64>> {
    match v {
        None | Some(Json::Null) => Ok(None),
        Some(j) => Ok(Some(j.as_u64_exact().context(
            "\"seed\" must be a non-negative integer below 2^53 (f64-exact)",
        )?)),
    }
}

/// Parse one request line. `default_max_new` fills an absent `max_new`;
/// `default_id` names the response when the line carries no `id`.
pub fn parse_request(
    line: &str,
    default_id: usize,
    default_max_new: usize,
) -> Result<ParsedRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {}", e))?;
    let id = match j.get("id") {
        Some(Json::Str(s)) => s.clone(),
        Some(Json::Num(n)) => Json::Num(*n).to_string_compact(),
        _ => default_id.to_string(),
    };
    let prompt_text = j
        .get("prompt")
        .and_then(Json::as_str)
        .context("request needs a string \"prompt\"")?;
    let prompt = tokenizer::try_encode(prompt_text)
        .map_err(|c| anyhow::anyhow!("prompt char {:?} not in the vocabulary", c))?;
    let max_new = parse_max_new(j.get("max_new"), default_max_new, "max_new")?;
    let tau = parse_tau(j.get("tau"), "tau")?;
    let seed = parse_seed(j.get("seed"))?;
    Ok(ParsedRequest { id, req: GenRequest { prompt, max_new, tau, seed } })
}

/// Serialize a completed generation. `cached` reports the KV rows the
/// prefix cache reused at admission (0 = cold-primed) — observability
/// only, the text is bit-identical either way.
pub fn response_line(id: &str, out: &GenOutput) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Str(id.to_string()));
    m.insert("text".to_string(), Json::Str(out.text.clone()));
    m.insert("tokens".to_string(), Json::Num(out.tokens.len() as f64));
    m.insert("cached".to_string(), Json::Num(out.cached as f64));
    Json::Obj(m).to_string_compact()
}

/// Serialize a request failure.
pub fn error_line(id: &str, err: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Str(id.to_string()));
    m.insert("error".to_string(), Json::Str(err.to_string()));
    Json::Obj(m).to_string_compact()
}

/// Response to the `stats` line-protocol command: a JSON snapshot of
/// the whole metrics registry (counters/gauges as values, histograms as
/// `{count, sum, p50, p90, p99}`), keyed by metric name.
pub fn stats_line(id: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Str(id.to_string()));
    m.insert("stats".to_string(), obs::registry().snapshot_json());
    Json::Obj(m).to_string_compact()
}

/// Pump outcome counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub served: u64,
    pub errors: u64,
    /// The output sink died (broken pipe / failed flush). The loop stops
    /// driving the scheduler the moment this happens — a disconnected
    /// client must end the connection, not leave the server decoding
    /// into a dead sink.
    pub write_failed: bool,
}

/// One unit of intake from a connection pump: either a complete line or
/// a marker that a line blew past the reader's length cap (the payload
/// is the cap, for the error message — the excess bytes were discarded
/// at the socket, never buffered).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Intake {
    Line(String),
    Oversized(usize),
}

/// Read `reader` to EOF, splitting on `\n` and sending each line as
/// [`Intake::Line`]. A line longer than `max_line` bytes is discarded as
/// it streams past (bounded memory) and reported once as
/// [`Intake::Oversized`]. A read error — including a socket read
/// deadline firing (`WouldBlock`/`TimedOut`) — ends the pump; a
/// trailing unterminated line (at EOF *or* at a read error — a deadline
/// firing after a complete buffered request must not discard it) is
/// still delivered.
pub fn pump_lines<R: Read>(reader: R, max_line: usize, tx: &Sender<Intake>) {
    pump_lines_with(reader, max_line, |ev| tx.send(ev).is_ok());
}

/// [`pump_lines`] over an arbitrary sink — the connection mux feeds a
/// shared tagged channel through this. `sink` returns `false` when the
/// consumer is gone, which stops the pump. Returns `true` on a clean
/// EOF, `false` on a read error or a dead sink — the mux maps that onto
/// half-close (keep delivering responses) vs teardown.
pub fn pump_lines_with<R: Read, F: FnMut(Intake) -> bool>(
    reader: R,
    max_line: usize,
    mut sink: F,
) -> bool {
    let mut r = BufReader::new(reader);
    let mut buf: Vec<u8> = Vec::new();
    let mut over = false;
    let mut eof = false;
    while !eof {
        let mut events: Vec<Intake> = Vec::new();
        let data = {
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // read deadline or hard I/O error: the pump ends, but a
                // complete non-oversized buffered line is flushed first
                // (a deadline firing right after "req\n…req2" arrived
                // must deliver req2, not silently drop it)
                Err(_) => {
                    if !over && !buf.is_empty() {
                        sink(Intake::Line(String::from_utf8_lossy(&buf).into_owned()));
                    }
                    return false;
                }
            };
            if chunk.is_empty() {
                eof = true;
            }
            chunk.to_vec()
        };
        let mut start = 0usize;
        while let Some(pos) = data[start..].iter().position(|&b| b == b'\n') {
            let part = &data[start..start + pos];
            if over || buf.len() + part.len() > max_line {
                events.push(Intake::Oversized(max_line));
            } else {
                buf.extend_from_slice(part);
                events.push(Intake::Line(String::from_utf8_lossy(&buf).into_owned()));
            }
            buf.clear();
            over = false;
            start += pos + 1;
        }
        let tail = &data[start..];
        if over || buf.len() + tail.len() > max_line {
            over = true;
            buf.clear();
        } else {
            buf.extend_from_slice(tail);
        }
        r.consume(data.len());
        for ev in events {
            if !sink(ev) {
                return false; // consumer gone
            }
        }
    }
    // unterminated final line
    if over {
        sink(Intake::Oversized(max_line));
    } else if !buf.is_empty() {
        sink(Intake::Line(String::from_utf8_lossy(&buf).into_owned()));
    }
    true
}

/// Drive the scheduler against an intake channel until the channel
/// closes AND every accepted request has completed, writing one response
/// line per finished generation (and one error line per rejected or
/// oversized request). A failed write or flush — a broken-pipe client —
/// ends the connection immediately: the loop returns with
/// [`ServeStats::write_failed`] set instead of stepping the scheduler
/// into a dead sink.
pub fn serve_loop<W: Write>(
    sched: &mut Scheduler<'_>,
    lines: &Receiver<Intake>,
    out: &mut W,
) -> Result<ServeStats> {
    let default_max_new = sched.cfg().t_max;
    // ticket -> (response id, submit timestamp for the latency histogram)
    let mut ids: HashMap<usize, (String, u64)> = HashMap::new();
    let mut next_id = 0usize;
    let mut stats = ServeStats::default();
    let mut open = true;
    'conn: loop {
        // intake: everything already queued, without blocking the batch
        while open {
            match lines.try_recv() {
                Ok(intake) => {
                    if submit_intake(
                        sched,
                        intake,
                        default_max_new,
                        &mut ids,
                        &mut next_id,
                        out,
                        &mut stats,
                    )
                    .is_err()
                    {
                        stats.write_failed = true;
                        break 'conn;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        // emit everything finished so far (zero-budget requests complete
        // at submit time, before any step runs)
        for (ticket, o) in sched.drain_finished() {
            let (id, t_submit) = ids
                .remove(&ticket.index())
                .unwrap_or_else(|| (ticket.index().to_string(), 0));
            if t_submit > 0 {
                obs::m().serve_latency_ns.observe(obs::now_ns().saturating_sub(t_submit));
            }
            if writeln!(out, "{}", response_line(&id, &o)).is_err() {
                stats.write_failed = true;
                break 'conn;
            }
            stats.served += 1;
            obs::m().serve_served.inc();
        }
        if out.flush().is_err() {
            stats.write_failed = true;
            break 'conn;
        }
        if sched.idle() {
            if !open {
                break;
            }
            // nothing in flight: block for the next request
            match lines.recv() {
                Ok(intake) => {
                    if submit_intake(
                        sched,
                        intake,
                        default_max_new,
                        &mut ids,
                        &mut next_id,
                        out,
                        &mut stats,
                    )
                    .is_err()
                    {
                        stats.write_failed = true;
                        break 'conn;
                    }
                }
                Err(_) => open = false,
            }
            continue;
        }
        sched.step()?;
    }
    Ok(stats)
}

/// Feed one intake event to the scheduler, writing any error response.
/// `Err` is an I/O failure on `out` — the caller treats that as the end
/// of the connection; request-level failures (bad JSON, OOV prompts,
/// oversized lines, submit rejections) are answered inline and counted,
/// never returned.
#[allow(clippy::too_many_arguments)]
fn submit_intake<W: Write>(
    sched: &mut Scheduler<'_>,
    intake: Intake,
    default_max_new: usize,
    ids: &mut HashMap<usize, (String, u64)>,
    next_id: &mut usize,
    out: &mut W,
    stats: &mut ServeStats,
) -> std::io::Result<()> {
    match intake {
        Intake::Line(line) => {
            submit_line(sched, &line, default_max_new, ids, next_id, out, stats)
        }
        Intake::Oversized(cap) => {
            let default_id = *next_id;
            *next_id += 1;
            writeln!(
                out,
                "{}",
                error_line(
                    &default_id.to_string(),
                    &format!("request line exceeds {} bytes", cap),
                )
            )?;
            stats.errors += 1;
            obs::m().serve_errors.inc();
            Ok(())
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn submit_line<W: Write>(
    sched: &mut Scheduler<'_>,
    line: &str,
    default_max_new: usize,
    ids: &mut HashMap<usize, (String, u64)>,
    next_id: &mut usize,
    out: &mut W,
    stats: &mut ServeStats,
) -> std::io::Result<()> {
    let line = line.trim();
    if line.is_empty() {
        return Ok(());
    }
    let default_id = *next_id;
    *next_id += 1;
    // registry snapshot on demand (same command the mux understands) —
    // a control command, counted as neither served nor error
    if line == "stats" {
        writeln!(out, "{}", stats_line(&default_id.to_string()))?;
        return Ok(());
    }
    match parse_request(line, default_id, default_max_new) {
        Ok(pr) => match sched.submit(pr.req) {
            Ok(ticket) => {
                ids.insert(ticket.index(), (pr.id, obs::now_ns()));
                obs::m().serve_inflight.set(sched.pending() as u64);
            }
            Err(e) => {
                writeln!(out, "{}", error_line(&pr.id, &format!("{:#}", e)))?;
                stats.errors += 1;
                obs::m().serve_errors.inc();
            }
        },
        Err(e) => {
            writeln!(out, "{}", error_line(&default_id.to_string(), &format!("{:#}", e)))?;
            stats.errors += 1;
            obs::m().serve_errors.inc();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_defaults_and_errors() {
        let pr = parse_request(r#"{"prompt": "3,4,5=17:"}"#, 7, 12).unwrap();
        assert_eq!(pr.id, "7");
        assert_eq!(pr.req.max_new, 12);
        assert_eq!(pr.req.tau, 0.0);
        assert_eq!(pr.req.seed, None);
        assert_eq!(pr.req.prompt, tokenizer::encode("3,4,5=17:"));

        let pr = parse_request(
            r#"{"prompt": "1+2=", "max_new": 4, "tau": 0.5, "seed": 9, "id": "abc"}"#,
            0,
            12,
        )
        .unwrap();
        assert_eq!(pr.id, "abc");
        assert_eq!(pr.req.max_new, 4);
        assert!((pr.req.tau - 0.5).abs() < 1e-6);
        assert_eq!(pr.req.seed, Some(9));

        // numeric ids stringify
        assert_eq!(parse_request(r#"{"prompt": "1", "id": 3}"#, 0, 8).unwrap().id, "3");
        // malformed json / missing prompt / OOV chars are Err, not panics
        assert!(parse_request("not json", 0, 8).is_err());
        assert!(parse_request(r#"{"max_new": 4}"#, 0, 8).is_err());
        let e = parse_request(r#"{"prompt": "héllo"}"#, 0, 8).unwrap_err();
        assert!(format!("{}", e).contains("vocabulary"), "{}", e);
    }

    #[test]
    fn pump_lines_splits_caps_and_flushes_tail() {
        use std::sync::mpsc::channel;
        // normal lines split on \n, oversized line reported once, excess
        // discarded, unterminated tail flushed at EOF
        let input = format!("short\n{}\nafter\ntail", "x".repeat(100));
        let (tx, rx) = channel();
        pump_lines(input.as_bytes(), 16, &tx);
        drop(tx);
        let got: Vec<Intake> = rx.iter().collect();
        assert_eq!(
            got,
            vec![
                Intake::Line("short".to_string()),
                Intake::Oversized(16),
                Intake::Line("after".to_string()),
                Intake::Line("tail".to_string()),
            ]
        );

        // a line straddling the cap exactly at the boundary still fits
        let (tx, rx) = channel();
        pump_lines("abcd\n".as_bytes(), 4, &tx);
        drop(tx);
        assert_eq!(rx.iter().collect::<Vec<_>>(), vec![Intake::Line("abcd".to_string())]);

        // oversized unterminated tail is reported, not silently dropped
        let (tx, rx) = channel();
        pump_lines("yyyyyy".as_bytes(), 3, &tx);
        drop(tx);
        assert_eq!(rx.iter().collect::<Vec<_>>(), vec![Intake::Oversized(3)]);
    }

    #[test]
    fn parse_request_rejects_bad_seed_tau_and_budget() {
        // seed went through `as_f64() as u64` before: -1 saturated to
        // seed 0 and values at/above 2^53 lost precision silently — all
        // must be error responses now
        assert!(parse_request(r#"{"prompt": "1", "seed": -1}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "seed": 1.5}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "seed": 9007199254740992}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "seed": 1e300}"#, 0, 8).is_err());
        let e = parse_request(r#"{"prompt": "1", "seed": -1}"#, 0, 8).unwrap_err();
        assert!(format!("{:#}", e).contains("non-negative integer"), "{:#}", e);
        // the largest f64-exact seed still parses
        let pr = parse_request(r#"{"prompt": "1", "seed": 9007199254740991}"#, 0, 8).unwrap();
        assert_eq!(pr.req.seed, Some((1u64 << 53) - 1));
        // null means "absent", not an error
        let pr = parse_request(r#"{"prompt": "1", "seed": null, "tau": null}"#, 0, 8).unwrap();
        assert_eq!(pr.req.seed, None);
        assert_eq!(pr.req.tau, 0.0);

        // tau: negative / infinite / non-numeric flowed straight into
        // sampled decode before — now rejected
        assert!(parse_request(r#"{"prompt": "1", "tau": -0.5}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "tau": 1e999}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "tau": "hot"}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "tau": 0.0}"#, 0, 8).is_ok());

        // max_new: -1 used to saturate to 0 (an instant empty
        // completion for a malformed request)
        assert!(parse_request(r#"{"prompt": "1", "max_new": -1}"#, 0, 8).is_err());
        assert!(parse_request(r#"{"prompt": "1", "max_new": 2.5}"#, 0, 8).is_err());
        assert_eq!(parse_request(r#"{"prompt": "1", "max_new": 0}"#, 0, 8).unwrap().req.max_new, 0);
    }

    /// Reader that yields some chunks, then fails like a socket read
    /// deadline firing (`WouldBlock`).
    struct DeadlineReader {
        chunks: Vec<Vec<u8>>,
    }

    impl Read for DeadlineReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.chunks.is_empty() {
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "deadline"));
            }
            let chunk = self.chunks.remove(0);
            out[..chunk.len()].copy_from_slice(&chunk);
            Ok(chunk.len())
        }
    }

    #[test]
    fn pump_lines_flushes_buffered_line_when_deadline_fires() {
        use std::sync::mpsc::channel;
        // a complete request buffered without its trailing newline must
        // be delivered when the read deadline fires, not dropped
        let r = DeadlineReader { chunks: vec![b"a\n".to_vec(), b"{\"prompt\":\"1\"}".to_vec()] };
        let (tx, rx) = channel();
        assert!(!pump_lines_with(r, 64, |ev| tx.send(ev).is_ok()), "deadline is not a clean EOF");
        drop(tx);
        let got: Vec<Intake> = rx.iter().collect();
        assert_eq!(
            got,
            vec![
                Intake::Line("a".to_string()),
                Intake::Line("{\"prompt\":\"1\"}".to_string()),
            ]
        );

        // an OVERSIZED partial buffer is still discarded on a deadline
        let r = DeadlineReader { chunks: vec![b"xxxxxxxx".to_vec()] };
        let (tx, rx) = channel();
        pump_lines(r, 4, &tx);
        drop(tx);
        assert_eq!(rx.iter().count(), 0, "oversized partial must not be flushed");

        // an empty buffer on a deadline delivers nothing
        let r = DeadlineReader { chunks: vec![b"done\n".to_vec()] };
        let (tx, rx) = channel();
        pump_lines(r, 64, &tx);
        drop(tx);
        assert_eq!(rx.iter().collect::<Vec<_>>(), vec![Intake::Line("done".to_string())]);
    }

    #[test]
    fn response_and_error_lines_roundtrip() {
        let out = GenOutput { tokens: vec![3, 4, 20], text: "12".to_string(), cached: 7 };
        let r = response_line("r1", &out);
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("id").unwrap().as_str(), Some("r1"));
        assert_eq!(j.get("text").unwrap().as_str(), Some("12"));
        assert_eq!(j.get("tokens").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("cached").unwrap().as_usize(), Some(7));
        let e = error_line("r2", "boom");
        let j = Json::parse(&e).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("boom"));
    }
}
