//! Continuous-batching generation scheduler — the serving-shaped rollout
//! engine.
//!
//! The per-call `NativeBackend::generate` path pays, for every member ×
//! every batch: a full `resolve` (+ INT4 repack), a prompt prefill over
//! `b_gen` fixed rows (padding duplicates included), and `t_dec` decode
//! steps for every row whether or not it already emitted EOS. This module
//! replaces that with a slot-based engine:
//!
//! * [`GenRequest`]/[`GenTicket`] — submit prompts individually (variable
//!   length, per-request decode budget, greedy or per-request-seeded
//!   sampled decode) and collect each completion as it finishes;
//! * [`KvArena`] — PAGED per-layer KV storage: each slot holds a page
//!   table over fixed-size pages drawn from a shared pool, pages
//!   materialize on demand as a sequence grows (bytes track occupancy,
//!   not `slots × s_max`), retirement returns them to the pool, and a
//!   same-member shared-prefix cache maps matching prompt prefixes onto
//!   refcounted read-only pages, copy-on-write-forked at the divergence
//!   point (the `model/sharded.rs` COW discipline applied to KV);
//! * [`Scheduler::step`] — admit waiting requests into free slots, run
//!   ONE batched prefill over the newly admitted and ONE batched decode
//!   GEMM per step across ALL live slots (K-major
//!   `DotKernel::dot_packed_int4` per output channel for INT4 — see
//!   `gemm::matmul_decode`), and retire finished sequences mid-batch.
//!
//! # Determinism: batch invariance
//!
//! Every per-sequence result depends only on that sequence's request:
//! the GEMMs compute each output element from its own input row in fixed
//! K order, attention reads only the slot's own arena rows, sampling
//! noise is a per-request stream indexed by step (never by slot or batch
//! position). Greedy decode is therefore **batch-invariant** — output
//! tokens are bit-identical for any slot count × admission order ×
//! thread count, extending the repo's determinism contract
//! (`tests/scheduler.rs` enforces the matrix). Across KERNEL backends
//! the same bit-identity holds on the axpy decode path
//! (`SchedCfg::kmajor = false`); the K-major path inherits
//! `dot_packed_int4`'s documented reassociation tolerance, with the
//! scalar backend bit-identical to the axpy form by construction.
//!
//! Paging adds two more free dimensions to the contract: **page size**
//! ([`SchedCfg::page`], CI-forced via `QES_PAGE`) is pure memory layout —
//! KV rows live at the same logical positions whatever the page
//! geometry — and a **prefix-cache hit** is bit-identical to cold
//! priming, because arena rows are stored at LOGICAL positions (prompt
//! token `j` at row `j`, no pad rows), which makes a causal prefix row's
//! content independent of anything after it; the warm path
//! (`native::forward_suffix`) recomputes only the suffix with the exact
//! cold op sequence (see its bit-identity note for why dropping the
//! padded attention terms is exact, and why W8A8 — whose activation
//! grids are per-call — has the cache forced off).
//!
//! # Cross-member grouping: the population as one batch
//!
//! ES rollout evaluates a whole population of members that differ from
//! the shared base snapshot only by seeded perturbations. The grouped
//! path ([`Scheduler::new_grouped`], [`rollout_round_grouped`]) resolves
//! EVERY member against one snapshot view in ONE pass and tags each
//! slot with its member id: prefill and decode then run ONE grouped
//! GEMM per layer per step across the whole population
//! (`gemm::matmul_grouped_with` — each row computed under its own
//! member's weights in the identical K-order op sequence), instead of
//! one scheduler, one resolve and 6 GEMM calls per layer per step PER
//! MEMBER. Results are bit-identical to the per-member sequential
//! rollout — grouping is the contracted training form, so it always
//! stays on the axpy decode (the K-major reassociating pack remains
//! serving-only). [`SchedStats::resolves`] counts resolve+pack passes:
//! exactly 1 per scheduler lifetime, i.e. 1 per grouped ROUND versus
//! one per member per round on the sequential path.
//!
//! One resolve+pack per member serves a whole generation round, and the
//! weight-tied-head transpose can be shared across members/rounds
//! ([`crate::runtime::native::build_emb_t`]): `tok_emb` never changes
//! during ES fine-tuning. `GenWorkload` routes rollout and greedy eval
//! through [`rollout_round`]/[`greedy_texts`] (grouped rounds through
//! [`rollout_round_grouped`]); `qes serve` ([`serve`]) drives the same
//! engine over line-delimited JSON.

pub mod arena;
pub mod http;
pub mod mux;
pub mod serve;

use std::collections::{BTreeMap, VecDeque};

use anyhow::{Context, Result};

use crate::kernel::{self, DotKernel, KernelKind};
use crate::model::ParamsView;
use crate::obs;
use crate::quant::Format;
use crate::rng::SplitMix64;
use crate::runtime::encode::GenBatch;
use crate::runtime::native::{self, gemm, NativeBackend, NativeParams};
use crate::runtime::ModelConfig;
use crate::tasks::tokenizer;

pub use arena::KvArena;

/// Salt separating per-request decode-sampling streams from every other
/// consumer of the RNG substrate.
const REQ_GUMBEL_SALT: u64 = 0x7363_6865_645f_6774;
/// Odd multiplier decorrelating (request, step) stream seeds.
const STEP_MIX: u64 = 0x9e37_79b9_7f4a_7c15;
const EOS_TOK: i32 = tokenizer::EOS as i32;

/// Stock KV page granularity (rows per page): 16 rows keeps per-page
/// bytes small enough that short sequences strand little capacity while
/// the page-table walk stays a cheap shift-free index per row.
pub const DEFAULT_PAGE_ROWS: usize = 16;

/// Resolve the `QES_PAGE` env knob into the [`SchedCfg::page`] value the
/// stock configs start from: unset → [`DEFAULT_PAGE_ROWS`], an integer →
/// that many rows per page, `full`/`0` → one page spanning the whole
/// slot (the dense-equivalent layout; resolved to `s_max` at build
/// time). Results are invariant to this knob — it is how CI forces the
/// page-size matrix over the whole test surface, mirroring
/// `QES_KERNEL`/`QES_GROUPED`.
pub fn default_page_rows() -> usize {
    match std::env::var("QES_PAGE") {
        Ok(v) => {
            let v = v.trim().to_string();
            if v.eq_ignore_ascii_case("full") {
                0
            } else {
                v.parse::<usize>().unwrap_or(DEFAULT_PAGE_ROWS)
            }
        }
        Err(_) => DEFAULT_PAGE_ROWS,
    }
}

/// One generation request: prompt tokens plus its decode policy.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u8>,
    /// Decode budget; generation also stops at the first EOS token.
    pub max_new: usize,
    /// Sampling temperature (0 = greedy regardless of `seed`).
    pub tau: f32,
    /// Per-request decode-sampling stream (rollout passes the member's
    /// seed-override here). `None` decodes greedily.
    pub seed: Option<u64>,
}

impl GenRequest {
    /// Greedy request from prompt text (panics on out-of-vocabulary
    /// chars; serving front ends use `tokenizer::try_encode` first).
    pub fn greedy(prompt: &str, max_new: usize) -> GenRequest {
        GenRequest { prompt: tokenizer::encode(prompt), max_new, tau: 0.0, seed: None }
    }
}

/// Handle for one submitted request; redeem with [`Scheduler::take`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct GenTicket(usize);

impl GenTicket {
    pub fn index(&self) -> usize {
        self.0
    }
}

/// A finished generation: raw emitted tokens (EOS included when one was
/// emitted) and the decoded text up to EOS.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub tokens: Vec<i32>,
    pub text: String,
    /// KV rows adopted from the shared-prefix cache at admission
    /// (0 = cold-primed). Observability only: hits are bit-identical to
    /// cold priming, so this never affects `tokens`/`text`.
    pub cached: usize,
}

/// Scheduler geometry + execution knobs. Results are invariant to
/// `slots` and `threads` (the batch-invariance contract); they are pure
/// memory/wall-clock tuning.
#[derive(Debug, Clone)]
pub struct SchedCfg {
    /// KV arena slots = maximum simultaneously live sequences.
    pub slots: usize,
    /// Prompt budget: prompts are left-padded to this width (the fixed
    /// geometry that makes per-sequence prefill grouping-invariant).
    pub s_prompt: usize,
    /// Per-sequence decode budget; arena rows per slot = s_prompt + t_max.
    pub t_max: usize,
    /// GEMM thread fan-out.
    pub threads: usize,
    /// Route decode GEMMs through the K-major transposed pack (INT4
    /// only). Off = the axpy form, bit-identical across kernel backends.
    pub kmajor: bool,
    /// Pin the microkernel backend (None = the process-wide dispatch).
    pub kernel: Option<KernelKind>,
    /// KV page granularity in rows. Pages materialize on demand as a
    /// sequence grows, so arena bytes track occupancy instead of
    /// `slots × s_max`; `0` = one page spanning the whole slot (the
    /// dense-equivalent layout, resolved to `s_max` at build time).
    /// Results are invariant to this knob — the paging dimension of the
    /// batch-invariance contract.
    pub page: usize,
    /// Shared-prefix cache capacity in entries (`0` = off): primed
    /// prompts pin their full KV pages for SAME-MEMBER reuse, refcounted
    /// read-only, copy-on-write-forked at the divergence point. Hits are
    /// bit-identical to cold priming, so this too is pure wall-clock
    /// tuning (forced off for W8A8, whose per-call activation grids
    /// break the per-row independence the identity needs).
    pub prefix_cache: usize,
}

impl SchedCfg {
    /// Model-shaped defaults: `b_gen` slots, the model's prompt/decode
    /// widths, single-threaded GEMMs, K-major decode on.
    pub fn for_model(mcfg: &ModelConfig) -> SchedCfg {
        SchedCfg {
            slots: mcfg.b_gen,
            s_prompt: mcfg.s_prompt,
            t_max: mcfg.t_dec,
            threads: 1,
            kmajor: true,
            kernel: None,
            page: default_page_rows(),
            prefix_cache: 32,
        }
    }

    /// Round-shaped geometry for the grouped rollout: enough slots to
    /// keep the WHOLE population resident (`b_gen` per member — the
    /// point of grouping is that every member's rows ride the same
    /// weight pass), axpy decode (the training contract; grouped
    /// schedulers force this off anyway), single-threaded GEMMs.
    pub fn for_round(mcfg: &ModelConfig, members: usize) -> SchedCfg {
        // prefix caching stays OFF on the training path: bit-identity
        // holds regardless, but training rollouts keep the exact
        // submitted-work shape so perf deltas never masquerade as
        // training effects
        SchedCfg {
            slots: mcfg.b_gen * members.max(1),
            kmajor: false,
            prefix_cache: 0,
            ..SchedCfg::for_model(mcfg)
        }
    }
}

/// Run telemetry (tests use `max_live` to prove exhaustion queues and
/// `resolves` to pin the one-resolve-per-round invariant).
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub steps: u64,
    pub prefill_rows: u64,
    pub decode_rows: u64,
    pub retired: u64,
    pub max_live: usize,
    /// Resolve+pack passes over the snapshot performed for this
    /// scheduler: always exactly 1 (paid at construction). A grouped
    /// round therefore costs 1 TOTAL, where the per-member sequential
    /// round costs one per member (one scheduler each).
    pub resolves: u64,
    /// Population members this scheduler serves (1 = single-member).
    pub members: usize,
    /// Most KV pages ever simultaneously in use (occupancy high-water;
    /// resident KV bytes ≈ this × [`KvArena::bytes_per_page`]).
    pub pages_high_water: usize,
    /// Prefill admissions that adopted cached prefix pages.
    pub prefix_hits: u64,
    /// Prefill admissions that found no reusable prefix (cache enabled).
    pub prefix_misses: u64,
    /// Copy-on-write page forks (first write into a still-shared page).
    pub cow_forks: u64,
}

/// A request accepted but not yet admitted into an arena slot.
struct Waiting {
    ticket: usize,
    member: usize,
    req: GenRequest,
    /// Serving-plane connection tag (None on direct/training submits) —
    /// carried into trace spans only, never into compute.
    conn: Option<u64>,
    /// Submit timestamp for the queued-phase span (0 when tracing off).
    t_submit_ns: u64,
}

/// A sequence currently occupying an arena slot.
struct Live {
    ticket: usize,
    slot: usize,
    /// Index into the scheduler's resolved member set (0 on the
    /// single-member path): which member's weights this sequence runs
    /// under.
    member: usize,
    /// See [`Waiting::conn`].
    conn: Option<u64>,
    /// Admission timestamp for the retired-phase span (0 = tracing off).
    t_admit_ns: u64,
    prompt: Vec<u8>,
    max_new: usize,
    tau: f32,
    seed: Option<u64>,
    /// KV rows adopted from the prefix cache at admission (0 = cold).
    cached: usize,
    /// Tokens emitted so far.
    tokens: Vec<i32>,
    /// Next-token logits for the position fed last (prefill's final row,
    /// then each decode step's head output).
    logits: Vec<f32>,
}

/// Per-step batch buffers, reused across steps (capacity sticks).
#[derive(Default)]
struct StepScratch {
    h: Vec<f32>,
    x: Vec<f32>,
    qb: Vec<f32>,
    kb: Vec<f32>,
    vb: Vec<f32>,
    ab: Vec<f32>,
    pj: Vec<f32>,
    ff: Vec<f32>,
    ff2: Vec<f32>,
    logits: Vec<f32>,
    att: Vec<f32>,
}

fn resize(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// The continuous-batching engine. Borrows one resolved model per member
/// (ONE on the classic path, the whole population on the grouped path)
/// for its lifetime; submit any number of requests against it.
pub struct Scheduler<'v> {
    mcfg: ModelConfig,
    scfg: SchedCfg,
    kr: &'static dyn DotKernel,
    /// Resolved member models. `ps[0]` additionally provides the shared
    /// fp32 tensors (embeddings, layernorms, head operand) — identical
    /// store slices for every member by construction.
    ps: Vec<NativeParams<'v>>,
    arena: KvArena,
    waiting: VecDeque<Waiting>,
    live: Vec<Live>,
    done: BTreeMap<usize, GenOutput>,
    next_ticket: usize,
    stats: SchedStats,
    scratch: StepScratch,
}

impl<'v> Scheduler<'v> {
    /// Resolve `view` (+ optional member overrides, optional shared head
    /// transpose) once and build the arena. The resolve+pack cost is paid
    /// here, then amortized over every request this scheduler serves.
    pub fn new(
        backend: &NativeBackend,
        view: &ParamsView<'v>,
        overrides: Option<&'v [Vec<i8>]>,
        emb_t: Option<&'v [f32]>,
        mut scfg: SchedCfg,
    ) -> Result<Scheduler<'v>> {
        Self::check_geometry(&scfg)?;
        let mcfg = backend.cfg().clone();
        let kr = match scfg.kernel {
            Some(kind) => kernel::by_kind(kind),
            None => kernel::active_kernel(),
        };
        // W8A8 quantizes ACTIVATIONS on a per-call grid (absmax over all
        // rows of the call — gemm::quantize_act), so a row's bits depend
        // on what it was batched with and a cached prefix row could
        // differ from its cold recompute. Every other format reads each
        // row independently; for W8A8 the cache is simply off.
        if backend.format() == Format::W8A8 {
            scfg.prefix_cache = 0;
        }
        // The K-major pack pays off where dot_packed_int4 is the 8-lane
        // FMA reduction (vector backends). On the scalar backend that dot
        // IS the sequential axpy op sequence — identical bits, slower
        // per-element nibble access — so skip the pack there. Pure
        // wall-clock tuning, like thread counts.
        let kmajor = scfg.kmajor
            && backend.format() == Format::Int4
            && kr.kind() != KernelKind::Scalar;
        let t0 = if obs::trace_enabled() { obs::now_ns() } else { 0 };
        let p = backend.resolve_params(view, overrides, emb_t, kmajor)?;
        if obs::trace_enabled() {
            obs::record_span(obs::Span {
                request: 0,
                conn: None,
                member: None,
                phase: obs::Phase::Resolve,
                t_start_ns: t0,
                t_end_ns: obs::now_ns(),
                tokens: 1,
            });
        }
        Self::build(mcfg, scfg, kr, vec![p])
    }

    /// The grouped-population scheduler: ONE resolve pass serves every
    /// member of the round, and every submitted request carries a member
    /// id ([`Scheduler::submit_member`]) naming the weight set its rows
    /// run under. Always uses the axpy decode form regardless of
    /// `scfg.kmajor` — grouping is the contracted training path, and the
    /// reassociating K-major pack stays serving-only.
    pub fn new_grouped(
        backend: &NativeBackend,
        view: &ParamsView<'v>,
        member_overrides: &'v [Vec<Vec<i8>>],
        emb_t: Option<&'v [f32]>,
        mut scfg: SchedCfg,
    ) -> Result<Scheduler<'v>> {
        Self::check_geometry(&scfg)?;
        anyhow::ensure!(!member_overrides.is_empty(), "grouped scheduler: zero members");
        let mcfg = backend.cfg().clone();
        let kr = match scfg.kernel {
            Some(kind) => kernel::by_kind(kind),
            None => kernel::active_kernel(),
        };
        scfg.kmajor = false;
        // same W8A8 gating as `Scheduler::new` (see the note there)
        if backend.format() == Format::W8A8 {
            scfg.prefix_cache = 0;
        }
        let t0 = if obs::trace_enabled() { obs::now_ns() } else { 0 };
        let ps = backend.resolve_params_grouped(view, member_overrides, emb_t)?;
        if obs::trace_enabled() {
            obs::record_span(obs::Span {
                request: 0,
                conn: None,
                member: None,
                phase: obs::Phase::Resolve,
                t_start_ns: t0,
                t_end_ns: obs::now_ns(),
                tokens: ps.len() as u64,
            });
        }
        Self::build(mcfg, scfg, kr, ps)
    }

    fn check_geometry(scfg: &SchedCfg) -> Result<()> {
        anyhow::ensure!(scfg.slots > 0, "scheduler needs at least one KV slot");
        anyhow::ensure!(scfg.t_max > 0 && scfg.s_prompt > 0, "degenerate scheduler geometry");
        Ok(())
    }

    fn build(
        mcfg: ModelConfig,
        mut scfg: SchedCfg,
        kr: &'static dyn DotKernel,
        ps: Vec<NativeParams<'v>>,
    ) -> Result<Scheduler<'v>> {
        let d = mcfg.d_model;
        let max_pos = ps[0].pos_emb.len() / d;
        anyhow::ensure!(
            scfg.s_prompt + scfg.t_max <= max_pos,
            "arena rows {} + {} exceed the model's {} positions",
            scfg.s_prompt,
            scfg.t_max,
            max_pos
        );
        let s_max = scfg.s_prompt + scfg.t_max;
        // resolve the page knob: 0 = one dense-equivalent page per slot
        scfg.page = if scfg.page == 0 { s_max } else { scfg.page.min(s_max) };
        let arena =
            KvArena::new(mcfg.n_layers, scfg.slots, s_max, d, scfg.page, scfg.prefix_cache);
        // the ONE resolve+pack pass this scheduler will ever perform
        // happened in the constructor, serving all `ps.len()` members
        let stats = SchedStats { resolves: 1, members: ps.len(), ..SchedStats::default() };
        obs::m().sched_resolves.inc();
        obs::m().sched_slots.set(scfg.slots as u64);
        Ok(Scheduler {
            mcfg,
            scfg,
            kr,
            ps,
            arena,
            waiting: VecDeque::new(),
            live: Vec::new(),
            done: BTreeMap::new(),
            next_ticket: 0,
            stats,
            scratch: StepScratch::default(),
        })
    }

    pub fn cfg(&self) -> &SchedCfg {
        &self.scfg
    }

    pub fn stats(&self) -> &SchedStats {
        &self.stats
    }

    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    /// Nothing in flight and nothing waiting.
    pub fn idle(&self) -> bool {
        self.live.is_empty() && self.waiting.is_empty()
    }

    /// Queue a request. Oversized prompts/budgets error here (the serving
    /// front end maps that to an error response); a full arena does NOT —
    /// the request waits for a recycled slot.
    pub fn submit(&mut self, req: GenRequest) -> Result<GenTicket> {
        self.submit_member(0, req)
    }

    /// [`Scheduler::submit`] against a specific member's weights (grouped
    /// schedulers; member 0 is the only valid id on the classic path).
    pub fn submit_member(&mut self, member: usize, req: GenRequest) -> Result<GenTicket> {
        self.submit_from(member, req, None)
    }

    /// [`Scheduler::submit_member`] with a serving-plane connection tag.
    /// The tag feeds trace spans only — it never influences scheduling,
    /// batching, or numerics (which connection a request arrives on is a
    /// free dimension of the batch-invariance contract).
    pub fn submit_from(
        &mut self,
        member: usize,
        req: GenRequest,
        conn: Option<u64>,
    ) -> Result<GenTicket> {
        anyhow::ensure!(
            member < self.ps.len(),
            "member {} out of range for a {}-member scheduler",
            member,
            self.ps.len()
        );
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            req.prompt.len() <= self.scfg.s_prompt,
            "prompt of {} tokens exceeds the {}-token budget",
            req.prompt.len(),
            self.scfg.s_prompt
        );
        anyhow::ensure!(
            req.max_new <= self.scfg.t_max,
            "max_new {} exceeds the decode budget {}",
            req.max_new,
            self.scfg.t_max
        );
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        if req.max_new == 0 {
            self.done
                .insert(ticket, GenOutput { tokens: Vec::new(), text: String::new(), cached: 0 });
        } else {
            let t_submit_ns = if obs::trace_enabled() { obs::now_ns() } else { 0 };
            self.waiting.push_back(Waiting { ticket, member, req, conn, t_submit_ns });
        }
        Ok(GenTicket(ticket))
    }

    /// One scheduler iteration: admit → batched prefill (new slots) →
    /// sample + retire (recycling slots without draining the batch) →
    /// one batched decode across all survivors. Returns `false` once
    /// idle.
    pub fn step(&mut self) -> Result<bool> {
        if self.idle() {
            return Ok(false);
        }
        self.stats.steps += 1;
        let mm = obs::m();
        mm.sched_steps.inc();
        let trace = obs::trace_enabled();
        // --- admit waiting requests into free slots ---
        let mut newly: Vec<usize> = Vec::new();
        while !self.waiting.is_empty() {
            let Some(slot) = self.arena.alloc() else { break };
            let w = self.waiting.pop_front().expect("nonempty queue");
            let t_admit_ns = if trace {
                let t = obs::now_ns();
                obs::record_span(obs::Span {
                    request: w.ticket as u64,
                    conn: w.conn,
                    member: Some(w.member as u64),
                    phase: obs::Phase::Queued,
                    t_start_ns: w.t_submit_ns,
                    t_end_ns: t,
                    tokens: 0,
                });
                obs::record_span(obs::Span {
                    request: w.ticket as u64,
                    conn: w.conn,
                    member: Some(w.member as u64),
                    phase: obs::Phase::Admitted,
                    t_start_ns: t,
                    t_end_ns: t,
                    tokens: w.req.prompt.len() as u64,
                });
                t
            } else {
                0
            };
            self.live.push(Live {
                ticket: w.ticket,
                slot,
                member: w.member,
                conn: w.conn,
                t_admit_ns,
                prompt: w.req.prompt,
                max_new: w.req.max_new,
                tau: w.req.tau,
                seed: w.req.seed,
                cached: 0,
                tokens: Vec::new(),
                logits: vec![0.0f32; self.mcfg.vocab],
            });
            newly.push(self.live.len() - 1);
        }
        self.stats.max_live = self.stats.max_live.max(self.live.len());
        mm.sched_max_live.max(self.live.len() as u64);
        // --- one batched prefill over the newly admitted ---
        if !newly.is_empty() {
            let t0 = if trace { obs::now_ns() } else { 0 };
            let rows0 = self.stats.prefill_rows;
            self.prefill(&newly);
            mm.sched_prefill_rows.add(self.stats.prefill_rows - rows0);
            if trace {
                obs::record_span(obs::Span {
                    request: self.stats.steps,
                    conn: None,
                    member: None,
                    phase: obs::Phase::Prefill,
                    t_start_ns: t0,
                    t_end_ns: obs::now_ns(),
                    tokens: self.stats.prefill_rows - rows0,
                });
            }
        }
        // --- sample one token per live sequence; retire finished ---
        let mut emitted = 0u64;
        let mut i = 0;
        while i < self.live.len() {
            let lv = &mut self.live[i];
            let tok = next_token(lv);
            lv.tokens.push(tok);
            emitted += 1;
            if tok == EOS_TOK || lv.tokens.len() >= lv.max_new {
                let lv = self.live.swap_remove(i);
                self.arena.release(lv.slot);
                self.stats.retired += 1;
                mm.sched_retired.inc();
                if trace {
                    obs::record_span(obs::Span {
                        request: lv.ticket as u64,
                        conn: lv.conn,
                        member: Some(lv.member as u64),
                        phase: obs::Phase::Retired,
                        t_start_ns: lv.t_admit_ns,
                        t_end_ns: obs::now_ns(),
                        tokens: lv.tokens.len() as u64,
                    });
                }
                self.done.insert(
                    lv.ticket,
                    GenOutput {
                        text: tokenizer::decode_to_eos(&lv.tokens),
                        cached: lv.cached,
                        tokens: lv.tokens,
                    },
                );
            } else {
                i += 1;
            }
        }
        mm.sched_tokens.add(emitted);
        // --- one batched decode across all survivors ---
        if !self.live.is_empty() {
            let t0 = if trace { obs::now_ns() } else { 0 };
            let rows = self.live.len() as u64;
            self.decode_step();
            mm.sched_decode_rows.add(rows);
            if trace {
                obs::record_span(obs::Span {
                    request: self.stats.steps,
                    conn: None,
                    member: None,
                    phase: obs::Phase::DecodeStep,
                    t_start_ns: t0,
                    t_end_ns: obs::now_ns(),
                    tokens: rows,
                });
            }
        }
        self.sync_kv_stats();
        Ok(true)
    }

    /// Mirror the arena's paging/prefix counters into the stats block so
    /// `stats()` is current after every step, and feed the increments
    /// into the global registry ([`crate::obs`]). Registry mirroring is
    /// delta-based against the last synced value, so the call is
    /// idempotent — the `Drop` impl runs it once more to catch anything
    /// accrued since the final step without double counting.
    fn sync_kv_stats(&mut self) {
        let mm = obs::m();
        let (ph, h, mi, f) = (
            self.arena.pages_high_water(),
            self.arena.prefix_hits(),
            self.arena.prefix_misses(),
            self.arena.cow_forks(),
        );
        mm.kv_pages_high_water.max(ph as u64);
        mm.kv_prefix_hits.add(h - self.stats.prefix_hits);
        mm.kv_prefix_misses.add(mi - self.stats.prefix_misses);
        mm.kv_cow_forks.add(f - self.stats.cow_forks);
        self.stats.pages_high_water = ph;
        self.stats.prefix_hits = h;
        self.stats.prefix_misses = mi;
        self.stats.cow_forks = f;
    }

    /// Drive [`Scheduler::step`] until idle.
    pub fn run(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Redeem a finished ticket (None until its sequence completes).
    pub fn take(&mut self, ticket: GenTicket) -> Option<GenOutput> {
        self.done.remove(&ticket.0)
    }

    /// Remove and return every finished generation, in ticket order.
    pub fn drain_finished(&mut self) -> Vec<(GenTicket, GenOutput)> {
        std::mem::take(&mut self.done).into_iter().map(|(t, o)| (GenTicket(t), o)).collect()
    }

    /// Cancel a still-queued request that has never been admitted into a
    /// slot. Returns `true` if the ticket was waiting and is now gone;
    /// `false` if it is unknown, already in flight, or already finished —
    /// those are deliberately left untouched (the serving mux cancels a
    /// closed connection's queue without disturbing in-flight slots).
    pub fn cancel_waiting(&mut self, ticket: GenTicket) -> bool {
        if let Some(pos) = self.waiting.iter().position(|w| w.ticket == ticket.0) {
            self.waiting.remove(pos);
            true
        } else {
            false
        }
    }

    /// Accepted requests not yet completed (in flight + queued) — the
    /// quantity admission control bounds with its global in-flight cap.
    pub fn pending(&self) -> usize {
        self.live.len() + self.waiting.len()
    }

    /// Batched full-sequence prefill for the newly admitted sequences.
    ///
    /// Each sequence first tries the arena's prefix cache
    /// ([`KvArena::adopt_prefix`] — SAME member only; perturbed members
    /// never share KV). Misses are left-padded to the fixed `s_prompt`
    /// width (the geometry that makes per-sequence results independent
    /// of the grouping) and run through ONE batched forward — across ALL
    /// members at once on the grouped path; hits run a per-sequence
    /// `native::forward_suffix` that computes ONLY the rows past the
    /// adopted prefix, attending to the cached pages through the page
    /// table. Either way the arena receives REAL rows only, at their
    /// LOGICAL positions (pad rows are never stored — their attention
    /// terms are exact zeros, see `forward_suffix`'s bit-identity note),
    /// and every newly primed prompt is then published back to the
    /// cache. Adoption is bit-identical to cold priming, so the cache is
    /// pure wall-clock tuning.
    fn prefill(&mut self, newly: &[usize]) {
        let Scheduler { mcfg, scfg, kr, ps, arena, live, stats, scratch, .. } = self;
        let kr = *kr;
        let sp = scfg.s_prompt;
        let d = mcfg.d_model;
        let v = mcfg.vocab;
        // split the admission wave: cold (batched full prefill) vs warm
        // (adopted a cached prefix; suffix-only prefill)
        let mut cold: Vec<usize> = Vec::new();
        let mut warm: Vec<(usize, usize)> = Vec::new();
        for &li in newly {
            let lv = &live[li];
            let lc = arena.adopt_prefix(lv.slot, lv.member, &lv.prompt);
            live[li].cached = lc;
            if lc == 0 {
                cold.push(li);
            } else {
                warm.push((li, lc));
            }
        }
        if !cold.is_empty() {
            let b = cold.len();
            let mut tokens = vec![tokenizer::PAD as i32; b * sp];
            let mut pos_ids = vec![0i32; b * sp];
            let mut mask = vec![0.0f32; b * sp];
            for (i, &li) in cold.iter().enumerate() {
                let lv = &live[li];
                let pad = sp - lv.prompt.len();
                for (j, &t) in lv.prompt.iter().enumerate() {
                    tokens[i * sp + pad + j] = t as i32;
                    pos_ids[i * sp + pad + j] = j as i32;
                    mask[i * sp + pad + j] = 1.0;
                }
            }
            let fw = if ps.len() == 1 {
                native::forward_full(
                    mcfg,
                    scfg.threads,
                    kr,
                    &ps[0],
                    &tokens,
                    &pos_ids,
                    &mask,
                    b,
                    sp,
                    true,
                    None,
                )
            } else {
                // ONE member-grouped prefill: each admitted sequence's
                // rows run under its own member's weights in the same pass
                let assign: Vec<usize> = cold.iter().map(|&li| live[li].member).collect();
                native::forward_full_grouped(
                    mcfg,
                    scfg.threads,
                    kr,
                    ps,
                    &assign,
                    &tokens,
                    &pos_ids,
                    &mask,
                    b,
                    sp,
                    true,
                )
            };
            for (i, &li) in cold.iter().enumerate() {
                let (slot, len) = (live[li].slot, live[li].prompt.len());
                let pad = sp - len;
                // store REAL rows only, at LOGICAL positions: row j holds
                // prompt token j whatever the padded batch geometry was,
                // which is exactly what makes the row shareable with
                // later prompts of different lengths
                for (layer, (kf, vf)) in fw.kvs.iter().enumerate() {
                    for j in 0..len {
                        let src = (i * sp + pad + j) * d;
                        arena.write_kv(layer, slot, j, &kf[src..src + d], &vf[src..src + d]);
                    }
                }
            }
            let rows: Vec<usize> = (0..b).map(|i| i * sp + sp - 1).collect();
            resize(&mut scratch.logits, b * v);
            // the weight-tied head is fp32 and shared across members
            native::head_rows(mcfg, scfg.threads, kr, &ps[0], &fw.h, &rows, &mut scratch.logits);
            for (i, &li) in cold.iter().enumerate() {
                live[li].logits.copy_from_slice(&scratch.logits[i * v..(i + 1) * v]);
            }
            stats.prefill_rows += (b * sp) as u64;
        }
        // warm sequences: per-sequence suffix forward over just the rows
        // past the adopted prefix — the compute the cache saved
        for &(li, lc) in &warm {
            let (slot, member, plen) = {
                let lv = &live[li];
                (lv.slot, lv.member, lv.prompt.len())
            };
            let prefix: Vec<native::PrefixKv<'_>> = (0..mcfg.n_layers)
                .map(|l| native::PrefixKv {
                    k: arena.k_slab(l),
                    v: arena.v_slab(l),
                    table: arena.table_of(slot),
                    page: arena.page(),
                    len: lc,
                })
                .collect();
            let sf = native::forward_suffix(
                mcfg,
                scfg.threads,
                kr,
                &ps[member],
                &live[li].prompt,
                lc,
                &prefix,
            );
            drop(prefix);
            for (layer, (kf, vf)) in sf.kvs.iter().enumerate() {
                for (r, pos) in (lc..plen).enumerate() {
                    let src = r * d;
                    arena.write_kv(layer, slot, pos, &kf[src..src + d], &vf[src..src + d]);
                }
            }
            resize(&mut scratch.logits, v);
            let last = [plen - lc - 1];
            native::head_rows(mcfg, scfg.threads, kr, &ps[0], &sf.h, &last, &mut scratch.logits);
            live[li].logits.copy_from_slice(&scratch.logits[..v]);
            stats.prefill_rows += (plen - lc) as u64;
        }
        // publish every newly primed prompt (full pages only; identical
        // entries dedupe inside the arena) so later admissions can adopt
        for &li in newly {
            let lv = &live[li];
            arena.publish_prefix(lv.slot, lv.member, &lv.prompt);
        }
    }

    /// One decode forward over all live sequences: one batched GEMM per
    /// linear layer with M = live slots (K-major for INT4 on the
    /// single-member serving path; member-grouped axpy on the population
    /// path — ONE weight-stream pass per layer per step serving every
    /// member), per-slot attention against the arena, one batched head.
    fn decode_step(&mut self) {
        let Scheduler { mcfg, scfg, kr, ps, arena, live, stats, scratch, .. } = self;
        let kr = *kr;
        let m = live.len();
        let d = mcfg.d_model;
        let v = mcfg.vocab;
        let heads = mcfg.n_heads;
        let dh = d / heads;
        let threads = scfg.threads;
        let grouped = ps.len() > 1;
        let assign: Vec<usize> =
            if grouped { live.iter().map(|lv| lv.member).collect() } else { Vec::new() };
        resize(&mut scratch.h, m * d);
        resize(&mut scratch.x, m * d);
        resize(&mut scratch.qb, m * d);
        resize(&mut scratch.kb, m * d);
        resize(&mut scratch.vb, m * d);
        resize(&mut scratch.ab, m * d);
        resize(&mut scratch.pj, m * d);
        resize(&mut scratch.ff, m * mcfg.d_ff);
        resize(&mut scratch.ff2, m * d);
        resize(&mut scratch.logits, m * v);
        resize(&mut scratch.att, arena.s_max());
        // embed the token each sequence just emitted, at its own position
        // (embeddings are fp32 and shared across members)
        let p0 = &ps[0];
        for (i, lv) in live.iter().enumerate() {
            let tok = *lv.tokens.last().expect("decode_step after sampling") as usize;
            let pos = lv.prompt.len() + lv.tokens.len() - 1;
            for j in 0..d {
                scratch.h[i * d + j] = p0.tok_emb[tok * d + j] + p0.pos_emb[pos * d + j];
            }
        }
        for layer_i in 0..p0.layers.len() {
            // single-member: K-major-capable decode GEMM, untouched.
            // grouped: ONE pass over each matrix's member set, every row
            // under its own member's weights (contracted axpy op order).
            macro_rules! mm {
                ($field:ident, $x:expr, $out:expr) => {{
                    if grouped {
                        let lins: Vec<&gemm::Lin> =
                            ps.iter().map(|p| &p.layers[layer_i].$field).collect();
                        gemm::matmul_grouped_with($x, m, &lins, &assign, $out, threads, kr);
                    } else {
                        gemm::matmul_decode($x, m, &ps[0].layers[layer_i].$field, $out, threads, kr);
                    }
                }};
            }
            let layer = &ps[0].layers[layer_i];
            native::layernorm(&scratch.h, d, layer.ln1_g, layer.ln1_b, &mut scratch.x);
            mm!(wq, &scratch.x, &mut scratch.qb);
            mm!(wk, &scratch.x, &mut scratch.kb);
            mm!(wv, &scratch.x, &mut scratch.vb);
            for (i, lv) in live.iter().enumerate() {
                // LOGICAL position: decode rows continue directly after
                // the prompt rows, whatever the page geometry
                let pos = lv.prompt.len() + lv.tokens.len() - 1;
                arena.write_kv(
                    layer_i,
                    lv.slot,
                    pos,
                    &scratch.kb[i * d..(i + 1) * d],
                    &scratch.vb[i * d..(i + 1) * d],
                );
            }
            attend_arena(
                arena,
                live,
                heads,
                dh,
                layer_i,
                &scratch.qb,
                &mut scratch.att,
                &mut scratch.ab,
            );
            mm!(wo, &scratch.ab, &mut scratch.pj);
            for i in 0..m * d {
                scratch.h[i] += scratch.pj[i];
            }
            native::layernorm(&scratch.h, d, layer.ln2_g, layer.ln2_b, &mut scratch.x);
            mm!(w1, &scratch.x, &mut scratch.ff);
            for fv in scratch.ff.iter_mut() {
                *fv = native::gelu(*fv);
            }
            mm!(w2, &scratch.ff, &mut scratch.ff2);
            for i in 0..m * d {
                scratch.h[i] += scratch.ff2[i];
            }
        }
        let rows: Vec<usize> = (0..m).collect();
        native::head_rows(mcfg, threads, kr, &ps[0], &scratch.h, &rows, &mut scratch.logits);
        for (i, lv) in live.iter_mut().enumerate() {
            lv.logits.copy_from_slice(&scratch.logits[i * v..(i + 1) * v]);
        }
        stats.decode_rows += m as u64;
    }
}

impl Drop for Scheduler<'_> {
    fn drop(&mut self) {
        // final delta-based mirror into the registry — idempotent, so a
        // scheduler that already synced on its last step adds nothing
        self.sync_kv_stats();
    }
}

/// Single-position attention for every live sequence against its own
/// slot's PAGED KV rows — the exact per-row op sequence of
/// `native::attend_decode`, walking the slot's page table over the
/// logical rows `0..st` the current occupant owns. There is no key mask
/// any more: pad rows are never stored (rows live at logical positions),
/// and stale rows in recycled pages are unreachable because the walk is
/// bounded by the occupant's own length (pinned at page granularity by
/// the arena's aliasing property tests). Dropping the old
/// NEG_INF-masked pad terms is bit-identical: a `-1e9`-biased logit
/// underflows to an exact `+0.0` softmax weight whose V-term cannot
/// change the accumulator (see `forward_suffix`'s bit-identity note).
#[allow(clippy::too_many_arguments)]
fn attend_arena(
    arena: &KvArena,
    live: &[Live],
    heads: usize,
    dh: usize,
    layer: usize,
    q: &[f32],
    logits: &mut [f32],
    out: &mut [f32],
) {
    let d = heads * dh;
    out.fill(0.0);
    let scale = 1.0 / (dh as f32).sqrt();
    let kc = arena.k_slab(layer);
    let vc = arena.v_slab(layer);
    let page = arena.page();
    for (i, lv) in live.iter().enumerate() {
        // logical rows 0..st belong to this occupant (last written at
        // st-1: the prompt rows plus one KV row per emitted token)
        let st = lv.prompt.len() + lv.tokens.len();
        let table = arena.table_of(lv.slot);
        for h in 0..heads {
            let qo = i * d + h * dh;
            for sk in 0..st {
                let ko = (table[sk / page] as usize * page + sk % page) * d + h * dh;
                let mut dot = 0.0f32;
                for j in 0..dh {
                    dot += q[qo + j] * kc[ko + j];
                }
                logits[sk] = dot * scale;
            }
            native::softmax_inplace(&mut logits[..st]);
            let oo = i * d + h * dh;
            for sk in 0..st {
                let w = logits[sk];
                let vo = (table[sk / page] as usize * page + sk % page) * d + h * dh;
                for j in 0..dh {
                    out[oo + j] += w * vc[vo + j];
                }
            }
        }
    }
}

/// Pick the next token from a sequence's logits: first-max argmax over
/// `logit + tau * gumbel`, with the gumbel stream keyed by (request
/// seed, step index) — never by slot or batch position, which is what
/// makes sampled decode admission-order-invariant.
fn next_token(lv: &Live) -> i32 {
    let step = lv.tokens.len() as u64;
    let mut grng = if lv.tau > 0.0 {
        lv.seed.map(|s| {
            SplitMix64::new(s ^ REQ_GUMBEL_SALT ^ step.wrapping_add(1).wrapping_mul(STEP_MIX))
        })
    } else {
        None
    };
    let mut best = 0usize;
    let mut bestv = f32::NEG_INFINITY;
    for (c, &l) in lv.logits.iter().enumerate() {
        let g = match &mut grng {
            Some(r) => r.gumbel(),
            None => 0.0,
        };
        let val = l + lv.tau * g;
        if val > bestv {
            bestv = val;
            best = c;
        }
    }
    best as i32
}

/// Submit a request list against one resolved model and run to
/// completion, returning outputs in request order.
pub fn run_requests<'v>(
    backend: &NativeBackend,
    view: &ParamsView<'v>,
    overrides: Option<&'v [Vec<i8>]>,
    emb_t: Option<&'v [f32]>,
    scfg: SchedCfg,
    reqs: Vec<GenRequest>,
) -> Result<Vec<GenOutput>> {
    let mut sched = Scheduler::new(backend, view, overrides, emb_t, scfg)?;
    let tickets: Vec<GenTicket> =
        reqs.into_iter().map(|r| sched.submit(r)).collect::<Result<_>>()?;
    sched.run()?;
    tickets.into_iter().map(|t| sched.take(t).context("scheduler lost a ticket")).collect()
}

/// One member's whole-round rollout through the scheduler: ONE
/// resolve+pack serves every batch, only REAL rows are submitted (no
/// padding-duplicate compute), and sequences retire at EOS instead of
/// burning the full decode budget. Returns completion strings grouped
/// per input batch.
pub fn rollout_round<'v>(
    backend: &NativeBackend,
    view: &ParamsView<'v>,
    overrides: Option<&'v [Vec<i8>]>,
    emb_t: Option<&'v [f32]>,
    batches: &[GenBatch],
    tau: f32,
    member_seed: Option<u64>,
) -> Result<Vec<Vec<String>>> {
    let mut scfg = SchedCfg::for_model(backend.cfg());
    // match the per-call generate() path's GEMM fan-out: pool workers set
    // 1 (they are the parallelism axis), the inline leader all cores
    scfg.threads = backend.gemm_threads();
    // TRAINING stays on the axpy decode form: fine-tuning results must be
    // bit-identical for any QES_KERNEL (the repo-wide contract — a
    // lattice evolved under AVX2 must re-materialize under scalar), and
    // only the axpy path is bit-exact across kernels. K-major decode
    // serves the serving path (`qes serve`), where the tolerance contract
    // is acceptable and wall-clock is king.
    scfg.kmajor = false;
    // training rollouts keep the exact submitted-work shape (same
    // rationale as SchedCfg::for_round): adoption is bit-identical
    // anyway, but the cache stays a serving/eval optimization
    scfg.prefix_cache = 0;
    let t_max = scfg.t_max;
    let mut reqs = Vec::new();
    let mut spans = Vec::with_capacity(batches.len());
    for (bi, batch) in batches.iter().enumerate() {
        spans.push(batch.n_real);
        for ri in 0..batch.n_real {
            reqs.push(GenRequest {
                prompt: tokenizer::encode(&batch.problems[ri].prompt),
                max_new: t_max,
                tau,
                seed: member_seed.map(|s| {
                    s ^ (((bi as u64) << 20) | ri as u64).wrapping_add(1).wrapping_mul(STEP_MIX)
                }),
            });
        }
    }
    let outs = run_requests(backend, view, overrides, emb_t, scfg, reqs)?;
    let mut it = outs.into_iter();
    Ok(spans.iter().map(|&n| it.by_ref().take(n).map(|o| o.text).collect()).collect())
}

/// A whole POPULATION's round rollout through one grouped scheduler:
/// ONE resolve pass and one weight-stream walk per layer per step serve
/// every member. `member_overrides[j]` / `member_seeds[j]` are member
/// `j`'s perturbed lattices and decode-sampling seed; returns
/// completions as `[member][batch][row]`.
///
/// Bit-identical to calling [`rollout_round`] once per member with the
/// same overrides/seed: per-request seeds use the identical formula, the
/// grouped GEMM preserves each row's per-element op sequence under its
/// own member's weights, and per-sequence results are batch-invariant —
/// so interleaving members changes nothing (enforced across member
/// counts × slots × threads × kernels by `tests/scheduler.rs`).
pub fn rollout_round_grouped<'v>(
    backend: &NativeBackend,
    view: &ParamsView<'v>,
    member_overrides: &'v [Vec<Vec<i8>>],
    emb_t: Option<&'v [f32]>,
    batches: &[GenBatch],
    tau: f32,
    member_seeds: &[Option<u64>],
) -> Result<Vec<Vec<Vec<String>>>> {
    let members = member_overrides.len();
    anyhow::ensure!(members > 0, "grouped rollout: zero members");
    anyhow::ensure!(
        member_seeds.len() == members,
        "grouped rollout: {} seeds for {} members",
        member_seeds.len(),
        members
    );
    let mut scfg = SchedCfg::for_round(backend.cfg(), members);
    scfg.threads = backend.gemm_threads();
    let t_max = scfg.t_max;
    let mut sched = Scheduler::new_grouped(backend, view, member_overrides, emb_t, scfg)?;
    let mut tickets = Vec::new();
    for (j, &seed) in member_seeds.iter().enumerate() {
        for (bi, batch) in batches.iter().enumerate() {
            for ri in 0..batch.n_real {
                // the same (member seed, batch, row) -> request-seed map
                // as rollout_round, so sampled decode draws the exact
                // same gumbel streams as the sequential path
                let req = GenRequest {
                    prompt: tokenizer::encode(&batch.problems[ri].prompt),
                    max_new: t_max,
                    tau,
                    seed: seed.map(|s| {
                        s ^ (((bi as u64) << 20) | ri as u64)
                            .wrapping_add(1)
                            .wrapping_mul(STEP_MIX)
                    }),
                };
                tickets.push(sched.submit_member(j, req)?);
            }
        }
    }
    sched.run()?;
    let mut it = tickets.into_iter();
    let mut out = Vec::with_capacity(members);
    for _ in 0..members {
        let mut per_batch = Vec::with_capacity(batches.len());
        for batch in batches {
            let mut texts = Vec::with_capacity(batch.n_real);
            for _ in 0..batch.n_real {
                let t = it.next().expect("ticket arithmetic is exact");
                texts.push(sched.take(t).context("scheduler lost a ticket")?.text);
            }
            per_batch.push(texts);
        }
        out.push(per_batch);
    }
    Ok(out)
}

/// Greedy completions for a prompt list (accuracy eval): the whole set
/// flows through one scheduler — one resolve+pack total, sequences
/// admitted as slots free up.
pub fn greedy_texts(
    backend: &NativeBackend,
    view: &ParamsView<'_>,
    prompts: &[&str],
) -> Result<Vec<String>> {
    let mut scfg = SchedCfg::for_model(backend.cfg());
    scfg.threads = backend.gemm_threads();
    // same rationale as rollout_round: eval accuracies must not move
    // with the dispatched kernel
    scfg.kmajor = false;
    let t_max = scfg.t_max;
    let reqs: Vec<GenRequest> =
        prompts.iter().map(|p| GenRequest::greedy(p, t_max)).collect();
    Ok(run_requests(backend, view, None, None, scfg, reqs)?
        .into_iter()
        .map(|o| o.text)
        .collect())
}
