//! Connection multiplexer — N concurrent clients, ONE scheduler.
//!
//! [`serve_loop`](crate::sched::serve::serve_loop) drives the scheduler
//! for a single connection; this module generalizes it to many. Every
//! connection gets a pump thread (reusing the
//! [`Intake`](crate::sched::serve::Intake) line discipline or the HTTP
//! reader in [`http`](crate::sched::http)) that tags its events with a
//! [`ConnId`] and sends them over ONE shared channel into [`mux_loop`],
//! which owns the scheduler on a single thread:
//!
//! ```text
//! conn 0 pump ─┐                       ┌─ writer 0 (owns write half)
//! conn 1 pump ─┼→ mpsc<MuxEvent> → mux ┼─ writer 1
//! conn 2 pump ─┘        │              └─ writer 2
//!                   Scheduler (one, shared, single-threaded)
//! ```
//!
//! The mux routes each finished generation back through a tagged
//! `(conn, request)` table the moment the sequence retires — admission
//! order never gates emission, and a slow connection never blocks
//! another's responses. Writer threads own the socket write halves and
//! receive framed bytes over per-connection channels; a writer dying
//! (broken pipe) surfaces to the mux as a send failure and tears the
//! connection down.
//!
//! # Batch invariance under multi-tenancy
//!
//! Every per-sequence result depends only on that sequence's request
//! (see the module docs on [`crate::sched`]), so greedy tokens are
//! bit-identical for any connection count × interleaving × admission
//! order — which connection a request arrived on is just one more free
//! dimension of the determinism contract. `tests/scheduler.rs` pins the
//! matrix.
//!
//! # Admission control / backpressure
//!
//! Two bounds, both shed with an explicit `"overloaded"` error response
//! (line protocol: `{"id":...,"error":"overloaded"}`; HTTP: `429`)
//! instead of stalling or crashing:
//!
//! * **global in-flight cap** ([`MuxCfg::max_inflight`]): requests
//!   pending in the scheduler (live slots + waiting queue) across ALL
//!   connections;
//! * **per-connection queue depth** ([`MuxCfg::conn_queue`]): one
//!   client cannot monopolize the waiting queue past its bound.
//!
//! # Teardown
//!
//! A half-closed connection (client sent EOF but keeps reading —
//! [`MuxIn::HalfClosed`]) stays registered until its last response
//! flushes. A dead connection ([`MuxIn::Gone`] from a pump read error,
//! or any writer failure) is torn down immediately: its
//! queued-but-unadmitted requests are cancelled
//! ([`Scheduler::cancel_waiting`]) without touching in-flight slots,
//! and any output that retires afterwards is dropped as orphaned.

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};

use anyhow::Result;

use crate::obs;
use crate::sched::http::{self, HttpReq};
use crate::sched::serve::{self, Intake};
use crate::sched::{GenOutput, GenTicket, Scheduler};

/// Connection identity — allocated by the accept loop, unique per
/// server lifetime.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ConnId(pub u64);

/// Response framing a connection speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proto {
    /// Line-delimited JSON (`qes serve` classic protocol). Responses
    /// are id-tagged and emitted the moment a sequence retires.
    Line,
    /// HTTP/1.1. Responses go back in request order per connection
    /// (pipelining discipline) — completed out of order, stashed until
    /// their turn.
    Http,
}

/// One event from a connection pump.
#[derive(Debug)]
pub enum MuxIn {
    /// Connection established: register its protocol and the channel
    /// feeding its writer thread.
    Open(Proto, Sender<Vec<u8>>),
    /// One request line (line protocol).
    Line(String),
    /// A line blew past the reader's cap (payload = the cap).
    Oversized(usize),
    /// One parsed HTTP request.
    Http(HttpReq),
    /// Unparseable HTTP on the wire: answer 400 and tear down.
    BadHttp(String),
    /// Clean read-side EOF: no more requests, but responses still flow;
    /// the mux closes the connection once nothing is outstanding.
    HalfClosed,
    /// Hard disconnect (read error): tear down now, cancelling this
    /// connection's queued-but-unadmitted requests.
    Gone,
}

/// A tagged event on the shared mux channel.
#[derive(Debug)]
pub struct MuxEvent {
    pub conn: ConnId,
    pub ev: MuxIn,
}

/// Mux policy knobs.
#[derive(Debug, Clone)]
pub struct MuxCfg {
    /// Global in-flight cap: shed when `Scheduler::pending()` reaches
    /// this (0 = unbounded).
    pub max_inflight: usize,
    /// Per-connection outstanding-request bound (0 = unbounded).
    pub conn_queue: usize,
    /// Model name echoed in OpenAI-compatible responses.
    pub model: String,
}

impl Default for MuxCfg {
    fn default() -> MuxCfg {
        MuxCfg { max_inflight: 0, conn_queue: 0, model: "qes".to_string() }
    }
}

/// Mux outcome counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MuxStats {
    /// Connections ever registered.
    pub conns: u64,
    /// Completions delivered.
    pub served: u64,
    /// Error responses delivered (bad JSON, OOV prompts, oversized
    /// lines, submit rejections, HTTP 4xx) — sheds counted separately.
    pub errors: u64,
    /// Requests shed by admission control (counted in addition to the
    /// `"overloaded"` error response each one gets).
    pub shed: u64,
    /// Queued-but-unadmitted requests cancelled at teardown.
    pub cancelled: u64,
    /// Finished generations dropped because their connection was gone.
    pub orphaned: u64,
    /// Connections torn down by a writer failure (broken pipe).
    pub write_failed: u64,
}

/// Where a finished generation goes.
struct Route {
    ticket: GenTicket,
    conn: ConnId,
    /// Line protocol: response id. HTTP: completion id (`cmpl-<id>`).
    id: String,
    /// HTTP only: per-connection pipeline sequence number.
    seq: Option<u64>,
    /// HTTP only: prompt token count for the `usage` block.
    prompt_tokens: usize,
    /// Submit timestamp feeding the `qes_serve_latency_ns` histogram at
    /// delivery (observability only — never read by compute).
    t_submit_ns: u64,
}

struct Conn {
    proto: Proto,
    writer: Sender<Vec<u8>>,
    /// Requests submitted (line) / enqueued (HTTP) and not yet answered.
    outstanding: usize,
    /// Line protocol: default response id for id-less requests.
    next_id: usize,
    half_closed: bool,
    /// HTTP pipeline: next sequence number to assign.
    next_seq: u64,
    /// HTTP pipeline: sequence numbers awaiting emission, in order.
    order: VecDeque<u64>,
    /// HTTP pipeline: responses completed out of order.
    ready: HashMap<u64, Vec<u8>>,
    /// HTTP: close the connection after flushing this sequence number.
    close_at: Option<u64>,
}

impl Conn {
    fn new(proto: Proto, writer: Sender<Vec<u8>>) -> Conn {
        Conn {
            proto,
            writer,
            outstanding: 0,
            next_id: 0,
            half_closed: false,
            next_seq: 0,
            order: VecDeque::new(),
            ready: HashMap::new(),
            close_at: None,
        }
    }
}

struct Mux {
    cfg: MuxCfg,
    conns: HashMap<ConnId, Conn>,
    routes: HashMap<usize, Route>,
    stats: MuxStats,
}

/// Drive ONE scheduler for every connection feeding `rx` until the
/// channel closes (all pumps gone) and every accepted request has
/// completed. This is [`serve_loop`](serve::serve_loop)'s discipline —
/// drain queued events without blocking, emit everything finished,
/// step, block on intake only when idle — lifted over tagged
/// multi-connection events.
pub fn mux_loop(
    sched: &mut Scheduler<'_>,
    rx: &Receiver<MuxEvent>,
    cfg: &MuxCfg,
) -> Result<MuxStats> {
    let mut m = Mux {
        cfg: cfg.clone(),
        conns: HashMap::new(),
        routes: HashMap::new(),
        stats: MuxStats::default(),
    };
    let mut open = true;
    loop {
        // intake: everything already queued, without blocking the batch
        while open {
            match rx.try_recv() {
                Ok(ev) => m.handle(sched, ev),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        // route everything finished so far (zero-budget requests
        // complete at submit time, before any step runs)
        for (ticket, out) in sched.drain_finished() {
            m.deliver(sched, ticket, out);
        }
        if sched.idle() {
            if !open {
                break;
            }
            match rx.recv() {
                Ok(ev) => m.handle(sched, ev),
                Err(_) => open = false,
            }
            continue;
        }
        sched.step()?;
    }
    Ok(m.stats)
}

impl Mux {
    fn handle(&mut self, sched: &mut Scheduler<'_>, event: MuxEvent) {
        let conn = event.conn;
        match event.ev {
            MuxIn::Open(proto, writer) => {
                self.stats.conns += 1;
                obs::m().serve_conns.inc();
                obs::m().serve_active_conns.add(1);
                self.conns.insert(conn, Conn::new(proto, writer));
            }
            MuxIn::Line(line) => self.on_line(sched, conn, &line),
            MuxIn::Oversized(cap) => {
                if !self.conns.contains_key(&conn) {
                    return;
                }
                let id = self.next_line_id(conn).to_string();
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                self.send_line(
                    sched,
                    conn,
                    serve::error_line(&id, &format!("request line exceeds {} bytes", cap)),
                );
            }
            MuxIn::Http(req) => self.on_http(sched, conn, req),
            MuxIn::BadHttp(msg) => {
                if !self.conns.contains_key(&conn) {
                    return;
                }
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                let body =
                    http::error_body(&format!("bad request: {}", msg), "invalid_request_error");
                self.http_immediate(sched, conn, 400, "Bad Request", &body, true);
            }
            MuxIn::HalfClosed => {
                let drained = match self.conns.get_mut(&conn) {
                    Some(c) => {
                        c.half_closed = true;
                        c.outstanding == 0
                    }
                    None => false,
                };
                if drained {
                    self.close(conn);
                }
            }
            MuxIn::Gone => self.teardown(sched, conn),
        }
    }

    /// Route one finished generation back to its connection (or drop it
    /// as orphaned when the connection died mid-flight).
    fn deliver(&mut self, sched: &mut Scheduler<'_>, ticket: GenTicket, out: GenOutput) {
        let Some(route) = self.routes.remove(&ticket.index()) else {
            self.stats.orphaned += 1;
            obs::m().serve_orphaned.inc();
            return;
        };
        if !self.conns.contains_key(&route.conn) {
            self.stats.orphaned += 1;
            obs::m().serve_orphaned.inc();
            return;
        }
        obs::m().serve_latency_ns.observe(obs::now_ns().saturating_sub(route.t_submit_ns));
        match route.seq {
            None => {
                let line = serve::response_line(&route.id, &out);
                if self.send_line(sched, route.conn, line) {
                    self.stats.served += 1;
                    obs::m().serve_served.inc();
                    self.after_line_response(route.conn);
                }
            }
            Some(seq) => {
                let body =
                    http::completion_body(&route.id, &self.cfg.model, &out, route.prompt_tokens);
                let bytes = http::response(200, "OK", &body, false);
                self.stats.served += 1;
                obs::m().serve_served.inc();
                self.http_stash(sched, route.conn, seq, bytes);
            }
        }
        obs::m().serve_inflight.set(sched.pending() as u64);
    }

    // ---- line protocol ----

    fn on_line(&mut self, sched: &mut Scheduler<'_>, conn: ConnId, line: &str) {
        if !self.conns.contains_key(&conn) {
            return; // teardown raced the pump
        }
        let line = line.trim();
        if line.is_empty() {
            return;
        }
        let default_id = self.next_line_id(conn);
        // registry snapshot on demand — `stats` is a control command,
        // not a generation request, so it skips admission control and
        // counts as neither served nor error
        if line == "stats" {
            self.send_line(sched, conn, serve::stats_line(&default_id.to_string()));
            return;
        }
        let default_max_new = sched.cfg().t_max;
        let pr = match serve::parse_request(line, default_id, default_max_new) {
            Ok(pr) => pr,
            Err(e) => {
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                self.send_line(
                    sched,
                    conn,
                    serve::error_line(&default_id.to_string(), &format!("{:#}", e)),
                );
                return;
            }
        };
        if self.shed(sched, conn) {
            self.stats.shed += 1;
            obs::m().serve_shed.inc();
            self.send_line(sched, conn, serve::error_line(&pr.id, "overloaded"));
            return;
        }
        match sched.submit_from(0, pr.req, Some(conn.0)) {
            Ok(ticket) => {
                self.routes.insert(
                    ticket.index(),
                    Route {
                        ticket,
                        conn,
                        id: pr.id,
                        seq: None,
                        prompt_tokens: 0,
                        t_submit_ns: obs::now_ns(),
                    },
                );
                if let Some(c) = self.conns.get_mut(&conn) {
                    c.outstanding += 1;
                    obs::m().serve_conn_queue_depth.observe(c.outstanding as u64);
                }
                obs::m().serve_inflight.set(sched.pending() as u64);
            }
            Err(e) => {
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                self.send_line(sched, conn, serve::error_line(&pr.id, &format!("{:#}", e)));
            }
        }
    }

    /// Allocate the per-connection default response id.
    fn next_line_id(&mut self, conn: ConnId) -> usize {
        let c = self.conns.get_mut(&conn).expect("known conn");
        let id = c.next_id;
        c.next_id += 1;
        id
    }

    /// Emit one line-protocol response; a dead writer (broken pipe)
    /// tears the connection down and returns `false`.
    fn send_line(&mut self, sched: &mut Scheduler<'_>, conn: ConnId, line: String) -> bool {
        let Some(c) = self.conns.get(&conn) else { return false };
        let mut bytes = line.into_bytes();
        bytes.push(b'\n');
        if c.writer.send(bytes).is_err() {
            self.stats.write_failed += 1;
            obs::m().serve_write_failed.inc();
            self.teardown(sched, conn);
            return false;
        }
        true
    }

    /// Bookkeeping after a routed line response: one fewer outstanding;
    /// a drained half-closed connection closes.
    fn after_line_response(&mut self, conn: ConnId) {
        let drained = match self.conns.get_mut(&conn) {
            Some(c) => {
                if c.outstanding > 0 {
                    c.outstanding -= 1;
                }
                c.half_closed && c.outstanding == 0
            }
            None => false,
        };
        if drained {
            self.close(conn);
        }
    }

    // ---- HTTP ----

    fn on_http(&mut self, sched: &mut Scheduler<'_>, conn: ConnId, req: HttpReq) {
        if !self.conns.contains_key(&conn) {
            return;
        }
        let close = req.close_requested();
        match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/completions") => self.on_completions(sched, conn, &req, close),
            ("GET", "/health") => {
                self.http_immediate(sched, conn, 200, "OK", "{\"ok\":true}", close)
            }
            ("GET", "/v1/models") => {
                let body = http::models_body(&self.cfg.model);
                self.http_immediate(sched, conn, 200, "OK", &body, close)
            }
            ("GET", "/metrics") => {
                let body = obs::registry().render_prometheus();
                self.http_immediate_typed(
                    sched,
                    conn,
                    200,
                    "OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    &body,
                    close,
                )
            }
            // known path, wrong method: 405, not a 404 (and not the old
            // 400) — the resource exists, the verb is what's rejected
            (_, "/v1/completions" | "/health" | "/v1/models" | "/metrics") => {
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                let body = http::error_body(
                    &format!("method {} not allowed for {}", req.method, req.path),
                    "invalid_request_error",
                );
                self.http_immediate(sched, conn, 405, "Method Not Allowed", &body, close)
            }
            _ => {
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                let body = http::error_body(
                    &format!("no route for {} {}", req.method, req.path),
                    "invalid_request_error",
                );
                self.http_immediate(sched, conn, 404, "Not Found", &body, close)
            }
        }
    }

    fn on_completions(
        &mut self,
        sched: &mut Scheduler<'_>,
        conn: ConnId,
        req: &HttpReq,
        close: bool,
    ) {
        let default_max_new = sched.cfg().t_max;
        let body = String::from_utf8_lossy(&req.body).into_owned();
        let gen = match http::parse_completions(&body, default_max_new) {
            Ok(g) => g,
            Err(e) => {
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                let body = http::error_body(&format!("{:#}", e), "invalid_request_error");
                self.http_immediate(sched, conn, 400, "Bad Request", &body, close);
                return;
            }
        };
        if self.shed(sched, conn) {
            self.stats.shed += 1;
            obs::m().serve_shed.inc();
            let body = http::error_body("overloaded", "overloaded_error");
            self.http_immediate(sched, conn, 429, "Too Many Requests", &body, close);
            return;
        }
        let prompt_tokens = gen.prompt.len();
        match sched.submit_from(0, gen, Some(conn.0)) {
            Ok(ticket) => {
                let Some(c) = self.conns.get_mut(&conn) else { return };
                let seq = c.next_seq;
                c.next_seq += 1;
                c.order.push_back(seq);
                c.outstanding += 1;
                obs::m().serve_conn_queue_depth.observe(c.outstanding as u64);
                if close {
                    c.close_at = Some(seq);
                }
                let id = format!("cmpl-{}", ticket.index());
                let route = Route {
                    ticket,
                    conn,
                    id,
                    seq: Some(seq),
                    prompt_tokens,
                    t_submit_ns: obs::now_ns(),
                };
                self.routes.insert(ticket.index(), route);
                obs::m().serve_inflight.set(sched.pending() as u64);
            }
            Err(e) => {
                self.stats.errors += 1;
                obs::m().serve_errors.inc();
                let body = http::error_body(&format!("{:#}", e), "invalid_request_error");
                self.http_immediate(sched, conn, 400, "Bad Request", &body, close);
            }
        }
    }

    /// Enqueue a response that is ready NOW (errors, health, models) at
    /// the back of the connection's pipeline and flush whatever is due.
    fn http_immediate(
        &mut self,
        sched: &mut Scheduler<'_>,
        conn: ConnId,
        status: u16,
        reason: &str,
        body: &str,
        close: bool,
    ) {
        self.http_immediate_typed(sched, conn, status, reason, "application/json", body, close)
    }

    /// [`Mux::http_immediate`] with an explicit Content-Type
    /// (`/metrics` serves Prometheus text, not JSON).
    #[allow(clippy::too_many_arguments)]
    fn http_immediate_typed(
        &mut self,
        sched: &mut Scheduler<'_>,
        conn: ConnId,
        status: u16,
        reason: &str,
        content_type: &str,
        body: &str,
        close: bool,
    ) {
        let Some(c) = self.conns.get_mut(&conn) else { return };
        let seq = c.next_seq;
        c.next_seq += 1;
        c.order.push_back(seq);
        c.outstanding += 1;
        if close {
            c.close_at = Some(seq);
        }
        let bytes = http::response_typed(status, reason, content_type, body, close);
        self.http_stash(sched, conn, seq, bytes);
    }

    /// Record a completed HTTP response and flush the pipeline head —
    /// responses leave in request order per connection, whatever order
    /// they completed in.
    fn http_stash(&mut self, sched: &mut Scheduler<'_>, conn: ConnId, seq: u64, bytes: Vec<u8>) {
        let Some(c) = self.conns.get_mut(&conn) else { return };
        c.ready.insert(seq, bytes);
        let mut do_close = false;
        let mut dead = false;
        while let Some(&head) = c.order.front() {
            let Some(bytes) = c.ready.remove(&head) else { break };
            c.order.pop_front();
            if c.outstanding > 0 {
                c.outstanding -= 1;
            }
            if c.writer.send(bytes).is_err() {
                dead = true;
                break;
            }
            if c.close_at == Some(head) {
                do_close = true;
                break;
            }
        }
        if dead {
            self.stats.write_failed += 1;
            obs::m().serve_write_failed.inc();
            self.teardown(sched, conn);
            return;
        }
        let drained = do_close
            || self
                .conns
                .get(&conn)
                .map(|c| c.half_closed && c.outstanding == 0)
                .unwrap_or(false);
        if drained {
            self.close(conn);
        }
    }

    // ---- admission control / lifecycle ----

    /// Shed this request? Global in-flight cap first, then the
    /// per-connection queue bound.
    fn shed(&self, sched: &Scheduler<'_>, conn: ConnId) -> bool {
        if self.cfg.max_inflight > 0 && sched.pending() >= self.cfg.max_inflight {
            return true;
        }
        if self.cfg.conn_queue > 0 {
            if let Some(c) = self.conns.get(&conn) {
                return c.outstanding >= self.cfg.conn_queue;
            }
        }
        false
    }

    /// Graceful close: drop the writer (its thread exits, closing the
    /// socket write half). Routes already emptied by the caller.
    fn close(&mut self, conn: ConnId) {
        if self.conns.remove(&conn).is_some() {
            obs::m().serve_active_conns.sub(1);
        }
    }

    /// Hard teardown: cancel this connection's queued-but-unadmitted
    /// requests; in-flight slots keep decoding and their outputs are
    /// dropped as orphaned at drain time.
    fn teardown(&mut self, sched: &mut Scheduler<'_>, conn: ConnId) {
        if self.conns.remove(&conn).is_some() {
            obs::m().serve_active_conns.sub(1);
        }
        let mine: Vec<usize> = self
            .routes
            .iter()
            .filter(|(_, r)| r.conn == conn)
            .map(|(&idx, _)| idx)
            .collect();
        for idx in mine {
            let ticket = self.routes[&idx].ticket;
            if sched.cancel_waiting(ticket) {
                self.routes.remove(&idx);
                self.stats.cancelled += 1;
                obs::m().serve_cancelled.inc();
            }
            // else: already admitted — leave the route; deliver() will
            // drop the finished output as orphaned.
        }
        obs::m().serve_inflight.set(sched.pending() as u64);
    }
}

/// Writer-thread body: own the connection's write half, drain framed
/// responses until the mux drops the sender (graceful close) or a write
/// fails (the mux learns via its next send failing).
pub fn writer_thread<W: Write>(mut w: W, rx: Receiver<Vec<u8>>) {
    while let Ok(bytes) = rx.recv() {
        if w.write_all(&bytes).is_err() || w.flush().is_err() {
            return;
        }
    }
}

/// Feed one connection's read half into the shared mux channel with the
/// line-protocol framing; reports `HalfClosed` on clean EOF and `Gone`
/// on a read error. Returns when the connection stops producing.
pub fn pump_conn_lines<R: std::io::Read>(
    reader: R,
    conn: ConnId,
    max_line: usize,
    tx: &Sender<MuxEvent>,
) {
    let clean = serve::pump_lines_with(reader, max_line, |ev| {
        let ev = match ev {
            Intake::Line(l) => MuxIn::Line(l),
            Intake::Oversized(cap) => MuxIn::Oversized(cap),
        };
        tx.send(MuxEvent { conn, ev }).is_ok()
    });
    let _ = tx.send(MuxEvent { conn, ev: if clean { MuxIn::HalfClosed } else { MuxIn::Gone } });
}

/// Feed one connection's read half into the shared mux channel with
/// HTTP framing; reports `BadHttp` (then stops reading — the mux
/// answers 400 and closes), `HalfClosed` on clean EOF, `Gone` on a
/// read error.
pub fn pump_conn_http<R: std::io::Read>(
    reader: R,
    conn: ConnId,
    max_head: usize,
    max_body: usize,
    tx: &Sender<MuxEvent>,
) {
    let mut r = std::io::BufReader::new(reader);
    loop {
        let ev = match http::read_request(&mut r, max_head, max_body) {
            http::ReadOutcome::Req(req) => MuxIn::Http(req),
            http::ReadOutcome::Eof => MuxIn::HalfClosed,
            http::ReadOutcome::Bad(msg) => MuxIn::BadHttp(msg),
            http::ReadOutcome::IoErr => MuxIn::Gone,
        };
        let terminal =
            matches!(ev, MuxIn::HalfClosed | MuxIn::Gone | MuxIn::BadHttp(_));
        if tx.send(MuxEvent { conn, ev }).is_err() || terminal {
            return;
        }
    }
}
