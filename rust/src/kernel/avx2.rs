//! AVX2 + FMA + F16C backend (x86-64, Haswell and later).
//!
//! 8-wide f32 lanes over the N (output-column) axis; the K-loop order of
//! every GEMM accumulator is untouched, and all mul/add sequences stay
//! unfused, so `axpy_*`/`axpby`/`unpack_int4_row`/f16 results are
//! bit-identical to the scalar backend (module docs in `kernel`). Only
//! `dot_packed_int4` uses `vfmadd` with the pinned 8-lane layout.
//!
//! # Safety
//!
//! Every `#[target_feature]` function in here is reached only through
//! [`Avx2Kernel`], which `kernel::by_kind` hands out only when
//! `KernelKind::Avx2.supported()` (AVX2 + FMA + F16C detected at
//! runtime). All raw-pointer loads/stores are bounds-asserted against
//! the slice lengths first.

use std::arch::x86_64::*;

use super::{DotKernel, KernelKind};
use crate::quant::pack;
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

pub struct Avx2Kernel;

impl DotKernel for Avx2Kernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Avx2
    }

    fn unpack_int4_row(&self, bytes: &[u8], start: usize, out: &mut [i8]) {
        // SAFETY: constructed only when avx2 is detected (see module docs).
        unsafe { unpack_row(bytes, start, out) }
    }

    fn axpy_i8(&self, acc: &mut [f32], xv: f32, w: &[i8]) {
        assert_eq!(acc.len(), w.len(), "axpy_i8 length mismatch");
        // SAFETY: avx2 detected; lengths checked above.
        unsafe { axpy_i8(acc, xv, w) }
    }

    fn axpy_f32(&self, acc: &mut [f32], xv: f32, w: &[f32]) {
        assert_eq!(acc.len(), w.len(), "axpy_f32 length mismatch");
        // SAFETY: avx2 detected; lengths checked above.
        unsafe { axpy_f32(acc, xv, w) }
    }

    fn axpby(&self, alpha: f32, g: &[f32], gamma: f32, u: &mut [f32]) {
        assert_eq!(g.len(), u.len(), "axpby length mismatch");
        // SAFETY: avx2 detected; lengths checked above.
        unsafe { axpby(alpha, g, gamma, u) }
    }

    fn dot_packed_int4(&self, bytes: &[u8], start: usize, x: &[f32]) -> f32 {
        // SAFETY: avx2 + fma detected.
        unsafe { dot_packed(bytes, start, x) }
    }

    fn f16_encode(&self, xs: &[f32], out: &mut [u16]) {
        assert_eq!(xs.len(), out.len(), "f16 encode length mismatch");
        // SAFETY: f16c detected; lengths checked above.
        unsafe { f16_encode(xs, out) }
    }

    fn f16_decode(&self, bits: &[u16], out: &mut [f32]) {
        assert_eq!(bits.len(), out.len(), "f16 decode length mismatch");
        // SAFETY: f16c detected; lengths checked above.
        unsafe { f16_decode(bits, out) }
    }
}

/// Nibble-LUT unpack, 32 int4 values per 16-byte load: `pshufb` over a
/// sign-extension table, then interleave the low/high-nibble lanes back
/// into element order. Exact integer work.
#[target_feature(enable = "avx2")]
unsafe fn unpack_row(bytes: &[u8], start: usize, out: &mut [i8]) {
    let n = out.len();
    if n == 0 {
        return;
    }
    assert!(
        bytes.len() * 2 >= start + n,
        "packed buffer too short: {} bytes for window [{}, {})",
        bytes.len(),
        start,
        start + n
    );
    if start % 2 != 0 {
        // misaligned half-byte start: rare (GEMM rows are element-aligned)
        pack::unpack_int4_row(bytes, start, out);
        return;
    }
    // value = sign_extend4(index) for index in 0..16
    let lut = _mm_setr_epi8(0, 1, 2, 3, 4, 5, 6, 7, -8, -7, -6, -5, -4, -3, -2, -1);
    let maskf = _mm_set1_epi8(0x0f);
    let mut i = 0usize;
    while i + 32 <= n {
        let x = _mm_loadu_si128(bytes.as_ptr().add((start + i) / 2) as *const __m128i);
        let lo = _mm_shuffle_epi8(lut, _mm_and_si128(x, maskf));
        let hi = _mm_shuffle_epi8(lut, _mm_and_si128(_mm_srli_epi16::<4>(x), maskf));
        _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, _mm_unpacklo_epi8(lo, hi));
        _mm_storeu_si128(
            out.as_mut_ptr().add(i + 16) as *mut __m128i,
            _mm_unpackhi_epi8(lo, hi),
        );
        i += 32;
    }
    if i < n {
        // start + i stays even (i is a multiple of 32), so the scalar
        // tail takes its aligned fast path
        pack::unpack_int4_row(&bytes[(start + i) / 2..], 0, &mut out[i..]);
    }
}

/// `acc[c] += xv * w[c] as f32`, 8 columns per iteration. Unfused
/// mul+add — identical rounding to the scalar loop, per element.
#[target_feature(enable = "avx2")]
unsafe fn axpy_i8(acc: &mut [f32], xv: f32, w: &[i8]) {
    let n = acc.len();
    let xvv = _mm256_set1_ps(xv);
    let mut i = 0usize;
    while i + 8 <= n {
        let q = _mm_loadl_epi64(w.as_ptr().add(i) as *const __m128i);
        let wf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let r = _mm256_add_ps(a, _mm256_mul_ps(xvv, wf));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), r);
        i += 8;
    }
    while i < n {
        acc[i] += xv * w[i] as f32;
        i += 1;
    }
}

/// `acc[c] += xv * w[c]`, 8 columns per iteration, unfused.
#[target_feature(enable = "avx2")]
unsafe fn axpy_f32(acc: &mut [f32], xv: f32, w: &[f32]) {
    let n = acc.len();
    let xvv = _mm256_set1_ps(xv);
    let mut i = 0usize;
    while i + 8 <= n {
        let wv = _mm256_loadu_ps(w.as_ptr().add(i));
        let a = _mm256_loadu_ps(acc.as_ptr().add(i));
        let r = _mm256_add_ps(a, _mm256_mul_ps(xvv, wv));
        _mm256_storeu_ps(acc.as_mut_ptr().add(i), r);
        i += 8;
    }
    while i < n {
        acc[i] += xv * w[i];
        i += 1;
    }
}

/// `u[i] = alpha * g[i] + gamma * u[i]`: two unfused multiplies and one
/// add per element, same rounding sequence as the scalar loop.
#[target_feature(enable = "avx2")]
unsafe fn axpby(alpha: f32, g: &[f32], gamma: f32, u: &mut [f32]) {
    let n = u.len();
    let av = _mm256_set1_ps(alpha);
    let cv = _mm256_set1_ps(gamma);
    let mut i = 0usize;
    while i + 8 <= n {
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        let uv = _mm256_loadu_ps(u.as_ptr().add(i));
        let r = _mm256_add_ps(_mm256_mul_ps(av, gv), _mm256_mul_ps(cv, uv));
        _mm256_storeu_ps(u.as_mut_ptr().add(i), r);
        i += 8;
    }
    while i < n {
        u[i] = alpha * g[i] + gamma * u[i];
        i += 1;
    }
}

/// Packed-int4 dot with the pinned 8-lane FMA layout (see
/// `DotKernel::dot_packed_int4`): lane `l` owns elements `8b + l`,
/// reduced in the fixed order the conformance lane model replays.
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_packed(bytes: &[u8], start: usize, x: &[f32]) -> f32 {
    let n = x.len();
    assert!(
        bytes.len() * 2 >= start + n,
        "packed buffer too short: {} bytes for window [{}, {})",
        bytes.len(),
        start,
        start + n
    );
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    if start % 2 == 0 {
        let mut s32 = [0i8; 32];
        while i + 32 <= n {
            unpack_row(bytes, start + i, &mut s32);
            for b in 0..4 {
                let q = _mm_loadl_epi64(s32.as_ptr().add(8 * b) as *const __m128i);
                let wf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
                let xv = _mm256_loadu_ps(x.as_ptr().add(i + 8 * b));
                acc = _mm256_fmadd_ps(xv, wf, acc);
            }
            i += 32;
        }
    }
    let mut s8 = [0i8; 8];
    while i + 8 <= n {
        pack::unpack_int4_row(bytes, start + i, &mut s8);
        let q = _mm_loadl_epi64(s8.as_ptr() as *const __m128i);
        let wf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        acc = _mm256_fmadd_ps(xv, wf, acc);
        i += 8;
    }
    // fixed lane reduction: s4[l] = acc[l] + acc[l+4];
    // s2[l] = s4[l] + s4[l+2]; s = s2[0] + s2[1]
    let s4 = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
    let s2 = _mm_add_ps(s4, _mm_movehl_ps(s4, s4));
    let s1 = _mm_add_ss(s2, _mm_shuffle_ps::<1>(s2, s2));
    let mut sum = _mm_cvtss_f32(s1);
    let mut one = [0i8; 1];
    while i < n {
        pack::unpack_int4_row(bytes, start + i, &mut one);
        sum += x[i] * one[0] as f32;
        i += 1;
    }
    sum
}

/// Hardware f32 -> f16 (vcvtps2ph), round-to-nearest-even — the uniquely
/// defined IEEE conversion, bit-identical to the scalar converter for
/// every non-NaN input.
#[target_feature(enable = "avx2,f16c")]
unsafe fn f16_encode(xs: &[f32], out: &mut [u16]) {
    let n = xs.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(xs.as_ptr().add(i));
        // imm8[1:0] = round-to-nearest-even (vcvtps2ph takes a 3-bit
        // immediate: rounding mode + MXCSR-select; no SAE bit here)
        let h = _mm256_cvtps_ph::<{ _MM_FROUND_TO_NEAREST_INT }>(v);
        _mm_storeu_si128(out.as_mut_ptr().add(i) as *mut __m128i, h);
        i += 8;
    }
    while i < n {
        out[i] = f32_to_f16_bits(xs[i]);
        i += 1;
    }
}

/// Hardware f16 -> f32 (vcvtph2ps) — exact.
#[target_feature(enable = "avx2,f16c")]
unsafe fn f16_decode(bits: &[u16], out: &mut [f32]) {
    let n = bits.len();
    let mut i = 0usize;
    while i + 8 <= n {
        let h = _mm_loadu_si128(bits.as_ptr().add(i) as *const __m128i);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_cvtph_ps(h));
        i += 8;
    }
    while i < n {
        out[i] = f16_bits_to_f32(bits[i]);
        i += 1;
    }
}
