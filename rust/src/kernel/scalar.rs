//! Portable scalar reference backend: the exact historical inner loops,
//! delegating to the canonical primitives in `quant::pack` / `util::f16`.
//! Every SIMD backend is conformance-tested against this one.

use super::{DotKernel, KernelKind};
use crate::quant::pack;
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

pub struct ScalarKernel;

impl DotKernel for ScalarKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Scalar
    }

    fn unpack_int4_row(&self, bytes: &[u8], start: usize, out: &mut [i8]) {
        pack::unpack_int4_row(bytes, start, out);
    }

    fn axpy_i8(&self, acc: &mut [f32], xv: f32, w: &[i8]) {
        assert_eq!(acc.len(), w.len(), "axpy_i8 length mismatch");
        for (o, &q) in acc.iter_mut().zip(w.iter()) {
            *o += xv * q as f32;
        }
    }

    fn axpy_f32(&self, acc: &mut [f32], xv: f32, w: &[f32]) {
        assert_eq!(acc.len(), w.len(), "axpy_f32 length mismatch");
        for (o, &wv) in acc.iter_mut().zip(w.iter()) {
            *o += xv * wv;
        }
    }

    fn axpby(&self, alpha: f32, g: &[f32], gamma: f32, u: &mut [f32]) {
        assert_eq!(g.len(), u.len(), "axpby length mismatch");
        for (uv, &gv) in u.iter_mut().zip(g.iter()) {
            *uv = alpha * gv + gamma * *uv;
        }
    }

    fn dot_packed_int4(&self, bytes: &[u8], start: usize, x: &[f32]) -> f32 {
        pack::unpack_int4_dot(bytes, start, x)
    }

    fn f16_encode(&self, xs: &[f32], out: &mut [u16]) {
        assert_eq!(xs.len(), out.len(), "f16 encode length mismatch");
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = f32_to_f16_bits(x);
        }
    }

    fn f16_decode(&self, bits: &[u16], out: &mut [f32]) {
        assert_eq!(bits.len(), out.len(), "f16 decode length mismatch");
        for (o, &h) in out.iter_mut().zip(bits.iter()) {
            *o = f16_bits_to_f32(h);
        }
    }
}
