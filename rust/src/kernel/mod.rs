//! Runtime-dispatched SIMD microkernels for the vectorizable inner loops.
//!
//! The fused dequant-GEMM (`runtime::native::gemm`), the INT4 packing
//! primitives (`quant::pack`), the f16 residual codec (`util::f16`) and
//! the error-feedback step of the update kernels (`opt::kernels`) all
//! bottom out in a handful of dense inner loops. This module gives each
//! of them one scalar reference implementation and per-ISA vector
//! implementations behind the [`DotKernel`] trait, selected at runtime:
//!
//! * **scalar** — portable reference, runs everywhere; every other
//!   backend is conformance-tested against it.
//! * **avx2** — x86-64 with AVX2 + FMA + F16C (Haswell and later),
//!   detected via `is_x86_feature_detected!`. 8-wide f32 lanes, 16-byte
//!   nibble-LUT unpack (`pshufb`), hardware f16 conversion.
//! * **neon** — aarch64 (NEON is baseline in the AArch64 ABI). 4-wide
//!   f32 lanes paired to the same 8-lane layout, `tbl`-based nibble LUT;
//!   the f16 codec stays scalar (stable Rust exposes no aarch64 f16
//!   conversion intrinsics).
//!
//! # Selection
//!
//! The process-wide dispatch resolves once, in priority order: a forced
//! kind from [`force`] (the CLI `--kernel` flag), else the `QES_KERNEL`
//! environment variable (`scalar` | `avx2` | `neon` | `auto` — how CI
//! pins the backend per leg; unknown or CPU-unsupported values fail
//! loudly rather than silently running a different backend), else
//! [`detect`]. Call sites that need an explicit backend (benches,
//! conformance tests, `KernelPolicy::kernel`) go through [`by_kind`]
//! instead and are unaffected by the global choice.
//!
//! # Determinism
//!
//! The dispatched kernels are held to the same contract as the
//! chunk-parallel update kernels, with one documented exception:
//!
//! * [`DotKernel::unpack_int4_row`] is exact integer work — bit-identical
//!   across every backend.
//! * [`DotKernel::axpy_i8`] / [`DotKernel::axpy_f32`] /
//!   [`DotKernel::axpby`] vectorize ACROSS elements while keeping each
//!   element's op sequence (round-after-multiply, round-after-add, in
//!   the same order as the scalar loop). No fused multiply-add, no
//!   reassociation — results are bit-identical across backends, which is
//!   why `QES_KERNEL` never changes a lattice, residual or forward
//!   output. The GEMM's K-loop accumulation order is untouched: SIMD
//!   runs along the N (output-column) axis.
//! * [`DotKernel::dot_packed_int4`] is the one reassociating primitive:
//!   it reduces over K in a fixed 8-lane layout with fused
//!   multiply-adds (documented in the method; the lane model is pinned
//!   exactly by the conformance tests, and agreement with the
//!   sequential reference is tolerance-checked). Nothing on the
//!   bit-exactness-contracted paths calls it.
//! * [`DotKernel::f16_encode`]/[`f16_decode`](DotKernel::f16_decode) are
//!   IEEE 754 round-to-nearest-even conversions — uniquely defined, so
//!   hardware (F16C) and scalar agree bit-for-bit on every non-NaN
//!   input (NaNs stay NaNs; payloads may differ and never occur in
//!   residual state).

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::Result;

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;

#[cfg(target_arch = "aarch64")]
mod neon;

/// Which ISA microkernel backend services the inner loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable scalar reference (always available).
    Scalar,
    /// x86-64 AVX2 + FMA + F16C.
    Avx2,
    /// aarch64 NEON.
    Neon,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
        }
    }

    /// Parse a `--kernel` / `QES_KERNEL` value; `auto` means "re-resolve
    /// from the environment and CPU" and maps to `None`.
    pub fn parse_choice(s: &str) -> Result<Option<KernelKind>> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => None,
            "scalar" => Some(KernelKind::Scalar),
            "avx2" => Some(KernelKind::Avx2),
            "neon" => Some(KernelKind::Neon),
            other => anyhow::bail!("unknown kernel {:?} (auto|scalar|avx2|neon)", other),
        })
    }

    /// Can this backend run on the current CPU?
    pub fn supported(self) -> bool {
        match self {
            KernelKind::Scalar => true,
            KernelKind::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                        && std::arch::is_x86_feature_detected!("f16c")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            // NEON is mandatory in the AArch64 ABI — no runtime check.
            KernelKind::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// The microkernel interface: every method has a scalar reference
/// implementation and (where the ISA is present) a vector one. See the
/// module docs for which methods are bit-exact across backends.
pub trait DotKernel: Sync + Send {
    fn kind(&self) -> KernelKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Unpack `out.len()` int4 values starting at flat element `start`
    /// of a nibble-packed buffer (sign-extended). Exact integer work —
    /// bit-identical across backends.
    fn unpack_int4_row(&self, bytes: &[u8], start: usize, out: &mut [i8]);

    /// `acc[c] += xv * w[c] as f32` — the quantized GEMM's row update.
    /// Per-element op order matches the scalar loop exactly.
    fn axpy_i8(&self, acc: &mut [f32], xv: f32, w: &[i8]);

    /// `acc[c] += xv * w[c]` — the fp GEMM / autograd row update.
    /// Per-element op order matches the scalar loop exactly.
    fn axpy_f32(&self, acc: &mut [f32], xv: f32, w: &[f32]);

    /// `u[i] = alpha * g[i] + gamma * u[i]` — the vectorizable half of
    /// the error-feedback update (Eq. 6): two rounded multiplies and one
    /// rounded add per element, exactly as the scalar loop computed it.
    fn axpby(&self, alpha: f32, g: &[f32], gamma: f32, u: &mut [f32]);

    /// Fused gather + dot over a nibble-packed buffer:
    /// `sum_j x[j] * q[start + j]`, for K-major (transposed-weight)
    /// consumers. The ONE reassociating primitive: SIMD backends
    /// accumulate in a fixed 8-lane layout — lane `l` owns elements
    /// `8b + l` via fused multiply-adds, lanes reduce as
    /// `s4[l] = acc[l] + acc[l+4]`, `s2[l] = s4[l] + s4[l+2]`,
    /// `s = s2[0] + s2[1]`, then the `len % 8` tail is added
    /// sequentially (unfused). The scalar backend keeps the historical
    /// sequential order (`quant::pack::unpack_int4_dot`).
    fn dot_packed_int4(&self, bytes: &[u8], start: usize, x: &[f32]) -> f32;

    /// Slice f32 -> f16-bits conversion, IEEE round-to-nearest-even.
    fn f16_encode(&self, xs: &[f32], out: &mut [u16]);

    /// Slice f16-bits -> f32 conversion (exact).
    fn f16_decode(&self, bits: &[u16], out: &mut [f32]);
}

static SCALAR: scalar::ScalarKernel = scalar::ScalarKernel;

#[cfg(target_arch = "x86_64")]
static AVX2: avx2::Avx2Kernel = avx2::Avx2Kernel;

#[cfg(target_arch = "aarch64")]
static NEON: neon::NeonKernel = neon::NeonKernel;

/// The kernel implementing `kind`. Panics if this CPU cannot run `kind`
/// — the same loud-failure policy as [`force`]/[`resolve_name`]: a
/// caller that pinned a backend (e.g. `KernelPolicy::with_kernel`) must
/// never be handed a different one, or a suite believed to exercise
/// that backend would green-light having tested nothing. Gate with
/// [`KernelKind::supported`] / [`available`] first.
pub fn by_kind(kind: KernelKind) -> &'static dyn DotKernel {
    match kind {
        KernelKind::Scalar => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 if KernelKind::Avx2.supported() => &AVX2,
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => &NEON,
        other => panic!(
            "kernel {} is not supported on this CPU (available: {})",
            other.name(),
            available().iter().map(|k| k.name()).collect::<Vec<_>>().join(", ")
        ),
    }
}

/// Best backend this CPU supports.
pub fn detect() -> KernelKind {
    if KernelKind::Avx2.supported() {
        KernelKind::Avx2
    } else if KernelKind::Neon.supported() {
        KernelKind::Neon
    } else {
        KernelKind::Scalar
    }
}

/// Every backend that can run on this CPU (scalar first) — what the
/// conformance tests and benches iterate.
pub fn available() -> Vec<KernelKind> {
    let mut out = vec![KernelKind::Scalar];
    for k in [KernelKind::Avx2, KernelKind::Neon] {
        if k.supported() {
            out.push(k);
        }
    }
    out
}

// 0 = unresolved; first use resolves from QES_KERNEL / detection. The
// benign race (two threads resolving concurrently) writes the same value.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn code(k: KernelKind) -> u8 {
    match k {
        KernelKind::Scalar => 1,
        KernelKind::Avx2 => 2,
        KernelKind::Neon => 3,
    }
}

fn decode(c: u8) -> KernelKind {
    match c {
        2 => KernelKind::Avx2,
        3 => KernelKind::Neon,
        _ => KernelKind::Scalar,
    }
}

/// Resolve a `QES_KERNEL`-style name against this CPU. Strict: an
/// unknown value or a backend this CPU cannot run is an error, never a
/// silent fallback — forcing a backend exists precisely to PROVE the
/// bit-exactness contract, so running a different one than requested
/// would green-light a suite that tested nothing.
pub fn resolve_name(name: &str) -> Result<KernelKind> {
    match KernelKind::parse_choice(name)? {
        None => Ok(detect()),
        Some(k) => {
            anyhow::ensure!(
                k.supported(),
                "kernel {} is not supported on this CPU (available: {})",
                k.name(),
                available().iter().map(|k| k.name()).collect::<Vec<_>>().join(", ")
            );
            Ok(k)
        }
    }
}

/// Panics on an invalid `QES_KERNEL` (see [`resolve_name`] — explicit
/// forcing requests fail loudly).
fn resolve_env() -> KernelKind {
    match std::env::var("QES_KERNEL") {
        Ok(v) => resolve_name(&v)
            .unwrap_or_else(|e| panic!("invalid QES_KERNEL={:?}: {}", v, e)),
        Err(_) => detect(),
    }
}

/// The process-wide dispatched backend (resolving it on first use).
pub fn active() -> KernelKind {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let k = resolve_env();
            ACTIVE.store(code(k), Ordering::Relaxed);
            k
        }
        c => decode(c),
    }
}

/// The process-wide dispatched kernel.
pub fn active_kernel() -> &'static dyn DotKernel {
    by_kind(active())
}

/// Override the process-wide dispatch (the CLI `--kernel` flag; benches
/// toggle it to time each backend). `None` re-resolves from
/// `QES_KERNEL`/detection; `Some(kind)` errors if this CPU cannot run
/// `kind`. Returns the kind now active.
pub fn force(choice: Option<KernelKind>) -> Result<KernelKind> {
    let k = match choice {
        None => resolve_env(),
        Some(k) => {
            anyhow::ensure!(
                k.supported(),
                "kernel {} is not supported on this CPU (available: {})",
                k.name(),
                available().iter().map(|k| k.name()).collect::<Vec<_>>().join(", ")
            );
            k
        }
    };
    ACTIVE.store(code(k), Ordering::Relaxed);
    Ok(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::{pack_int4, unpack_int4_dot, unpack_int4_row};
    use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
    use crate::util::prop::prop_check;

    fn non_scalar() -> Vec<&'static dyn DotKernel> {
        available()
            .into_iter()
            .filter(|&k| k != KernelKind::Scalar)
            .map(by_kind)
            .collect()
    }

    #[test]
    fn parse_and_support() {
        assert_eq!(KernelKind::parse_choice("auto").unwrap(), None);
        assert_eq!(KernelKind::parse_choice("SCALAR").unwrap(), Some(KernelKind::Scalar));
        assert_eq!(KernelKind::parse_choice("avx2").unwrap(), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse_choice("neon").unwrap(), Some(KernelKind::Neon));
        assert!(KernelKind::parse_choice("sse9").is_err());
        assert!(KernelKind::Scalar.supported());
        // every advertised backend must actually be constructible and
        // report its own kind; unsupported kinds fall back to scalar
        for k in available() {
            assert_eq!(by_kind(k).kind(), k, "{}", k.name());
        }
        assert!(available().contains(&detect()));
        // explicit forcing is strict: unknown names error instead of
        // silently running a different backend than requested
        assert!(resolve_name("bogus").is_err());
        assert!(resolve_name("auto").unwrap().supported());
        assert_eq!(resolve_name("scalar").unwrap(), KernelKind::Scalar);
        #[cfg(not(target_arch = "aarch64"))]
        assert!(resolve_name("neon").is_err());
    }

    #[test]
    fn prop_unpack_conformance_every_backend() {
        // Exact match vs the scalar reference over random shapes, odd
        // starts (the misaligned half-byte path), the full nibble range
        // including -8, and tails shorter than any lane width.
        prop_check("kernel unpack vs scalar reference", 300, |g| {
            let n = g.usize_in(1, 400);
            let q = g.vec_i8(n, -8, 7);
            let packed = pack_int4(&q);
            let start = g.usize_in(0, n - 1);
            let len = g.usize_in(0, n - start);
            let mut want = vec![0i8; len];
            unpack_int4_row(&packed, start, &mut want);
            for k in available() {
                let kr = by_kind(k);
                let mut got = vec![0i8; len];
                kr.unpack_int4_row(&packed, start, &mut got);
                if got != want {
                    return Err(format!(
                        "{}: unpack mismatch at start={} len={} (n={})",
                        kr.name(),
                        start,
                        len,
                        n
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_axpy_bit_exact_every_backend() {
        // axpy is on the bit-exactness contract: vector lanes must
        // produce the very same f32s as the scalar loop, for every
        // length (including < lane-width tails and length 0).
        prop_check("kernel axpy vs scalar, bitwise", 300, |g| {
            let n = g.usize_in(0, 100);
            let xv = g.f32_in(-2.0, 2.0);
            let wq = g.vec_i8(n, -8, 7);
            let wf = g.vec_f32(n, -1.0, 1.0);
            let acc0 = g.vec_f32(n, -4.0, 4.0);
            let mut want_q = acc0.clone();
            by_kind(KernelKind::Scalar).axpy_i8(&mut want_q, xv, &wq);
            let mut want_f = acc0.clone();
            by_kind(KernelKind::Scalar).axpy_f32(&mut want_f, xv, &wf);
            for kr in non_scalar() {
                let mut got = acc0.clone();
                kr.axpy_i8(&mut got, xv, &wq);
                if got.iter().zip(&want_q).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{}: axpy_i8 diverged at n={}", kr.name(), n));
                }
                let mut got = acc0.clone();
                kr.axpy_f32(&mut got, xv, &wf);
                if got.iter().zip(&want_f).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{}: axpy_f32 diverged at n={}", kr.name(), n));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_axpby_bit_exact_every_backend() {
        prop_check("kernel axpby vs scalar, bitwise", 300, |g| {
            let n = g.usize_in(0, 100);
            let alpha = g.f32_in(-1.0, 1.0);
            let gamma = g.f32_in(0.0, 1.0);
            let gv = g.vec_f32(n, -3.0, 3.0);
            let u0 = g.vec_f32(n, -0.6, 0.6);
            let mut want = u0.clone();
            by_kind(KernelKind::Scalar).axpby(alpha, &gv, gamma, &mut want);
            for kr in non_scalar() {
                let mut got = u0.clone();
                kr.axpby(alpha, &gv, gamma, &mut got);
                if got.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{}: axpby diverged at n={}", kr.name(), n));
                }
            }
            Ok(())
        });
    }

    /// Scalar emulation of the documented 8-lane FMA dot: the EXACT model
    /// every SIMD backend must implement (f32::mul_add is the correctly
    /// rounded fused op, same as the hardware instruction).
    fn dot_lane_model(q: &[i8], x: &[f32]) -> f32 {
        let mut acc = [0.0f32; 8];
        let blocks = x.len() / 8;
        for b in 0..blocks {
            for l in 0..8 {
                let j = 8 * b + l;
                acc[l] = x[j].mul_add(q[j] as f32, acc[l]);
            }
        }
        let s4: Vec<f32> = (0..4).map(|l| acc[l] + acc[l + 4]).collect();
        let s2 = [s4[0] + s4[2], s4[1] + s4[3]];
        let mut s = s2[0] + s2[1];
        for j in 8 * blocks..x.len() {
            s += x[j] * q[j] as f32;
        }
        s
    }

    #[test]
    fn prop_dot_matches_lane_model_exactly_and_reference_loosely() {
        prop_check("kernel dot: lane model exact, reference close", 300, |g| {
            let n = g.usize_in(1, 400);
            let q = g.vec_i8(n, -8, 7);
            let packed = pack_int4(&q);
            let start = g.usize_in(0, n - 1);
            let len = g.usize_in(0, n - start);
            let x = g.vec_f32(len, -2.0, 2.0);
            let reference = unpack_int4_dot(&packed, start, &x);
            let scalar = by_kind(KernelKind::Scalar).dot_packed_int4(&packed, start, &x);
            if scalar.to_bits() != reference.to_bits() {
                return Err("scalar kernel dot must BE the sequential reference".into());
            }
            let model = dot_lane_model(&q[start..start + len], &x);
            for kr in non_scalar() {
                let got = kr.dot_packed_int4(&packed, start, &x);
                if got.to_bits() != model.to_bits() {
                    return Err(format!(
                        "{}: dot deviates from the pinned 8-lane model at start={} len={}: {} vs {}",
                        kr.name(),
                        start,
                        len,
                        got,
                        model
                    ));
                }
                // reassociation tolerance vs the sequential order:
                // bounded by ~len * eps * sum|x_j q_j|
                let mag: f32 =
                    x.iter().zip(&q[start..]).map(|(&xv, &qv)| (xv * qv as f32).abs()).sum();
                let tol = 1e-6 * mag + 1e-6;
                if (got - reference).abs() > tol {
                    return Err(format!(
                        "{}: dot too far from sequential reference: {} vs {} (tol {})",
                        kr.name(),
                        got,
                        reference,
                        tol
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_f16_codec_bit_exact_every_backend() {
        prop_check("kernel f16 codec vs scalar, bitwise", 200, |g| {
            let n = g.usize_in(0, 70);
            let mut xs = g.vec_f32(n, -2.0, 2.0);
            // salt with specials + boundary cases every round
            for v in [
                0.0f32,
                -0.0,
                1.0,
                -1.0,
                65504.0,   // f16 max
                65520.0,   // rounds up to +inf
                1e6,       // overflow
                -1e6,
                6.1e-5,    // smallest normal neighborhood
                5.96e-8,   // ~2^-24, smallest subnormal
                4.5e-8,    // in (2^-25, 2^-24): rounds to 0x0001
                2.9e-8,    // just below 2^-25: flushes to zero
                -4.5e-8,
                f32::INFINITY,
                f32::NEG_INFINITY,
                g.f32_in(-1e-4, 1e-4), // subnormal-f16 territory
            ] {
                xs.push(v);
            }
            let m = xs.len();
            let mut want_bits = vec![0u16; m];
            by_kind(KernelKind::Scalar).f16_encode(&xs, &mut want_bits);
            // the scalar slice path must equal the per-element converter
            for (j, (&x, &h)) in xs.iter().zip(&want_bits).enumerate() {
                if h != f32_to_f16_bits(x) {
                    return Err(format!("scalar slice encode != per-element at {}", j));
                }
            }
            let mut want_back = vec![0.0f32; m];
            by_kind(KernelKind::Scalar).f16_decode(&want_bits, &mut want_back);
            for kr in non_scalar() {
                let mut got = vec![0u16; m];
                kr.f16_encode(&xs, &mut got);
                if got != want_bits {
                    let j = got.iter().zip(&want_bits).position(|(a, b)| a != b).unwrap();
                    return Err(format!(
                        "{}: f16 encode mismatch at {} (x={}): {:#06x} vs {:#06x}",
                        kr.name(),
                        j,
                        xs[j],
                        got[j],
                        want_bits[j]
                    ));
                }
                let mut back = vec![0.0f32; m];
                kr.f16_decode(&want_bits, &mut back);
                if back.iter().zip(&want_back).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err(format!("{}: f16 decode mismatch", kr.name()));
                }
            }
            Ok(())
        });
        // NaN: encode/decode must stay NaN on every backend (payloads are
        // unspecified — residual state never contains NaNs).
        for k in available() {
            let kr = by_kind(k);
            let mut h = [0u16; 1];
            kr.f16_encode(&[f32::NAN], &mut h);
            let mut back = [0.0f32; 1];
            kr.f16_decode(&h, &mut back);
            assert!(back[0].is_nan(), "{}: NaN lost in f16 codec", kr.name());
            assert!(f16_bits_to_f32(h[0]).is_nan());
        }
    }

    #[test]
    #[should_panic(expected = "not supported")]
    #[cfg(target_arch = "x86_64")]
    fn by_kind_rejects_unsupported_kind() {
        let _ = by_kind(KernelKind::Neon);
    }

    #[test]
    #[should_panic(expected = "not supported")]
    #[cfg(target_arch = "aarch64")]
    fn by_kind_rejects_unsupported_kind() {
        let _ = by_kind(KernelKind::Avx2);
    }

    #[test]
    fn dispatched_kernel_is_supported_and_forcible() {
        let k = active();
        assert!(k.supported());
        // forcing scalar then restoring auto must both succeed anywhere
        assert_eq!(force(Some(KernelKind::Scalar)).unwrap(), KernelKind::Scalar);
        assert_eq!(active(), KernelKind::Scalar);
        let restored = force(None).unwrap();
        assert!(restored.supported());
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        assert!(force(Some(KernelKind::Avx2)).is_err());
    }
}
