//! NEON backend (aarch64, where NEON is part of the base ABI).
//!
//! Mirrors the AVX2 backend at 4-wide f32 granularity: unfused mul+add
//! along the output-column axis keeps `axpy_*`/`axpby` bit-identical to
//! scalar; `dot_packed_int4` implements the SAME pinned 8-lane FMA
//! layout as AVX2 (two 4-lane accumulators side by side), so the one
//! reassociating primitive agrees bit-for-bit across ISAs. The f16
//! codec stays scalar — stable Rust exposes no aarch64 f16 conversion
//! intrinsics.
//!
//! # Safety
//!
//! NEON is mandatory on aarch64, so the `#[target_feature]` functions
//! here are callable on every aarch64 CPU; raw-pointer loads/stores are
//! bounds-asserted against slice lengths first.

use std::arch::aarch64::*;

use super::{DotKernel, KernelKind};
use crate::quant::pack;
use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

pub struct NeonKernel;

impl DotKernel for NeonKernel {
    fn kind(&self) -> KernelKind {
        KernelKind::Neon
    }

    fn unpack_int4_row(&self, bytes: &[u8], start: usize, out: &mut [i8]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { unpack_row(bytes, start, out) }
    }

    fn axpy_i8(&self, acc: &mut [f32], xv: f32, w: &[i8]) {
        assert_eq!(acc.len(), w.len(), "axpy_i8 length mismatch");
        // SAFETY: NEON is baseline on aarch64; lengths checked above.
        unsafe { axpy_i8(acc, xv, w) }
    }

    fn axpy_f32(&self, acc: &mut [f32], xv: f32, w: &[f32]) {
        assert_eq!(acc.len(), w.len(), "axpy_f32 length mismatch");
        // SAFETY: NEON is baseline on aarch64; lengths checked above.
        unsafe { axpy_f32(acc, xv, w) }
    }

    fn axpby(&self, alpha: f32, g: &[f32], gamma: f32, u: &mut [f32]) {
        assert_eq!(g.len(), u.len(), "axpby length mismatch");
        // SAFETY: NEON is baseline on aarch64; lengths checked above.
        unsafe { axpby(alpha, g, gamma, u) }
    }

    fn dot_packed_int4(&self, bytes: &[u8], start: usize, x: &[f32]) -> f32 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { dot_packed(bytes, start, x) }
    }

    fn f16_encode(&self, xs: &[f32], out: &mut [u16]) {
        assert_eq!(xs.len(), out.len(), "f16 encode length mismatch");
        for (o, &x) in out.iter_mut().zip(xs.iter()) {
            *o = f32_to_f16_bits(x);
        }
    }

    fn f16_decode(&self, bits: &[u16], out: &mut [f32]) {
        assert_eq!(bits.len(), out.len(), "f16 decode length mismatch");
        for (o, &h) in out.iter_mut().zip(bits.iter()) {
            *o = f16_bits_to_f32(h);
        }
    }
}

/// Nibble-LUT unpack, 32 int4 values per 16-byte load: `tbl` over the
/// sign-extension table, then zip the low/high-nibble lanes back into
/// element order. Exact integer work.
#[target_feature(enable = "neon")]
unsafe fn unpack_row(bytes: &[u8], start: usize, out: &mut [i8]) {
    let n = out.len();
    if n == 0 {
        return;
    }
    assert!(
        bytes.len() * 2 >= start + n,
        "packed buffer too short: {} bytes for window [{}, {})",
        bytes.len(),
        start,
        start + n
    );
    if start % 2 != 0 {
        pack::unpack_int4_row(bytes, start, out);
        return;
    }
    const LUT: [i8; 16] = [0, 1, 2, 3, 4, 5, 6, 7, -8, -7, -6, -5, -4, -3, -2, -1];
    let lut = vld1q_s8(LUT.as_ptr());
    let maskf = vdupq_n_u8(0x0f);
    let mut i = 0usize;
    while i + 32 <= n {
        let x = vld1q_u8(bytes.as_ptr().add((start + i) / 2));
        let lo = vqtbl1q_s8(lut, vandq_u8(x, maskf));
        let hi = vqtbl1q_s8(lut, vshrq_n_u8::<4>(x));
        vst1q_s8(out.as_mut_ptr().add(i), vzip1q_s8(lo, hi));
        vst1q_s8(out.as_mut_ptr().add(i + 16), vzip2q_s8(lo, hi));
        i += 32;
    }
    if i < n {
        pack::unpack_int4_row(&bytes[(start + i) / 2..], 0, &mut out[i..]);
    }
}

/// Widen 8 int8 weights to two 4-lane f32 vectors.
#[inline(always)]
unsafe fn widen8(w: *const i8) -> (float32x4_t, float32x4_t) {
    let w16 = vmovl_s8(vld1_s8(w));
    (
        vcvtq_f32_s32(vmovl_s16(vget_low_s16(w16))),
        vcvtq_f32_s32(vmovl_s16(vget_high_s16(w16))),
    )
}

/// `acc[c] += xv * w[c] as f32`, unfused — bit-identical to scalar.
#[target_feature(enable = "neon")]
unsafe fn axpy_i8(acc: &mut [f32], xv: f32, w: &[i8]) {
    let n = acc.len();
    let xvv = vdupq_n_f32(xv);
    let mut i = 0usize;
    while i + 8 <= n {
        let (w03, w47) = widen8(w.as_ptr().add(i));
        let a03 = vld1q_f32(acc.as_ptr().add(i));
        let a47 = vld1q_f32(acc.as_ptr().add(i + 4));
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a03, vmulq_f32(xvv, w03)));
        vst1q_f32(acc.as_mut_ptr().add(i + 4), vaddq_f32(a47, vmulq_f32(xvv, w47)));
        i += 8;
    }
    while i < n {
        acc[i] += xv * w[i] as f32;
        i += 1;
    }
}

/// `acc[c] += xv * w[c]`, unfused — bit-identical to scalar.
#[target_feature(enable = "neon")]
unsafe fn axpy_f32(acc: &mut [f32], xv: f32, w: &[f32]) {
    let n = acc.len();
    let xvv = vdupq_n_f32(xv);
    let mut i = 0usize;
    while i + 4 <= n {
        let wv = vld1q_f32(w.as_ptr().add(i));
        let a = vld1q_f32(acc.as_ptr().add(i));
        vst1q_f32(acc.as_mut_ptr().add(i), vaddq_f32(a, vmulq_f32(xvv, wv)));
        i += 4;
    }
    while i < n {
        acc[i] += xv * w[i];
        i += 1;
    }
}

/// `u[i] = alpha * g[i] + gamma * u[i]`, unfused — bit-identical to
/// scalar.
#[target_feature(enable = "neon")]
unsafe fn axpby(alpha: f32, g: &[f32], gamma: f32, u: &mut [f32]) {
    let n = u.len();
    let av = vdupq_n_f32(alpha);
    let cv = vdupq_n_f32(gamma);
    let mut i = 0usize;
    while i + 4 <= n {
        let gv = vld1q_f32(g.as_ptr().add(i));
        let uv = vld1q_f32(u.as_ptr().add(i));
        vst1q_f32(u.as_mut_ptr().add(i), vaddq_f32(vmulq_f32(av, gv), vmulq_f32(cv, uv)));
        i += 4;
    }
    while i < n {
        u[i] = alpha * g[i] + gamma * u[i];
        i += 1;
    }
}

/// Packed-int4 dot with the SAME pinned 8-lane FMA layout as AVX2: two
/// 4-lane accumulators stand in for lanes 0-3 / 4-7, `vfma` is the
/// correctly-rounded fused op, and the reduction replays the fixed order
/// `s4[l] = acc[l] + acc[l+4]; s2[l] = s4[l] + s4[l+2]; s2[0] + s2[1]`.
#[target_feature(enable = "neon")]
unsafe fn dot_packed(bytes: &[u8], start: usize, x: &[f32]) -> f32 {
    let n = x.len();
    assert!(
        bytes.len() * 2 >= start + n,
        "packed buffer too short: {} bytes for window [{}, {})",
        bytes.len(),
        start,
        start + n
    );
    let mut acc0 = vdupq_n_f32(0.0); // model lanes 0..4
    let mut acc1 = vdupq_n_f32(0.0); // model lanes 4..8
    let mut i = 0usize;
    let mut s8 = [0i8; 8];
    while i + 8 <= n {
        pack::unpack_int4_row(bytes, start + i, &mut s8);
        let (w03, w47) = widen8(s8.as_ptr());
        let x03 = vld1q_f32(x.as_ptr().add(i));
        let x47 = vld1q_f32(x.as_ptr().add(i + 4));
        acc0 = vfmaq_f32(acc0, x03, w03);
        acc1 = vfmaq_f32(acc1, x47, w47);
        i += 8;
    }
    let s4 = vaddq_f32(acc0, acc1);
    let s2 = vadd_f32(vget_low_f32(s4), vget_high_f32(s4));
    let mut sum = vget_lane_f32::<0>(s2) + vget_lane_f32::<1>(s2);
    let mut one = [0i8; 1];
    while i < n {
        pack::unpack_int4_row(bytes, start + i, &mut one);
        sum += x[i] * one[0] as f32;
        i += 1;
    }
    sum
}
