//! Quantization substrate: lattice formats, PTQ, GPTQ and INT4 packing.
//!
//! The paper's weights live on a symmetric per-output-channel integer grid
//! (Appendix A.1): scale `s_j = max_i |W_ij| / (2^{B-1}-1)`, lattice range
//! `[-(2^{B-1}-1), 2^{B-1}-1]` (note -2^{B-1} is excluded — symmetric).
//! Weight layout convention is `[in, out]` = `[rows, cols]`, with one scale
//! per *column* (output channel), matching the L1 kernels.

pub mod gptq;
pub mod pack;

pub use gptq::gptq_quantize;
pub use pack::{pack_int4, unpack_int4, unpack_int4_dot, unpack_int4_row};

/// The quantization formats evaluated in the paper (Tables 1-2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Format {
    /// 4-bit weights, FP activations (GPTQ-style).
    Int4,
    /// 8-bit weights, FP activations (GPTQ-style).
    Int8,
    /// 8-bit weights AND 8-bit (dynamic per-tensor) activations.
    W8A8,
    /// Full precision (baselines: MeZO, first-order).
    Fp32,
}

impl Format {
    pub fn parse(s: &str) -> anyhow::Result<Format> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "int4" | "w4" => Format::Int4,
            "int8" | "w8" => Format::Int8,
            "w8a8" => Format::W8A8,
            "fp32" | "fp" => Format::Fp32,
            other => anyhow::bail!("unknown format {:?} (int4|int8|w8a8|fp32)", other),
        })
    }

    /// Bits per weight on the lattice.
    pub fn bits(self) -> u32 {
        match self {
            Format::Int4 => 4,
            Format::Int8 | Format::W8A8 => 8,
            Format::Fp32 => 32,
        }
    }

    /// Largest admissible |lattice value|: 2^{B-1} - 1.
    pub fn qmax(self) -> i8 {
        match self {
            Format::Int4 => 7,
            Format::Int8 | Format::W8A8 => 127,
            Format::Fp32 => panic!("fp32 has no lattice"),
        }
    }

    /// Which AOT artifact family serves this format.
    pub fn artifact_format(self) -> &'static str {
        match self {
            Format::Int4 | Format::Int8 => "wq",
            Format::W8A8 => "w8a8",
            Format::Fp32 => "fp",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::Int4 => "int4",
            Format::Int8 => "int8",
            Format::W8A8 => "w8a8",
            Format::Fp32 => "fp32",
        }
    }
}

/// A per-output-channel symmetrically quantized matrix, layout `[rows, cols]`
/// (row-major), one scale per column.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl QuantizedTensor {
    /// Dequantize back to f32 (for tests / baselines).
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.q.len()];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let i = r * self.cols + c;
                out[i] = self.q[i] as f32 * self.scale[c];
            }
        }
        out
    }
}

/// Round-to-nearest PTQ onto the symmetric per-channel grid.
///
/// `w` is `[rows, cols]` row-major; returns lattice values clipped to
/// `[-qmax, qmax]` and per-column scales.
pub fn ptq_quantize(w: &[f32], rows: usize, cols: usize, qmax: i8) -> QuantizedTensor {
    assert_eq!(w.len(), rows * cols);
    let qmaxf = qmax as f32;
    let mut scale = vec![0.0f32; cols];
    for c in 0..cols {
        let mut absmax = 0.0f32;
        for r in 0..rows {
            absmax = absmax.max(w[r * cols + c].abs());
        }
        scale[c] = if absmax > 0.0 { absmax / qmaxf } else { 1.0 };
    }
    let mut q = vec![0i8; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            let v = (w[i] / scale[c]).round();
            q[i] = v.clamp(-qmaxf, qmaxf) as i8;
        }
    }
    QuantizedTensor { q, scale, rows, cols }
}

/// Max elementwise |W - dequant(Q)| — the PTQ reconstruction error.
pub fn recon_error(w: &[f32], qt: &QuantizedTensor) -> f32 {
    let deq = qt.dequant();
    w.iter()
        .zip(deq.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn format_parsing() {
        assert_eq!(Format::parse("INT4").unwrap(), Format::Int4);
        assert_eq!(Format::parse("w8").unwrap(), Format::Int8);
        assert_eq!(Format::parse("w8a8").unwrap(), Format::W8A8);
        assert!(Format::parse("int2").is_err());
    }

    #[test]
    fn qmax_values() {
        assert_eq!(Format::Int4.qmax(), 7);
        assert_eq!(Format::Int8.qmax(), 127);
        assert_eq!(Format::W8A8.qmax(), 127);
    }

    #[test]
    fn ptq_zero_matrix() {
        let qt = ptq_quantize(&[0.0; 12], 3, 4, 7);
        assert!(qt.q.iter().all(|&x| x == 0));
        assert!(qt.scale.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn ptq_absmax_hits_qmax() {
        // The per-column absmax element must map exactly to ±qmax.
        let w = vec![0.1, -2.0, 0.05, 1.0]; // 2x2: cols {0.1,0.05}, {-2,1}
        let qt = ptq_quantize(&w, 2, 2, 7);
        assert_eq!(qt.q[0], 7); // 0.1 is col-0 absmax
        assert_eq!(qt.q[2 * 0 + 1], -7); // -2.0 is col-1 absmax
    }

    #[test]
    fn ptq_reconstruction_error_bounded() {
        prop_check("ptq error <= scale/2", 50, |g| {
            let rows = g.usize_in(1, 24);
            let cols = g.usize_in(1, 24);
            let w = g.vec_f32(rows * cols, -3.0, 3.0);
            for &qmax in &[7i8, 127] {
                let qt = ptq_quantize(&w, rows, cols, qmax);
                for r in 0..rows {
                    for c in 0..cols {
                        let i = r * cols + c;
                        let err = (w[i] - qt.q[i] as f32 * qt.scale[c]).abs();
                        if err > qt.scale[c] / 2.0 + 1e-5 {
                            return Err(format!(
                                "err {} > scale/2 {} at ({},{})",
                                err,
                                qt.scale[c] / 2.0,
                                r,
                                c
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ptq_lattice_in_range() {
        prop_check("lattice within ±qmax", 50, |g| {
            let rows = g.usize_in(1, 16);
            let cols = g.usize_in(1, 16);
            let w = g.vec_f32(rows * cols, -10.0, 10.0);
            let qt = ptq_quantize(&w, rows, cols, 7);
            if qt.q.iter().any(|&x| x < -7 || x > 7) {
                return Err("out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn dequant_roundtrip_int8_precise() {
        // INT8 on a well-conditioned matrix: relative error < 1%.
        let mut g = crate::util::prop::Gen::from_seed(5);
        let w = g.vec_f32(64 * 32, -1.0, 1.0);
        let qt = ptq_quantize(&w, 64, 32, 127);
        let err = recon_error(&w, &qt);
        assert!(err < 0.01, "err={}", err);
    }
}
