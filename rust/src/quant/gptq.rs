//! GPTQ: Hessian-aware error-compensated quantization (Frantar et al. 2023).
//!
//! The paper quantizes its backbones "to INT4 and INT8 with GPTQ" (§4.1);
//! this module implements the algorithm so the repo's PTQ pipeline matches.
//!
//! For a linear layer `y = x W` with `W in R^{K x N}` ([in, out], one grid
//! per output column), GPTQ minimizes `||x W - x W_q||^2` over a calibration
//! set. Writing `H = X^T X + lambda I` (K x K), columns of W are quantized
//! one *input row* at a time in order; after quantizing row k, the induced
//! error is propagated into the not-yet-quantized rows using the Cholesky
//! factor of `H^{-1}` — exactly the "lazy batch" formulation of the paper,
//! specialized to full-matrix updates (our K <= 512, so no batching needed).
//!
//! Per-column scales are fixed up-front from absmax (the same grid PTQ
//! uses), so GPTQ here only improves the *rounding*, not the grid — which is
//! the configuration QES assumes (a fixed lattice it can walk on).

use super::QuantizedTensor;

/// Dense symmetric positive-definite matrix utilities (row-major, n x n).
pub(crate) fn cholesky(a: &mut [f64], n: usize) -> anyhow::Result<()> {
    // In-place lower Cholesky: a = L L^T, L stored in the lower triangle.
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            d -= a[j * n + k] * a[j * n + k];
        }
        if d <= 0.0 {
            anyhow::bail!("cholesky: matrix not positive definite at {}", j);
        }
        let ljj = d.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / ljj;
        }
    }
    // zero the strict upper triangle for cleanliness
    for i in 0..n {
        for j in (i + 1)..n {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Invert an SPD matrix via its Cholesky factor (returns row-major inverse).
pub(crate) fn spd_inverse(a: &[f64], n: usize) -> anyhow::Result<Vec<f64>> {
    let mut l = a.to_vec();
    cholesky(&mut l, n)?;
    // Solve L Y = I, then L^T X = Y  =>  X = A^{-1}.
    let mut inv = vec![0.0f64; n * n];
    for col in 0..n {
        // forward solve L y = e_col
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l[i * n + k] * y[k];
            }
            y[i] = s / l[i * n + i];
        }
        // back solve L^T x = y
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= l[k * n + i] * inv[k * n + col];
            }
            inv[i * n + col] = s / l[i * n + i];
        }
    }
    Ok(inv)
}

/// GPTQ quantization of `w` ([rows=K(in), cols=N(out)], row-major) against
/// calibration activations `x` ([n_samples, K], row-major).
///
/// `damp` is the relative dampening factor (lambda = damp * mean(diag H)),
/// GPTQ's default is 0.01.
pub fn gptq_quantize(
    w: &[f32],
    rows: usize,
    cols: usize,
    qmax: i8,
    x: &[f32],
    n_samples: usize,
    damp: f64,
) -> anyhow::Result<QuantizedTensor> {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(x.len(), n_samples * rows);
    let k = rows;
    let qmaxf = qmax as f32;

    // Per-column scales from absmax (grid identical to plain PTQ).
    let mut scale = vec![0.0f32; cols];
    for c in 0..cols {
        let mut absmax = 0.0f32;
        for r in 0..k {
            absmax = absmax.max(w[r * cols + c].abs());
        }
        scale[c] = if absmax > 0.0 { absmax / qmaxf } else { 1.0 };
    }

    // H = X^T X + lambda I  (K x K, f64 for stability).
    let mut h = vec![0.0f64; k * k];
    for s in 0..n_samples {
        let xs = &x[s * k..(s + 1) * k];
        for i in 0..k {
            let xi = xs[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in 0..k {
                h[i * k + j] += xi * xs[j] as f64;
            }
        }
    }
    let mean_diag = (0..k).map(|i| h[i * k + i]).sum::<f64>() / k as f64;
    let lambda = damp * if mean_diag > 0.0 { mean_diag } else { 1.0 };
    for i in 0..k {
        h[i * k + i] += lambda;
    }

    // Hinv and its Cholesky factorization (upper form used by GPTQ).
    let hinv = spd_inverse(&h, k)?;
    // U = chol(Hinv)^T upper-triangular with U[i][i] = sqrt diag factor:
    // GPTQ uses Cholesky of Hinv in *upper* form; compute lower then
    // transpose.
    let mut lo = hinv.clone();
    cholesky(&mut lo, k)?;
    // upper[i][j] = lo[j][i] for j >= i
    let upper = |i: usize, j: usize| lo[j * k + i];

    // Work on a residual copy of W (f64 accumulation).
    let mut wr: Vec<f64> = w.iter().map(|&v| v as f64).collect();
    let mut q = vec![0i8; rows * cols];

    for i in 0..k {
        let d = upper(i, i); // = sqrt(Hinv[i,i]) after factorization
        for c in 0..cols {
            let wv = wr[i * cols + c];
            let qv = (wv / scale[c] as f64).round().clamp(-(qmaxf as f64), qmaxf as f64);
            q[i * cols + c] = qv as i8;
            let err = (wv - qv * scale[c] as f64) / d;
            // propagate into remaining rows j > i
            for j in (i + 1)..k {
                let u = upper(i, j);
                if u != 0.0 {
                    wr[j * cols + c] -= err * u;
                }
            }
        }
    }

    Ok(QuantizedTensor { q, scale, rows, cols })
}

/// Quantization objective: ||X W - X dequant(Q)||_F^2 over the calibration
/// set — the quantity GPTQ minimizes; used by tests and the ablation bench.
pub fn calib_loss(
    w: &[f32],
    qt: &QuantizedTensor,
    x: &[f32],
    n_samples: usize,
) -> f64 {
    let k = qt.rows;
    let n = qt.cols;
    let deq = qt.dequant();
    let mut total = 0.0f64;
    for s in 0..n_samples {
        let xs = &x[s * k..(s + 1) * k];
        for c in 0..n {
            let mut y = 0.0f64;
            let mut yq = 0.0f64;
            for r in 0..k {
                let xv = xs[r] as f64;
                y += xv * w[r * n + c] as f64;
                yq += xv * deq[r * n + c] as f64;
            }
            total += (y - yq) * (y - yq);
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ptq_quantize;
    use crate::util::prop::{prop_check, Gen};

    #[test]
    fn cholesky_identity() {
        let mut a = vec![0.0f64; 9];
        for i in 0..3 {
            a[i * 3 + i] = 4.0;
        }
        cholesky(&mut a, 3).unwrap();
        for i in 0..3 {
            assert!((a[i * 3 + i] - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn spd_inverse_correct() {
        // A = [[2,1],[1,3]]; A^{-1} = 1/5 [[3,-1],[-1,2]]
        let a = vec![2.0, 1.0, 1.0, 3.0];
        let inv = spd_inverse(&a, 2).unwrap();
        assert!((inv[0] - 0.6).abs() < 1e-12);
        assert!((inv[1] + 0.2).abs() < 1e-12);
        assert!((inv[3] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&mut a, 2).is_err());
    }

    fn random_problem(g: &mut Gen, k: usize, n: usize, ns: usize) -> (Vec<f32>, Vec<f32>) {
        let w = g.vec_f32(k * n, -1.0, 1.0);
        // correlated activations to make the Hessian non-trivial
        let base = g.vec_f32(ns * k, -1.0, 1.0);
        let mut x = base.clone();
        for s in 0..ns {
            for i in 1..k {
                x[s * k + i] = 0.6 * x[s * k + i - 1] + 0.4 * base[s * k + i];
            }
        }
        (w, x)
    }

    #[test]
    fn gptq_beats_or_matches_ptq_on_calib_loss() {
        // The whole point of GPTQ: lower ||XW - XWq||^2 than naive rounding.
        let mut wins = 0;
        let trials = 10;
        for seed in 0..trials {
            let mut g = Gen::from_seed(seed + 100);
            let (k, n, ns) = (16, 8, 64);
            let (w, x) = random_problem(&mut g, k, n, ns);
            let ptq = ptq_quantize(&w, k, n, 7);
            let gq = gptq_quantize(&w, k, n, 7, &x, ns, 0.01).unwrap();
            let lp = calib_loss(&w, &ptq, &x, ns);
            let lg = calib_loss(&w, &gq, &x, ns);
            if lg <= lp * 1.0001 {
                wins += 1;
            }
        }
        assert!(wins >= 8, "gptq no better than ptq in {}/{} trials", trials - wins, trials);
    }

    #[test]
    fn gptq_lattice_in_range() {
        prop_check("gptq lattice in ±qmax", 20, |g| {
            let k = g.usize_in(2, 12);
            let n = g.usize_in(1, 8);
            let ns = g.usize_in(4, 32);
            let (w, x) = random_problem(g, k, n, ns);
            let qt = gptq_quantize(&w, k, n, 7, &x, ns, 0.01).map_err(|e| e.to_string())?;
            if qt.q.iter().any(|&v| v < -7 || v > 7) {
                return Err("lattice out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn gptq_identity_hessian_equals_ptq() {
        // With orthonormal-ish (identity) calibration, error propagation is
        // zero and GPTQ must reduce to round-to-nearest.
        let k = 8;
        let n = 4;
        let mut g = Gen::from_seed(7);
        let w = g.vec_f32(k * n, -1.0, 1.0);
        // X = sqrt(ns) * I pattern: each sample is a unit basis vector
        let ns = k;
        let mut x = vec![0.0f32; ns * k];
        for s in 0..ns {
            x[s * k + s] = 1.0;
        }
        let gq = gptq_quantize(&w, k, n, 7, &x, ns, 1e-6).unwrap();
        let ptq = ptq_quantize(&w, k, n, 7);
        assert_eq!(gq.q, ptq.q);
    }
}
