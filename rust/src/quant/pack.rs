//! INT4 nibble packing.
//!
//! The PJRT artifacts take int8 tensors (S4 is not marshallable through the
//! runtime), so INT4 lattices are *stored and executed* as int8 — but the
//! paper's memory accounting (Table 8) and the checkpoint format both use
//! the true packed footprint: two 4-bit values per byte.

/// Pack int4 values (each in [-8, 7]; QES uses [-7, 7]) into nibbles.
/// Odd-length inputs get a zero pad nibble.
pub fn pack_int4(q: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity((q.len() + 1) / 2);
    let mut i = 0;
    while i + 1 < q.len() {
        let lo = (q[i] as u8) & 0x0f;
        let hi = (q[i + 1] as u8) & 0x0f;
        out.push(lo | (hi << 4));
        i += 2;
    }
    if i < q.len() {
        out.push((q[i] as u8) & 0x0f);
    }
    out
}

/// Unpack nibbles back to int8 (sign-extended from 4 bits). `n` is the
/// original element count (to drop a possible pad nibble). Dispatches to
/// the active SIMD microkernel (`crate::kernel`); [`unpack_int4_row`] is
/// the scalar reference every backend is conformance-tested against.
pub fn unpack_int4(bytes: &[u8], n: usize) -> Vec<i8> {
    assert!(bytes.len() * 2 >= n, "byte buffer too short for {} int4 values", n);
    let mut out = vec![0i8; n];
    crate::kernel::active_kernel().unpack_int4_row(bytes, 0, &mut out);
    out
}

#[inline]
fn sign_extend4(nib: u8) -> i8 {
    ((nib << 4) as i8) >> 4
}

#[inline]
fn nibble_at(bytes: &[u8], i: usize) -> i8 {
    let b = bytes[i / 2];
    sign_extend4(if i % 2 == 0 { b & 0x0f } else { b >> 4 })
}

/// Row-gather for the fused GEMM inner loop: unpack `out.len()` int4
/// values starting at flat element `start` into caller-owned scratch —
/// one weight row per call, no full-slice unpack, no allocation.
///
/// This is the SCALAR REFERENCE implementation; the GEMM hot path goes
/// through `crate::kernel` (which dispatches to the AVX2/NEON nibble-LUT
/// unpack and is property-tested for exact agreement with this one).
pub fn unpack_int4_row(bytes: &[u8], start: usize, out: &mut [i8]) {
    if start % 2 == 0 {
        // aligned fast path: whole bytes, two lanes at a time
        let mut i = 0;
        let mut byte = start / 2;
        while i + 1 < out.len() {
            let b = bytes[byte];
            out[i] = sign_extend4(b & 0x0f);
            out[i + 1] = sign_extend4(b >> 4);
            i += 2;
            byte += 1;
        }
        if i < out.len() {
            out[i] = sign_extend4(bytes[byte] & 0x0f);
        }
    } else {
        for (j, o) in out.iter_mut().enumerate() {
            *o = nibble_at(bytes, start + j);
        }
    }
}

/// Fused gather + dot over a nibble-packed buffer:
/// `sum_j x[j] * q[start + j]`, accumulated in f32 in index order
/// (deterministic for any caller partitioning). The row-major GEMM in
/// `runtime::native::gemm` uses the axpy formulation over
/// [`unpack_int4_row`]; this is the companion primitive for K-major
/// (transposed-weight) consumers, and the bit-exactness reference the
/// property tests pin both against.
pub fn unpack_int4_dot(bytes: &[u8], start: usize, x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (j, &xv) in x.iter().enumerate() {
        acc += xv * nibble_at(bytes, start + j) as f32;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_even() {
        let q: Vec<i8> = vec![-7, 7, 0, 1, -1, 3, -4, 5];
        assert_eq!(unpack_int4(&pack_int4(&q), q.len()), q);
    }

    #[test]
    fn roundtrip_odd() {
        let q: Vec<i8> = vec![-7, 7, 3];
        let packed = pack_int4(&q);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_int4(&packed, 3), q);
    }

    #[test]
    fn packed_size_halves() {
        let q = vec![1i8; 1000];
        assert_eq!(pack_int4(&q).len(), 500);
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend4(0x0f), -1);
        assert_eq!(sign_extend4(0x08), -8);
        assert_eq!(sign_extend4(0x07), 7);
        assert_eq!(sign_extend4(0x00), 0);
    }

    #[test]
    fn prop_roundtrip() {
        prop_check("int4 pack/unpack roundtrip", 100, |g| {
            let n = g.usize_in(0, 257);
            let q = g.vec_i8(n, -8, 7);
            let got = if n == 0 {
                Vec::new()
            } else {
                unpack_int4(&pack_int4(&q), n)
            };
            if got != q {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_odd_lengths_full_nibble_range() {
        // Two properties the plain roundtrip only hits by chance:
        // (a) ODD lengths — the pad-nibble path on pack and the early-
        //     break path on unpack must agree for every odd n;
        // (b) the FULL [-8, 7] nibble range — every value must survive
        //     sign extension, including -8 (0b1000), which QES itself
        //     never produces (its grid is symmetric, [-7, 7]).
        prop_check("int4 roundtrip, odd n + full range", 200, |g| {
            let n = 2 * g.usize_in(0, 128) + 1; // always odd, 1..=257
            let q = g.vec_i8(n, -8, 7);
            let packed = pack_int4(&q);
            if packed.len() != n / 2 + 1 {
                return Err(format!("odd n={} packed to {} bytes", n, packed.len()));
            }
            // the pad nibble must be zero so packed bytes are canonical
            if packed[n / 2] >> 4 != 0 {
                return Err("nonzero pad nibble".into());
            }
            let got = unpack_int4(&packed, n);
            if got != q {
                return Err(format!("odd-length mismatch at n={}", n));
            }
            Ok(())
        });
        // exhaustive: every nibble value in [-8, 7], both lane positions
        let all: Vec<i8> = (-8..=7).collect();
        assert_eq!(unpack_int4(&pack_int4(&all), all.len()), all);
        let mut rev = all.clone();
        rev.reverse();
        assert_eq!(unpack_int4(&pack_int4(&rev), rev.len()), rev);
        for &v in &all {
            // each value alone exercises the lo lane + pad
            assert_eq!(unpack_int4(&pack_int4(&[v]), 1), vec![v], "value {}", v);
        }
    }

    #[test]
    fn prop_row_gather_and_dot_match_scalar_reference() {
        // The GEMM inner-loop primitives must agree with the scalar
        // reference (full-slice unpack) for EVERY window — in particular
        // odd `start` (the misaligned half-byte path) and windows ending
        // mid-byte.
        prop_check("unpack_int4_row/dot vs full unpack", 200, |g| {
            let n = g.usize_in(1, 300);
            let q = g.vec_i8(n, -8, 7);
            let packed = pack_int4(&q);
            let reference = unpack_int4(&packed, n); // scalar reference
            let start = g.usize_in(0, n - 1);
            let len = g.usize_in(0, n - start);
            let mut row = vec![0i8; len];
            unpack_int4_row(&packed, start, &mut row);
            if row != reference[start..start + len] {
                return Err(format!(
                    "row gather mismatch at start={} len={} (n={})",
                    start, len, n
                ));
            }
            // fused dot == dot over the reference window, bit-for-bit
            // (both accumulate in index order)
            let x = g.vec_f32(len, -2.0, 2.0);
            let got = unpack_int4_dot(&packed, start, &x);
            let mut want = 0.0f32;
            for (j, &xv) in x.iter().enumerate() {
                want += xv * reference[start + j] as f32;
            }
            if got.to_bits() != want.to_bits() {
                return Err(format!(
                    "dot mismatch at start={} len={}: {} vs {}",
                    start, len, got, want
                ));
            }
            Ok(())
        });
    }
}
