//! INT4 nibble packing.
//!
//! The PJRT artifacts take int8 tensors (S4 is not marshallable through the
//! runtime), so INT4 lattices are *stored and executed* as int8 — but the
//! paper's memory accounting (Table 8) and the checkpoint format both use
//! the true packed footprint: two 4-bit values per byte.

/// Pack int4 values (each in [-8, 7]; QES uses [-7, 7]) into nibbles.
/// Odd-length inputs get a zero pad nibble.
pub fn pack_int4(q: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity((q.len() + 1) / 2);
    let mut i = 0;
    while i + 1 < q.len() {
        let lo = (q[i] as u8) & 0x0f;
        let hi = (q[i + 1] as u8) & 0x0f;
        out.push(lo | (hi << 4));
        i += 2;
    }
    if i < q.len() {
        out.push((q[i] as u8) & 0x0f);
    }
    out
}

/// Unpack nibbles back to int8 (sign-extended from 4 bits). `n` is the
/// original element count (to drop a possible pad nibble).
pub fn unpack_int4(bytes: &[u8], n: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(n);
    for &b in bytes {
        out.push(sign_extend4(b & 0x0f));
        if out.len() == n {
            break;
        }
        out.push(sign_extend4(b >> 4));
        if out.len() == n {
            break;
        }
    }
    assert_eq!(out.len(), n, "byte buffer too short for {} int4 values", n);
    out
}

#[inline]
fn sign_extend4(nib: u8) -> i8 {
    ((nib << 4) as i8) >> 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn roundtrip_even() {
        let q: Vec<i8> = vec![-7, 7, 0, 1, -1, 3, -4, 5];
        assert_eq!(unpack_int4(&pack_int4(&q), q.len()), q);
    }

    #[test]
    fn roundtrip_odd() {
        let q: Vec<i8> = vec![-7, 7, 3];
        let packed = pack_int4(&q);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_int4(&packed, 3), q);
    }

    #[test]
    fn packed_size_halves() {
        let q = vec![1i8; 1000];
        assert_eq!(pack_int4(&q).len(), 500);
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend4(0x0f), -1);
        assert_eq!(sign_extend4(0x08), -8);
        assert_eq!(sign_extend4(0x07), 7);
        assert_eq!(sign_extend4(0x00), 0);
    }

    #[test]
    fn prop_roundtrip() {
        prop_check("int4 pack/unpack roundtrip", 100, |g| {
            let n = g.usize_in(0, 257);
            let q = g.vec_i8(n, -8, 7);
            let got = if n == 0 {
                Vec::new()
            } else {
                unpack_int4(&pack_int4(&q), n)
            };
            if got != q {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_odd_lengths_full_nibble_range() {
        // Two properties the plain roundtrip only hits by chance:
        // (a) ODD lengths — the pad-nibble path on pack and the early-
        //     break path on unpack must agree for every odd n;
        // (b) the FULL [-8, 7] nibble range — every value must survive
        //     sign extension, including -8 (0b1000), which QES itself
        //     never produces (its grid is symmetric, [-7, 7]).
        prop_check("int4 roundtrip, odd n + full range", 200, |g| {
            let n = 2 * g.usize_in(0, 128) + 1; // always odd, 1..=257
            let q = g.vec_i8(n, -8, 7);
            let packed = pack_int4(&q);
            if packed.len() != n / 2 + 1 {
                return Err(format!("odd n={} packed to {} bytes", n, packed.len()));
            }
            // the pad nibble must be zero so packed bytes are canonical
            if packed[n / 2] >> 4 != 0 {
                return Err("nonzero pad nibble".into());
            }
            let got = unpack_int4(&packed, n);
            if got != q {
                return Err(format!("odd-length mismatch at n={}", n));
            }
            Ok(())
        });
        // exhaustive: every nibble value in [-8, 7], both lane positions
        let all: Vec<i8> = (-8..=7).collect();
        assert_eq!(unpack_int4(&pack_int4(&all), all.len()), all);
        let mut rev = all.clone();
        rev.reverse();
        assert_eq!(unpack_int4(&pack_int4(&rev), rev.len()), rev);
        for &v in &all {
            // each value alone exercises the lo lane + pad
            assert_eq!(unpack_int4(&pack_int4(&[v]), 1), vec![v], "value {}", v);
        }
    }
}
