//! `qes` — the QES launcher.
//!
//! ```text
//! qes info                                          manifest / artifact / metric summary
//! qes pretrain  --size nano --task countdown ...    produce a base fp model
//! qes quantize  --run <dir> --format int4 [--gptq]  PTQ/GPTQ the base model
//! qes eval      --run <dir> --format int4 ...       greedy accuracy of a ckpt
//! qes finetune  --run <dir> --format int4 \
//!               --variant qes|qes-full|quzo \
//!               [--workers n] [--quorum f] \
//!               [--faults spec] [--ckpt-every n] \
//!               [--resume] [--trace-out f]          ES fine-tuning (the paper) on a
//!                                                   supervised fault-tolerant pool,
//!                                                   with crash-consistent resume
//! qes serve     [--ckpt p] [--tcp addr] [--slots n] multi-tenant continuous-batching
//!               [--http addr]                       server: concurrent connections on
//!               [--max-inflight n] [--conn-queue n] ONE scheduler; line-delimited JSON
//!               [--max-line bytes]                  on stdin/--tcp, OpenAI-compatible
//!               [--read-timeout-ms t]               POST /v1/completions on --http;
//!               [--trace-out f]                     GET /metrics serves Prometheus text,
//!                                                   a "stats" line returns a JSON metric
//!                                                   snapshot, --trace-out (or QES_TRACE=1)
//!                                                   records trace spans, dumped as JSONL
//! qes exp       table1|table2|table5|table6|        regenerate a paper table
//!               table7|table8|table9|fig2|fig3 ...  or figure
//! ```
//!
//! Runs live under `runs/<size>_<task>/`: `fp.ckpt` (pretrained base),
//! `<format>.ckpt` (quantized), `<format>_<variant>.ckpt` (+ `.csv` log),
//! `<format>_<variant>.train.ckpt` (crash-consistent training state for
//! `--resume`). Fault injection reads `--faults` or the `QES_FAULTS` env
//! var (e.g. `seed=7,eval=0.1,kill=0.05,drop=0.05,delay=0.2,delay_ms=10`).

use anyhow::Result;
use qes::exp;
use qes::util::args::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("usage: qes <info|pretrain|quantize|eval|finetune|serve|exp> [--flags]");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(argv[1..].iter().cloned()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {:#}", e);
            std::process::exit(2);
        }
    };
    let r = match cmd.as_str() {
        "info" => cmd_info(args),
        "pretrain" => exp::cli::cmd_pretrain(args),
        "quantize" => exp::cli::cmd_quantize(args),
        "eval" => exp::cli::cmd_eval(args),
        "finetune" => exp::cli::cmd_finetune(args),
        "serve" => exp::cli::cmd_serve(args),
        "exp" => exp::cli::cmd_exp(args),
        other => {
            eprintln!("unknown command {:?}", other);
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {:#}", e);
        std::process::exit(1);
    }
}

fn cmd_info(mut args: Args) -> Result<()> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    args.finish()?;
    let man = qes::runtime::Manifest::load(&manifest)?;
    println!("manifest: {}", manifest);
    println!(
        "kernels: dispatched {} | available on this CPU: {}",
        qes::kernel::active().name(),
        qes::kernel::available()
            .iter()
            .map(|k| k.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!("\nmodel configs:");
    for (name, c) in &man.configs {
        println!(
            "  {:<6} d={} L={} H={} ff={} vocab={} | prompt {} dec {} train {} | lattice params {}",
            name, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.vocab, c.s_prompt, c.t_dec,
            c.s_train, c.lattice_params
        );
        // serving-side KV memory: the paged arena allocates bytes/page on
        // demand, so the dense bytes/slot number is a worst-case bound
        let s_max = c.s_prompt + c.t_dec;
        let page_rows = match qes::sched::default_page_rows() {
            0 => s_max,
            p => p.min(s_max),
        };
        let slot_bytes = c.n_layers * 2 * s_max * c.d_model * 4;
        let page_bytes = c.n_layers * 2 * page_rows * c.d_model * 4;
        println!(
            "         kv: paged {}/page ({} rows, on demand) | dense bound {}/slot x b_gen={} = {}",
            qes::util::human_bytes(page_bytes as u64),
            page_rows,
            qes::util::human_bytes(slot_bytes as u64),
            c.b_gen,
            qes::util::human_bytes((slot_bytes * c.b_gen) as u64),
        );
    }
    println!("\nartifacts ({}):", man.artifacts().len());
    for a in man.artifacts() {
        println!("  {:<28} {:>2} data inputs, {:>3} param inputs, {} outputs",
            a.file, a.data_inputs.len(), a.n_param_inputs, a.outputs.len());
    }
    // the observability catalog: every built-in metric family, as served
    // by `GET /metrics` / the `stats` command (register them first)
    let _ = qes::obs::m();
    let catalog = qes::obs::registry().catalog();
    println!("\nmetrics ({}):", catalog.len());
    for (name, kind, help) in catalog {
        println!("  {:<32} {:<9} {}", name, kind, help);
    }
    Ok(())
}
