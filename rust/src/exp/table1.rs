//! Table 1 — SFT accuracy (%) on the four synthetic classification tasks:
//! FO-FP32 (upper bound), MeZO-FP32, FO+STE-W8, QuZO-W8, QES-W8.
//!
//! Shape criteria: FO-FP32 on top; among W8 methods QES > QuZO and
//! QES > FO+STE on average; QES also beats full-precision MeZO.

use anyhow::Result;

use crate::coordinator::{
    finetune_mezo, finetune_store, pretrain_cls, ClsWorkload, EngineSet, FinetuneCfg,
    PretrainCfg, Session, Variant, Workload,
};
use crate::exp::cli::parse_ft_args;
use crate::exp::write_result;
use crate::model::{init::init_fp, AsParams, ParamStore};
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::cls_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let mut fa = parse_ft_args(args)?;
    let size = args.get_or("cls-size", "nano");
    let tasks: Vec<String> = args
        .get_or("tasks", "snli,mnli,rte,sst5")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let fo_steps = args.get_usize("fo-steps", 300)?;
    args.finish()?;
    fa.size = size;
    let man = Manifest::load(&fa.manifest)?;

    let methods = ["first-order fp32", "mezo fp32", "first-order+ste w8", "quzo w8", "qes w8"];
    let mut table: Vec<Vec<f32>> = vec![Vec::new(); methods.len()];

    for task_name in &tasks {
        let task = cls_task(task_name)?;
        // A COMMON random init for every method (pretraining from scratch is
        // the "fine-tuning" here, matching the k-shot-from-pretrained setup
        // as closely as our from-scratch pipeline allows: the pretrained
        // state is the LM-initialized backbone).
        let fp_session = Session::new(&man, &fa.size, Format::Fp32, EngineSet {
            cls: true, grad: true, ..Default::default()
        })?;
        let mut fp0 = ParamStore::from_manifest(&man, &fa.size, Format::Fp32)?;
        init_fp(&mut fp0, 0x517);
        // light LM warmup so quantization grids are meaningful, shared by all
        let warm = PretrainCfg { steps: 150, lr: 3e-3, seed: 3, ste_qmax: None, verbose: false };
        let mut fp_base = fp0.clone();
        pretrain_cls(&fp_session, task.as_ref(), &mut fp_base, &warm)?;
        // ONE workload per task: every method trains against the same
        // k-shot batches and is measured on the same held-out eval set.
        let cfg = FinetuneCfg { verbose: false, ..fa.cfg.clone() };
        let workload = ClsWorkload::new(cls_task(task_name)?, &fp_session.cfg, &cfg, fa.k_shot);

        // --- FO FP32 (upper bound): continue training with Adam ---
        let mut fo_store = fp_base.clone();
        let focfg = PretrainCfg { steps: fo_steps, lr: 1e-3, seed: 11, ste_qmax: None, verbose: false };
        pretrain_cls(&fp_session, task.as_ref(), &mut fo_store, &focfg)?;
        let fo_acc = workload.eval_accuracy(&fp_session, &fo_store.params_view())?;
        table[0].push(fo_acc);

        // --- MeZO FP32 ---
        let mut mezo_store = fp_base.clone();
        let log = finetune_mezo(&fp_session, &workload, &mut mezo_store, &cfg)?;
        table[1].push(log.final_acc);

        // --- FO + STE on the W8 grid ---
        let mut ste_store = fp_base.clone();
        let stecfg = PretrainCfg { steps: fo_steps, lr: 1e-3, seed: 11, ste_qmax: Some(127), verbose: false };
        pretrain_cls(&fp_session, task.as_ref(), &mut ste_store, &stecfg)?;
        let ste_acc = workload.eval_accuracy(&fp_session, &ste_store.params_view())?;
        table[2].push(ste_acc);

        // --- quantized ES methods on the W8 backbone ---
        let q_base = ParamStore::quantize_from(&fp_base, &man, Format::Int8, None)?;
        let q_session = Session::new(&man, &fa.size, Format::Int8, EngineSet::cls_only())?;
        for (mi, variant) in [(3usize, Variant::Quzo), (4usize, Variant::Qes)] {
            let (log, _) =
                finetune_store(&q_session, &workload, q_base.clone(), variant, &cfg, None)?;
            table[mi].push(log.final_acc);
        }
        println!(
            "{}: fo {:.1} mezo {:.1} ste {:.1} quzo {:.1} qes {:.1}",
            task_name, table[0].last().unwrap(), table[1].last().unwrap(),
            table[2].last().unwrap(), table[3].last().unwrap(), table[4].last().unwrap()
        );
    }

    let mut md = String::from("# Table 1: SFT accuracy (%)\n\n| METHOD | PREC. |");
    for t in &tasks {
        md.push_str(&format!(" {} |", t.to_uppercase()));
    }
    md.push_str(" AVG |\n|---|---|");
    md.push_str(&"---|".repeat(tasks.len() + 1));
    md.push('\n');
    let precs = ["FP32", "FP32", "W8", "W8", "W8"];
    let mut csv = String::from("method,prec,".to_string() + &tasks.join(",") + ",avg\n");
    for (mi, m) in methods.iter().enumerate() {
        let avg = crate::util::mean(&table[mi]);
        md.push_str(&format!("| {} | {} |", m.to_uppercase(), precs[mi]));
        for v in &table[mi] {
            md.push_str(&format!(" {:.1} |", v));
        }
        md.push_str(&format!(" {:.1} |\n", avg));
        csv.push_str(&format!(
            "{},{},{},{:.1}\n",
            m,
            precs[mi],
            table[mi].iter().map(|v| format!("{:.1}", v)).collect::<Vec<_>>().join(","),
            avg
        ));
    }
    println!("\n{}", md);
    write_result("table1.md", &md)?;
    write_result("table1.csv", &csv)?;
    Ok(())
}
