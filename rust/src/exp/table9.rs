//! Table 9 — Per-iteration wall-clock: rollout phase vs replay/update phase,
//! and the replay-overhead-vs-K curve (§4.6).
//!
//! Absolute numbers are testbed-specific (single-core CPU PJRT vs the
//! paper's A100s); the reproduced *shape* is (a) replay cost linear in K,
//! (b) the K=small point retaining most accuracy at a fraction of the cost
//! (Table 7), (c) rollout and update measured separately.

use anyhow::Result;

use crate::coordinator::{finetune_store, EngineSet, FinetuneCfg, GenWorkload, Session, Variant};
use crate::exp::cli::{ensure_quantized, parse_ft_args};
use crate::exp::write_result;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let sizes: Vec<String> =
        args.get_or("sizes", "nano,micro").split(',').map(|s| s.to_string()).collect();
    let windows: Vec<usize> = args
        .get_or("windows", "2,4,8,16")
        .split(',')
        .map(|s| s.parse().unwrap_or(8))
        .collect();
    let gens = args.get_usize("bench-gens", 12)?;
    let task_name = args.get_or("bench-task", "countdown");
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;

    let mut md = String::from(
        "# Table 9: per-iteration wall-clock (ms) — rollout vs update\n\n\
         | MODEL | VARIANT | K | ROLLOUT (ms) | UPDATE (ms) | OVERHEAD vs ORACLE |\n\
         |---|---|---|---|---|---|\n",
    );
    let mut csv = String::from("size,variant,k,rollout_ms,update_ms,overhead\n");

    for size in &sizes {
        let store0 =
            ensure_quantized(&man, size, &task_name, Format::Int4, fa.pretrain_steps, true)?;
        let session = Session::new(&man, size, Format::Int4, EngineSet::gen_only())?;
        let mut cfg = FinetuneCfg { gens, verbose: false, eval_every: 0, ..fa.cfg.clone() };
        let workload = GenWorkload::new(
            gen_task(&task_name, session.cfg.s_prompt, session.cfg.t_dec)?,
            &session.cfg,
            &cfg,
        );

        // oracle reference: Full Residual (the "no-replay" variant)
        let (oracle, _) = finetune_store(
            &session, &workload, store0.clone(), Variant::QesFullResidual, &cfg, None,
        )?;
        let oracle_total = oracle.mean_rollout_ms() + oracle.mean_update_ms();
        md.push_str(&format!(
            "| {} | full-residual | — | {:.1} | {:.1} | 1.00x |\n",
            size,
            oracle.mean_rollout_ms(),
            oracle.mean_update_ms()
        ));
        csv.push_str(&format!(
            "{},full-residual,0,{:.2},{:.2},1.0\n",
            size,
            oracle.mean_rollout_ms(),
            oracle.mean_update_ms()
        ));

        for &k in &windows {
            cfg.hyper.k_window = k;
            let (log, _) =
                finetune_store(&session, &workload, store0.clone(), Variant::Qes, &cfg, None)?;
            let total = log.mean_rollout_ms() + log.mean_update_ms();
            let overhead = total / oracle_total;
            println!(
                "{} qes K={}: rollout {:.1}ms update {:.1}ms ({:.2}x oracle)",
                size, k, log.mean_rollout_ms(), log.mean_update_ms(), overhead
            );
            md.push_str(&format!(
                "| {} | seed-replay | {} | {:.1} | {:.1} | {:.2}x |\n",
                size, k, log.mean_rollout_ms(), log.mean_update_ms(), overhead
            ));
            csv.push_str(&format!(
                "{},seed-replay,{},{:.2},{:.2},{:.3}\n",
                size, k, log.mean_rollout_ms(), log.mean_update_ms(), overhead
            ));
        }
    }
    println!("\n{}", md);
    write_result("table9.md", &md)?;
    write_result("table9.csv", &csv)?;
    Ok(())
}
