//! Experiment drivers: one module per paper table/figure (DESIGN.md §5).
//! Each prints the paper-formatted rows and writes CSV/markdown under
//! `results/`.

pub mod ablate;
pub mod cli;
pub mod fig2;
pub mod fig3;
pub mod table1;
pub mod table2;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod table9;

use std::path::PathBuf;

/// Where experiment outputs land.
pub fn results_dir() -> PathBuf {
    let p = PathBuf::from("results");
    std::fs::create_dir_all(&p).ok();
    p
}

/// Write a result artifact and echo its path.
pub fn write_result(name: &str, content: &str) -> anyhow::Result<()> {
    let p = results_dir().join(name);
    std::fs::write(&p, content)?;
    println!("[results] wrote {:?}", p);
    Ok(())
}
