//! Design-choice ablations (DESIGN.md §5 "ablation benches"):
//!
//! 1. **GPTQ vs round-to-nearest PTQ** — calibration-loss and downstream
//!    LM-loss comparison per quantized layer (the quantizer QES inherits
//!    its lattice from).
//! 2. **Antithetic pairs vs one-sided sampling** — gradient-estimate
//!    quality at equal rollout budget, measured as cosine alignment with a
//!    large-population reference estimate.

use anyhow::Result;

use crate::coordinator::{EngineSet, LmBatch, Session};
use crate::exp::write_result;
use crate::model::{init::init_fp, ParamKind, ParamStore};
use crate::opt::{accumulate_grad, PopulationSpec};
use crate::quant::{gptq::calib_loss, gptq_quantize, ptq_quantize, Format};
use crate::rng::SplitMix64;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    let n_calib = args.get_usize("calib", 64)?;
    args.finish()?;
    let man = Manifest::load(&manifest)?;

    let mut md = String::from("# Ablations\n\n## GPTQ vs PTQ (nano, INT4)\n\n");

    // ---- 1. GPTQ vs PTQ ----
    let mut fp = ParamStore::from_manifest(&man, "nano", Format::Fp32)?;
    init_fp(&mut fp, 9);
    // pretrain briefly so weights are structured, not just Gaussian
    let session = Session::new(&man, "nano", Format::Fp32, EngineSet::pretrain())?;
    let task = gen_task("countdown", session.cfg.s_prompt, session.cfg.t_dec)?;
    crate::coordinator::pretrain_gen(
        &session,
        task.as_ref(),
        &mut fp,
        &crate::coordinator::PretrainCfg { steps: 300, verbose: false, ..Default::default() },
    )?;

    md.push_str("| layer | PTQ calib loss | GPTQ calib loss | improvement |\n|---|---|---|---|\n");
    let mut rng = SplitMix64::new(4);
    let mut total_ptq = 0.0f64;
    let mut total_gptq = 0.0f64;
    let lat: Vec<usize> = fp.lattice_indices().to_vec();
    for &i in lat.iter().take(6) {
        let e = &fp.entries[i];
        debug_assert_eq!(e.kind, ParamKind::LatticeAsFp);
        let (rows, cols) = (e.shape[0], e.shape[1]);
        let w = e.data.as_f32();
        // correlated calibration activations
        let mut x = vec![0.0f32; n_calib * rows];
        for s in 0..n_calib {
            for r in 0..rows {
                let base = rng.normal() * 0.5;
                x[s * rows + r] =
                    if r == 0 { base } else { 0.5 * x[s * rows + r - 1] + 0.5 * base };
            }
        }
        let ptq = ptq_quantize(w, rows, cols, 7);
        let gptq = gptq_quantize(w, rows, cols, 7, &x, n_calib, 0.01)?;
        let lp = calib_loss(w, &ptq, &x, n_calib);
        let lg = calib_loss(w, &gptq, &x, n_calib);
        total_ptq += lp;
        total_gptq += lg;
        md.push_str(&format!(
            "| {} | {:.4e} | {:.4e} | {:.1}% |\n",
            e.name,
            lp,
            lg,
            100.0 * (1.0 - lg / lp.max(1e-12))
        ));
    }
    md.push_str(&format!(
        "\ntotal: PTQ {:.4e} vs GPTQ {:.4e} ({:.1}% lower)\n",
        total_ptq,
        total_gptq,
        100.0 * (1.0 - total_gptq / total_ptq.max(1e-12))
    ));

    // downstream LM loss of both quantizations
    let q_ptq = ParamStore::quantize_from(&fp, &man, Format::Int4, None)?;
    let mut crng = SplitMix64::new(5);
    let mut calib_fn = |_: &str, rows: usize, _: usize| -> Option<Vec<f32>> {
        Some((0..32 * rows).map(|_| crng.normal() * 0.5).collect())
    };
    let q_gptq = ParamStore::quantize_from(&fp, &man, Format::Int4, Some(&mut calib_fn))?;
    let qsession = Session::new(&man, "nano", Format::Int4, EngineSet {
        loss: true,
        ..Default::default()
    })?;
    let mut rng2 = SplitMix64::new(11);
    let pairs: Vec<(String, String)> =
        (0..qsession.cfg.b_train).map(|_| task.supervised(&mut rng2)).collect();
    let batch = LmBatch::build(&qsession.cfg, &pairs);
    let (fp_loss, _) = session.lm_loss(&fp, None, &batch)?;
    let (ptq_loss, _) = qsession.lm_loss(&q_ptq, None, &batch)?;
    let (gptq_loss, _) = qsession.lm_loss(&q_gptq, None, &batch)?;
    md.push_str(&format!(
        "\ndownstream LM loss: fp32 {:.4} | INT4-PTQ {:.4} | INT4-GPTQ {:.4}\n",
        fp_loss, ptq_loss, gptq_loss
    ));

    // ---- 2. antithetic vs one-sided gradient quality ----
    md.push_str("\n## Antithetic pairs vs one-sided sampling\n\n");
    let q = q_ptq;
    let d = q.lattice_dim();
    // reference: a big population's estimate
    let ref_spec = PopulationSpec { gen_seed: 777, pairs: 256, sigma: 0.05 };
    let mut rng3 = SplitMix64::new(21);
    let ref_fit: Vec<f32> = (0..512).map(|_| rng3.uniform01() - 0.5).collect();
    let mut g_ref = vec![0.0f32; d];
    accumulate_grad(&ref_spec, &ref_fit, &mut g_ref);
    // small-budget estimates drawn from the same population prefix
    let small = PopulationSpec { gen_seed: 777, pairs: 8, sigma: 0.05 };
    let mut g_anti = vec![0.0f32; d];
    accumulate_grad(&small, &ref_fit[..16], &mut g_anti);
    // one-sided: same 16 rollouts but signs all +: kill the '-' half
    let mut onesided = ref_fit[..16].to_vec();
    for i in (1..16).step_by(2) {
        onesided[i] = 0.0;
    }
    let mut g_one = vec![0.0f32; d];
    accumulate_grad(&small, &onesided, &mut g_one);
    let cos = |a: &[f32], b: &[f32]| -> f64 {
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for (x, y) in a.iter().zip(b.iter()) {
            dot += (*x as f64) * (*y as f64);
            na += (*x as f64) * (*x as f64);
            nb += (*y as f64) * (*y as f64);
        }
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na.sqrt() * nb.sqrt())
        }
    };
    md.push_str(&format!(
        "cosine alignment with the 512-member reference estimate:\n\
         antithetic (16 rollouts): {:.3}\none-sided (16 rollouts): {:.3}\n",
        cos(&g_anti, &g_ref),
        cos(&g_one, &g_ref)
    ));

    println!("\n{}", md);
    write_result("ablations.md", &md)?;
    Ok(())
}
