//! Table 7 — Seed-replay ablations (Appendix D).
//!
//! Top: replay window K x decay gamma, two regimes — "scaled" sets gamma so
//! gamma^K ~ 0 (shrinking K forces aggressive decay and collapses accuracy)
//! vs "fixed" gamma = 0.90 (graceful degradation).
//! Bottom: measured update ratio and boundary-hit ratio rho per format —
//! the fidelity argument of §4.5.

use anyhow::Result;

use crate::coordinator::{finetune_store, EngineSet, FinetuneCfg, GenWorkload, Session, Variant};
use crate::exp::cli::{ensure_quantized, parse_ft_args};
use crate::exp::write_result;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let size = args.get_or("abl-size", "nano");
    let task_name = args.get_or("abl-task", "countdown");
    let windows: Vec<usize> = args
        .get_or("windows", "16,12,8,4,2")
        .split(',')
        .map(|s| s.parse().unwrap_or(8))
        .collect();
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;
    let k_ref = *windows.first().unwrap_or(&16) as f32;

    // ---- Top: K x gamma ----
    let store0 = ensure_quantized(&man, &size, &task_name, Format::Int4, fa.pretrain_steps, true)?;
    let session = Session::new(&man, &size, Format::Int4, EngineSet::gen_only())?;
    // One workload for the whole grid: the cells vary only hyper.k_window /
    // hyper.gamma, which the workload's rollout data never depends on.
    let base_cfg = FinetuneCfg { verbose: false, ..fa.cfg.clone() };
    let workload = GenWorkload::new(
        gen_task(&task_name, session.cfg.s_prompt, session.cfg.t_dec)?,
        &session.cfg,
        &base_cfg,
    );

    let mut md = String::from(
        "# Table 7 (top): replay window K and decay gamma — INT4 Countdown\n\n\
         | REGIME | K | gamma | ACCURACY (%) |\n|---|---|---|---|\n",
    );
    let mut csv = String::from("regime,k,gamma,accuracy\n");
    let gamma_ref = fa.cfg.hyper.gamma; // e.g. 0.90 at K_ref
    for regime in ["scaled", "fixed"] {
        for &k in &windows {
            // scaled: keep gamma^K constant == gamma_ref^K_ref
            let gamma = if regime == "scaled" {
                gamma_ref.powf(k_ref / k as f32)
            } else {
                gamma_ref
            };
            let mut cfg = base_cfg.clone();
            cfg.hyper.k_window = k;
            cfg.hyper.gamma = gamma;
            let (log, _) =
                finetune_store(&session, &workload, store0.clone(), Variant::Qes, &cfg, None)?;
            println!("{} K={} gamma={:.2}: {:.2}%", regime, k, gamma, log.final_acc);
            md.push_str(&format!(
                "| {} | {} | {:.2} | {:.2} |\n",
                regime, k, gamma, log.final_acc
            ));
            csv.push_str(&format!("{},{},{:.3},{:.2}\n", regime, k, gamma, log.final_acc));
        }
    }

    // ---- Bottom: update ratio and boundary-hit ratio per format ----
    md.push_str(
        "\n# Table 7 (bottom): update ratio and boundary-hit ratio rho\n\n\
         | QUANTIZATION | UPDATE RATIO | HIT RATIO rho |\n|---|---|---|\n",
    );
    let mut csv2 = String::from("format,update_ratio,hit_ratio\n");
    for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
        let store0 = ensure_quantized(&man, &size, &task_name, fmt, fa.pretrain_steps, true)?;
        let session = Session::new(&man, &size, fmt, EngineSet::gen_only())?;
        // same model config for every format -> the top workload is reusable
        let (log, _) =
            finetune_store(&session, &workload, store0, Variant::Qes, &base_cfg, None)?;
        // mean over generations that actually moved
        let moved: Vec<&crate::coordinator::GenLog> =
            log.entries.iter().filter(|e| e.update_ratio > 0.0).collect();
        let ur = if moved.is_empty() {
            0.0
        } else {
            moved.iter().map(|e| e.update_ratio).sum::<f64>() / moved.len() as f64
        };
        let rho = if moved.is_empty() {
            0.0
        } else {
            moved.iter().map(|e| e.boundary_ratio).sum::<f64>() / moved.len() as f64
        };
        println!("{}: update ratio {:.2e}, rho {:.2e}", fmt.name(), ur, rho);
        md.push_str(&format!(
            "| {} | {:.2e} | {:.2e} |\n",
            fmt.name().to_uppercase(),
            ur,
            rho
        ));
        csv2.push_str(&format!("{},{:.6e},{:.6e}\n", fmt.name(), ur, rho));
    }

    println!("\n{}", md);
    write_result("table7.md", &md)?;
    write_result("table7_top.csv", &csv)?;
    write_result("table7_bottom.csv", &csv2)?;
    Ok(())
}
