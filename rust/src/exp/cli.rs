//! CLI subcommands: pretrain / quantize / eval / finetune, plus the `exp`
//! dispatcher that regenerates each paper table and figure.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{
    finetune_resumable, pretrain_cls, pretrain_gen, workload_for, EngineSet, FinetuneCfg,
    PretrainCfg, Session, TrainCkptCfg, Variant, Workload, WorkerPool,
};
use crate::model::{checkpoint, init::init_fp, AsParams, ParamStore, ShardedParamStore};
use crate::opt::EsHyper;
use crate::quant::Format;
use crate::runtime::{BackendPolicy, Manifest, NativeBackend};
use crate::sched::{mux, serve, SchedCfg, Scheduler};
use crate::tasks::{cls_task, gen_task, is_cls_task};
use crate::util::args::Args;
use crate::util::fault::FaultPlan;
use crate::util::parallel;

pub fn run_dir(size: &str, task: &str) -> PathBuf {
    PathBuf::from("runs").join(format!("{}_{}", size, task))
}

/// Resolve (or lazily create) the pretrained base model for (size, task).
/// Pretraining is cached: reruns load `fp.ckpt`.
pub fn ensure_pretrained(
    man: &Manifest,
    size: &str,
    task_name: &str,
    steps: usize,
    verbose: bool,
) -> Result<ParamStore> {
    let dir = run_dir(size, task_name);
    let path = dir.join("fp.ckpt");
    if path.exists() {
        return checkpoint::load(man, &path);
    }
    if verbose {
        println!("[pretrain] no cached base model at {:?}; training ({} steps)", path, steps);
    }
    let session = Session::new(man, size, Format::Fp32, EngineSet::pretrain())?;
    let mut store = ParamStore::from_manifest(man, size, Format::Fp32)?;
    init_fp(&mut store, 0xba5e ^ seed_of(size, task_name));
    let cfg = PretrainCfg { steps, verbose, ..Default::default() };
    if is_cls_task(task_name) {
        let task = cls_task(task_name)?;
        pretrain_cls(&session, task.as_ref(), &mut store, &cfg)?;
    } else {
        let task = gen_task(task_name, session.cfg.s_prompt, session.cfg.t_dec)?;
        pretrain_gen(&session, task.as_ref(), &mut store, &cfg)?;
    }
    checkpoint::save(&store, &path)?;
    Ok(store)
}

fn seed_of(size: &str, task: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in size.bytes().chain(task.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Resolve (or lazily create) the quantized base model.
pub fn ensure_quantized(
    man: &Manifest,
    size: &str,
    task_name: &str,
    format: Format,
    pretrain_steps: usize,
    verbose: bool,
) -> Result<ParamStore> {
    let dir = run_dir(size, task_name);
    let path = dir.join(format!("{}.ckpt", format.name()));
    if path.exists() {
        return checkpoint::load(man, &path);
    }
    let fp = ensure_pretrained(man, size, task_name, pretrain_steps, verbose)?;
    let q = ParamStore::quantize_from(&fp, man, format, None)?;
    checkpoint::save(&q, &path)?;
    Ok(q)
}

pub fn cmd_pretrain(mut args: Args) -> Result<()> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    let size = args.get_or("size", "nano");
    let task = args.get_or("task", "countdown");
    let steps = args.get_usize("steps", 400)?;
    args.finish()?;
    let man = Manifest::load(&manifest)?;
    // force retrain: remove cached ckpt first
    let path = run_dir(&size, &task).join("fp.ckpt");
    if path.exists() {
        std::fs::remove_file(&path)?;
    }
    let store = ensure_pretrained(&man, &size, &task, steps, true)?;
    println!("saved {:?} ({} params)", path, store.entries.iter().map(|e| e.numel()).sum::<usize>());
    report_accuracy(&man, &size, &task, &store)?;
    Ok(())
}

pub fn cmd_quantize(mut args: Args) -> Result<()> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    let size = args.get_or("size", "nano");
    let task = args.get_or("task", "countdown");
    let format = Format::parse(&args.get_or("format", "int4"))?;
    let steps = args.get_usize("pretrain-steps", 400)?;
    args.finish()?;
    let man = Manifest::load(&manifest)?;
    let path = run_dir(&size, &task).join(format!("{}.ckpt", format.name()));
    if path.exists() {
        std::fs::remove_file(&path)?;
    }
    let store = ensure_quantized(&man, &size, &task, format, steps, true)?;
    println!("saved {:?} ({} lattice params, {} weight bytes)",
        path, store.lattice_dim(), store.weight_bytes());
    report_accuracy(&man, &size, &task, &store)?;
    Ok(())
}

pub fn cmd_eval(mut args: Args) -> Result<()> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    let size = args.get_or("size", "nano");
    let task = args.get_or("task", "countdown");
    let ckpt = args.opt("ckpt");
    let format = Format::parse(&args.get_or("format", "int4"))?;
    args.finish()?;
    let man = Manifest::load(&manifest)?;
    let store = match ckpt {
        Some(p) => checkpoint::load(&man, Path::new(&p))?,
        None => {
            let p = run_dir(&size, &task).join(format!("{}.ckpt", format.name()));
            checkpoint::load(&man, &p)?
        }
    };
    report_accuracy(&man, &size, &task, &store)?;
    Ok(())
}

fn report_accuracy(man: &Manifest, size: &str, task_name: &str, store: &ParamStore) -> Result<()> {
    let mcfg = man.config(size)?.clone();
    // 128-problem eval set, fixed seed. Reasoning tasks keep the historical
    // `qes eval` problem set; classification tasks now use the workload's
    // k-shot-protocol eval split (seeded from `seed`), so cls accuracies are
    // not comparable with pre-Workload-refactor reports.
    let eval_cfg = FinetuneCfg { eval_n: 128, seed: 42, ..Default::default() };
    let workload = workload_for(task_name, &mcfg, &eval_cfg, 16)?;
    let session = Session::new(man, size, store.format, workload.engines())?;
    let acc = workload.eval_accuracy(&session, &store.params_view())?;
    println!("eval accuracy ({}, {}): {:.2}%", task_name, store.format.name(), acc);
    Ok(())
}

/// Shared flag parsing for ES fine-tuning runs.
pub struct FtArgs {
    pub manifest: String,
    pub size: String,
    pub task: String,
    pub format: Format,
    pub variant: Variant,
    /// Forward backend: auto (default) | native | pjrt.
    pub backend: BackendPolicy,
    /// ISA microkernel backend resolved from `--kernel` (default: auto —
    /// `QES_KERNEL` env, else CPU detection). Applied process-wide.
    pub kernel: crate::kernel::KernelKind,
    pub cfg: FinetuneCfg,
    pub pretrain_steps: usize,
    pub k_shot: usize,
    /// Rollout worker processes (`--workers`, 0 = inline on the leader).
    pub workers: usize,
    /// Training-checkpoint cadence in generations (`--ckpt-every`,
    /// 0 disables crash-consistent checkpoints).
    pub ckpt_every: usize,
    /// Resume from the run's training checkpoint (`--resume`).
    pub resume: bool,
    /// Dump train-side trace spans as JSONL on exit (`--trace-out`;
    /// setting it also switches tracing on, same as QES_TRACE=1).
    pub trace_out: Option<String>,
}

pub fn parse_ft_args(args: &mut Args) -> Result<FtArgs> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    let size = args.get_or("size", "nano");
    let task = args.get_or("task", "countdown");
    let format = Format::parse(&args.get_or("format", "int4"))?;
    let variant = Variant::parse(&args.get_or("variant", "qes"))?;
    let backend = BackendPolicy::parse(&args.get_or("backend", "auto"))?;
    let kernel_choice = crate::kernel::KernelKind::parse_choice(&args.get_or("kernel", "auto"))?;
    let hyper = EsHyper {
        sigma: args.get_f32("sigma", 0.01)?,
        alpha: args.get_f32("alpha", 5e-4)?,
        gamma: args.get_f32("gamma", 0.9)?,
        pairs: args.get_usize("pairs", 8)?,
        k_window: args.get_usize("k", 8)?,
    };
    // fault plan: explicit --faults wins, else the QES_FAULTS env var,
    // else inert
    let faults = match args.opt("faults") {
        Some(spec) => FaultPlan::parse(&spec)?,
        None => FaultPlan::from_env()?,
    };
    let cfg = FinetuneCfg {
        hyper,
        gens: args.get_usize("gens", 60)?,
        tau: args.get_f32("tau", 0.7)?,
        batches_per_gen: args.get_usize("batches", 2)?,
        train_pool: args.get_usize("pool", 256)?,
        eval_every: args.get_usize("eval-every", 0)?,
        eval_n: args.get_usize("eval-n", 64)?,
        seed: args.get_u64("seed", 42)?,
        verbose: !args.get_bool("quiet"),
        min_quorum: args.get_f32("quorum", 0.5)?,
        faults,
        // `--no-grouped` (or QES_GROUPED=0) forces the per-member
        // sequential rollout; rewards are bit-identical either way.
        grouped: !args.get_bool("no-grouped")
            && crate::coordinator::workload::grouped_rollout_enabled(),
    };
    let pretrain_steps = args.get_usize("pretrain-steps", 400)?;
    let k_shot = args.get_usize("k-shot", 16)?;
    let workers = args.get_usize("workers", 0)?;
    let ckpt_every = args.get_usize("ckpt-every", 1)?;
    let resume = args.get_bool("resume");
    let trace_out = args.opt("trace-out");
    if trace_out.is_some() {
        crate::obs::set_trace(true);
    }
    // apply the process-wide dispatch only after every flag THIS function
    // parses has succeeded, so an argument error can't leave the global
    // kernel repinned (the caller's trailing `args.finish()` can still
    // fail afterwards — by then the user's explicit --kernel choice
    // standing is the lesser surprise)
    let kernel = crate::kernel::force(kernel_choice)?;
    Ok(FtArgs {
        manifest,
        size,
        task,
        format,
        variant,
        backend,
        kernel,
        cfg,
        pretrain_steps,
        k_shot,
        workers,
        ckpt_every,
        resume,
        trace_out,
    })
}

pub fn cmd_finetune(mut args: Args) -> Result<()> {
    let fa = parse_ft_args(&mut args)?;
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;
    let variant_name = fa.variant.name();
    let dir = run_dir(&fa.size, &fa.task);
    let train_ckpt = dir.join(format!("{}_{}.train.ckpt", fa.format.name(), variant_name));

    // --resume continues from the run's training checkpoint: the lattice
    // comes from the checkpoint, not from the cached quantized base.
    let resume_state = if fa.resume {
        Some(checkpoint::load_train(&man, &train_ckpt)?)
    } else {
        None
    };
    let store0 = match &resume_state {
        Some(ts) => {
            println!(
                "[finetune] resuming {:?} at round {} ({})",
                train_ckpt, ts.rounds_done, ts.variant
            );
            ts.store.clone()
        }
        None => ensure_quantized(&man, &fa.size, &fa.task, fa.format, fa.pretrain_steps, true)?,
    };
    // ONE loop for every scenario: the task name picks the Workload impl
    // and --backend picks the runtime (native default on offline builds).
    let mcfg = man.config(&fa.size)?.clone();
    let workload = workload_for(&fa.task, &mcfg, &fa.cfg, fa.k_shot)?;
    let session =
        Session::with_policy(&man, &fa.size, fa.format, workload.engines(), fa.backend)?;
    println!("[finetune] backend: {} | kernel: {}", session.backend_name(), fa.kernel.name());
    if fa.cfg.faults.is_active() {
        println!("[finetune] fault injection active: {:?}", fa.cfg.faults);
    }

    // supervised worker pool (--workers N); 0 = inline on the leader
    let pool = if fa.workers > 0 {
        let workload_arc: std::sync::Arc<dyn Workload> = std::sync::Arc::from(workload_for(
            &fa.task, &mcfg, &fa.cfg, fa.k_shot,
        )?);
        Some(WorkerPool::spawn_with(
            fa.workers,
            &fa.manifest,
            &fa.size,
            fa.format,
            fa.backend,
            workload_arc,
            Default::default(),
            fa.cfg.faults,
        )?)
    } else {
        None
    };

    let ckpt_cfg = (fa.ckpt_every > 0)
        .then(|| TrainCkptCfg { path: train_ckpt.clone(), every: fa.ckpt_every });
    let mut sharded = ShardedParamStore::with_default_shards(store0)?;
    let log = finetune_resumable(
        &session,
        workload.as_ref(),
        &mut sharded,
        fa.variant,
        &fa.cfg,
        pool.as_ref(),
        ckpt_cfg.as_ref(),
        resume_state.as_ref(),
    )?;
    let store = sharded.materialize();
    if let Some(p) = pool {
        // with injected worker kills, unreaped panics surface at
        // shutdown — the run itself already committed, so warn, don't fail
        if let Err(e) = p.shutdown() {
            if fa.cfg.faults.is_active() {
                eprintln!("[finetune] pool shutdown after fault injection: {:#}", e);
            } else {
                return Err(e);
            }
        }
    }
    let ckpt = dir.join(format!("{}_{}.ckpt", fa.format.name(), variant_name));
    checkpoint::save(&store, &ckpt)?;
    let csv = dir.join(format!("{}_{}.csv", fa.format.name(), variant_name));
    std::fs::write(&csv, log.to_csv())?;
    println!(
        "final eval accuracy {:.2}% | optimizer state {} | saved {:?}, {:?}",
        log.final_acc,
        crate::util::human_bytes(log.optimizer_state_bytes),
        ckpt,
        csv
    );
    if let Some(p) = &fa.trace_out {
        let n = crate::obs::dump_trace_jsonl(Path::new(p))?;
        println!("[finetune] wrote {} trace spans to {}", n, p);
    }
    Ok(())
}

/// `qes serve`: line-delimited JSON over stdin (default), a TCP
/// listener (`--tcp addr:port`, line protocol), and/or an HTTP listener
/// (`--http addr:port`, OpenAI-compatible `POST /v1/completions`),
/// driving the continuous-batching scheduler against a checkpoint
/// (`--ckpt`, or the cached quantized model for `--size`/`--task`).
/// TCP and HTTP accept CONCURRENT connections multiplexed onto ONE
/// shared scheduler (`sched/mux.rs`); admission control sheds load past
/// `--max-inflight` pending requests globally or `--conn-queue`
/// outstanding per connection with explicit `"overloaded"` responses.
/// Responses stream to stdout (or the connection) as sequences finish;
/// diagnostics go to stderr.
pub fn cmd_serve(mut args: Args) -> Result<()> {
    let manifest = args.get_or("manifest", "artifacts/manifest.json");
    let size = args.get_or("size", "nano");
    let task = args.get_or("task", "countdown");
    let format = Format::parse(&args.get_or("format", "int4"))?;
    let ckpt = args.opt("ckpt");
    let slots = args.get_usize("slots", 0)?; // 0 = model default (b_gen)
    let max_new = args.get_usize("max-new", 0)?; // 0 = model default (t_dec)
    let threads = args.get_usize("threads", 0)?; // 0 = all cores
    let no_kmajor = args.get_bool("no-kmajor");
    // paged-KV knobs: rows per page (default = QES_PAGE / 16; "0" means
    // one dense-equivalent page per slot) and prefix-cache entries
    let page = args.get_usize("page", crate::sched::default_page_rows())?;
    let prefix_cache = args.get_usize("prefix-cache", 32)?;
    let tcp = args.opt("tcp");
    let http = args.opt("http");
    let kernel_choice = crate::kernel::KernelKind::parse_choice(&args.get_or("kernel", "auto"))?;
    let pretrain_steps = args.get_usize("pretrain-steps", 400)?;
    // intake hardening: per-line byte cap (oversized lines are answered
    // with an error response, excess bytes discarded at the socket) and
    // a TCP read deadline so a silent client cannot pin the server
    let max_line = args.get_usize("max-line", 65536)?;
    let read_timeout_ms = args.get_u64("read-timeout-ms", 30_000)?;
    // multi-tenant backpressure: global pending cap and per-connection
    // outstanding bound (0 = unbounded); past either, requests are shed
    // with an explicit "overloaded" error response / HTTP 429
    let max_inflight = args.get_usize("max-inflight", 256)?;
    let conn_queue = args.get_usize("conn-queue", 64)?;
    // --trace-out FILE: switch per-request trace spans on (same switch
    // as QES_TRACE=1) and dump the span ring as JSONL on exit
    let trace_out = args.opt("trace-out");
    args.finish()?;
    let kernel = crate::kernel::force(kernel_choice)?;
    if trace_out.is_some() {
        crate::obs::set_trace(true);
    }
    let man = Manifest::load(&manifest)?;
    let store = match &ckpt {
        Some(p) => checkpoint::load(&man, Path::new(p))?,
        None => ensure_quantized(&man, &size, &task, format, pretrain_steps, true)?,
    };
    let backend = NativeBackend::with_engine_set(&man, &size, store.format, EngineSet::gen_only())?;
    let mut scfg = SchedCfg::for_model(man.config(&size)?);
    if slots > 0 {
        scfg.slots = slots;
    }
    if max_new > 0 {
        scfg.t_max = max_new;
    }
    scfg.threads = if threads > 0 { threads } else { parallel::default_threads() };
    scfg.kmajor = !no_kmajor;
    scfg.page = page;
    scfg.prefix_cache = prefix_cache;
    let view = store.params_view();
    let mcfg = backend.cfg();
    let s_max = scfg.s_prompt + scfg.t_max;
    // the paged KvArena memory model: bytes/page = n_layers * 2 (K+V) *
    // page * d * 4, allocated on demand as sequences grow; the dense
    // bytes/slot number (x s_max rows) survives as the worst-case bound
    // one slot can reach
    let page_rows = if page == 0 { s_max } else { page.min(s_max) };
    let slot_bytes = mcfg.n_layers * 2 * s_max * mcfg.d_model * 4;
    let page_bytes = mcfg.n_layers * 2 * page_rows * mcfg.d_model * 4;
    eprintln!(
        "[serve] native backend | kernel {} | format {} | {} slots x {} rows | paged kv: {}/page x {} rows/page, on demand ({}/slot dense bound, {} arena cap) | prefix cache {} | K-major {}",
        kernel.name(),
        store.format.name(),
        scfg.slots,
        s_max,
        crate::util::human_bytes(page_bytes as u64),
        page_rows,
        crate::util::human_bytes(slot_bytes as u64),
        crate::util::human_bytes((scfg.slots * slot_bytes) as u64),
        scfg.prefix_cache,
        if scfg.kmajor { "on" } else { "off" },
    );
    if tcp.is_none() && http.is_none() {
        // stdin: one implicit connection, the classic single-tenant pump
        let (tx, rx) = std::sync::mpsc::channel::<serve::Intake>();
        std::thread::spawn(move || {
            serve::pump_lines(std::io::stdin().lock(), max_line, &tx);
        });
        let mut sched = Scheduler::new(&backend, &view, None, None, scfg)?;
        let mut out = std::io::stdout();
        let stats = serve::serve_loop(&mut sched, &rx, &mut out)?;
        let bpp = sched.arena().bytes_per_page();
        drop(sched); // Drop mirrors the final kv deltas into the registry
        let mm = crate::obs::m();
        eprintln!(
            "[serve] done: {} responses, {} errors{} | {} steps, {} decode rows, max live {} | kv pages hw {} ({}) | prefix {}/{} hit, {} cow forks",
            mm.serve_served.get(),
            mm.serve_errors.get(),
            if stats.write_failed { " (output sink died)" } else { "" },
            mm.sched_steps.get(),
            mm.sched_decode_rows.get(),
            mm.sched_max_live.get(),
            mm.kv_pages_high_water.get(),
            crate::util::human_bytes(mm.kv_pages_high_water.get() * bpp as u64),
            mm.kv_prefix_hits.get(),
            mm.kv_prefix_hits.get() + mm.kv_prefix_misses.get(),
            mm.kv_cow_forks.get()
        );
        if let Some(p) = &trace_out {
            let n = crate::obs::dump_trace_jsonl(Path::new(p))?;
            eprintln!("[serve] wrote {} trace spans to {}", n, p);
        }
        return Ok(());
    }
    // TCP/HTTP: concurrent accept loops feeding ONE scheduler through
    // the connection mux — every connection's pump tags its events with
    // a ConnId onto one shared channel; the mux owns the scheduler here
    // on the main thread and routes each finished sequence back to its
    // connection's writer the moment it retires.
    let (tx, rx) = std::sync::mpsc::channel::<mux::MuxEvent>();
    let conn_ids = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    let mux_cfg = mux::MuxCfg {
        max_inflight,
        conn_queue,
        model: format!("qes-{}-{}", size, store.format.name()),
    };
    if let Some(addr) = tcp {
        let listener = std::net::TcpListener::bind(&addr)
            .with_context(|| format!("cannot bind {}", addr))?;
        eprintln!("[serve] line protocol on {} (multi-tenant)", addr);
        let (ptx, pids) = (tx.clone(), conn_ids.clone());
        spawn_accept_loop(listener, mux::Proto::Line, ptx, pids, max_line, read_timeout_ms);
    }
    if let Some(addr) = http {
        let listener = std::net::TcpListener::bind(&addr)
            .with_context(|| format!("cannot bind {}", addr))?;
        eprintln!("[serve] http on {} (POST /v1/completions, multi-tenant)", addr);
        let (ptx, pids) = (tx.clone(), conn_ids.clone());
        spawn_accept_loop(listener, mux::Proto::Http, ptx, pids, max_line, read_timeout_ms);
    }
    drop(tx); // the accept loops hold the only remaining senders
    let mut sched = Scheduler::new(&backend, &view, None, None, scfg)?;
    mux::mux_loop(&mut sched, &rx, &mux_cfg)?;
    drop(sched); // Drop mirrors the final kv deltas into the registry
    let mm = crate::obs::m();
    eprintln!(
        "[serve] done: {} conns, {} served, {} errors, {} shed, {} cancelled, {} orphaned, {} write-failed",
        mm.serve_conns.get(),
        mm.serve_served.get(),
        mm.serve_errors.get(),
        mm.serve_shed.get(),
        mm.serve_cancelled.get(),
        mm.serve_orphaned.get(),
        mm.serve_write_failed.get(),
    );
    if let Some(p) = &trace_out {
        let n = crate::obs::dump_trace_jsonl(Path::new(p))?;
        eprintln!("[serve] wrote {} trace spans to {}", n, p);
    }
    Ok(())
}

/// Accept connections forever, wiring each one into the shared mux
/// channel: a writer thread owning the socket (write half) fed by a
/// per-connection byte channel, and a pump thread parsing the read half
/// into tagged [`mux::MuxEvent`]s. Transient accept failures
/// (ECONNABORTED, EMFILE, a client resetting mid-handshake) are logged
/// and skipped, never fatal.
fn spawn_accept_loop(
    listener: std::net::TcpListener,
    proto: mux::Proto,
    tx: std::sync::mpsc::Sender<mux::MuxEvent>,
    conn_ids: std::sync::Arc<std::sync::atomic::AtomicU64>,
    max_line: usize,
    read_timeout_ms: u64,
) {
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[serve] accept failed: {}", e);
                    continue;
                }
            };
            let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
            let conn = mux::ConnId(conn_ids.fetch_add(1, std::sync::atomic::Ordering::Relaxed));
            let pname = match proto {
                mux::Proto::Line => "line",
                mux::Proto::Http => "http",
            };
            eprintln!("[serve] conn {} from {} ({})", conn.0, peer, pname);
            let _ = stream.set_nodelay(true);
            if read_timeout_ms > 0 {
                // a deadline on the read half: the pump thread exits
                // (half-closing the connection) instead of blocking
                // forever on a client that went silent mid-stream
                if let Err(e) = stream
                    .set_read_timeout(Some(std::time::Duration::from_millis(read_timeout_ms)))
                {
                    eprintln!("[serve] conn {}: cannot set read deadline: {}", conn.0, e);
                    continue;
                }
            }
            let reader = match stream.try_clone() {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("[serve] conn {}: clone failed: {}", conn.0, e);
                    continue;
                }
            };
            let (wtx, wrx) = std::sync::mpsc::channel::<Vec<u8>>();
            std::thread::spawn(move || mux::writer_thread(stream, wrx));
            // Open must be enqueued before the pump can race its first
            // line in: send it HERE, then spawn the pump
            if tx.send(mux::MuxEvent { conn, ev: mux::MuxIn::Open(proto, wtx) }).is_err() {
                return; // mux gone
            }
            let ptx = tx.clone();
            std::thread::spawn(move || match proto {
                mux::Proto::Line => mux::pump_conn_lines(reader, conn, max_line, &ptx),
                mux::Proto::Http => mux::pump_conn_http(reader, conn, 16 * 1024, max_line, &ptx),
            });
        }
    });
}

pub fn cmd_exp(mut args: Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: qes exp <table1|table2|table5|table6|table7|table8|table9|fig2|fig3>"))?;
    match which.as_str() {
        "table1" => crate::exp::table1::run(&mut args),
        "table2" => crate::exp::table2::run(&mut args),
        "table5" => crate::exp::table5::run(&mut args),
        "table6" => crate::exp::table6::run(&mut args),
        "table7" => crate::exp::table7::run(&mut args),
        "table8" => crate::exp::table8::run(&mut args),
        "table9" => crate::exp::table9::run(&mut args),
        "fig2" => crate::exp::fig2::run(&mut args),
        "fig3" => crate::exp::fig3::run(&mut args),
        "ablate" => crate::exp::ablate::run(&mut args),
        other => anyhow::bail!("unknown experiment {:?}", other),
    }
}
