//! Table 8 — Peak memory accounting: model weights + optimizer state per
//! method and format, with EXACT byte accounting (INT4 nibble-packed, FP16
//! residuals, seed/reward buffers).
//!
//! Shape criteria: QES total == QuZO total == the inference-only footprint;
//! Full Residual adds 2 bytes/lattice-param; QES state is ~KB and constant
//! in d. A QAT-style FO row (fp32 weights + grads + Adam m/v) is included
//! for the paper's "13x" comparison.

use anyhow::Result;

use crate::exp::cli::parse_ft_args;
use crate::exp::write_result;
use crate::model::{ParamStore, ShardedParamStore};
use crate::opt::{EsHyper, LatticeOptimizer, QesFullResidual, QuzoOptimizer, SeedReplayQes};
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::util::args::Args;
use crate::util::human_bytes;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let sizes: Vec<String> =
        args.get_or("sizes", "nano,micro,small").split(',').map(|s| s.to_string()).collect();
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;

    let mut md = String::from(
        "# Table 8: weight + optimizer-state memory (exact bytes)\n\n\
         | MODEL | FMT | WEIGHTS | QuZO TOTAL | FULL-RES TOTAL | QES TOTAL | QES STATE |\n\
         |---|---|---|---|---|---|---|\n",
    );
    let mut csv =
        String::from("size,format,weight_bytes,quzo_total,fullres_total,qes_total,qes_state\n");

    for size in &sizes {
        for fmt in [Format::Int4, Format::Int8, Format::W8A8] {
            let store = ParamStore::from_manifest(&man, size, fmt)?;
            let d = store.lattice_dim();
            let w = store.weight_bytes();
            // Exercise the real optimizers so state_bytes() is measured, not
            // hand-computed.
            let hyper = EsHyper { pairs: fa.cfg.hyper.pairs, k_window: fa.cfg.hyper.k_window, ..Default::default() };
            let quzo = QuzoOptimizer::new(d, fmt.qmax(), hyper.clone());
            let full = QesFullResidual::new(d, fmt.qmax(), hyper.clone());
            let mut replay = SeedReplayQes::new(d, fmt.qmax(), hyper.clone());
            // fill the replay history to its cap for honest accounting
            {
                let mut s2 = ShardedParamStore::with_default_shards(store.clone())?;
                let mut rng = crate::rng::SplitMix64::new(1);
                for _ in 0..hyper.k_window {
                    let spec = crate::opt::PopulationSpec {
                        gen_seed: rng.next_u64(),
                        pairs: hyper.pairs,
                        sigma: 0.01,
                    };
                    let fitness = vec![0.0f32; spec.n_members()];
                    replay.update(&mut s2, &spec, &fitness)?;
                }
            }
            let (qb, fb, rb) = (quzo.state_bytes(), full.state_bytes(), replay.state_bytes());
            println!(
                "{} {}: weights {} | quzo {} | full-res {} | qes {} (state {})",
                size, fmt.name(), human_bytes(w), human_bytes(w + qb),
                human_bytes(w + fb), human_bytes(w + rb), human_bytes(rb)
            );
            md.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} |\n",
                size, fmt.name().to_uppercase(), human_bytes(w), human_bytes(w + qb),
                human_bytes(w + fb), human_bytes(w + rb), human_bytes(rb)
            ));
            csv.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                size, fmt.name(), w, w + qb, w + fb, w + rb, rb
            ));
        }
        // QAT-style first-order reference: fp32 weights + grads + Adam m,v
        let fp = ParamStore::from_manifest(&man, size, Format::Fp32)?;
        let n: usize = fp.entries.iter().map(|e| e.numel()).sum();
        let qat = (n * 4 * 4) as u64; // w, g, m, v
        md.push_str(&format!(
            "| {} | QAT-FO | {} | — | — | — | — |\n",
            size, human_bytes(qat)
        ));
        csv.push_str(&format!("{},qat_fo,{},,,,\n", size, qat));
    }
    println!("\n{}", md);
    write_result("table8.md", &md)?;
    write_result("table8.csv", &csv)?;
    Ok(())
}
