//! Figure 2 — Countdown training curves: QuZO vs QES vs Full-Residual
//! against the Base Model line. Emits one CSV per method with the
//! mean-reward and eval-accuracy series.
//!
//! Shape criteria: QES tracks the Full-Residual oracle closely; QuZO is
//! flat/unstable; Base is a horizontal reference.

use anyhow::Result;

use crate::coordinator::{
    finetune_store, EngineSet, FinetuneCfg, GenWorkload, Session, Variant, Workload,
};
use crate::exp::cli::{ensure_quantized, parse_ft_args};
use crate::exp::write_result;
use crate::model::AsParams;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let size = args.get_or("fig-size", "nano");
    let task_name = args.get_or("fig-task", "countdown");
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;

    let store0 = ensure_quantized(&man, &size, &task_name, fa.format, fa.pretrain_steps, true)?;
    let session = Session::new(&man, &size, fa.format, EngineSet::gen_only())?;
    let cfg = FinetuneCfg {
        verbose: false,
        eval_every: fa.cfg.eval_every.max(10),
        ..fa.cfg.clone()
    };
    let task = gen_task(&task_name, session.cfg.s_prompt, session.cfg.t_dec)?;
    let workload = GenWorkload::new(task, &session.cfg, &cfg);
    let base_acc = workload.eval_accuracy(&session, &store0.params_view())?;
    println!("base accuracy (horizontal reference): {:.2}%", base_acc);

    let mut summary = format!(
        "# Figure 2 series ({} {} on {})\nbase accuracy: {:.2}%\n\n",
        size,
        fa.format.name(),
        task_name,
        base_acc
    );
    for (name, variant) in [
        ("quzo", Variant::Quzo),
        ("qes", Variant::Qes),
        ("qes_full_residual", Variant::QesFullResidual),
    ] {
        let (log, _) = finetune_store(&session, &workload, store0.clone(), variant, &cfg, None)?;
        write_result(&format!("fig2_{}.csv", name), &log.to_csv())?;
        println!(
            "{}: final eval {:.2}% (mean reward {:.3} -> {:.3})",
            name,
            log.final_acc,
            log.entries.first().map(|e| e.mean_reward).unwrap_or(0.0),
            log.entries.last().map(|e| e.mean_reward).unwrap_or(0.0)
        );
        summary.push_str(&format!("{}: final eval {:.2}%\n", name, log.final_acc));
    }
    write_result("fig2_summary.md", &summary)?;
    Ok(())
}
