//! Figure 3 — Continuous optimization on a discrete grid: the illustrative
//! toy experiment behind §5's temporal-equivalence analysis.
//!
//! A 1-D smooth reward J(w) = -(w*Delta - target)^2 is optimized on an
//! integer lattice by four methods: ideal continuous gradient ascent, naive
//! deterministic rounding (stagnates), stochastic rounding (random-walks),
//! and QES error feedback (tracks the continuous path within half a grid
//! step — checked numerically). Emits the trajectories as CSV.

use anyhow::Result;

use crate::exp::write_result;
use crate::rng::SplitMix64;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let steps = args.get_usize("steps", 400)?;
    let alpha = args.get_f32("toy-alpha", 0.04)?;
    let delta = args.get_f32("toy-delta", 1.0)?; // grid spacing
    args.finish()?;

    let target = 37.4f32; // continuous optimum, off-grid on purpose
    let grad = |w: f32| -> f32 { -2.0 * (w - target) / 100.0 };

    let w0 = 5.0f32;
    let mut w_cont = w0;
    let mut w_naive = w0; // round(alpha g): stagnates once |u| < Delta/2
    let mut w_stoch = w0; // stochastic rounding: unbiased + random walk
    let mut w_qes = w0;
    let mut e_qes = 0.0f32;
    let mut rng = SplitMix64::new(7);

    let mut csv = String::from("step,continuous,naive_round,stochastic_round,qes,qes_residual\n");
    let mut max_dev = 0.0f32;
    for t in 0..steps {
        // ideal continuous ascent
        w_cont += alpha * grad(w_cont);
        // naive deterministic rounding
        let u_n = alpha * grad(w_naive);
        w_naive += (u_n / delta).round() * delta;
        // stochastic rounding
        let u_s = alpha * grad(w_stoch) / delta;
        let f = u_s.floor();
        let dw = f + if rng.bernoulli(u_s - f) { 1.0 } else { 0.0 };
        w_stoch += dw * delta;
        // QES error feedback (gamma = 1 for the pure integrator view)
        let u_q = alpha * grad(w_qes + 0.0) + e_qes;
        let dw_q = (u_q / delta).round() * delta;
        w_qes += dw_q;
        e_qes = u_q - dw_q;
        max_dev = max_dev.max((w_qes + e_qes - w_cont).abs());
        csv.push_str(&format!(
            "{},{:.4},{:.4},{:.4},{:.4},{:.4}\n",
            t, w_cont, w_naive, w_stoch, w_qes, e_qes
        ));
    }
    println!(
        "after {} steps: continuous {:.2} | naive {:.2} (stagnated at start: {}) | \
         stochastic {:.2} | qes {:.2} (residual {:.3})",
        steps,
        w_cont,
        w_naive,
        w_naive == w0,
        w_stoch,
        w_qes,
        e_qes
    );
    // §5 invariants, checked numerically:
    anyhow::ensure!(w_naive == w0, "naive rounding should stagnate in this regime");
    anyhow::ensure!(e_qes.abs() <= delta / 2.0 + 1e-5, "|e_T| must be <= Delta/2");
    anyhow::ensure!(
        (w_qes - w_cont).abs() <= delta / 2.0 + 1e-4,
        "QES must stay within half a grid step of the continuous trajectory \
         (got {} vs {})",
        w_qes,
        w_cont
    );
    println!(
        "temporal equivalence verified: |W_t - Theta_t| <= Delta/2 throughout \
         (max virtual-trajectory deviation {:.2e})",
        max_dev
    );
    write_result("fig3.csv", &csv)?;
    write_result(
        "fig3_summary.md",
        &format!(
            "# Figure 3 (toy): discrete-grid optimization\n\n\
             | method | final w (target {:.1}) |\n|---|---|\n\
             | continuous ascent | {:.2} |\n| naive rounding | {:.2} (stagnated) |\n\
             | stochastic rounding | {:.2} |\n| QES error feedback | {:.2} |\n\n\
             QES invariants verified: |e_T| <= Delta/2; |W - Theta| <= Delta/2.\n",
            target, w_cont, w_naive, w_stoch, w_qes
        ),
    )?;
    Ok(())
}
