//! Table 5 — Scaling case study: the largest backbone ("small" stands in
//! for Llama-3.1-8B), INT4, hyperparameters reused VERBATIM from the
//! mid-size config — no per-model tuning, as in Appendix C.

use anyhow::Result;

use crate::coordinator::{
    finetune_store, EngineSet, FinetuneCfg, GenWorkload, Session, Variant, Workload,
};
use crate::exp::cli::{ensure_quantized, parse_ft_args};
use crate::exp::write_result;
use crate::model::AsParams;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let size = args.get_or("scale-size", "small");
    let task_name = args.get_or("scale-task", "mathchain");
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;

    let store0 = ensure_quantized(&man, &size, &task_name, Format::Int4, fa.pretrain_steps, true)?;
    let session = Session::new(&man, &size, Format::Int4, EngineSet::gen_only())?;
    let cfg = FinetuneCfg { verbose: true, ..fa.cfg.clone() };
    let task = gen_task(&task_name, session.cfg.s_prompt, session.cfg.t_dec)?;
    let workload = GenWorkload::new(task, &session.cfg, &cfg);
    let base = workload.eval_accuracy(&session, &store0.params_view())?;

    // hyperparameters reused verbatim from the mid-size reasoning config
    let (log, _store) = finetune_store(&session, &workload, store0, Variant::Qes, &cfg, None)?;

    let md = format!(
        "# Table 5: Scaling case study ({} INT4 on {})\n\n\
         | MODEL | BASE | QES |\n|---|---|---|\n| {} (INT4) | {:.2} | {:.2} |\n\n\
         Hyperparameters reused from the mid-size reasoning config verbatim \
         (sigma={}, alpha={}, gamma={}, pairs={}, K={}); no per-model tuning.\n",
        size, task_name, size.to_uppercase(), base, log.final_acc,
        fa.cfg.hyper.sigma, fa.cfg.hyper.alpha, fa.cfg.hyper.gamma,
        fa.cfg.hyper.pairs, fa.cfg.hyper.k_window,
    );
    println!("\n{}", md);
    write_result("table5.md", &md)?;
    write_result(
        "table5.csv",
        &format!("model,base,qes\n{},{:.2},{:.2}\n", size, base, log.final_acc),
    )?;
    Ok(())
}
