//! Table 2 — Reasoning accuracy (%) on Countdown and MathChain across model
//! sizes and quantization formats: Base vs QuZO vs QES.
//!
//! Paper shape criteria (DESIGN.md §5): QES > QuZO >= Base everywhere; QuZO
//! brittle on INT4 / the smaller model; gaps widen with task difficulty.

use anyhow::Result;

use crate::coordinator::{
    finetune_store, EngineSet, FinetuneCfg, GenWorkload, Session, Variant, Workload,
};
use crate::exp::cli::{ensure_quantized, parse_ft_args};
use crate::exp::write_result;
use crate::model::AsParams;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let sizes: Vec<String> = args
        .get_or("sizes", "nano,micro")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let tasks: Vec<String> = args
        .get_or("tasks", "countdown,mathchain")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let formats: Vec<Format> = args
        .get_or("formats", "int4,int8,w8a8")
        .split(',')
        .map(Format::parse)
        .collect::<Result<_>>()?;
    let suffix = args.get_or("suffix", "");
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;

    let mut md = String::from(
        "# Table 2: Reasoning accuracy (%) — Base / QuZO / QES\n\n\
         | MODEL | FORMAT | TASK | BASE | QuZO | QES |\n|---|---|---|---|---|---|\n",
    );
    let mut csv = String::from("size,format,task,base,quzo,qes\n");

    for size in &sizes {
        for task_name in &tasks {
            for &format in &formats {
                let store0 =
                    ensure_quantized(&man, size, task_name, format, fa.pretrain_steps, true)?;
                let session = Session::new(&man, size, format, EngineSet::gen_only())?;
                let cfg = FinetuneCfg { verbose: false, ..fa.cfg.clone() };
                let task = gen_task(task_name, session.cfg.s_prompt, session.cfg.t_dec)?;
                let workload = GenWorkload::new(task, &session.cfg, &cfg);
                let base_acc = workload.eval_accuracy(&session, &store0.params_view())?;

                let run_variant = |variant: Variant| -> Result<f32> {
                    let (log, _) = finetune_store(
                        &session, &workload, store0.clone(), variant, &cfg, None,
                    )?;
                    Ok(log.final_acc)
                };
                let quzo = run_variant(Variant::Quzo)?;
                let qes = run_variant(Variant::Qes)?;
                println!(
                    "{} {} {}: base {:.2} quzo {:.2} qes {:.2}",
                    size, format.name(), task_name, base_acc, quzo, qes
                );
                md.push_str(&format!(
                    "| {} | {} | {} | {:.2} | {:.2} | {:.2} |\n",
                    size, format.name().to_uppercase(), task_name, base_acc, quzo, qes
                ));
                csv.push_str(&format!(
                    "{},{},{},{:.2},{:.2},{:.2}\n",
                    size, format.name(), task_name, base_acc, quzo, qes
                ));
            }
        }
    }
    println!("\n{}", md);
    write_result(&format!("table2{}.md", suffix), &md)?;
    write_result(&format!("table2{}.csv", suffix), &csv)?;
    Ok(())
}
