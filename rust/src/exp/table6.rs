//! Table 6 — Stateless Seed Replay (QES) vs Full Residual oracle on
//! Countdown across all six (model, format) configurations.
//!
//! Shape criterion: the two variants stay within a few points of each other
//! while the oracle's optimizer state is gigabyte-scale (d-proportional)
//! and replay's is kilobytes.

use anyhow::Result;

use crate::coordinator::{finetune_store, EngineSet, FinetuneCfg, GenWorkload, Session, Variant};
use crate::exp::cli::{ensure_quantized, parse_ft_args};
use crate::exp::write_result;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;
use crate::util::args::Args;

pub fn run(args: &mut Args) -> Result<()> {
    let fa = parse_ft_args(args)?;
    let sizes: Vec<String> =
        args.get_or("sizes", "nano,micro").split(',').map(|s| s.to_string()).collect();
    let formats: Vec<Format> = args
        .get_or("formats", "int4,int8,w8a8")
        .split(',')
        .map(Format::parse)
        .collect::<Result<_>>()?;
    let task_name = args.get_or("task6", "countdown");
    args.finish()?;
    let man = Manifest::load(&fa.manifest)?;

    let mut md = String::from(
        "# Table 6: Countdown accuracy (%) — Seed Replay (QES) vs Full Residual\n\n\
         | MODEL | FORMAT | QES | FULL RESIDUAL | QES STATE | FULL-RES STATE |\n|---|---|---|---|---|---|\n",
    );
    let mut csv = String::from("size,format,qes,full_residual,qes_bytes,fullres_bytes\n");

    for size in &sizes {
        for &format in &formats {
            let store0 =
                ensure_quantized(&man, size, &task_name, format, fa.pretrain_steps, true)?;
            let session = Session::new(&man, size, format, EngineSet::gen_only())?;
            let cfg = FinetuneCfg { verbose: false, ..fa.cfg.clone() };
            let task = gen_task(&task_name, session.cfg.s_prompt, session.cfg.t_dec)?;
            let workload = GenWorkload::new(task, &session.cfg, &cfg);
            let mut accs = Vec::new();
            let mut bytes = Vec::new();
            for variant in [Variant::Qes, Variant::QesFullResidual] {
                let (log, _) =
                    finetune_store(&session, &workload, store0.clone(), variant, &cfg, None)?;
                accs.push(log.final_acc);
                bytes.push(log.optimizer_state_bytes);
            }
            println!(
                "{} {}: qes {:.2} ({}) vs full {:.2} ({})",
                size, format.name(), accs[0],
                crate::util::human_bytes(bytes[0]), accs[1],
                crate::util::human_bytes(bytes[1])
            );
            md.push_str(&format!(
                "| {} | {} | {:.2} | {:.2} | {} | {} |\n",
                size, format.name().to_uppercase(), accs[0], accs[1],
                crate::util::human_bytes(bytes[0]), crate::util::human_bytes(bytes[1])
            ));
            csv.push_str(&format!(
                "{},{},{:.2},{:.2},{},{}\n",
                size, format.name(), accs[0], accs[1], bytes[0], bytes[1]
            ));
        }
    }
    println!("\n{}", md);
    write_result("table6.md", &md)?;
    write_result("table6.csv", &csv)?;
    Ok(())
}
