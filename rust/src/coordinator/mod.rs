//! Layer-3 coordinator: the ES leader, worker pool, batch encoders and the
//! pretrain / fine-tune drivers (the paper's training system).
//!
//! Topology (mirrors the paper's rollout/update split, §4.6):
//!
//! ```text
//!   leader ──seed──▶ workers (own PJRT engines) ──rewards──▶ leader
//!     │                                                        │
//!     └── optimizer.update(gen_seed, fitness) ── lattice store ┘
//! ```

pub mod encode;
pub mod finetune;
pub mod pool;
pub mod pretrain;
pub mod rollout;
pub mod session;

pub use encode::{ClsBatch, GenBatch, LmBatch};
pub use finetune::{
    eval_problems, finetune_cls, finetune_cls_mezo, finetune_gen, FinetuneCfg, GenLog, RunLog,
    Variant,
};
pub use pool::{Job, MemberResult, WorkerPool};
pub use pretrain::{pretrain_cls, pretrain_gen, PretrainCfg};
pub use rollout::{eval_accuracy_cls, eval_accuracy_gen, MemberScratch};
pub use session::{EngineSet, Session};
