//! Layer-3 coordinator: the ES leader, worker pool, batch encoders and the
//! pretrain / fine-tune drivers (the paper's training system).
//!
//! Topology (mirrors the paper's rollout/update split, §4.6):
//!
//! ```text
//!   leader ──COW snapshot + seed──▶ workers (own PJRT engines) ──rewards──▶ leader
//!     │                                                                      │
//!     └── optimizer.update(gen_seed, fitness) ── sharded lattice plane ──────┘
//! ```
//!
//! Scenarios (reasoning RLVR, k-shot SFT, future mixed generations) are
//! [`Workload`] impls; the leader loop, the pool and the job protocol are
//! generic over the trait.

pub mod finetune;
pub mod pool;
pub mod pretrain;
pub mod session;
pub mod workload;

// Batch encoders moved to the runtime layer (they are backend inputs, not
// coordinator logic); re-exported here so coordinator callers keep their
// historical import paths.
pub use crate::runtime::encode::{ClsBatch, GenBatch, LmBatch};
pub use finetune::{
    finetune, finetune_mezo, finetune_resumable, finetune_store, FinetuneCfg, GenLog, RunLog,
    TrainCkptCfg, Variant,
};
pub use pool::{Job, MemberResult, RoundOutcome, SupervisorCfg, WorkerPool};
pub use pretrain::{pretrain_cls, pretrain_gen, PretrainCfg};
pub use session::{EngineSet, Session};
pub use workload::{
    eval_problems, workload_for, ClsRound, ClsWorkload, GenRound, GenWorkload, MemberScratch,
    Round, Workload,
};
