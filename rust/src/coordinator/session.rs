//! A Session binds one (model size, weight format) to a forward backend —
//! PJRT engines or the pure-Rust native interpreter — behind the
//! [`ForwardBackend`] trait, and layers the task-facing conveniences on
//! top (string decode, real-row stats).
//!
//! Sessions are thread-local (the PJRT client is `Rc`-based); the worker
//! pool builds one per thread. Which backend a session executes on is a
//! [`BackendPolicy`]: `Auto` (the default) picks PJRT when a real `xla`
//! runtime is linked and the native backend otherwise, so the same
//! coordinator code runs end-to-end on the offline build.

use anyhow::Result;

use crate::model::{AsParams, ParamsView};
use crate::quant::Format;
use crate::runtime::encode::{ClsBatch, GenBatch, LmBatch};
use crate::runtime::{
    BackendPolicy, ForwardBackend, Manifest, ModelConfig, NativeBackend, PjrtBackend,
};
use crate::tasks::tokenizer;

pub use crate::runtime::backend::EngineSet;

pub struct Session {
    pub cfg: ModelConfig,
    pub size: String,
    pub format: Format,
    backend: Box<dyn ForwardBackend>,
}

impl Session {
    /// Build a session with [`BackendPolicy::Auto`]: PJRT when the linked
    /// `xla` crate has a real runtime, the native interpreter otherwise.
    pub fn new(man: &Manifest, size: &str, format: Format, set: EngineSet) -> Result<Session> {
        Session::with_policy(man, size, format, set, BackendPolicy::Auto)
    }

    /// Build a session on an explicit backend. `set` declares which
    /// graphs the run uses: the PJRT path compiles exactly those, and the
    /// native interpreter enforces the same declaration (so
    /// under-declaring fails on every backend, not only under PJRT).
    pub fn with_policy(
        man: &Manifest,
        size: &str,
        format: Format,
        set: EngineSet,
        policy: BackendPolicy,
    ) -> Result<Session> {
        let backend: Box<dyn ForwardBackend> = if policy.use_pjrt() {
            Box::new(PjrtBackend::new(man, size, format, set)?)
        } else {
            Box::new(NativeBackend::with_engine_set(man, size, format, set)?)
        };
        let cfg = man.config(size)?.clone();
        Ok(Session { cfg, size: size.to_string(), format, backend })
    }

    /// Which backend this session executes on ("pjrt" | "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Cap the backend's internal parallelism (the native GEMM's thread
    /// fan-out). Results are invariant to it; sessions that live on one
    /// of many parallel worker threads should set 1 so worker-level and
    /// GEMM-level parallelism don't multiply (the same rationale as
    /// `MemberScratch::sequential`).
    pub fn set_backend_threads(&mut self, threads: usize) {
        self.backend.set_threads(threads);
    }

    /// Direct access to the forward backend (parity tests, benches).
    pub fn backend(&self) -> &dyn ForwardBackend {
        self.backend.as_ref()
    }

    /// Batched autoregressive generation. `params` is any parameter
    /// source (plain store, sharded plane, snapshot, or a prebuilt view);
    /// `overrides` replaces the lattice tensors (a member's perturbed
    /// weights); `gumbel_seed = None` decodes greedily. Returns one
    /// completion string (up to EOS) per REAL row.
    pub fn generate<P: AsParams + ?Sized>(
        &self,
        params: &P,
        overrides: Option<&[Vec<i8>]>,
        batch: &GenBatch,
        tau: f32,
        gumbel_seed: Option<u64>,
    ) -> Result<Vec<String>> {
        let view = params.params_view();
        let toks = self.backend.generate(&view, overrides, batch, tau, gumbel_seed)?;
        let t = self.cfg.t_dec;
        Ok((0..batch.n_real)
            .map(|i| tokenizer::decode_to_eos(&toks[i * t..(i + 1) * t]))
            .collect())
    }

    /// Classification loss + accuracy over the REAL rows of a ClsBatch.
    /// Returns (mean CE over real rows, n_correct among real rows).
    pub fn cls_eval<P: AsParams + ?Sized>(
        &self,
        params: &P,
        overrides: Option<&[Vec<i8>]>,
        batch: &ClsBatch,
    ) -> Result<(f32, usize)> {
        let view = params.params_view();
        // scores are per padded row; padded rows repeat a real example, so
        // real-row stats are recomputed host-side to stay exact.
        let scores = self.backend.cls_scores(&view, overrides, batch)?;
        let c = 8usize;
        let mut sum_ce = 0.0f32;
        let mut correct = 0usize;
        for i in 0..batch.n_real {
            let row = &scores[i * c..(i + 1) * c];
            let label = batch.labels[i] as usize;
            // log-softmax over the first n_classes entries (rest are
            // duplicates of class 0 — exclude them)
            let n_cls = row
                .len()
                .min(batch.class_ids.iter().collect::<std::collections::BTreeSet<_>>().len());
            let m = row[..n_cls].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logz = m + row[..n_cls].iter().map(|&s| (s - m).exp()).sum::<f32>().ln();
            sum_ce += logz - row[label];
            let pred = row[..n_cls]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label {
                correct += 1;
            }
        }
        Ok((sum_ce / batch.n_real as f32, correct))
    }

    /// Teacher-forced loss over an LmBatch: (mean CE, token accuracy).
    pub fn lm_loss<P: AsParams + ?Sized>(
        &self,
        params: &P,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<(f32, f32)> {
        let view = params.params_view();
        let (sum_ce, n_tok, n_correct) = self.backend.lm_loss(&view, overrides, batch)?;
        let n_tok = n_tok.max(1.0);
        Ok((sum_ce / n_tok, n_correct / n_tok))
    }

    /// Loss + gradients for every parameter (fp sessions only).
    pub fn lm_grads<P: AsParams + ?Sized>(
        &self,
        params: &P,
        batch: &LmBatch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let view: ParamsView<'_> = params.params_view();
        self.backend.lm_grads(&view, batch)
    }
}
