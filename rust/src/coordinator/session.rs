//! A Session binds one (model size, weight format) to a PJRT client and the
//! compiled engines a run needs. Sessions are thread-local (the client is
//! `Rc`-based); the worker pool builds one per thread.

use anyhow::Result;

use crate::coordinator::encode::{gumbel_noise, ClsBatch, GenBatch, LmBatch};
use crate::model::{AsParams, ParamsView};
use crate::quant::Format;
use crate::runtime::{self, Engine, Manifest, ModelConfig};
use crate::tasks::tokenizer;

pub struct Session {
    pub cfg: ModelConfig,
    pub size: String,
    pub format: Format,
    #[allow(dead_code)] client: xla::PjRtClient,
    gen: Option<Engine>,
    loss: Option<Engine>,
    cls: Option<Engine>,
    grad: Option<Engine>,
}

/// Which engines to compile (compilation is ~1s each; pay only for what the
/// run uses).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineSet {
    pub gen: bool,
    pub loss: bool,
    pub cls: bool,
    pub grad: bool,
}

impl EngineSet {
    pub fn gen_only() -> Self {
        EngineSet { gen: true, ..Default::default() }
    }
    pub fn cls_only() -> Self {
        EngineSet { cls: true, ..Default::default() }
    }
    pub fn pretrain() -> Self {
        EngineSet { grad: true, loss: true, gen: true, ..Default::default() }
    }
}

impl Session {
    pub fn new(man: &Manifest, size: &str, format: Format, set: EngineSet) -> Result<Session> {
        let cfg = man.config(size)?.clone();
        let client = xla::PjRtClient::cpu()?;
        let fmt = format.artifact_format();
        let mk = |want: bool, func: &str| -> Result<Option<Engine>> {
            if !want {
                return Ok(None);
            }
            Ok(Some(Engine::load(&client, man, man.artifact(size, fmt, func)?)?))
        };
        let gen = mk(set.gen, "gen")?;
        let loss = mk(set.loss, "loss")?;
        let cls = mk(set.cls, "cls")?;
        let grad = mk(set.grad, "grad")?;
        Ok(Session { cfg, size: size.to_string(), format, client, gen, loss, cls, grad })
    }

    fn engine<'a>(e: &'a Option<Engine>, what: &str) -> Result<&'a Engine> {
        e.as_ref().ok_or_else(|| anyhow::anyhow!("engine {:?} not compiled for this session", what))
    }

    /// Batched autoregressive generation. `params` is any parameter
    /// source (plain store, sharded plane, snapshot, or a prebuilt view);
    /// `overrides` replaces the lattice tensors (a member's perturbed
    /// weights); `gumbel_seed = None` decodes greedily. Returns one
    /// completion string (up to EOS) per REAL row.
    pub fn generate<P: AsParams + ?Sized>(
        &self,
        params: &P,
        overrides: Option<&[Vec<i8>]>,
        batch: &GenBatch,
        tau: f32,
        gumbel_seed: Option<u64>,
    ) -> Result<Vec<String>> {
        let view = params.params_view();
        let eng = Self::engine(&self.gen, "gen")?;
        let cfg = &self.cfg;
        let mut args = Vec::with_capacity(4 + view.store.entries.len());
        args.push(runtime::literal_for(
            &eng.meta.data_inputs[0],
            &runtime::HostTensor::I32(batch.prompt.clone()),
        )?);
        args.push(runtime::literal_for(
            &eng.meta.data_inputs[1],
            &runtime::HostTensor::I32(batch.lens.clone()),
        )?);
        args.push(xla::Literal::scalar(tau));
        args.push(runtime::literal_for(
            &eng.meta.data_inputs[3],
            &runtime::HostTensor::F32(gumbel_noise(cfg, gumbel_seed)),
        )?);
        args.extend(runtime::param_literals_view(&view, overrides)?);
        let outs = eng.run(&args)?;
        let toks = runtime::to_i32_vec(&outs[0])?;
        let t = cfg.t_dec;
        Ok((0..batch.n_real)
            .map(|i| tokenizer::decode_to_eos(&toks[i * t..(i + 1) * t]))
            .collect())
    }

    /// Classification loss + accuracy over the REAL rows of a ClsBatch.
    /// Returns (mean CE over real rows, n_correct among real rows).
    pub fn cls_eval<P: AsParams + ?Sized>(
        &self,
        params: &P,
        overrides: Option<&[Vec<i8>]>,
        batch: &ClsBatch,
    ) -> Result<(f32, usize)> {
        let view = params.params_view();
        let eng = Self::engine(&self.cls, "cls")?;
        let d = &eng.meta.data_inputs;
        let mut args = Vec::with_capacity(6 + view.store.entries.len());
        args.push(runtime::literal_for(&d[0], &runtime::HostTensor::I32(batch.tokens.clone()))?);
        args.push(runtime::literal_for(&d[1], &runtime::HostTensor::I32(batch.pos_ids.clone()))?);
        args.push(runtime::literal_for(&d[2], &runtime::HostTensor::F32(batch.mask.clone()))?);
        args.push(runtime::literal_for(&d[3], &runtime::HostTensor::I32(batch.cls_pos.clone()))?);
        args.push(runtime::literal_for(&d[4], &runtime::HostTensor::I32(batch.class_ids.clone()))?);
        args.push(runtime::literal_for(&d[5], &runtime::HostTensor::I32(batch.labels.clone()))?);
        args.extend(runtime::param_literals_view(&view, overrides)?);
        let outs = eng.run(&args)?;
        // outputs: (sum_ce over ALL rows, n_correct over ALL rows, scores)
        // padded rows repeat a real example; recompute real-row stats from
        // the returned scores to stay exact.
        let scores = runtime::to_f32_vec(&outs[2])?;
        let c = 8usize;
        let mut sum_ce = 0.0f32;
        let mut correct = 0usize;
        for i in 0..batch.n_real {
            let row = &scores[i * c..(i + 1) * c];
            let label = batch.labels[i] as usize;
            // log-softmax over the first n_classes entries (rest are
            // duplicates of class 0 — exclude them)
            let n_cls = row
                .len()
                .min(batch.class_ids.iter().collect::<std::collections::BTreeSet<_>>().len());
            let m = row[..n_cls].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logz = m + row[..n_cls].iter().map(|&s| (s - m).exp()).sum::<f32>().ln();
            sum_ce += logz - row[label];
            let pred = row[..n_cls]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label {
                correct += 1;
            }
        }
        Ok((sum_ce / batch.n_real as f32, correct))
    }

    /// Teacher-forced loss over an LmBatch: (mean CE, token accuracy).
    pub fn lm_loss<P: AsParams + ?Sized>(
        &self,
        params: &P,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<(f32, f32)> {
        let view = params.params_view();
        let eng = Self::engine(&self.loss, "loss")?;
        let outs = eng.run(&self.lm_args(eng, &view, overrides, batch)?)?;
        let sum_ce = runtime::to_f32_scalar(&outs[0])?;
        let n_tok = runtime::to_f32_scalar(&outs[1])?.max(1.0);
        let n_correct = runtime::to_f32_scalar(&outs[2])?;
        Ok((sum_ce / n_tok, n_correct / n_tok))
    }

    /// Loss + gradients for every parameter (fp sessions only).
    pub fn lm_grads<P: AsParams + ?Sized>(
        &self,
        params: &P,
        batch: &LmBatch,
    ) -> Result<(f32, Vec<Vec<f32>>)> {
        let view = params.params_view();
        let eng = Self::engine(&self.grad, "grad")?;
        let outs = eng.run(&self.lm_args(eng, &view, None, batch)?)?;
        let loss = runtime::to_f32_scalar(&outs[0])?;
        let grads = outs[1..]
            .iter()
            .map(runtime::to_f32_vec)
            .collect::<Result<Vec<_>>>()?;
        Ok((loss, grads))
    }

    fn lm_args(
        &self,
        eng: &Engine,
        view: &ParamsView<'_>,
        overrides: Option<&[Vec<i8>]>,
        batch: &LmBatch,
    ) -> Result<Vec<xla::Literal>> {
        let d = &eng.meta.data_inputs;
        let mut args = Vec::with_capacity(5 + view.store.entries.len());
        args.push(runtime::literal_for(&d[0], &runtime::HostTensor::I32(batch.tokens.clone()))?);
        args.push(runtime::literal_for(&d[1], &runtime::HostTensor::I32(batch.pos_ids.clone()))?);
        args.push(runtime::literal_for(&d[2], &runtime::HostTensor::F32(batch.mask.clone()))?);
        args.push(runtime::literal_for(&d[3], &runtime::HostTensor::I32(batch.targets.clone()))?);
        args.push(runtime::literal_for(
            &d[4],
            &runtime::HostTensor::F32(batch.loss_mask.clone()),
        )?);
        args.extend(runtime::param_literals_view(view, overrides)?);
        Ok(args)
    }
}
