//! Pretraining pipeline: Adam over the AOT `grad_fp` artifact.
//!
//! The paper fine-tunes *pretrained* quantized backbones; this repo has no
//! external checkpoints, so base models are produced here — supervised
//! training on each task's synthetic corpus, stopped at partial competence
//! so that PTQ + fine-tuning has headroom (DESIGN.md §2). Also powers the
//! FO-FP32 / FO+STE baselines of Table 1.

use anyhow::Result;

use crate::coordinator::session::Session;
use crate::runtime::encode::LmBatch;
use crate::model::ParamStore;
use crate::opt::{Adam, AdamConfig};
use crate::rng::SplitMix64;
use crate::tasks::{ClsTask, GenTask};

#[derive(Debug, Clone)]
pub struct PretrainCfg {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// STE snap grid (first-order quantized baseline); None = plain Adam.
    pub ste_qmax: Option<i8>,
    pub verbose: bool,
}

impl Default for PretrainCfg {
    fn default() -> Self {
        PretrainCfg { steps: 400, lr: 3e-3, seed: 7, ste_qmax: None, verbose: false }
    }
}

/// Supervised pretraining on a reasoning task's (prompt, solution) corpus.
/// Returns the final training loss.
pub fn pretrain_gen(
    session: &Session,
    task: &dyn GenTask,
    store: &mut ParamStore,
    cfg: &PretrainCfg,
) -> Result<f32> {
    let mut adam = Adam::new(
        store,
        AdamConfig { lr: cfg.lr, ste_qmax: cfg.ste_qmax, ..Default::default() },
    );
    let mut rng = SplitMix64::new(cfg.seed);
    let b = session.cfg.b_train;
    let mut last = f32::NAN;
    for step in 0..cfg.steps {
        let pairs: Vec<(String, String)> = (0..b).map(|_| task.supervised(&mut rng)).collect();
        let batch = LmBatch::build(&session.cfg, &pairs);
        let (loss, grads) = session.lm_grads(&*store, &batch)?;
        adam.step(store, &grads)?;
        last = loss;
        if cfg.verbose && step % 50 == 0 {
            println!("[pretrain step {:>5}] loss {:.4}", step, loss);
        }
    }
    Ok(last)
}

/// Supervised training on an SFT task: LM loss on the verbalizer token of
/// "text" -> "<verbalizer>;" pairs. This is both the pretraining recipe for
/// SFT backbones and the FO baseline's training loop (with ste_qmax set).
pub fn pretrain_cls(
    session: &Session,
    task: &dyn ClsTask,
    store: &mut ParamStore,
    cfg: &PretrainCfg,
) -> Result<f32> {
    let mut adam = Adam::new(
        store,
        AdamConfig { lr: cfg.lr, ste_qmax: cfg.ste_qmax, ..Default::default() },
    );
    let mut rng = SplitMix64::new(cfg.seed);
    let b = session.cfg.b_train;
    let mut last = f32::NAN;
    for step in 0..cfg.steps {
        let pairs: Vec<(String, String)> = (0..b)
            .map(|_| {
                let ex = task.sample(&mut rng, true);
                // verbalizer char for the label: 'a' + label (see ClsTask)
                let v = (b'a' + ex.label as u8) as char;
                (ex.text, format!("{};", v))
            })
            .collect();
        let batch = LmBatch::build(&session.cfg, &pairs);
        let (loss, grads) = session.lm_grads(&*store, &batch)?;
        adam.step(store, &grads)?;
        last = loss;
        if cfg.verbose && step % 50 == 0 {
            println!("[pretrain-cls step {:>5}] loss {:.4}", step, loss);
        }
    }
    Ok(last)
}
