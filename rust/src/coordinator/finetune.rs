//! The ES generation loop — the paper's training procedure (§3, §A.3).
//!
//! ONE generic loop for every scenario: per generation, ask the
//! `Workload` for the round payload (common across members — common
//! random numbers cut fitness variance), evaluate all 2N antithetic
//! members (inline or on the worker pool against a COW snapshot of the
//! sharded parameter plane), rank-normalize rewards, and hand
//! (gen_seed, fitness) to the optimizer. Rollout and update wall-clock
//! are measured separately — they are Table 9's two columns.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::pool::{Job, WorkerPool};
use crate::coordinator::session::Session;
use crate::coordinator::workload::{ClsWorkload, MemberScratch, Workload};
use crate::model::checkpoint::{self, TrainState};
use crate::model::{AsParams, ParamStore, ShardedParamStore};
use crate::obs;
use crate::opt::{
    quorum_fitness, EsHyper, LatticeOptimizer, MezoOptimizer, PopulationSpec,
    QesFullResidual, QuzoOptimizer, SeedReplayQes,
};
use crate::rng::SplitMix64;
use crate::util::fault::{FaultPlan, DEFAULT_MAX_RETRIES};

/// Which optimizer drives the run (paper method names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// QES with Stateless Seed Replay (Algorithm 2) — the paper's method.
    Qes,
    /// QES with explicit FP16 residuals (Algorithm 1) — the oracle.
    QesFullResidual,
    /// QuZO: stateless stochastic-rounding ZO (primary baseline).
    Quzo,
    /// QES with the adaptive-K extension (paper §6 future work).
    QesAdaptive,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "qes" => Variant::Qes,
            "qes-full" | "full-residual" => Variant::QesFullResidual,
            "quzo" => Variant::Quzo,
            "qes-adaptive" => Variant::QesAdaptive,
            other => {
                anyhow::bail!("unknown variant {:?} (qes|qes-full|quzo|qes-adaptive)", other)
            }
        })
    }

    /// Canonical CLI/checkpoint name (inverse of [`Variant::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Variant::Qes => "qes",
            Variant::QesFullResidual => "qes-full",
            Variant::Quzo => "quzo",
            Variant::QesAdaptive => "qes-adaptive",
        }
    }

    pub fn build(self, d: usize, qmax: i8, hyper: EsHyper) -> Box<dyn LatticeOptimizer> {
        match self {
            Variant::Qes => Box::new(SeedReplayQes::new(d, qmax, hyper)),
            Variant::QesFullResidual => Box::new(QesFullResidual::new(d, qmax, hyper)),
            Variant::Quzo => Box::new(QuzoOptimizer::new(d, qmax, hyper)),
            Variant::QesAdaptive => {
                let k0 = hyper.k_window;
                Box::new(crate::opt::AdaptiveReplayQes::new(
                    d,
                    qmax,
                    hyper,
                    (k0 / 4).max(1),
                    k0 * 4,
                ))
            }
        }
    }
}

/// One generation's telemetry.
#[derive(Debug, Clone)]
pub struct GenLog {
    pub gen: usize,
    pub mean_reward: f32,
    pub best_reward: f32,
    pub eval_acc: Option<f32>,
    pub update_ratio: f64,
    pub boundary_ratio: f64,
    pub rollout_ms: f64,
    pub update_ms: f64,
    /// Members that exhausted their retry budget this generation (the
    /// round committed degraded when > 0).
    pub failed_members: usize,
    /// KV-plane telemetry read from the metrics registry
    /// ([`crate::obs::KvDelta`] over the `qes_kv_*` counters fed by the
    /// schedulers this generation ran): prefix-cache hits and
    /// copy-on-write page forks as per-generation deltas, pages-in-use
    /// high-water as the PROCESS-lifetime running maximum (the
    /// `qes_kv_pages_high_water` gauge). Observability, never part of
    /// the determinism contract.
    pub kv_pages_hw: u64,
    pub kv_prefix_hits: u64,
    pub kv_cow_forks: u64,
}

#[derive(Debug, Default)]
pub struct RunLog {
    pub entries: Vec<GenLog>,
    pub final_acc: f32,
    pub optimizer_state_bytes: u64,
}

impl RunLog {
    pub fn mean_rollout_ms(&self) -> f64 {
        crate::util::mean(&self.entries.iter().map(|e| e.rollout_ms as f32).collect::<Vec<_>>())
            as f64
    }
    pub fn mean_update_ms(&self) -> f64 {
        crate::util::mean(&self.entries.iter().map(|e| e.update_ms as f32).collect::<Vec<_>>())
            as f64
    }

    /// Dump the reward/eval curves as CSV (Fig. 2 series).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("gen,mean_reward,best_reward,eval_acc,update_ratio,boundary_ratio,rollout_ms,update_ms,failed_members,kv_pages_hw,kv_prefix_hits,kv_cow_forks\n");
        for e in &self.entries {
            s.push_str(&format!(
                "{},{:.4},{:.4},{},{:.6},{:.6},{:.2},{:.2},{},{},{},{}\n",
                e.gen,
                e.mean_reward,
                e.best_reward,
                e.eval_acc.map(|a| format!("{:.2}", a)).unwrap_or_default(),
                e.update_ratio,
                e.boundary_ratio,
                e.rollout_ms,
                e.update_ms,
                e.failed_members,
                e.kv_pages_hw,
                e.kv_prefix_hits,
                e.kv_cow_forks
            ));
        }
        s
    }
}

/// Run configuration for a fine-tuning run.
#[derive(Debug, Clone)]
pub struct FinetuneCfg {
    pub hyper: EsHyper,
    pub gens: usize,
    /// Decode-sampling temperature during training rollouts (0 = greedy).
    pub tau: f32,
    /// Rollout batches (of b_gen problems) per member per generation —
    /// fitness granularity is 1/(b_gen * batches).
    pub batches_per_gen: usize,
    /// Fixed training-pool size (problems are drawn from a persistent pool,
    /// like the paper's GSM8K train split, so the fitness signal has a
    /// consistent direction across generations).
    pub train_pool: usize,
    /// Evaluate greedy accuracy every this many generations (0 = only at end).
    pub eval_every: usize,
    pub eval_n: usize,
    pub seed: u64,
    pub verbose: bool,
    /// Minimum fraction of antithetic pairs that must score for a round
    /// to commit (degraded); below this the run errors (`opt::quorum_fitness`).
    pub min_quorum: f32,
    /// Deterministic fault-injection plan (inert by default). On the
    /// inline path this simulates exactly the permanently-failed member
    /// set a pool run would commit — `FaultPlan::member_fails` with the
    /// shared `DEFAULT_MAX_RETRIES` budget.
    pub faults: FaultPlan,
    /// Cross-member grouped rollout: score whole member subsets through
    /// ONE scheduler/resolve pass per round (`Workload::eval_members`)
    /// instead of one per member. Rewards are bit-identical either way —
    /// this is pure wall-clock. Defaults from `QES_GROUPED` (on unless
    /// `0|off|false`); tests flip it programmatically.
    pub grouped: bool,
}

impl Default for FinetuneCfg {
    fn default() -> Self {
        FinetuneCfg {
            hyper: EsHyper::default(),
            gens: 60,
            tau: 0.7,
            batches_per_gen: 2,
            train_pool: 256,
            eval_every: 0,
            eval_n: 64,
            seed: 42,
            verbose: false,
            min_quorum: 0.5,
            faults: FaultPlan::default(),
            grouped: crate::coordinator::workload::grouped_rollout_enabled(),
        }
    }
}

/// Periodic crash-consistent training checkpoints for
/// [`finetune_resumable`].
#[derive(Debug, Clone)]
pub struct TrainCkptCfg {
    pub path: PathBuf,
    /// Checkpoint every N generations (and always after the last one).
    /// 0 disables periodic saves entirely.
    pub every: usize,
}

/// Fine-tune the sharded parameter plane with an ES-family optimizer on
/// any [`Workload`]. `pool` distributes members when Some (each
/// generation publishes one O(dirty-shards) snapshot); otherwise member
/// evaluation runs inline on the leader.
///
/// NOTE on `cfg`: the loop reads only `gens`, `eval_every`, `seed` and
/// `hyper` here — the rollout-data fields (`tau`, `train_pool`,
/// `batches_per_gen`, `eval_n`) were captured by the workload when it was
/// constructed. Varying those between construction and this call has no
/// effect; rebuild the workload instead (varying `hyper.*` per call, as
/// table7/table9 do, is fine).
pub fn finetune(
    session: &Session,
    workload: &dyn Workload,
    store: &mut ShardedParamStore,
    variant: Variant,
    cfg: &FinetuneCfg,
    pool: Option<&WorkerPool>,
) -> Result<RunLog> {
    finetune_resumable(session, workload, store, variant, cfg, pool, None, None)
}

/// [`finetune`] with crash-consistent checkpointing and resume.
///
/// * `ckpt` — write an atomic training checkpoint (lattice + optimizer
///   state blob + round/RNG counters) every `ckpt.every` generations and
///   after the final one.
/// * `resume` — continue a run from a [`TrainState`]: the caller must
///   have built `store` from `resume.store`; this function validates
///   seed/variant, restores the optimizer state, fast-forwards the
///   master RNG by `rounds_done` draws (one per generation — the
///   SplitMix64 Weyl sequence makes that O(1)), and runs the remaining
///   generations. The continued run is bit-identical to an
///   uninterrupted one.
///
/// Degraded rounds: when a pool reports permanently-failed members (or
/// the inline path simulates them from `cfg.faults`), fitness is
/// renormalized over the pairs actually scored (`opt::quorum_fitness`)
/// subject to `cfg.min_quorum`. Given the same failed-member set the
/// committed lattice is bit-identical regardless of topology, retries
/// or arrival order.
#[allow(clippy::too_many_arguments)]
pub fn finetune_resumable(
    session: &Session,
    workload: &dyn Workload,
    store: &mut ShardedParamStore,
    variant: Variant,
    cfg: &FinetuneCfg,
    pool: Option<&WorkerPool>,
    ckpt: Option<&TrainCkptCfg>,
    resume: Option<&TrainState>,
) -> Result<RunLog> {
    let qmax = store.format().qmax();
    let d = store.lattice_dim();
    let mut opt = variant.build(d, qmax, cfg.hyper.clone());
    let mut master = SplitMix64::new(cfg.seed);
    let mut start_gen = 0usize;
    if let Some(ts) = resume {
        anyhow::ensure!(
            ts.seed == cfg.seed,
            "cannot resume: checkpoint seed {} != configured seed {}",
            ts.seed,
            cfg.seed
        );
        anyhow::ensure!(
            ts.variant == variant.name(),
            "cannot resume: checkpoint variant {:?} != configured {:?}",
            ts.variant,
            variant.name()
        );
        anyhow::ensure!(
            ts.rounds_done as usize <= cfg.gens,
            "cannot resume: checkpoint has {} rounds, run wants {}",
            ts.rounds_done,
            cfg.gens
        );
        opt.load_state(&mut ts.opt_state.as_slice())?;
        // The master RNG draws exactly one u64 per generation.
        master.jump(ts.rounds_done);
        start_gen = ts.rounds_done as usize;
    }
    let mut log = RunLog::default();
    // perturbation buffers reused across every inline member evaluation
    let mut scratch = MemberScratch::default();
    // non-destructive per-generation reader over the registry's KV
    // counters — other readers (a serve summary in the same process)
    // see the same totals, nothing is stolen
    let mut kv = obs::KvDelta::new();

    for gen in start_gen..cfg.gens {
        let gen_seed = master.next_u64();
        let spec = PopulationSpec { gen_seed, pairs: cfg.hyper.pairs, sigma: cfg.hyper.sigma };
        let n_members = spec.n_members();
        let round = workload.build_round(gen_seed)?;
        let round_id = gen as u64;

        // --- rollout phase ---
        let trace = obs::trace_enabled();
        let tr0 = if trace { obs::now_ns() } else { 0 };
        let t0 = Instant::now();
        let rewards: Vec<Option<f32>> = match pool {
            Some(p) => {
                let snapshot = store.snapshot();
                let w = p.n_workers();
                // jobs stream straight into the worker channels — no
                // leader-side Vec<Job>, and the round/snapshot payloads
                // are Arc bumps, never data clones
                let jobs = (0..w).map(|i| Job::Eval {
                    snapshot: snapshot.clone(),
                    gen_seed,
                    pairs: spec.pairs,
                    sigma: spec.sigma,
                    members: (0..n_members)
                        .filter(|m| m % w == i)
                        .map(|m| (m, 0))
                        .collect(),
                    round: round.clone(),
                    round_id,
                });
                p.run_round(jobs, n_members)?.rewards
            }
            None => {
                let view = store.params_view();
                // Inline replica of the pool's failure semantics: a
                // member whose every scoring attempt faults under the
                // plan is permanently failed — the same pure function of
                // (plan, round, member) the supervised pool converges
                // to. Survivors are scored through the round-level
                // grouped entry the pool workers use too (ONE
                // resolve+pack and one weight pass per layer per step
                // across the whole surviving population when
                // `cfg.grouped` is on).
                let survivors: Vec<usize> = (0..n_members)
                    .filter(|&m| {
                        !(cfg.faults.is_active()
                            && cfg.faults.member_fails(round_id, m, DEFAULT_MAX_RETRIES))
                    })
                    .collect();
                let mut rewards: Vec<Option<f32>> = vec![None; n_members];
                if !survivors.is_empty() {
                    let scored = workload.eval_members(
                        session,
                        &view,
                        &spec,
                        &survivors,
                        round.as_ref(),
                        &mut scratch,
                    );
                    for (&m, r) in survivors.iter().zip(scored) {
                        rewards[m] = Some(r?);
                    }
                }
                rewards
            }
        };
        let rollout_ms = t0.elapsed().as_secs_f64() * 1e3;
        let failed_members = rewards.iter().filter(|r| r.is_none()).count();
        obs::m().train_rollout_ns.observe((rollout_ms * 1e6) as u64);
        if trace {
            obs::record_span(obs::Span {
                request: gen as u64,
                conn: None,
                member: None,
                phase: obs::Phase::Rollout,
                t_start_ns: tr0,
                t_end_ns: obs::now_ns(),
                tokens: n_members as u64,
            });
        }

        // --- update phase ---
        let fitness = quorum_fitness(&rewards, cfg.min_quorum)?;
        let tu0 = if trace { obs::now_ns() } else { 0 };
        let t1 = Instant::now();
        let stats = opt.update(store, &spec, &fitness)?;
        let update_ms = t1.elapsed().as_secs_f64() * 1e3;
        obs::m().train_update_ns.observe((update_ms * 1e6) as u64);
        if trace {
            obs::record_span(obs::Span {
                request: gen as u64,
                conn: None,
                member: None,
                phase: obs::Phase::Update,
                t_start_ns: tu0,
                t_end_ns: obs::now_ns(),
                tokens: n_members as u64,
            });
        }

        let eval_acc = if cfg.eval_every > 0 && (gen + 1) % cfg.eval_every == 0 {
            Some(workload.eval_accuracy(session, &store.params_view())?)
        } else {
            None
        };
        let scored: Vec<f32> = rewards.iter().filter_map(|r| *r).collect();
        // per-generation KV deltas straight off the registry counters
        // (rollout + any eval pass; pages_hw is the process-lifetime
        // high-water gauge)
        let (kv_pages_hw, kv_prefix_hits, _kv_misses, kv_cow_forks) = kv.delta();
        let entry = GenLog {
            gen,
            mean_reward: crate::util::mean(&scored),
            best_reward: scored.iter().cloned().fold(f32::MIN, f32::max),
            eval_acc,
            update_ratio: stats.update_ratio(),
            boundary_ratio: stats.boundary_hit_ratio(),
            rollout_ms,
            update_ms,
            failed_members,
            kv_pages_hw,
            kv_prefix_hits,
            kv_cow_forks,
        };
        if cfg.verbose {
            println!(
                "[{} gen {:>4}] reward {:.3} (best {:.3}) upd {:.4}% roll {:.0}ms upd {:.0}ms{}{}",
                opt.name(),
                gen,
                entry.mean_reward,
                entry.best_reward,
                100.0 * entry.update_ratio,
                rollout_ms,
                update_ms,
                entry.eval_acc.map(|a| format!(" eval {:.1}%", a)).unwrap_or_default(),
                if failed_members > 0 {
                    format!(" DEGRADED ({} members failed)", failed_members)
                } else {
                    String::new()
                }
            );
        }
        log.entries.push(entry);
        obs::m().train_rounds.inc();
        if trace {
            // generation committed: the lattice update is applied and the
            // round's entry is logged
            let t = obs::now_ns();
            obs::record_span(obs::Span {
                request: gen as u64,
                conn: None,
                member: None,
                phase: obs::Phase::Commit,
                t_start_ns: t,
                t_end_ns: t,
                tokens: failed_members as u64,
            });
        }

        // --- crash-consistent checkpoint ---
        if let Some(c) = ckpt {
            if c.every > 0 && ((gen + 1) % c.every == 0 || gen + 1 == cfg.gens) {
                let tc0 = if trace { obs::now_ns() } else { 0 };
                let mut blob = Vec::new();
                opt.save_state(&mut blob)?;
                let plain = store.materialize();
                checkpoint::save_train(
                    &c.path,
                    &plain,
                    (gen + 1) as u64,
                    cfg.seed,
                    variant.name(),
                    &blob,
                )?;
                if trace {
                    obs::record_span(obs::Span {
                        request: gen as u64,
                        conn: None,
                        member: None,
                        phase: obs::Phase::Checkpoint,
                        t_start_ns: tc0,
                        t_end_ns: obs::now_ns(),
                        tokens: (gen + 1) as u64,
                    });
                }
            }
        }
    }
    log.final_acc = workload.eval_accuracy(session, &store.params_view())?;
    log.optimizer_state_bytes = opt.state_bytes();
    Ok(log)
}

/// [`finetune`] over a plain store: shards it with the default layout,
/// runs the generic loop, and materializes the trained store back —
/// the convenience entry point for the CLI and experiment drivers.
pub fn finetune_store(
    session: &Session,
    workload: &dyn Workload,
    store: ParamStore,
    variant: Variant,
    cfg: &FinetuneCfg,
    pool: Option<&WorkerPool>,
) -> Result<(RunLog, ParamStore)> {
    let mut sharded = ShardedParamStore::with_default_shards(store)?;
    let log = finetune(session, workload, &mut sharded, variant, cfg, pool)?;
    Ok((log, sharded.materialize()))
}

/// MeZO on an fp store (Table 1's FP32 zeroth-order baseline): SPSA with
/// continuous perturbations, fitness = -CE on the workload's k-shot
/// batches. Continuous weights have no lattice plane, so this stays a
/// plain-store loop outside the `LatticeOptimizer` protocol.
pub fn finetune_mezo(
    session: &Session,
    workload: &ClsWorkload,
    store: &mut ParamStore,
    cfg: &FinetuneCfg,
) -> Result<RunLog> {
    let mut opt = MezoOptimizer::new(cfg.hyper.clone());
    let mut master = SplitMix64::new(cfg.seed);
    let train_batches = workload.train_batches();
    let mut log = RunLog::default();

    for gen in 0..cfg.gens {
        let gen_seed = master.next_u64();
        let spec = PopulationSpec { gen_seed, pairs: cfg.hyper.pairs, sigma: cfg.hyper.sigma };
        let t0 = Instant::now();
        let mut raw = vec![0.0f32; spec.n_members()];
        for (m, slot) in raw.iter_mut().enumerate() {
            let perturbed = MezoOptimizer::perturb_fp(store, &spec, m);
            // evaluate by temporarily swapping in the perturbed tensors
            let mut loss = 0.0f32;
            let saved = swap_fp_lattice(store, &perturbed);
            for b in train_batches.iter() {
                let (ce, _) = session.cls_eval(&*store, None, b)?;
                loss += ce;
            }
            restore_fp_lattice(store, saved);
            *slot = -loss / train_batches.len() as f32;
        }
        let rollout_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        opt.update_fp(store, &spec, &raw)?;
        let update_ms = t1.elapsed().as_secs_f64() * 1e3;
        let eval_acc = if cfg.eval_every > 0 && (gen + 1) % cfg.eval_every == 0 {
            Some(workload.eval_accuracy(session, &store.params_view())?)
        } else {
            None
        };
        log.entries.push(GenLog {
            gen,
            mean_reward: crate::util::mean(&raw),
            best_reward: raw.iter().cloned().fold(f32::MIN, f32::max),
            eval_acc,
            update_ratio: 0.0,
            boundary_ratio: 0.0,
            rollout_ms,
            update_ms,
            failed_members: 0,
            kv_pages_hw: 0,
            kv_prefix_hits: 0,
            kv_cow_forks: 0,
        });
    }
    log.final_acc = workload.eval_accuracy(session, &store.params_view())?;
    log.optimizer_state_bytes = opt.state_bytes();
    Ok(log)
}

fn swap_fp_lattice(store: &mut ParamStore, values: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let lat: Vec<usize> = store.lattice_indices().to_vec();
    let mut saved = Vec::with_capacity(lat.len());
    for (k, &i) in lat.iter().enumerate() {
        let dst = store.entries[i].data.as_f32_mut();
        saved.push(dst.to_vec());
        dst.copy_from_slice(&values[k]);
    }
    saved
}

fn restore_fp_lattice(store: &mut ParamStore, saved: Vec<Vec<f32>>) {
    let lat: Vec<usize> = store.lattice_indices().to_vec();
    for (k, &i) in lat.iter().enumerate() {
        store.entries[i].data.as_f32_mut().copy_from_slice(&saved[k]);
    }
}
