//! The ES generation loop — the paper's training procedure (§3, §A.3).
//!
//! Per generation: sample a rollout problem batch (common across members —
//! common random numbers cut fitness variance), evaluate all 2N antithetic
//! members, rank-normalize rewards, and hand (gen_seed, fitness) to the
//! optimizer. Rollout and update wall-clock are measured separately — they
//! are Table 9's two columns.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::encode::{ClsBatch, GenBatch};
use crate::coordinator::pool::{Job, WorkerPool};
use crate::coordinator::rollout::{
    eval_accuracy_cls, eval_accuracy_gen, eval_member_cls_with, eval_member_gen_with,
    MemberScratch,
};
use crate::coordinator::session::Session;
use crate::model::ParamStore;
use crate::opt::{
    normalize_fitness, EsHyper, LatticeOptimizer, MezoOptimizer, PopulationSpec,
    QesFullResidual, QuzoOptimizer, SeedReplayQes,
};
use crate::rng::SplitMix64;
use crate::tasks::{ClsTask, GenProblem, GenTask};

/// Which optimizer drives the run (paper method names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// QES with Stateless Seed Replay (Algorithm 2) — the paper's method.
    Qes,
    /// QES with explicit FP16 residuals (Algorithm 1) — the oracle.
    QesFullResidual,
    /// QuZO: stateless stochastic-rounding ZO (primary baseline).
    Quzo,
    /// QES with the adaptive-K extension (paper §6 future work).
    QesAdaptive,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "qes" => Variant::Qes,
            "qes-full" | "full-residual" => Variant::QesFullResidual,
            "quzo" => Variant::Quzo,
            "qes-adaptive" => Variant::QesAdaptive,
            other => {
                anyhow::bail!("unknown variant {:?} (qes|qes-full|quzo|qes-adaptive)", other)
            }
        })
    }

    pub fn build(self, d: usize, qmax: i8, hyper: EsHyper) -> Box<dyn LatticeOptimizer> {
        match self {
            Variant::Qes => Box::new(SeedReplayQes::new(d, qmax, hyper)),
            Variant::QesFullResidual => Box::new(QesFullResidual::new(d, qmax, hyper)),
            Variant::Quzo => Box::new(QuzoOptimizer::new(d, qmax, hyper)),
            Variant::QesAdaptive => {
                let k0 = hyper.k_window;
                Box::new(crate::opt::AdaptiveReplayQes::new(
                    d,
                    qmax,
                    hyper,
                    (k0 / 4).max(1),
                    k0 * 4,
                ))
            }
        }
    }
}

/// One generation's telemetry.
#[derive(Debug, Clone)]
pub struct GenLog {
    pub gen: usize,
    pub mean_reward: f32,
    pub best_reward: f32,
    pub eval_acc: Option<f32>,
    pub update_ratio: f64,
    pub boundary_ratio: f64,
    pub rollout_ms: f64,
    pub update_ms: f64,
}

#[derive(Debug, Default)]
pub struct RunLog {
    pub entries: Vec<GenLog>,
    pub final_acc: f32,
    pub optimizer_state_bytes: u64,
}

impl RunLog {
    pub fn mean_rollout_ms(&self) -> f64 {
        crate::util::mean(&self.entries.iter().map(|e| e.rollout_ms as f32).collect::<Vec<_>>())
            as f64
    }
    pub fn mean_update_ms(&self) -> f64 {
        crate::util::mean(&self.entries.iter().map(|e| e.update_ms as f32).collect::<Vec<_>>())
            as f64
    }

    /// Dump the reward/eval curves as CSV (Fig. 2 series).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("gen,mean_reward,best_reward,eval_acc,update_ratio,boundary_ratio,rollout_ms,update_ms\n");
        for e in &self.entries {
            s.push_str(&format!(
                "{},{:.4},{:.4},{},{:.6},{:.6},{:.2},{:.2}\n",
                e.gen,
                e.mean_reward,
                e.best_reward,
                e.eval_acc.map(|a| format!("{:.2}", a)).unwrap_or_default(),
                e.update_ratio,
                e.boundary_ratio,
                e.rollout_ms,
                e.update_ms
            ));
        }
        s
    }
}

/// Run configuration for a fine-tuning run.
#[derive(Debug, Clone)]
pub struct FinetuneCfg {
    pub hyper: EsHyper,
    pub gens: usize,
    /// Decode-sampling temperature during training rollouts (0 = greedy).
    pub tau: f32,
    /// Rollout batches (of b_gen problems) per member per generation —
    /// fitness granularity is 1/(b_gen * batches).
    pub batches_per_gen: usize,
    /// Fixed training-pool size (problems are drawn from a persistent pool,
    /// like the paper's GSM8K train split, so the fitness signal has a
    /// consistent direction across generations).
    pub train_pool: usize,
    /// Evaluate greedy accuracy every this many generations (0 = only at end).
    pub eval_every: usize,
    pub eval_n: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for FinetuneCfg {
    fn default() -> Self {
        FinetuneCfg {
            hyper: EsHyper::default(),
            gens: 60,
            tau: 0.7,
            batches_per_gen: 2,
            train_pool: 256,
            eval_every: 0,
            eval_n: 64,
            seed: 42,
            verbose: false,
        }
    }
}

/// Sample a fixed eval problem set (disjoint seed space from training).
pub fn eval_problems(task: &dyn GenTask, n: usize, seed: u64) -> Vec<GenProblem> {
    let mut rng = SplitMix64::new(seed ^ 0x6576_616c_5f73_6574);
    (0..n).map(|_| task.sample(&mut rng)).collect()
}

/// Fine-tune a quantized store with an ES-family optimizer on a reasoning
/// task. `pool` distributes members when Some; otherwise inline.
#[allow(clippy::too_many_arguments)]
pub fn finetune_gen(
    session: &Session,
    task: &dyn GenTask,
    store: &mut ParamStore,
    variant: Variant,
    cfg: &FinetuneCfg,
    pool: Option<&WorkerPool>,
) -> Result<RunLog> {
    let qmax = store.format.qmax();
    let d = store.lattice_dim();
    let mut opt = variant.build(d, qmax, cfg.hyper.clone());
    let mut master = SplitMix64::new(cfg.seed);
    let mut problem_rng = SplitMix64::new(cfg.seed ^ 0x70_726f_62);
    let evalset = eval_problems(task, cfg.eval_n, cfg.seed);
    // persistent training pool (the paper's "training split")
    let pool_problems: Vec<GenProblem> =
        (0..cfg.train_pool).map(|_| task.sample(&mut problem_rng)).collect();
    let mut log = RunLog::default();
    // perturbation buffers reused across every inline member evaluation
    let mut scratch = MemberScratch::default();

    for gen in 0..cfg.gens {
        let gen_seed = master.next_u64();
        let spec = PopulationSpec { gen_seed, pairs: cfg.hyper.pairs, sigma: cfg.hyper.sigma };
        let n_members = spec.n_members();
        // draw this generation's batches from the fixed pool (common across
        // members — common random numbers)
        let mut batch_rng = SplitMix64::new(gen_seed ^ 0x6261_7463_68);
        let batches: Vec<GenBatch> = (0..cfg.batches_per_gen.max(1))
            .map(|_| {
                let problems: Vec<GenProblem> = (0..session.cfg.b_gen)
                    .map(|_| {
                        pool_problems[batch_rng.below(pool_problems.len() as u64) as usize]
                            .clone()
                    })
                    .collect();
                GenBatch::build(&session.cfg, problems)
            })
            .collect();

        // --- rollout phase ---
        let t0 = Instant::now();
        let mut raw = vec![0.0f32; n_members];
        match pool {
            Some(p) if p.n_workers() > 1 => {
                let snapshot = Arc::new(store.clone());
                let w = p.n_workers();
                for batch in &batches {
                    let ab = Arc::new(batch.clone());
                    let jobs: Vec<Job> = (0..w)
                        .map(|i| Job::EvalGen {
                            snapshot: snapshot.clone(),
                            gen_seed,
                            pairs: spec.pairs,
                            sigma: spec.sigma,
                            members: (0..n_members).filter(|m| m % w == i).collect(),
                            batch: ab.clone(),
                            tau: cfg.tau,
                        })
                        .collect();
                    for r in p.run_round(jobs, n_members)? {
                        raw[r.member] += r.reward? / batches.len() as f32;
                    }
                }
            }
            _ => {
                for m in 0..n_members {
                    for batch in &batches {
                        raw[m] += eval_member_gen_with(
                            session, task, store, &spec, m, batch, cfg.tau, qmax, &mut scratch,
                        )? / batches.len() as f32;
                    }
                }
            }
        }
        let rollout_ms = t0.elapsed().as_secs_f64() * 1e3;

        // --- update phase ---
        let fitness = normalize_fitness(&raw);
        let t1 = Instant::now();
        let stats = opt.update(store, &spec, &fitness)?;
        let update_ms = t1.elapsed().as_secs_f64() * 1e3;

        let eval_acc = if cfg.eval_every > 0 && (gen + 1) % cfg.eval_every == 0 {
            Some(eval_accuracy_gen(session, task, store, &evalset)?)
        } else {
            None
        };
        let entry = GenLog {
            gen,
            mean_reward: crate::util::mean(&raw),
            best_reward: raw.iter().cloned().fold(f32::MIN, f32::max),
            eval_acc,
            update_ratio: stats.update_ratio(),
            boundary_ratio: stats.boundary_hit_ratio(),
            rollout_ms,
            update_ms,
        };
        if cfg.verbose {
            println!(
                "[{} gen {:>4}] reward {:.3} (best {:.3}) upd {:.4}% roll {:.0}ms upd {:.0}ms{}",
                opt.name(),
                gen,
                entry.mean_reward,
                entry.best_reward,
                100.0 * entry.update_ratio,
                rollout_ms,
                update_ms,
                entry.eval_acc.map(|a| format!(" eval {:.1}%", a)).unwrap_or_default()
            );
        }
        log.entries.push(entry);
    }
    log.final_acc = eval_accuracy_gen(session, task, store, &evalset)?;
    log.optimizer_state_bytes = opt.state_bytes();
    Ok(log)
}

/// Fine-tune on an SFT task: fitness = -CE on the k-shot train batches;
/// accuracy reported on a held-out eval set.
#[allow(clippy::too_many_arguments)]
pub fn finetune_cls(
    session: &Session,
    task: &dyn ClsTask,
    store: &mut ParamStore,
    variant: Variant,
    cfg: &FinetuneCfg,
    k_shot: usize,
    pool: Option<&WorkerPool>,
) -> Result<RunLog> {
    let qmax = store.format.qmax();
    let d = store.lattice_dim();
    let mut opt = variant.build(d, qmax, cfg.hyper.clone());
    let mut master = SplitMix64::new(cfg.seed);
    let (train_batches, eval_batches) = build_cls_sets(session, task, k_shot, cfg)?;
    let train_arc = Arc::new(train_batches);
    let mut log = RunLog::default();
    let mut scratch = MemberScratch::default();

    for gen in 0..cfg.gens {
        let gen_seed = master.next_u64();
        let spec = PopulationSpec { gen_seed, pairs: cfg.hyper.pairs, sigma: cfg.hyper.sigma };
        let n_members = spec.n_members();

        let t0 = Instant::now();
        let mut raw = vec![0.0f32; n_members];
        match pool {
            Some(p) if p.n_workers() > 1 => {
                let snapshot = Arc::new(store.clone());
                let w = p.n_workers();
                let jobs: Vec<Job> = (0..w)
                    .map(|i| Job::EvalCls {
                        snapshot: snapshot.clone(),
                        gen_seed,
                        pairs: spec.pairs,
                        sigma: spec.sigma,
                        members: (0..n_members).filter(|m| m % w == i).collect(),
                        batches: train_arc.clone(),
                    })
                    .collect();
                for r in p.run_round(jobs, n_members)? {
                    raw[r.member] = r.reward?;
                }
            }
            _ => {
                for m in 0..n_members {
                    raw[m] = eval_member_cls_with(
                        session, store, &spec, m, &train_arc, qmax, &mut scratch,
                    )?;
                }
            }
        }
        let rollout_ms = t0.elapsed().as_secs_f64() * 1e3;

        let fitness = normalize_fitness(&raw);
        let t1 = Instant::now();
        let stats = opt.update(store, &spec, &fitness)?;
        let update_ms = t1.elapsed().as_secs_f64() * 1e3;

        let eval_acc = if cfg.eval_every > 0 && (gen + 1) % cfg.eval_every == 0 {
            Some(eval_accuracy_cls(session, store, &eval_batches)?)
        } else {
            None
        };
        if cfg.verbose && (gen % 10 == 0 || eval_acc.is_some()) {
            println!(
                "[{} gen {:>4}] fitness {:.4}{}",
                opt.name(),
                gen,
                crate::util::mean(&raw),
                eval_acc.map(|a| format!(" eval {:.1}%", a)).unwrap_or_default()
            );
        }
        log.entries.push(GenLog {
            gen,
            mean_reward: crate::util::mean(&raw),
            best_reward: raw.iter().cloned().fold(f32::MIN, f32::max),
            eval_acc,
            update_ratio: stats.update_ratio(),
            boundary_ratio: stats.boundary_hit_ratio(),
            rollout_ms,
            update_ms,
        });
    }
    log.final_acc = eval_accuracy_cls(session, store, &eval_batches)?;
    log.optimizer_state_bytes = opt.state_bytes();
    Ok(log)
}

/// MeZO on an fp store (Table 1's FP32 zeroth-order baseline): SPSA with
/// continuous perturbations, fitness = -CE on the k-shot batches.
pub fn finetune_cls_mezo(
    session: &Session,
    task: &dyn ClsTask,
    store: &mut ParamStore,
    cfg: &FinetuneCfg,
    k_shot: usize,
) -> Result<RunLog> {
    let mut opt = MezoOptimizer::new(cfg.hyper.clone());
    let mut master = SplitMix64::new(cfg.seed);
    let (train_batches, eval_batches) = build_cls_sets(session, task, k_shot, cfg)?;
    let mut log = RunLog::default();

    for gen in 0..cfg.gens {
        let gen_seed = master.next_u64();
        let spec = PopulationSpec { gen_seed, pairs: cfg.hyper.pairs, sigma: cfg.hyper.sigma };
        let t0 = Instant::now();
        let mut raw = vec![0.0f32; spec.n_members()];
        for m in 0..spec.n_members() {
            let perturbed = MezoOptimizer::perturb_fp(store, &spec, m);
            // evaluate by temporarily swapping in the perturbed tensors
            let mut loss = 0.0f32;
            let saved = swap_fp_lattice(store, &perturbed);
            for b in train_batches.iter() {
                let (ce, _) = session.cls_eval(store, None, b)?;
                loss += ce;
            }
            restore_fp_lattice(store, saved);
            raw[m] = -loss / train_batches.len() as f32;
        }
        let rollout_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        opt.update_fp(store, &spec, &raw)?;
        let update_ms = t1.elapsed().as_secs_f64() * 1e3;
        let eval_acc = if cfg.eval_every > 0 && (gen + 1) % cfg.eval_every == 0 {
            Some(eval_accuracy_cls(session, store, &eval_batches)?)
        } else {
            None
        };
        log.entries.push(GenLog {
            gen,
            mean_reward: crate::util::mean(&raw),
            best_reward: raw.iter().cloned().fold(f32::MIN, f32::max),
            eval_acc,
            update_ratio: 0.0,
            boundary_ratio: 0.0,
            rollout_ms,
            update_ms,
        });
    }
    log.final_acc = eval_accuracy_cls(session, store, &eval_batches)?;
    log.optimizer_state_bytes = opt.state_bytes();
    Ok(log)
}

/// Build k-shot train batches + a held-out eval set for an SFT task.
fn build_cls_sets(
    session: &Session,
    task: &dyn ClsTask,
    k_shot: usize,
    cfg: &FinetuneCfg,
) -> Result<(Vec<ClsBatch>, Vec<ClsBatch>)> {
    let mcfg = &session.cfg;
    let verb = task.verbalizers();
    let mut rng = SplitMix64::new(cfg.seed ^ 0x6b73_686f_74);
    // k examples per class (k-shot protocol)
    let mut train = Vec::new();
    let mut per_class = vec![0usize; task.n_classes()];
    while per_class.iter().any(|&c| c < k_shot) {
        let ex = task.sample(&mut rng, true);
        if per_class[ex.label] < k_shot {
            per_class[ex.label] += 1;
            train.push(ex);
        }
    }
    let train_batches: Vec<ClsBatch> =
        train.chunks(mcfg.b_train).map(|c| ClsBatch::build(mcfg, c, &verb)).collect();
    let eval: Vec<_> = (0..cfg.eval_n).map(|_| task.sample(&mut rng, false)).collect();
    let eval_batches: Vec<ClsBatch> =
        eval.chunks(mcfg.b_train).map(|c| ClsBatch::build(mcfg, c, &verb)).collect();
    Ok((train_batches, eval_batches))
}

fn swap_fp_lattice(store: &mut ParamStore, values: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let lat: Vec<usize> = store.lattice_indices().to_vec();
    let mut saved = Vec::with_capacity(lat.len());
    for (k, &i) in lat.iter().enumerate() {
        let dst = store.entries[i].data.as_f32_mut();
        saved.push(dst.to_vec());
        dst.copy_from_slice(&values[k]);
    }
    saved
}

fn restore_fp_lattice(store: &mut ParamStore, saved: Vec<Vec<f32>>) {
    let lat: Vec<usize> = store.lattice_indices().to_vec();
    for (k, &i) in lat.iter().enumerate() {
        store.entries[i].data.as_f32_mut().copy_from_slice(&saved[k]);
    }
}
