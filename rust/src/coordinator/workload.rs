//! The `Workload` abstraction: ONE generic generation loop for every
//! training scenario.
//!
//! The coordinator used to carry a Gen/Cls split through every layer —
//! `Job::EvalGen`/`EvalCls`, `finetune_gen`/`finetune_cls`,
//! `eval_accuracy_gen`/`eval_accuracy_cls` — so each new scenario meant a
//! fourth copy of the loop. A `Workload` now owns the scenario-specific
//! pieces behind three operations:
//!
//! * [`Workload::build_round`] — the generation's common rollout payload
//!   (common random numbers across members), derived deterministically
//!   from the generation seed;
//! * [`Workload::eval_member`] — score one population member against that
//!   payload (perturb → run engines → reward);
//! * [`Workload::eval_accuracy`] — unperturbed greedy accuracy.
//!
//! `WorkerPool`, `finetune` and the experiment drivers are generic over
//! the trait; new scenarios (new tasks, mixed-task generations) are a
//! trait impl, not another copy of the loop. Workloads are `Send + Sync`
//! and shared with worker threads via `Arc<dyn Workload>`.

use std::any::Any;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::finetune::FinetuneCfg;
use crate::coordinator::session::{EngineSet, Session};
use crate::model::{ParamStore, ParamsView};
use crate::opt::{apply_perturbation_into, KernelPolicy, PopulationSpec};
use crate::rng::SplitMix64;
use crate::runtime::encode::{ClsBatch, GenBatch};
use crate::runtime::ModelConfig;
use crate::sched;
use crate::tasks::{is_cls_task, ClsTask, GenProblem, GenTask};

/// Salt separating decode-sampling noise from perturbation noise.
const GUMBEL_SALT: u64 = 0x6465_636f_6465_5f67;

/// Reusable per-worker buffers for member evaluation: the perturbed
/// lattice is materialized into `overrides` in place, so a generation's
/// member loop performs zero per-member allocations on the perturbation
/// path. `policy` controls the fill's chunk parallelism — results are
/// identical for any policy (the kernels' determinism contract), so pick
/// it for the topology: the default exploits all cores (right for the
/// single-threaded inline leader loop), while code that already runs
/// many evaluations in parallel (the worker pool) should use
/// [`MemberScratch::sequential`] to avoid oversubscribing cores with
/// per-member thread fan-outs.
#[derive(Default)]
pub struct MemberScratch {
    pub overrides: Vec<Vec<i8>>,
    pub policy: KernelPolicy,
    /// Shared weight-tied-head operand (`tok_emb` transposed) for the
    /// scheduler rollout: `tok_emb` is not a lattice tensor, so ES
    /// fine-tuning never changes it — ONE transpose serves every member
    /// and round this scratch touches. Rebuilt if the model shape
    /// changes (length mismatch).
    pub emb_t: Vec<f32>,
}

impl MemberScratch {
    /// Scratch whose perturbation fill runs inline on the calling thread
    /// — for callers that are themselves one of many parallel workers.
    pub fn sequential() -> Self {
        MemberScratch {
            overrides: Vec::new(),
            policy: KernelPolicy::scalar(),
            emb_t: Vec::new(),
        }
    }
}

/// Fill the scratch's shared head transpose for `store` (no-op when the
/// cached one already matches the shape).
fn ensure_emb_t(cache: &mut Vec<f32>, store: &ParamStore) -> Result<()> {
    let numel = store.get("tok_emb").map(|e| e.numel()).unwrap_or(0);
    if cache.len() != numel {
        *cache = crate::runtime::native::build_emb_t(store)?;
    }
    Ok(())
}

/// One generation's rollout payload. Scenario-specific contents live
/// behind `Any` so the pool can broadcast rounds without knowing the
/// scenario (the owning `Workload` downcasts in `eval_member`).
pub trait Round: Any + Send + Sync {
    fn as_any(&self) -> &dyn Any;
}

/// A training scenario: task + data protocol + member scoring. See the
/// module docs for the contract.
pub trait Workload: Send + Sync {
    fn name(&self) -> &str;

    /// Engines a session must compile to run this workload.
    fn engines(&self) -> EngineSet;

    /// Build the generation's common evaluation payload. Deterministic in
    /// `gen_seed` (common random numbers across members and topologies).
    fn build_round(&self, gen_seed: u64) -> Result<Arc<dyn Round>>;

    /// Score member `member` of the population described by `spec`
    /// against `round`, reading weights through `params`.
    fn eval_member(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        member: usize,
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Result<f32>;

    /// Unperturbed greedy accuracy (%) on the workload's held-out set.
    fn eval_accuracy(&self, session: &Session, params: &ParamsView<'_>) -> Result<f32>;
}

/// Sample a fixed eval problem set (disjoint seed space from training).
pub fn eval_problems(task: &dyn GenTask, n: usize, seed: u64) -> Vec<GenProblem> {
    let mut rng = SplitMix64::new(seed ^ 0x6576_616c_5f73_6574);
    (0..n).map(|_| task.sample(&mut rng)).collect()
}

/// Instantiate the standard workload for a task name: reasoning tasks get
/// a [`GenWorkload`], SFT classification tasks a [`ClsWorkload`].
pub fn workload_for(
    task_name: &str,
    mcfg: &ModelConfig,
    cfg: &FinetuneCfg,
    k_shot: usize,
) -> Result<Box<dyn Workload>> {
    if is_cls_task(task_name) {
        let task = crate::tasks::cls_task(task_name)?;
        Ok(Box::new(ClsWorkload::new(task, mcfg, cfg, k_shot)))
    } else {
        let task = crate::tasks::gen_task(task_name, mcfg.s_prompt, mcfg.t_dec)?;
        Ok(Box::new(GenWorkload::new(task, mcfg, cfg)))
    }
}

// ---------------------------------------------------------------------------
// Reasoning (generative RLVR) workload
// ---------------------------------------------------------------------------

/// A generation's rollout batches for a reasoning task (all members score
/// against the same batches — common random numbers).
pub struct GenRound {
    pub batches: Vec<GenBatch>,
}

impl Round for GenRound {
    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Reasoning fine-tuning: fitness = mean RLVR reward of sampled rollouts
/// over the generation's batches; accuracy = greedy solve rate.
pub struct GenWorkload {
    task: Box<dyn GenTask>,
    mcfg: ModelConfig,
    /// Decode-sampling temperature during training rollouts (0 = greedy).
    tau: f32,
    batches_per_gen: usize,
    /// Persistent training pool (the paper's "training split"): batches
    /// are drawn from here so the fitness signal keeps a consistent
    /// direction across generations.
    pool: Vec<GenProblem>,
    evalset: Vec<GenProblem>,
}

impl GenWorkload {
    pub fn new(task: Box<dyn GenTask>, mcfg: &ModelConfig, cfg: &FinetuneCfg) -> GenWorkload {
        let mut problem_rng = SplitMix64::new(cfg.seed ^ 0x70_726f_62);
        let pool: Vec<GenProblem> =
            (0..cfg.train_pool).map(|_| task.sample(&mut problem_rng)).collect();
        let evalset = eval_problems(task.as_ref(), cfg.eval_n, cfg.seed);
        GenWorkload {
            task,
            mcfg: mcfg.clone(),
            tau: cfg.tau,
            batches_per_gen: cfg.batches_per_gen.max(1),
            pool,
            evalset,
        }
    }

    pub fn task(&self) -> &dyn GenTask {
        self.task.as_ref()
    }
}

impl Workload for GenWorkload {
    fn name(&self) -> &str {
        self.task.name()
    }

    fn engines(&self) -> EngineSet {
        EngineSet::gen_only()
    }

    fn build_round(&self, gen_seed: u64) -> Result<Arc<dyn Round>> {
        let mut batch_rng = SplitMix64::new(gen_seed ^ 0x6261_7463_68);
        let batches: Vec<GenBatch> = (0..self.batches_per_gen)
            .map(|_| {
                let problems: Vec<GenProblem> = (0..self.mcfg.b_gen)
                    .map(|_| {
                        self.pool[batch_rng.below(self.pool.len() as u64) as usize].clone()
                    })
                    .collect();
                GenBatch::build(&self.mcfg, problems)
            })
            .collect();
        Ok(Arc::new(GenRound { batches }))
    }

    fn eval_member(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        member: usize,
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Result<f32> {
        let round = round
            .as_any()
            .downcast_ref::<GenRound>()
            .ok_or_else(|| anyhow::anyhow!("gen workload got a foreign round payload"))?;
        let qmax = params.store.format.qmax();
        apply_perturbation_into(params, spec, member, qmax, &mut scratch.overrides, scratch.policy);
        let gumbel_seed = if self.tau > 0.0 {
            Some(spec.gen_seed ^ GUMBEL_SALT ^ (member as u64) << 17)
        } else {
            None
        };
        // Native sessions roll out through the continuous-batching
        // scheduler: one resolve+pack per member per ROUND (not per
        // batch), a shared head transpose across members, real rows only,
        // EOS retirement. Rewards are a pure function of (weights, round,
        // seeds) — identical on any worker topology, slot count or thread
        // count, which the pool-vs-inline test pins.
        if let Some(nb) = session.backend().as_native() {
            ensure_emb_t(&mut scratch.emb_t, params.store)?;
            let texts = sched::rollout_round(
                nb,
                params,
                Some(&scratch.overrides),
                Some(&scratch.emb_t),
                &round.batches,
                self.tau,
                gumbel_seed,
            )?;
            let mut total = 0.0f32;
            for (batch, comps) in round.batches.iter().zip(&texts) {
                let mut batch_total = 0.0f32;
                for (i, c) in comps.iter().enumerate() {
                    batch_total += self.task.reward(&batch.problems[i].key, c);
                }
                total += batch_total / batch.n_real as f32;
            }
            return Ok(total / round.batches.len() as f32);
        }
        // PJRT sessions keep the per-batch compiled-graph path.
        let mut total = 0.0f32;
        for batch in &round.batches {
            let completions = session.generate(
                params,
                Some(&scratch.overrides),
                batch,
                self.tau,
                gumbel_seed,
            )?;
            let mut batch_total = 0.0f32;
            for (i, c) in completions.iter().enumerate() {
                batch_total += self.task.reward(&batch.problems[i].key, c);
            }
            total += batch_total / batch.n_real as f32;
        }
        Ok(total / round.batches.len() as f32)
    }

    fn eval_accuracy(&self, session: &Session, params: &ParamsView<'_>) -> Result<f32> {
        let mut correct = 0usize;
        let mut total = 0usize;
        if let Some(nb) = session.backend().as_native() {
            // greedy eval through the scheduler: ONE resolve+pack serves
            // the whole eval set as a single continuous batch
            let prompts: Vec<&str> = self.evalset.iter().map(|p| p.prompt.as_str()).collect();
            let texts = sched::greedy_texts(nb, params, &prompts)?;
            for (p, c) in self.evalset.iter().zip(&texts) {
                if self.task.reward(&p.key, c) >= 1.0 {
                    correct += 1;
                }
                total += 1;
            }
        } else {
            let cfg = &session.cfg;
            for chunk in self.evalset.chunks(cfg.b_gen) {
                let batch = GenBatch::build(cfg, chunk.to_vec());
                let completions = session.generate(params, None, &batch, 0.0, None)?;
                for (i, c) in completions.iter().enumerate() {
                    if self.task.reward(&batch.problems[i].key, c) >= 1.0 {
                        correct += 1;
                    }
                    total += 1;
                }
            }
        }
        Ok(100.0 * correct as f32 / total.max(1) as f32)
    }
}

// ---------------------------------------------------------------------------
// SFT (k-shot classification) workload
// ---------------------------------------------------------------------------

/// The fixed k-shot train batches an SFT generation scores against (the
/// same every generation, by protocol).
pub struct ClsRound {
    pub batches: Vec<ClsBatch>,
}

impl Round for ClsRound {
    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// SFT fine-tuning: fitness = -mean CE over the k-shot train batches (ES
/// ascends fitness, so this descends the loss); accuracy on a held-out
/// eval set.
pub struct ClsWorkload {
    task: Box<dyn ClsTask>,
    round: Arc<ClsRound>,
    eval_batches: Vec<ClsBatch>,
}

impl ClsWorkload {
    pub fn new(
        task: Box<dyn ClsTask>,
        mcfg: &ModelConfig,
        cfg: &FinetuneCfg,
        k_shot: usize,
    ) -> ClsWorkload {
        let verb = task.verbalizers();
        let mut rng = SplitMix64::new(cfg.seed ^ 0x6b73_686f_74);
        // k examples per class (k-shot protocol)
        let mut train = Vec::new();
        let mut per_class = vec![0usize; task.n_classes()];
        while per_class.iter().any(|&c| c < k_shot) {
            let ex = task.sample(&mut rng, true);
            if per_class[ex.label] < k_shot {
                per_class[ex.label] += 1;
                train.push(ex);
            }
        }
        let train_batches: Vec<ClsBatch> =
            train.chunks(mcfg.b_train).map(|c| ClsBatch::build(mcfg, c, &verb)).collect();
        let eval: Vec<_> = (0..cfg.eval_n).map(|_| task.sample(&mut rng, false)).collect();
        let eval_batches: Vec<ClsBatch> =
            eval.chunks(mcfg.b_train).map(|c| ClsBatch::build(mcfg, c, &verb)).collect();
        ClsWorkload { task, round: Arc::new(ClsRound { batches: train_batches }), eval_batches }
    }

    /// The k-shot train batches (the MeZO fp baseline scores these
    /// directly, outside the lattice-optimizer loop).
    pub fn train_batches(&self) -> &[ClsBatch] {
        &self.round.batches
    }

    pub fn eval_batches(&self) -> &[ClsBatch] {
        &self.eval_batches
    }
}

impl Workload for ClsWorkload {
    fn name(&self) -> &str {
        self.task.name()
    }

    fn engines(&self) -> EngineSet {
        EngineSet::cls_only()
    }

    fn build_round(&self, _gen_seed: u64) -> Result<Arc<dyn Round>> {
        // k-shot SFT scores the same train batches every generation.
        let round: Arc<dyn Round> = self.round.clone();
        Ok(round)
    }

    fn eval_member(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        member: usize,
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Result<f32> {
        let round = round
            .as_any()
            .downcast_ref::<ClsRound>()
            .ok_or_else(|| anyhow::anyhow!("cls workload got a foreign round payload"))?;
        let qmax = params.store.format.qmax();
        apply_perturbation_into(params, spec, member, qmax, &mut scratch.overrides, scratch.policy);
        let mut loss = 0.0f32;
        for b in &round.batches {
            let (ce, _) = session.cls_eval(params, Some(&scratch.overrides), b)?;
            loss += ce;
        }
        Ok(-loss / round.batches.len() as f32)
    }

    fn eval_accuracy(&self, session: &Session, params: &ParamsView<'_>) -> Result<f32> {
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in &self.eval_batches {
            let (_, c) = session.cls_eval(params, None, b)?;
            correct += c;
            total += b.n_real;
        }
        Ok(100.0 * correct as f32 / total.max(1) as f32)
    }
}
