//! The `Workload` abstraction: ONE generic generation loop for every
//! training scenario.
//!
//! The coordinator used to carry a Gen/Cls split through every layer —
//! `Job::EvalGen`/`EvalCls`, `finetune_gen`/`finetune_cls`,
//! `eval_accuracy_gen`/`eval_accuracy_cls` — so each new scenario meant a
//! fourth copy of the loop. A `Workload` now owns the scenario-specific
//! pieces behind three operations:
//!
//! * [`Workload::build_round`] — the generation's common rollout payload
//!   (common random numbers across members), derived deterministically
//!   from the generation seed;
//! * [`Workload::eval_member`] — score one population member against that
//!   payload (perturb → run engines → reward);
//! * [`Workload::eval_accuracy`] — unperturbed greedy accuracy.
//!
//! `WorkerPool`, `finetune` and the experiment drivers are generic over
//! the trait; new scenarios (new tasks, mixed-task generations) are a
//! trait impl, not another copy of the loop. Workloads are `Send + Sync`
//! and shared with worker threads via `Arc<dyn Workload>`.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::finetune::FinetuneCfg;
use crate::coordinator::session::{EngineSet, Session};
use crate::model::{ParamStore, ParamsView};
use crate::opt::{
    apply_perturbation_into, apply_population_into, KernelPolicy, PopulationSpec,
};
use crate::rng::SplitMix64;
use crate::runtime::encode::{ClsBatch, GenBatch};
use crate::runtime::ModelConfig;
use crate::sched;
use crate::tasks::{is_cls_task, ClsTask, GenProblem, GenTask};

/// Salt separating decode-sampling noise from perturbation noise.
const GUMBEL_SALT: u64 = 0x6465_636f_6465_5f67;

/// Round-level grouped rollout toggle (the `QES_KERNEL`-style env knob):
/// `QES_GROUPED=0|off|false` forces the per-member sequential path —
/// CI's equivalence legs run the suites both ways — anything else,
/// including unset, leaves cross-member grouping ON. Read once at
/// [`FinetuneCfg`] construction (workloads carry the resolved flag), so
/// tests flip the field programmatically instead of racing on the
/// process environment.
pub fn grouped_rollout_enabled() -> bool {
    match std::env::var("QES_GROUPED") {
        Ok(v) => !matches!(v.trim(), "0" | "off" | "false"),
        Err(_) => true,
    }
}

/// Render a `catch_unwind` payload (shared with the worker pool).
pub(crate) fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Reusable per-worker buffers for member evaluation: the perturbed
/// lattice is materialized into `overrides` in place, so a generation's
/// member loop performs zero per-member allocations on the perturbation
/// path. `policy` controls the fill's chunk parallelism — results are
/// identical for any policy (the kernels' determinism contract), so pick
/// it for the topology: the default exploits all cores (right for the
/// single-threaded inline leader loop), while code that already runs
/// many evaluations in parallel (the worker pool) should use
/// [`MemberScratch::sequential`] to avoid oversubscribing cores with
/// per-member thread fan-outs.
#[derive(Default)]
pub struct MemberScratch {
    pub overrides: Vec<Vec<i8>>,
    /// Per-member perturbed lattices for the grouped round path
    /// ([`Workload::eval_members`]): `member_overrides[j]` is the j-th
    /// grouped member's slab, filled by `opt::apply_population_into` and
    /// reused across rounds like `overrides`.
    pub member_overrides: Vec<Vec<Vec<i8>>>,
    pub policy: KernelPolicy,
    /// Shared weight-tied-head operand (`tok_emb` transposed) for the
    /// scheduler rollout: `tok_emb` is not a lattice tensor, so ES
    /// fine-tuning never changes it — ONE transpose serves every member
    /// and round this scratch touches. Rebuilt if the model shape
    /// changes (length mismatch).
    pub emb_t: Vec<f32>,
}

impl MemberScratch {
    /// Scratch whose perturbation fill runs inline on the calling thread
    /// — for callers that are themselves one of many parallel workers.
    pub fn sequential() -> Self {
        MemberScratch {
            overrides: Vec::new(),
            member_overrides: Vec::new(),
            policy: KernelPolicy::scalar(),
            emb_t: Vec::new(),
        }
    }
}

/// Fill the scratch's shared head transpose for `store` (no-op when the
/// cached one already matches the shape).
fn ensure_emb_t(cache: &mut Vec<f32>, store: &ParamStore) -> Result<()> {
    let numel = store.get("tok_emb").map(|e| e.numel()).unwrap_or(0);
    if cache.len() != numel {
        *cache = crate::runtime::native::build_emb_t(store)?;
    }
    Ok(())
}

/// One generation's rollout payload. Scenario-specific contents live
/// behind `Any` so the pool can broadcast rounds without knowing the
/// scenario (the owning `Workload` downcasts in `eval_member`).
pub trait Round: Any + Send + Sync {
    fn as_any(&self) -> &dyn Any;
}

/// A training scenario: task + data protocol + member scoring. See the
/// module docs for the contract.
pub trait Workload: Send + Sync {
    fn name(&self) -> &str;

    /// Engines a session must compile to run this workload.
    fn engines(&self) -> EngineSet;

    /// Build the generation's common evaluation payload. Deterministic in
    /// `gen_seed` (common random numbers across members and topologies).
    fn build_round(&self, gen_seed: u64) -> Result<Arc<dyn Round>>;

    /// Score member `member` of the population described by `spec`
    /// against `round`, reading weights through `params`.
    fn eval_member(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        member: usize,
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Result<f32>;

    /// Score a whole member subset against `round` in one call — the
    /// round-level grouped entry point both rollout topologies (inline
    /// leader loop, pool workers) go through. Returns one result per
    /// member of `members`, in order; a panicking evaluation surfaces as
    /// that member's `Err`, never as the caller's unwind.
    ///
    /// The default walks members sequentially through
    /// [`Workload::eval_member`]. Workloads with a grouped fast path
    /// (Gen, Cls on the native backend) override it to batch every
    /// member's rows through ONE resolve pass and ONE weight-stream walk
    /// per layer per step — with rewards bit-identical to this default
    /// (the grouped GEMM's per-row member routing preserves the exact
    /// per-element op sequence).
    fn eval_members(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        members: &[usize],
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Vec<Result<f32>> {
        eval_members_seq(self, session, params, spec, members, round, scratch)
    }

    /// Unperturbed greedy accuracy (%) on the workload's held-out set.
    fn eval_accuracy(&self, session: &Session, params: &ParamsView<'_>) -> Result<f32>;
}

/// The sequential member walk behind the [`Workload::eval_members`]
/// default: one `eval_member` per member with per-member panic isolation
/// (a panicking evaluation costs that member an `Err`, not the caller's
/// thread). Grouped overrides fall back to this when grouping is
/// disabled, the subset is a singleton, or the backend has no grouped
/// path.
fn eval_members_seq<W: Workload + ?Sized>(
    w: &W,
    session: &Session,
    params: &ParamsView<'_>,
    spec: &PopulationSpec,
    members: &[usize],
    round: &dyn Round,
    scratch: &mut MemberScratch,
) -> Vec<Result<f32>> {
    members
        .iter()
        .map(|&m| {
            match catch_unwind(AssertUnwindSafe(|| {
                w.eval_member(session, params, spec, m, round, scratch)
            })) {
                Ok(r) => r,
                Err(p) => Err(anyhow::anyhow!(
                    "workload panicked scoring member {}: {}",
                    m,
                    panic_message(&*p)
                )),
            }
        })
        .collect()
}

/// Spread one whole-group failure over every member of the group: the
/// grouped paths evaluate all members in one fused pass, so a grouped
/// error (or panic) has no single culprit — each member consumes one
/// retry, exactly as if its own evaluation had failed.
fn group_errs(members: &[usize], what: &str, msg: &str) -> Vec<Result<f32>> {
    members
        .iter()
        .map(|&m| Err(anyhow::anyhow!("{} scoring member {}: {}", what, m, msg)))
        .collect()
}

/// Sample a fixed eval problem set (disjoint seed space from training).
pub fn eval_problems(task: &dyn GenTask, n: usize, seed: u64) -> Vec<GenProblem> {
    let mut rng = SplitMix64::new(seed ^ 0x6576_616c_5f73_6574);
    (0..n).map(|_| task.sample(&mut rng)).collect()
}

/// Instantiate the standard workload for a task name: reasoning tasks get
/// a [`GenWorkload`], SFT classification tasks a [`ClsWorkload`].
pub fn workload_for(
    task_name: &str,
    mcfg: &ModelConfig,
    cfg: &FinetuneCfg,
    k_shot: usize,
) -> Result<Box<dyn Workload>> {
    if is_cls_task(task_name) {
        let task = crate::tasks::cls_task(task_name)?;
        Ok(Box::new(ClsWorkload::new(task, mcfg, cfg, k_shot)))
    } else {
        let task = crate::tasks::gen_task(task_name, mcfg.s_prompt, mcfg.t_dec)?;
        Ok(Box::new(GenWorkload::new(task, mcfg, cfg)))
    }
}

// ---------------------------------------------------------------------------
// Reasoning (generative RLVR) workload
// ---------------------------------------------------------------------------

/// A generation's rollout batches for a reasoning task (all members score
/// against the same batches — common random numbers).
pub struct GenRound {
    pub batches: Vec<GenBatch>,
}

impl Round for GenRound {
    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Reasoning fine-tuning: fitness = mean RLVR reward of sampled rollouts
/// over the generation's batches; accuracy = greedy solve rate.
pub struct GenWorkload {
    task: Box<dyn GenTask>,
    mcfg: ModelConfig,
    /// Decode-sampling temperature during training rollouts (0 = greedy).
    tau: f32,
    batches_per_gen: usize,
    /// Persistent training pool (the paper's "training split"): batches
    /// are drawn from here so the fitness signal keeps a consistent
    /// direction across generations.
    pool: Vec<GenProblem>,
    evalset: Vec<GenProblem>,
    /// Cross-member grouped rollout (`FinetuneCfg::grouped`): score whole
    /// member subsets through ONE scheduler per round instead of one per
    /// member. Rewards are bit-identical either way.
    grouped: bool,
}

impl GenWorkload {
    pub fn new(task: Box<dyn GenTask>, mcfg: &ModelConfig, cfg: &FinetuneCfg) -> GenWorkload {
        let mut problem_rng = SplitMix64::new(cfg.seed ^ 0x70_726f_62);
        let pool: Vec<GenProblem> =
            (0..cfg.train_pool).map(|_| task.sample(&mut problem_rng)).collect();
        let evalset = eval_problems(task.as_ref(), cfg.eval_n, cfg.seed);
        GenWorkload {
            task,
            mcfg: mcfg.clone(),
            tau: cfg.tau,
            batches_per_gen: cfg.batches_per_gen.max(1),
            pool,
            evalset,
            grouped: cfg.grouped,
        }
    }

    pub fn task(&self) -> &dyn GenTask {
        self.task.as_ref()
    }

    /// Member seed for decode sampling (`None` = greedy) — the one
    /// formula both the sequential and grouped rollout paths use, so
    /// grouped decode draws the exact same gumbel streams.
    fn gumbel_seed(&self, spec: &PopulationSpec, member: usize) -> Option<u64> {
        if self.tau > 0.0 {
            Some(spec.gen_seed ^ GUMBEL_SALT ^ (member as u64) << 17)
        } else {
            None
        }
    }

    /// Mean per-batch reward of one member's completions — the single
    /// aggregation both `eval_member` and the grouped path share, so the
    /// float sum order is identical.
    fn round_reward(&self, round: &GenRound, texts: &[Vec<String>]) -> f32 {
        let mut total = 0.0f32;
        for (batch, comps) in round.batches.iter().zip(texts) {
            let mut batch_total = 0.0f32;
            for (i, c) in comps.iter().enumerate() {
                batch_total += self.task.reward(&batch.problems[i].key, c);
            }
            total += batch_total / batch.n_real as f32;
        }
        total / round.batches.len() as f32
    }
}

impl Workload for GenWorkload {
    fn name(&self) -> &str {
        self.task.name()
    }

    fn engines(&self) -> EngineSet {
        EngineSet::gen_only()
    }

    fn build_round(&self, gen_seed: u64) -> Result<Arc<dyn Round>> {
        let mut batch_rng = SplitMix64::new(gen_seed ^ 0x6261_7463_68);
        let batches: Vec<GenBatch> = (0..self.batches_per_gen)
            .map(|_| {
                let problems: Vec<GenProblem> = (0..self.mcfg.b_gen)
                    .map(|_| {
                        self.pool[batch_rng.below(self.pool.len() as u64) as usize].clone()
                    })
                    .collect();
                GenBatch::build(&self.mcfg, problems)
            })
            .collect();
        Ok(Arc::new(GenRound { batches }))
    }

    fn eval_member(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        member: usize,
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Result<f32> {
        let round = round
            .as_any()
            .downcast_ref::<GenRound>()
            .ok_or_else(|| anyhow::anyhow!("gen workload got a foreign round payload"))?;
        let qmax = params.store.format.qmax();
        apply_perturbation_into(params, spec, member, qmax, &mut scratch.overrides, scratch.policy);
        let gumbel_seed = self.gumbel_seed(spec, member);
        // Native sessions roll out through the continuous-batching
        // scheduler: one resolve+pack per member per ROUND (not per
        // batch), a shared head transpose across members, real rows only,
        // EOS retirement. Rewards are a pure function of (weights, round,
        // seeds) — identical on any worker topology, slot count or thread
        // count, which the pool-vs-inline test pins.
        if let Some(nb) = session.backend().as_native() {
            ensure_emb_t(&mut scratch.emb_t, params.store)?;
            let texts = sched::rollout_round(
                nb,
                params,
                Some(&scratch.overrides),
                Some(&scratch.emb_t),
                &round.batches,
                self.tau,
                gumbel_seed,
            )?;
            return Ok(self.round_reward(round, &texts));
        }
        // PJRT sessions keep the per-batch compiled-graph path.
        let mut total = 0.0f32;
        for batch in &round.batches {
            let completions = session.generate(
                params,
                Some(&scratch.overrides),
                batch,
                self.tau,
                gumbel_seed,
            )?;
            let mut batch_total = 0.0f32;
            for (i, c) in completions.iter().enumerate() {
                batch_total += self.task.reward(&batch.problems[i].key, c);
            }
            total += batch_total / batch.n_real as f32;
        }
        Ok(total / round.batches.len() as f32)
    }

    /// Tentpole fast path: ONE grouped scheduler round serves the whole
    /// member subset — one resolve pass, one batched prefill and one
    /// batched decode GEMM per layer per step across the population —
    /// with rewards bit-identical to the sequential default (per-row
    /// member routing in the grouped GEMM preserves each member's exact
    /// per-element op sequence, and the request/gumbel seed maps are
    /// shared with `rollout_round`).
    fn eval_members(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        members: &[usize],
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Vec<Result<f32>> {
        let nb = match session.backend().as_native() {
            Some(nb) if self.grouped && members.len() > 1 => nb,
            _ => return eval_members_seq(self, session, params, spec, members, round, scratch),
        };
        let run = AssertUnwindSafe(|| -> Result<Vec<f32>> {
            let round = round
                .as_any()
                .downcast_ref::<GenRound>()
                .ok_or_else(|| anyhow::anyhow!("gen workload got a foreign round payload"))?;
            let qmax = params.store.format.qmax();
            apply_population_into(
                params,
                spec,
                members,
                qmax,
                &mut scratch.member_overrides,
                scratch.policy,
            );
            ensure_emb_t(&mut scratch.emb_t, params.store)?;
            let member_seeds: Vec<Option<u64>> =
                members.iter().map(|&m| self.gumbel_seed(spec, m)).collect();
            let texts = sched::rollout_round_grouped(
                nb,
                params,
                &scratch.member_overrides,
                Some(&scratch.emb_t),
                &round.batches,
                self.tau,
                &member_seeds,
            )?;
            Ok(texts.iter().map(|t| self.round_reward(round, t)).collect())
        });
        // A grouped failure has no single culprit: every member of the
        // group eats one retry (same budget the sequential walk charges).
        match catch_unwind(run) {
            Ok(Ok(rs)) => rs.into_iter().map(Ok).collect(),
            Ok(Err(e)) => group_errs(members, "grouped rollout failed", &format!("{:#}", e)),
            Err(p) => group_errs(members, "grouped rollout panicked", &panic_message(&*p)),
        }
    }

    fn eval_accuracy(&self, session: &Session, params: &ParamsView<'_>) -> Result<f32> {
        let mut correct = 0usize;
        let mut total = 0usize;
        if let Some(nb) = session.backend().as_native() {
            // greedy eval through the scheduler: ONE resolve+pack serves
            // the whole eval set as a single continuous batch
            let prompts: Vec<&str> = self.evalset.iter().map(|p| p.prompt.as_str()).collect();
            let texts = sched::greedy_texts(nb, params, &prompts)?;
            for (p, c) in self.evalset.iter().zip(&texts) {
                if self.task.reward(&p.key, c) >= 1.0 {
                    correct += 1;
                }
                total += 1;
            }
        } else {
            let cfg = &session.cfg;
            for chunk in self.evalset.chunks(cfg.b_gen) {
                let batch = GenBatch::build(cfg, chunk.to_vec());
                let completions = session.generate(params, None, &batch, 0.0, None)?;
                for (i, c) in completions.iter().enumerate() {
                    if self.task.reward(&batch.problems[i].key, c) >= 1.0 {
                        correct += 1;
                    }
                    total += 1;
                }
            }
        }
        Ok(100.0 * correct as f32 / total.max(1) as f32)
    }
}

// ---------------------------------------------------------------------------
// SFT (k-shot classification) workload
// ---------------------------------------------------------------------------

/// The fixed k-shot train batches an SFT generation scores against (the
/// same every generation, by protocol).
pub struct ClsRound {
    pub batches: Vec<ClsBatch>,
}

impl Round for ClsRound {
    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// SFT fine-tuning: fitness = -mean CE over the k-shot train batches (ES
/// ascends fitness, so this descends the loss); accuracy on a held-out
/// eval set.
pub struct ClsWorkload {
    task: Box<dyn ClsTask>,
    round: Arc<ClsRound>,
    eval_batches: Vec<ClsBatch>,
    /// Cross-member grouped scoring (`FinetuneCfg::grouped`): one
    /// resolve pass + one grouped forward per batch for the whole member
    /// subset. Losses are bit-identical either way.
    grouped: bool,
}

impl ClsWorkload {
    pub fn new(
        task: Box<dyn ClsTask>,
        mcfg: &ModelConfig,
        cfg: &FinetuneCfg,
        k_shot: usize,
    ) -> ClsWorkload {
        let verb = task.verbalizers();
        let mut rng = SplitMix64::new(cfg.seed ^ 0x6b73_686f_74);
        // k examples per class (k-shot protocol)
        let mut train = Vec::new();
        let mut per_class = vec![0usize; task.n_classes()];
        while per_class.iter().any(|&c| c < k_shot) {
            let ex = task.sample(&mut rng, true);
            if per_class[ex.label] < k_shot {
                per_class[ex.label] += 1;
                train.push(ex);
            }
        }
        let train_batches: Vec<ClsBatch> =
            train.chunks(mcfg.b_train).map(|c| ClsBatch::build(mcfg, c, &verb)).collect();
        let eval: Vec<_> = (0..cfg.eval_n).map(|_| task.sample(&mut rng, false)).collect();
        let eval_batches: Vec<ClsBatch> =
            eval.chunks(mcfg.b_train).map(|c| ClsBatch::build(mcfg, c, &verb)).collect();
        ClsWorkload {
            task,
            round: Arc::new(ClsRound { batches: train_batches }),
            eval_batches,
            grouped: cfg.grouped,
        }
    }

    /// The k-shot train batches (the MeZO fp baseline scores these
    /// directly, outside the lattice-optimizer loop).
    pub fn train_batches(&self) -> &[ClsBatch] {
        &self.round.batches
    }

    pub fn eval_batches(&self) -> &[ClsBatch] {
        &self.eval_batches
    }
}

/// Mean CE over a batch's REAL rows from per-row class scores — a
/// verbatim copy of the host-side loop in `Session::cls_eval` (same
/// float op order), so the grouped path's losses are bit-identical to
/// the sequential `cls_eval` walk.
fn cls_ce(scores: &[f32], batch: &ClsBatch) -> f32 {
    let c = 8usize;
    let mut sum_ce = 0.0f32;
    for i in 0..batch.n_real {
        let row = &scores[i * c..(i + 1) * c];
        let label = batch.labels[i] as usize;
        let n_cls = row
            .len()
            .min(batch.class_ids.iter().collect::<std::collections::BTreeSet<_>>().len());
        let m = row[..n_cls].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logz = m + row[..n_cls].iter().map(|&s| (s - m).exp()).sum::<f32>().ln();
        sum_ce += logz - row[label];
    }
    sum_ce / batch.n_real as f32
}

impl Workload for ClsWorkload {
    fn name(&self) -> &str {
        self.task.name()
    }

    fn engines(&self) -> EngineSet {
        EngineSet::cls_only()
    }

    fn build_round(&self, _gen_seed: u64) -> Result<Arc<dyn Round>> {
        // k-shot SFT scores the same train batches every generation.
        let round: Arc<dyn Round> = self.round.clone();
        Ok(round)
    }

    fn eval_member(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        member: usize,
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Result<f32> {
        let round = round
            .as_any()
            .downcast_ref::<ClsRound>()
            .ok_or_else(|| anyhow::anyhow!("cls workload got a foreign round payload"))?;
        let qmax = params.store.format.qmax();
        apply_perturbation_into(params, spec, member, qmax, &mut scratch.overrides, scratch.policy);
        let mut loss = 0.0f32;
        for b in &round.batches {
            let (ce, _) = session.cls_eval(params, Some(&scratch.overrides), b)?;
            loss += ce;
        }
        Ok(-loss / round.batches.len() as f32)
    }

    /// Grouped Cls scoring: ONE resolve pass + one grouped forward per
    /// batch for the whole member subset, with the CE recomputed
    /// host-side by the same loop `Session::cls_eval` runs — losses are
    /// bit-identical to the sequential default.
    fn eval_members(
        &self,
        session: &Session,
        params: &ParamsView<'_>,
        spec: &PopulationSpec,
        members: &[usize],
        round: &dyn Round,
        scratch: &mut MemberScratch,
    ) -> Vec<Result<f32>> {
        let nb = match session.backend().as_native() {
            Some(nb) if self.grouped && members.len() > 1 => nb,
            _ => return eval_members_seq(self, session, params, spec, members, round, scratch),
        };
        let run = AssertUnwindSafe(|| -> Result<Vec<f32>> {
            let round = round
                .as_any()
                .downcast_ref::<ClsRound>()
                .ok_or_else(|| anyhow::anyhow!("cls workload got a foreign round payload"))?;
            let qmax = params.store.format.qmax();
            apply_population_into(
                params,
                spec,
                members,
                qmax,
                &mut scratch.member_overrides,
                scratch.policy,
            );
            ensure_emb_t(&mut scratch.emb_t, params.store)?;
            let scores = crate::runtime::native::cls_scores_grouped(
                nb,
                params,
                &scratch.member_overrides,
                Some(&scratch.emb_t),
                &round.batches,
            )?;
            Ok(scores
                .iter()
                .map(|member_scores| {
                    let mut loss = 0.0f32;
                    for (b, s) in round.batches.iter().zip(member_scores) {
                        loss += cls_ce(s, b);
                    }
                    -loss / round.batches.len() as f32
                })
                .collect())
        });
        match catch_unwind(run) {
            Ok(Ok(rs)) => rs.into_iter().map(Ok).collect(),
            Ok(Err(e)) => group_errs(members, "grouped cls eval failed", &format!("{:#}", e)),
            Err(p) => group_errs(members, "grouped cls eval panicked", &panic_message(&*p)),
        }
    }

    fn eval_accuracy(&self, session: &Session, params: &ParamsView<'_>) -> Result<f32> {
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in &self.eval_batches {
            let (_, c) = session.cls_eval(params, None, b)?;
            correct += c;
            total += b.n_real;
        }
        Ok(100.0 * correct as f32 / total.max(1) as f32)
    }
}
