//! Supervised worker pool: the fault-tolerant leader/worker topology of
//! the paper's rollout phase.
//!
//! Each worker thread owns its own PJRT client + compiled engines (the
//! `xla` client is `Rc`-based and cannot cross threads) and evaluates the
//! population members assigned to it against a broadcast `Snapshot` of
//! the leader's sharded parameter plane (O(shards) to publish, immune to
//! subsequent leader updates). The scenario is a shared `Arc<dyn
//! Workload>` — the pool never branches on Gen vs Cls.
//!
//! Supervision exploits the paper's central property: a rollout job is a
//! pure, idempotent function of `(snapshot, gen_seed, member)`, so a
//! lost worker costs nothing but a re-dispatch and duplicate results are
//! harmless (first result per member wins). The leader tracks
//! outstanding `(round_id, member)` pairs under a per-round deadline
//! with exponential backoff, re-dispatches unscored members to surviving
//! workers, retries members whose scoring errored up to
//! `SupervisorCfg::max_retries`, and respawns workers that panic or
//! error (bounded by `max_respawns`). When retries are exhausted the
//! member is reported in `RoundOutcome::failed` and the round completes
//! degraded — the quorum decision belongs to the optimizer layer
//! (`opt::quorum_fitness`), not the pool.
//!
//! Determinism: eval retries carry an explicit attempt counter and the
//! injected-fault plan keys eval faults on `(round_id, member, attempt)`
//! only, so the set of permanently failed members is a pure function of
//! the `FaultPlan` — independent of worker count, respawns, drops,
//! delays or arrival order.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::session::Session;
use crate::coordinator::workload::{panic_message, MemberScratch, Round, Workload};
use crate::model::{AsParams, Snapshot};
use crate::opt::PopulationSpec;
use crate::quant::Format;
use crate::runtime::{BackendPolicy, Manifest};
use crate::util::fault::FaultPlan;

/// Work order broadcast to a worker for one generation. One variant for
/// every scenario — the payload is the workload's own `Round`. Members
/// carry their retry attempt so re-dispatched work is distinguishable
/// from first-try work (the fault plan and the leader's bookkeeping both
/// key on it).
pub enum Job {
    Eval {
        snapshot: Snapshot,
        gen_seed: u64,
        pairs: usize,
        sigma: f32,
        /// `(member, attempt)` — attempt is 0 on first dispatch.
        members: Vec<(usize, u32)>,
        round: Arc<dyn Round>,
        round_id: u64,
    },
    Shutdown,
}

pub struct MemberResult {
    pub round_id: u64,
    pub member: usize,
    pub attempt: u32,
    pub reward: Result<f32>,
}

/// Supervision policy for `run_round`. Defaults are tuned for local
/// thread workers (milliseconds of latency); a future TCP transport
/// would raise the deadlines.
#[derive(Debug, Clone, Copy)]
pub struct SupervisorCfg {
    /// Retry budget per member: a member whose scoring errors more than
    /// this many times is reported failed. Must match the inline
    /// simulation path (`fault::DEFAULT_MAX_RETRIES`) for the two
    /// topologies to commit identical degraded rounds.
    pub max_retries: u32,
    /// Initial per-round progress deadline: if no result arrives for
    /// this long, all outstanding members are re-dispatched.
    pub deadline_ms: u64,
    /// Deadline cap for the exponential backoff between waves.
    pub max_deadline_ms: u64,
    /// Result-channel poll granularity (also the reap cadence).
    pub poll_ms: u64,
    /// Respawn workers that die (panic, error, or premature exit).
    pub respawn: bool,
    /// Total respawn budget over the pool's lifetime.
    pub max_respawns: u32,
    /// Bail out ("round stalled") after this many deadline waves in a
    /// single round.
    pub max_waves: u32,
}

impl Default for SupervisorCfg {
    fn default() -> Self {
        SupervisorCfg {
            max_retries: crate::util::fault::DEFAULT_MAX_RETRIES,
            deadline_ms: 1000,
            max_deadline_ms: 8000,
            poll_ms: 50,
            respawn: true,
            max_respawns: 8,
            max_waves: 32,
        }
    }
}

/// What a supervised round committed: per-member rewards (`None` =
/// permanently failed after retries), the failed set, and recovery
/// counters for logging/inspection.
#[derive(Debug)]
pub struct RoundOutcome {
    pub rewards: Vec<Option<f32>>,
    pub failed: Vec<usize>,
    /// Eval errors observed (each consumes one retry of some member).
    pub retries: u32,
    /// Jobs re-sent: single-member retry dispatches + wave re-dispatches.
    pub redispatches: u32,
    /// Workers respawned while this round was in flight.
    pub respawns: u32,
}

struct SpawnCfg {
    manifest_path: String,
    size: String,
    format: Format,
    policy: BackendPolicy,
    workload: Arc<dyn Workload>,
    faults: FaultPlan,
}

struct WorkerSlot {
    /// `None` once the worker is known dead and was not respawned.
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<Result<()>>>,
    incarnation: u32,
}

struct PoolState {
    slots: Vec<WorkerSlot>,
    respawns_used: u32,
    /// Round-robin dispatch cursor.
    rr: usize,
    /// Most recent worker failure, kept for the all-dead error message.
    last_failure: Option<String>,
}

pub struct WorkerPool {
    spawn_cfg: SpawnCfg,
    sup: SupervisorCfg,
    state: Mutex<PoolState>,
    results: Receiver<MemberResult>,
    /// Kept so respawned workers can be handed a live result sender.
    /// Consequence: the results channel never disconnects while the
    /// pool is alive — stalls are caught by deadlines, not by
    /// `Disconnected`.
    res_tx: Sender<MemberResult>,
}

fn spawn_worker(
    cfg: &SpawnCfg,
    res_tx: Sender<MemberResult>,
    w: usize,
    incarnation: u32,
) -> Result<(Sender<Job>, JoinHandle<Result<()>>)> {
    let (tx, rx) = channel::<Job>();
    let mpath = cfg.manifest_path.clone();
    let size = cfg.size.clone();
    let format = cfg.format;
    let policy = cfg.policy;
    let workload = cfg.workload.clone();
    let faults = cfg.faults;
    let handle = std::thread::Builder::new()
        .name(format!("qes-worker-{}.{}", w, incarnation))
        .spawn(move || {
            worker_main(
                &mpath,
                &size,
                format,
                policy,
                workload.as_ref(),
                rx,
                res_tx,
                faults,
                w,
                incarnation,
            )
        })?;
    Ok((tx, handle))
}

impl WorkerPool {
    /// Spawn `n` workers with default supervision and the fault plan
    /// from `QES_FAULTS` (inert when unset). Each worker builds its own
    /// forward backend for (size, format) per `policy` (native by
    /// default, PJRT engines per `workload.engines()` when available)
    /// and scores members with the shared workload.
    pub fn spawn(
        n: usize,
        manifest_path: &str,
        size: &str,
        format: Format,
        policy: BackendPolicy,
        workload: Arc<dyn Workload>,
    ) -> Result<WorkerPool> {
        let faults = FaultPlan::from_env()?;
        Self::spawn_with(
            n,
            manifest_path,
            size,
            format,
            policy,
            workload,
            SupervisorCfg::default(),
            faults,
        )
    }

    /// Spawn with explicit supervision policy and fault plan (tests,
    /// chaos harness, CLI `--faults`).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_with(
        n: usize,
        manifest_path: &str,
        size: &str,
        format: Format,
        policy: BackendPolicy,
        workload: Arc<dyn Workload>,
        sup: SupervisorCfg,
        faults: FaultPlan,
    ) -> Result<WorkerPool> {
        let (res_tx, res_rx) = channel::<MemberResult>();
        let spawn_cfg = SpawnCfg {
            manifest_path: manifest_path.to_string(),
            size: size.to_string(),
            format,
            policy,
            workload,
            faults,
        };
        let mut slots = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, handle) = spawn_worker(&spawn_cfg, res_tx.clone(), w, 0)?;
            slots.push(WorkerSlot { tx: Some(tx), handle: Some(handle), incarnation: 0 });
        }
        Ok(WorkerPool {
            spawn_cfg,
            sup,
            state: Mutex::new(PoolState { slots, respawns_used: 0, rr: 0, last_failure: None }),
            results: res_rx,
            res_tx,
        })
    }

    pub fn n_workers(&self) -> usize {
        self.state.lock().expect("worker state lock poisoned").slots.len()
    }

    fn n_live(&self) -> usize {
        let state = self.state.lock().expect("worker state lock poisoned");
        state.slots.iter().filter(|s| s.tx.is_some()).count()
    }

    /// Send one job to the next live worker (round-robin). A send that
    /// fails marks the slot dead (its receiver is gone) and moves on.
    fn dispatch(&self, mut job: Job) -> Result<()> {
        let mut state = self.state.lock().expect("worker state lock poisoned");
        let n = state.slots.len();
        for i in 0..n {
            let w = (state.rr + i) % n;
            if let Some(tx) = state.slots[w].tx.as_ref() {
                match tx.send(job) {
                    Ok(()) => {
                        state.rr = (w + 1) % n;
                        return Ok(());
                    }
                    Err(std::sync::mpsc::SendError(j)) => {
                        state.slots[w].tx = None;
                        job = j;
                    }
                }
            }
        }
        anyhow::bail!("no live worker to dispatch to")
    }

    /// Join finished worker threads and (budget permitting) respawn
    /// them. Returns the number of workers respawned by this call. When
    /// every worker is dead and none could be respawned, bails with the
    /// most recent failure so the leader never blocks on a stream that
    /// cannot fill.
    fn reap_and_respawn(&self) -> Result<u32> {
        let mut state = self.state.lock().expect("worker state lock poisoned");
        let mut respawned = 0u32;
        for w in 0..state.slots.len() {
            let finished = state.slots[w].handle.as_ref().is_some_and(|h| h.is_finished());
            if !finished {
                continue;
            }
            let handle = state.slots[w].handle.take().expect("handle checked above");
            let failure = match handle.join() {
                Ok(Ok(())) => format!("worker {} exited before shutdown", w),
                Ok(Err(e)) => format!("worker {} failed: {:#}", w, e),
                Err(p) => format!("worker {} panicked: {}", w, panic_message(&*p)),
            };
            state.slots[w].tx = None;
            state.last_failure = Some(failure);
            if self.sup.respawn && state.respawns_used < self.sup.max_respawns {
                let incarnation = state.slots[w].incarnation + 1;
                let (tx, handle) =
                    spawn_worker(&self.spawn_cfg, self.res_tx.clone(), w, incarnation)?;
                state.slots[w] =
                    WorkerSlot { tx: Some(tx), handle: Some(handle), incarnation };
                state.respawns_used += 1;
                respawned += 1;
            }
        }
        let live = state.slots.iter().filter(|s| s.tx.is_some()).count();
        if live == 0 {
            let detail = state
                .last_failure
                .clone()
                .unwrap_or_else(|| "no worker failure recorded".to_string());
            anyhow::bail!("all workers dead ({})", detail);
        }
        Ok(respawned)
    }

    /// Dispatch jobs (member-partitioned, at most one per worker) and
    /// supervise the round to completion: collect results for all
    /// `n_members` members, retrying errored members up to
    /// `max_retries`, re-dispatching outstanding members on deadline
    /// waves with exponential backoff, and respawning dead workers. The
    /// round either completes (possibly degraded — see
    /// `RoundOutcome::failed`) or errors when the pool cannot make
    /// progress (all workers dead past the respawn budget, or
    /// `max_waves` deadlines with no result).
    pub fn run_round<I>(&self, jobs: I, n_members: usize) -> Result<RoundOutcome>
    where
        I: IntoIterator<Item = Job>,
    {
        let n_workers = self.n_workers();
        // bound the buffer at workers+1: enough to detect oversupply
        // BEFORE anything is dispatched (a partial dispatch would leave
        // in-flight results to poison the next round's collection)
        let batch: Vec<Job> = jobs.into_iter().take(n_workers + 1).collect();
        anyhow::ensure!(batch.len() <= n_workers, "more jobs than workers");

        // Validate the batch and capture the shared payload needed to
        // re-dispatch members later. `Snapshot`/`Arc<dyn Round>` clones
        // are O(shards) reference bumps.
        struct Payload {
            snapshot: Snapshot,
            gen_seed: u64,
            pairs: usize,
            sigma: f32,
            round: Arc<dyn Round>,
            round_id: u64,
        }
        let mut payload: Option<Payload> = None;
        let mut rewards: Vec<Option<f32>> = vec![None; n_members];
        let mut failed = vec![false; n_members];
        let mut attempts = vec![0u32; n_members];
        let mut seen = vec![false; n_members];
        for job in &batch {
            match job {
                Job::Shutdown => anyhow::bail!("cannot dispatch Shutdown through run_round"),
                Job::Eval { snapshot, gen_seed, pairs, sigma, members, round, round_id } => {
                    if let Some(p) = payload.as_ref() {
                        anyhow::ensure!(
                            p.round_id == *round_id,
                            "jobs in one round must share round_id"
                        );
                    } else {
                        payload = Some(Payload {
                            snapshot: snapshot.clone(),
                            gen_seed: *gen_seed,
                            pairs: *pairs,
                            sigma: *sigma,
                            round: round.clone(),
                            round_id: *round_id,
                        });
                    }
                    for &(m, a) in members {
                        anyhow::ensure!(m < n_members, "member {} out of range", m);
                        anyhow::ensure!(!seen[m], "member {} dispatched twice", m);
                        seen[m] = true;
                        attempts[m] = a;
                    }
                }
            }
        }

        for job in batch {
            self.dispatch(job)?;
        }

        let round_id = payload.as_ref().map(|p| p.round_id).unwrap_or(0);
        let make_job = |p: &Payload, members: Vec<(usize, u32)>| Job::Eval {
            snapshot: p.snapshot.clone(),
            gen_seed: p.gen_seed,
            pairs: p.pairs,
            sigma: p.sigma,
            members,
            round: p.round.clone(),
            round_id: p.round_id,
        };

        let mut pending = n_members;
        let mut retries = 0u32;
        let mut redispatches = 0u32;
        let mut respawns = 0u32;
        let mut wave = 0u32;
        let mut deadline = Duration::from_millis(self.sup.deadline_ms);
        let max_deadline =
            Duration::from_millis(self.sup.max_deadline_ms.max(self.sup.deadline_ms));
        let mut last_progress = Instant::now();

        while pending > 0 {
            match self.results.recv_timeout(Duration::from_millis(self.sup.poll_ms)) {
                Ok(r) => {
                    if r.round_id != round_id {
                        continue; // straggler from an abandoned round
                    }
                    let m = r.member;
                    if m >= n_members || rewards[m].is_some() || failed[m] {
                        continue; // duplicate — first result per member wins
                    }
                    match r.reward {
                        Ok(v) => {
                            rewards[m] = Some(v);
                            pending -= 1;
                            last_progress = Instant::now();
                        }
                        Err(_) => {
                            // Only the attempt currently outstanding may
                            // consume a retry — a duplicate error from a
                            // wave re-dispatch of an older attempt must
                            // not skip the retry ladder, or the failed
                            // set would depend on timing.
                            if r.attempt != attempts[m] {
                                continue;
                            }
                            retries += 1;
                            attempts[m] += 1;
                            last_progress = Instant::now();
                            if attempts[m] > self.sup.max_retries {
                                failed[m] = true;
                                pending -= 1;
                            } else if let Some(p) = &payload {
                                redispatches += 1;
                                self.dispatch(make_job(p, vec![(m, attempts[m])]))
                                    .or_else(|_| {
                                        respawns += self.reap_and_respawn()?;
                                        self.dispatch(make_job(p, vec![(m, attempts[m])]))
                                    })?;
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    respawns += self.reap_and_respawn()?;
                    if last_progress.elapsed() >= deadline {
                        wave += 1;
                        anyhow::ensure!(
                            wave <= self.sup.max_waves,
                            "round {} stalled: {}/{} members unscored after {} deadline waves",
                            round_id,
                            pending,
                            n_members,
                            wave - 1
                        );
                        if let Some(p) = &payload {
                            let outstanding: Vec<(usize, u32)> = (0..n_members)
                                .filter(|&m| rewards[m].is_none() && !failed[m])
                                .map(|m| (m, attempts[m]))
                                .collect();
                            let live = self.n_live().max(1);
                            let per = ((outstanding.len() + live - 1) / live).max(1);
                            for chunk in outstanding.chunks(per) {
                                redispatches += 1;
                                self.dispatch(make_job(p, chunk.to_vec())).or_else(|_| {
                                    respawns += self.reap_and_respawn()?;
                                    self.dispatch(make_job(p, chunk.to_vec()))
                                })?;
                            }
                        }
                        deadline = (deadline * 2).min(max_deadline);
                        last_progress = Instant::now();
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Unreachable while `self.res_tx` is held, but keep
                    // the old contract anyway.
                    anyhow::bail!(
                        "result channel closed with {}/{} members unscored",
                        pending,
                        n_members
                    );
                }
            }
        }

        let failed: Vec<usize> =
            (0..n_members).filter(|&m| failed[m]).collect();
        // mirror the round's fault-plane outcome into the registry
        let mm = crate::obs::m();
        mm.pool_retries.add(retries as u64);
        mm.pool_redispatches.add(redispatches as u64);
        mm.pool_respawns.add(respawns as u64);
        mm.pool_failed_members.add(failed.len() as u64);
        Ok(RoundOutcome { rewards, failed, retries, redispatches, respawns })
    }

    /// Unsupervised dispatch/collect, preserved for overhead
    /// benchmarking against `run_round` on the fault-free path: send
    /// jobs, block for exactly `expect` results, no retry/deadline/
    /// respawn bookkeeping. Dead workers still surface as `Err`.
    pub fn run_round_bare<I>(&self, jobs: I, expect: usize) -> Result<Vec<MemberResult>>
    where
        I: IntoIterator<Item = Job>,
    {
        let n_workers = self.n_workers();
        let batch: Vec<Job> = jobs.into_iter().take(n_workers + 1).collect();
        anyhow::ensure!(batch.len() <= n_workers, "more jobs than workers");
        for job in batch {
            self.dispatch(job)?;
        }
        let mut out = Vec::with_capacity(expect);
        while out.len() < expect {
            match self.results.recv_timeout(Duration::from_millis(200)) {
                Ok(r) => out.push(r),
                Err(RecvTimeoutError::Timeout) => {
                    // Reap without respawn: a dead worker fails the bare
                    // round like the pre-supervision pool did.
                    let mut state = self.state.lock().expect("worker state lock poisoned");
                    for w in 0..state.slots.len() {
                        let finished =
                            state.slots[w].handle.as_ref().is_some_and(|h| h.is_finished());
                        if finished {
                            let handle = state.slots[w].handle.take().expect("checked above");
                            state.slots[w].tx = None;
                            match handle.join() {
                                Ok(Ok(())) => {
                                    anyhow::bail!("worker {} exited before shutdown", w)
                                }
                                Ok(Err(e)) => {
                                    return Err(e.context(format!("worker {} failed", w)))
                                }
                                Err(p) => anyhow::bail!(
                                    "worker {} panicked: {}",
                                    w,
                                    panic_message(&*p)
                                ),
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!(
                        "result channel closed with {}/{} member results",
                        out.len(),
                        expect
                    );
                }
            }
        }
        Ok(out)
    }

    /// Orderly shutdown that PROPAGATES worker failures (Drop can only
    /// log them): send Shutdown to every live worker and join all
    /// threads.
    pub fn shutdown(self) -> Result<()> {
        let slots: Vec<WorkerSlot> = {
            let mut state = self.state.lock().expect("worker state lock poisoned");
            std::mem::take(&mut state.slots)
        };
        for slot in &slots {
            if let Some(tx) = &slot.tx {
                let _ = tx.send(Job::Shutdown);
            }
        }
        let mut first: Option<anyhow::Error> = None;
        for (w, slot) in slots.into_iter().enumerate() {
            if let Some(h) = slot.handle {
                let failure = match h.join() {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(e.context(format!("worker {} failed", w))),
                    Err(p) => {
                        Some(anyhow::anyhow!("worker {} panicked: {}", w, panic_message(&*p)))
                    }
                };
                if first.is_none() {
                    first = failure;
                }
            }
        }
        match first {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let mut state = self.state.lock().expect("worker state lock poisoned");
        for slot in state.slots.iter() {
            if let Some(tx) = &slot.tx {
                let _ = tx.send(Job::Shutdown);
            }
        }
        for (w, slot) in state.slots.iter_mut().enumerate() {
            if let Some(h) = slot.handle.take() {
                match h.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => eprintln!("worker {} failed: {:#}", w, e),
                    Err(p) => eprintln!("worker {} panicked: {}", w, panic_message(&*p)),
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    manifest_path: &str,
    size: &str,
    format: Format,
    policy: BackendPolicy,
    workload: &dyn Workload,
    rx: Receiver<Job>,
    res_tx: Sender<MemberResult>,
    faults: FaultPlan,
    worker: usize,
    incarnation: u32,
) -> Result<()> {
    let man = Manifest::load(manifest_path)?;
    let mut session = Session::with_policy(&man, size, format, workload.engines(), policy)?;
    // Workers ARE the parallelism axis: run both the perturbation fill
    // and the native backend's GEMMs sequentially per worker, so n
    // workers never nest n × cores thread fan-outs.
    session.set_backend_threads(1);
    let mut scratch = MemberScratch::sequential();
    let mut jobs_seen: u64 = 0;
    let mut sent: u64 = 0;
    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Eval { snapshot, gen_seed, pairs, sigma, members, round, round_id } => {
                jobs_seen += 1;
                if faults.worker_kill(worker, incarnation, jobs_seen) {
                    panic!("injected worker kill (worker {} inc {})", worker, incarnation);
                }
                let spec = PopulationSpec { gen_seed, pairs, sigma };
                let view = snapshot.params_view();
                // Fault-injected members error individually FIRST — the
                // plan keys on (round_id, member, attempt) and must
                // produce the same failed set whether or not the clean
                // members are scored grouped. The clean subset then goes
                // through ONE `eval_members` call (the workload decides
                // whether to fuse it into a grouped rollout); a
                // panicking workload still costs one retry per member,
                // never the worker (and its compiled engines).
                let mut rewards: Vec<Option<Result<f32>>> = members
                    .iter()
                    .map(|&(m, attempt)| {
                        faults.eval_fault(round_id, m, attempt).then(|| {
                            Err(anyhow::anyhow!(
                                "injected eval fault (round {} member {} attempt {})",
                                round_id,
                                m,
                                attempt
                            ))
                        })
                    })
                    .collect();
                let clean: Vec<usize> = members
                    .iter()
                    .zip(&rewards)
                    .filter(|(_, r)| r.is_none())
                    .map(|(&(m, _), _)| m)
                    .collect();
                if !clean.is_empty() {
                    let scored = match catch_unwind(AssertUnwindSafe(|| {
                        workload.eval_members(
                            &session,
                            &view,
                            &spec,
                            &clean,
                            round.as_ref(),
                            &mut scratch,
                        )
                    })) {
                        Ok(rs) => rs,
                        Err(p) => {
                            let msg = panic_message(&*p);
                            clean
                                .iter()
                                .map(|&m| {
                                    Err(anyhow::anyhow!(
                                        "workload panicked scoring member {}: {}",
                                        m,
                                        msg
                                    ))
                                })
                                .collect()
                        }
                    };
                    let mut it = scored.into_iter();
                    for slot in rewards.iter_mut().filter(|s| s.is_none()) {
                        *slot = Some(it.next().unwrap_or_else(|| {
                            Err(anyhow::anyhow!("workload returned too few member results"))
                        }));
                    }
                }
                // Emit per-member results in the job's member order: the
                // fault plan's drop/delay sequences key on this worker's
                // cumulative `sent` counter, so grouping must not
                // reorder it.
                for (&(m, attempt), reward) in members.iter().zip(rewards) {
                    let reward =
                        reward.expect("every member scored or fault-injected above");
                    sent += 1;
                    if faults.drop_result(worker, incarnation, sent) {
                        continue;
                    }
                    if let Some(d) = faults.delay(worker, incarnation, sent) {
                        std::thread::sleep(d);
                    }
                    let res = MemberResult { round_id, member: m, attempt, reward };
                    if res_tx.send(res).is_err() {
                        // Leader gone: stop scoring into the void.
                        return Ok(());
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::finetune::FinetuneCfg;
    use crate::coordinator::workload::GenWorkload;
    use crate::tasks::gen_task;

    fn test_workload() -> Arc<dyn Workload> {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mcfg = man.config("nano").unwrap().clone();
        let task = gen_task("countdown", mcfg.s_prompt, mcfg.t_dec).unwrap();
        let cfg = FinetuneCfg { train_pool: 8, eval_n: 4, ..Default::default() };
        Arc::new(GenWorkload::new(task, &mcfg, &cfg))
    }

    /// A worker whose setup fails (here: unreadable manifest) must turn
    /// into an `Err` from `run_round`, not a leader blocked forever on a
    /// result channel that will never fill — even though the supervisor
    /// burns its respawn budget trying to bring replacements up. Runs
    /// with or without a PJRT backend — the failure happens before
    /// engine compilation.
    #[test]
    fn worker_failure_surfaces_as_err() {
        let sup = SupervisorCfg {
            deadline_ms: 100,
            poll_ms: 10,
            max_respawns: 4,
            ..SupervisorCfg::default()
        };
        let pool = WorkerPool::spawn_with(
            2,
            "artifacts/does_not_exist.json",
            "nano",
            Format::Int4,
            BackendPolicy::Auto,
            test_workload(),
            sup,
            FaultPlan::default(),
        )
        .unwrap();
        let err = pool.run_round(Vec::new(), 1);
        assert!(err.is_err(), "dead workers must fail the round");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("worker"), "unhelpful error: {}", msg);
    }

    /// Same failure mode through the unsupervised path.
    #[test]
    fn bare_round_surfaces_worker_failure() {
        let pool = WorkerPool::spawn_with(
            1,
            "artifacts/does_not_exist.json",
            "nano",
            Format::Int4,
            BackendPolicy::Auto,
            test_workload(),
            SupervisorCfg::default(),
            FaultPlan::default(),
        )
        .unwrap();
        let err = pool.run_round_bare(Vec::new(), 1);
        assert!(err.is_err(), "dead worker must fail the bare round");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("worker"), "unhelpful error: {}", msg);
    }
}
