//! Worker pool: the leader/worker topology of the paper's rollout phase.
//!
//! Each worker thread owns its own PJRT client + compiled engines (the
//! `xla` client is `Rc`-based and cannot cross threads) and evaluates the
//! population members assigned to it against a broadcast `Snapshot` of
//! the leader's sharded parameter plane (O(shards) to publish, immune to
//! subsequent leader updates). The scenario is a shared `Arc<dyn
//! Workload>` — the pool never branches on Gen vs Cls. On the single-core
//! CI testbed the default is one worker; the topology is exercised by
//! tests with `workers = 2`.
//!
//! Worker failures are surfaced, not swallowed: each thread's
//! `JoinHandle<Result<()>>` is reaped when the result stream stalls or
//! closes, so a worker that errored or panicked turns into an `Err` on
//! the leader instead of a hung `run_round`.

use std::any::Any;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::session::Session;
use crate::coordinator::workload::{MemberScratch, Round, Workload};
use crate::model::{AsParams, Snapshot};
use crate::opt::PopulationSpec;
use crate::quant::Format;
use crate::runtime::{BackendPolicy, Manifest};

/// Work order broadcast to a worker for one generation. One variant for
/// every scenario — the payload is the workload's own `Round`.
pub enum Job {
    Eval {
        snapshot: Snapshot,
        gen_seed: u64,
        pairs: usize,
        sigma: f32,
        members: Vec<usize>,
        round: Arc<dyn Round>,
    },
    Shutdown,
}

pub struct MemberResult {
    pub member: usize,
    pub reward: Result<f32>,
}

pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    results: Receiver<MemberResult>,
    /// Slots are taken as handles are reaped (on failure or shutdown).
    handles: Mutex<Vec<Option<JoinHandle<Result<()>>>>>,
}

fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl WorkerPool {
    /// Spawn `n` workers, each building its own forward backend for
    /// (size, format) per `policy` (native by default, PJRT engines per
    /// `workload.engines()` when available) and scoring members with the
    /// shared workload.
    pub fn spawn(
        n: usize,
        manifest_path: &str,
        size: &str,
        format: Format,
        policy: BackendPolicy,
        workload: Arc<dyn Workload>,
    ) -> Result<WorkerPool> {
        let (res_tx, res_rx) = channel::<MemberResult>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            let res_tx = res_tx.clone();
            let mpath = manifest_path.to_string();
            let size = size.to_string();
            let workload = workload.clone();
            let handle = std::thread::Builder::new()
                .name(format!("qes-worker-{}", w))
                .spawn(move || {
                    worker_main(&mpath, &size, format, policy, workload.as_ref(), rx, res_tx)
                })?;
            handles.push(Some(handle));
        }
        Ok(WorkerPool { senders, results: res_rx, handles: Mutex::new(handles) })
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Dispatch jobs (member-partitioned, one per worker, built lazily —
    /// the leader never materializes a `Vec<Job>` or clones round data
    /// per worker beyond what each job itself holds) and collect exactly
    /// `expect` member results. A worker that dies mid-round (error or
    /// panic) surfaces as `Err` here instead of a leader that blocks
    /// forever on a short result stream.
    pub fn run_round<I>(&self, jobs: I, expect: usize) -> Result<Vec<MemberResult>>
    where
        I: IntoIterator<Item = Job>,
    {
        // bound the buffer at workers+1: enough to detect oversupply
        // BEFORE anything is dispatched (a partial dispatch would leave
        // in-flight results to poison the next round's collection)
        let batch: Vec<Job> = jobs.into_iter().take(self.senders.len() + 1).collect();
        anyhow::ensure!(batch.len() <= self.senders.len(), "more jobs than workers");
        for (tx, job) in self.senders.iter().zip(batch) {
            tx.send(job).map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        }
        let mut out = Vec::with_capacity(expect);
        while out.len() < expect {
            match self.results.recv_timeout(Duration::from_millis(200)) {
                Ok(r) => out.push(r),
                Err(RecvTimeoutError::Timeout) => self.reap_failed()?,
                Err(RecvTimeoutError::Disconnected) => {
                    self.reap_failed()?;
                    anyhow::bail!(
                        "result channel closed with {}/{} member results",
                        out.len(),
                        expect
                    );
                }
            }
        }
        Ok(out)
    }

    /// Join any finished worker threads; a worker that exited before
    /// shutdown — cleanly, with an error, or by panicking — is a failure.
    fn reap_failed(&self) -> Result<()> {
        let mut handles = self.handles.lock().expect("worker handle lock poisoned");
        for (w, slot) in handles.iter_mut().enumerate() {
            if slot.as_ref().is_some_and(|h| h.is_finished()) {
                match slot.take().expect("slot checked above").join() {
                    Ok(Ok(())) => anyhow::bail!("worker {} exited before shutdown", w),
                    Ok(Err(e)) => {
                        return Err(e.context(format!("worker {} failed", w)));
                    }
                    Err(p) => anyhow::bail!("worker {} panicked: {}", w, panic_message(&*p)),
                }
            }
        }
        Ok(())
    }

    /// Orderly shutdown that PROPAGATES worker failures (Drop can only
    /// log them): send Shutdown to every worker and join all threads.
    pub fn shutdown(self) -> Result<()> {
        for tx in &self.senders {
            let _ = tx.send(Job::Shutdown);
        }
        let slots: Vec<Option<JoinHandle<Result<()>>>> = {
            let mut handles = self.handles.lock().expect("worker handle lock poisoned");
            handles.iter_mut().map(|s| s.take()).collect()
        };
        let mut first: Option<anyhow::Error> = None;
        for (w, slot) in slots.into_iter().enumerate() {
            if let Some(h) = slot {
                let failure = match h.join() {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(e.context(format!("worker {} failed", w))),
                    Err(p) => {
                        Some(anyhow::anyhow!("worker {} panicked: {}", w, panic_message(&*p)))
                    }
                };
                if first.is_none() {
                    first = failure;
                }
            }
        }
        match first {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Job::Shutdown);
        }
        let mut handles = self.handles.lock().expect("worker handle lock poisoned");
        for (w, slot) in handles.iter_mut().enumerate() {
            if let Some(h) = slot.take() {
                match h.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => eprintln!("worker {} failed: {:#}", w, e),
                    Err(p) => eprintln!("worker {} panicked: {}", w, panic_message(&*p)),
                }
            }
        }
    }
}

fn worker_main(
    manifest_path: &str,
    size: &str,
    format: Format,
    policy: BackendPolicy,
    workload: &dyn Workload,
    rx: Receiver<Job>,
    res_tx: Sender<MemberResult>,
) -> Result<()> {
    let man = Manifest::load(manifest_path)?;
    let mut session = Session::with_policy(&man, size, format, workload.engines(), policy)?;
    // Workers ARE the parallelism axis: run both the perturbation fill
    // and the native backend's GEMMs sequentially per worker, so n
    // workers never nest n × cores thread fan-outs.
    session.set_backend_threads(1);
    let mut scratch = MemberScratch::sequential();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Eval { snapshot, gen_seed, pairs, sigma, members, round } => {
                let spec = PopulationSpec { gen_seed, pairs, sigma };
                let view = snapshot.params_view();
                for m in members {
                    let reward = workload
                        .eval_member(&session, &view, &spec, m, round.as_ref(), &mut scratch);
                    res_tx.send(MemberResult { member: m, reward }).ok();
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::finetune::FinetuneCfg;
    use crate::coordinator::workload::GenWorkload;
    use crate::tasks::gen_task;

    /// A worker whose setup fails (here: unreadable manifest) must turn
    /// into an `Err` from `run_round`, not a leader blocked forever on a
    /// result channel that will never fill. Runs with or without a PJRT
    /// backend — the failure happens before engine compilation.
    #[test]
    fn worker_failure_surfaces_as_err() {
        let man = Manifest::load("artifacts/manifest.json").unwrap();
        let mcfg = man.config("nano").unwrap().clone();
        let task = gen_task("countdown", mcfg.s_prompt, mcfg.t_dec).unwrap();
        let cfg = FinetuneCfg { train_pool: 8, eval_n: 4, ..Default::default() };
        let workload: Arc<dyn Workload> = Arc::new(GenWorkload::new(task, &mcfg, &cfg));
        let pool = WorkerPool::spawn(
            2,
            "artifacts/does_not_exist.json",
            "nano",
            Format::Int4,
            BackendPolicy::Auto,
            workload,
        )
        .unwrap();
        let err = pool.run_round(Vec::new(), 1);
        assert!(err.is_err(), "dead workers must fail the round");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("worker"), "unhelpful error: {}", msg);
    }
}
