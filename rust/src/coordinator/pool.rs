//! Worker pool: the leader/worker topology of the paper's rollout phase.
//!
//! Each worker thread owns its own PJRT client + compiled engines (the
//! `xla` client is `Rc`-based and cannot cross threads) and evaluates the
//! population members assigned to it against a broadcast snapshot of the
//! current lattice. On the single-core CI testbed the default is one
//! worker; the topology is exercised by tests with `workers = 2`.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::encode::{ClsBatch, GenBatch};
use crate::coordinator::rollout::{eval_member_cls_with, eval_member_gen_with, MemberScratch};
use crate::coordinator::session::{EngineSet, Session};
use crate::model::ParamStore;
use crate::quant::Format;
use crate::runtime::Manifest;
use crate::tasks::gen_task;

/// Work order broadcast to a worker for one generation.
pub enum Job {
    EvalGen {
        snapshot: Arc<ParamStore>,
        gen_seed: u64,
        pairs: usize,
        sigma: f32,
        members: Vec<usize>,
        batch: Arc<GenBatch>,
        tau: f32,
    },
    EvalCls {
        snapshot: Arc<ParamStore>,
        gen_seed: u64,
        pairs: usize,
        sigma: f32,
        members: Vec<usize>,
        batches: Arc<Vec<ClsBatch>>,
    },
    Shutdown,
}

pub struct MemberResult {
    pub member: usize,
    pub reward: Result<f32>,
}

pub struct WorkerPool {
    senders: Vec<Sender<Job>>,
    results: Receiver<MemberResult>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `n` workers, each compiling its own engines for
    /// (size, format) and reconstructing `task_name` for rewards.
    pub fn spawn(
        n: usize,
        manifest_path: &str,
        size: &str,
        format: Format,
        task_name: Option<&str>,
        set: EngineSet,
    ) -> Result<WorkerPool> {
        let (res_tx, res_rx) = channel::<MemberResult>();
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            let res_tx = res_tx.clone();
            let mpath = manifest_path.to_string();
            let size = size.to_string();
            let task_name = task_name.map(|s| s.to_string());
            let handle = std::thread::Builder::new()
                .name(format!("qes-worker-{}", w))
                .spawn(move || {
                    if let Err(e) = worker_main(&mpath, &size, format, task_name.as_deref(), set, rx, res_tx)
                    {
                        eprintln!("worker {} died: {:#}", w, e);
                    }
                })?;
            handles.push(handle);
        }
        Ok(WorkerPool { senders, results: res_rx, handles })
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Dispatch jobs (already member-partitioned, one per worker) and
    /// collect exactly `expect` member results.
    pub fn run_round(&self, jobs: Vec<Job>, expect: usize) -> Result<Vec<MemberResult>> {
        anyhow::ensure!(jobs.len() <= self.senders.len(), "more jobs than workers");
        for (tx, job) in self.senders.iter().zip(jobs) {
            tx.send(job).map_err(|_| anyhow::anyhow!("worker channel closed"))?;
        }
        let mut out = Vec::with_capacity(expect);
        for _ in 0..expect {
            out.push(
                self.results
                    .recv()
                    .map_err(|_| anyhow::anyhow!("result channel closed"))?,
            );
        }
        Ok(out)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Job::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_main(
    manifest_path: &str,
    size: &str,
    format: Format,
    task_name: Option<&str>,
    set: EngineSet,
    rx: Receiver<Job>,
    res_tx: Sender<MemberResult>,
) -> Result<()> {
    let man = Manifest::load(manifest_path)?;
    let session = Session::new(&man, size, format, set)?;
    let qmax = format.qmax();
    let task = match task_name {
        Some(t) => Some(gen_task(t, session.cfg.s_prompt, session.cfg.t_dec)?),
        None => None,
    };
    // Per-worker perturbation buffers, reused across every member this
    // worker ever evaluates (no per-member Vec<Vec<i8>> allocation).
    // Sequential fill: the pool already parallelizes across workers, so a
    // per-member thread fan-out would only oversubscribe the cores.
    let mut scratch = MemberScratch::sequential();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::EvalGen { snapshot, gen_seed, pairs, sigma, members, batch, tau } => {
                let spec = crate::opt::PopulationSpec { gen_seed, pairs, sigma };
                let task = task
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("gen job on a worker without a task"))?;
                for m in members {
                    let reward = eval_member_gen_with(
                        &session, task.as_ref(), &snapshot, &spec, m, &batch, tau, qmax,
                        &mut scratch,
                    );
                    res_tx.send(MemberResult { member: m, reward }).ok();
                }
            }
            Job::EvalCls { snapshot, gen_seed, pairs, sigma, members, batches } => {
                let spec = crate::opt::PopulationSpec { gen_seed, pairs, sigma };
                for m in members {
                    let reward = eval_member_cls_with(
                        &session, &snapshot, &spec, m, &batches, qmax, &mut scratch,
                    );
                    res_tx.send(MemberResult { member: m, reward }).ok();
                }
            }
        }
    }
    Ok(())
}
