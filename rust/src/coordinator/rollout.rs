//! Member evaluation: perturb -> rollout -> reward. Shared by the inline
//! (single-thread) path and the worker pool.

use anyhow::Result;

use crate::coordinator::encode::{ClsBatch, GenBatch};
use crate::coordinator::session::Session;
use crate::model::ParamStore;
use crate::opt::{apply_perturbation, PopulationSpec};
use crate::tasks::GenTask;

/// Salt separating decode-sampling noise from perturbation noise.
const GUMBEL_SALT: u64 = 0x6465_636f_6465_5f67;

/// Evaluate one population member on a reasoning task: mean RLVR reward
/// over the real rows of the rollout batch.
#[allow(clippy::too_many_arguments)]
pub fn eval_member_gen(
    session: &Session,
    task: &dyn GenTask,
    store: &ParamStore,
    spec: &PopulationSpec,
    member: usize,
    batch: &GenBatch,
    tau: f32,
    qmax: i8,
) -> Result<f32> {
    let overrides = apply_perturbation(store, spec, member, qmax);
    let gumbel_seed = if tau > 0.0 {
        Some(spec.gen_seed ^ GUMBEL_SALT ^ (member as u64) << 17)
    } else {
        None
    };
    let completions = session.generate(store, Some(&overrides), batch, tau, gumbel_seed)?;
    let mut total = 0.0f32;
    for (i, c) in completions.iter().enumerate() {
        total += task.reward(&batch.problems[i].key, c);
    }
    Ok(total / batch.n_real as f32)
}

/// Evaluate one member on an SFT task: fitness = -mean CE over the k-shot
/// batches (ES ascends fitness, so this descends the loss).
pub fn eval_member_cls(
    session: &Session,
    store: &ParamStore,
    spec: &PopulationSpec,
    member: usize,
    batches: &[ClsBatch],
    qmax: i8,
) -> Result<f32> {
    let overrides = apply_perturbation(store, spec, member, qmax);
    let mut loss = 0.0f32;
    for b in batches {
        let (ce, _) = session.cls_eval(store, Some(&overrides), b)?;
        loss += ce;
    }
    Ok(-loss / batches.len() as f32)
}

/// Unperturbed greedy evaluation on a reasoning task: accuracy (% of
/// problems with reward 1.0) over an eval problem set.
pub fn eval_accuracy_gen(
    session: &Session,
    task: &dyn GenTask,
    store: &ParamStore,
    problems: &[crate::tasks::GenProblem],
) -> Result<f32> {
    let cfg = &session.cfg;
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in problems.chunks(cfg.b_gen) {
        let batch = GenBatch::build(cfg, chunk.to_vec());
        let completions = session.generate(store, None, &batch, 0.0, None)?;
        for (i, c) in completions.iter().enumerate() {
            if task.reward(&batch.problems[i].key, c) >= 1.0 {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(100.0 * correct as f32 / total.max(1) as f32)
}

/// Unperturbed classification accuracy (%) over eval batches.
pub fn eval_accuracy_cls(
    session: &Session,
    store: &ParamStore,
    batches: &[ClsBatch],
) -> Result<f32> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in batches {
        let (_, c) = session.cls_eval(store, None, b)?;
        correct += c;
        total += b.n_real;
    }
    Ok(100.0 * correct as f32 / total.max(1) as f32)
}
