//! Member evaluation: perturb -> rollout -> reward. Shared by the inline
//! (single-thread) path and the worker pool.

use anyhow::Result;

use crate::coordinator::encode::{ClsBatch, GenBatch};
use crate::coordinator::session::Session;
use crate::model::ParamStore;
use crate::opt::{apply_perturbation_into, KernelPolicy, PopulationSpec};
use crate::tasks::GenTask;

/// Salt separating decode-sampling noise from perturbation noise.
const GUMBEL_SALT: u64 = 0x6465_636f_6465_5f67;

/// Evaluate one population member on a reasoning task: mean RLVR reward
/// over the real rows of the rollout batch. Allocates a fresh perturbation
/// buffer; evaluation loops should hold a [`MemberScratch`] and use
/// [`eval_member_gen_with`].
#[allow(clippy::too_many_arguments)]
pub fn eval_member_gen(
    session: &Session,
    task: &dyn GenTask,
    store: &ParamStore,
    spec: &PopulationSpec,
    member: usize,
    batch: &GenBatch,
    tau: f32,
    qmax: i8,
) -> Result<f32> {
    let mut scratch = MemberScratch::default();
    eval_member_gen_with(session, task, store, spec, member, batch, tau, qmax, &mut scratch)
}

/// Reusable per-worker buffers for member evaluation: the perturbed
/// lattice is materialized into `overrides` in place, so a generation's
/// member loop performs zero per-member allocations on the perturbation
/// path. `policy` controls the fill's chunk parallelism — results are
/// identical for any policy (the kernels' determinism contract), so pick
/// it for the topology: the default exploits all cores (right for the
/// single-threaded inline leader loop), while code that already runs
/// many evaluations in parallel (the worker pool) should use
/// [`MemberScratch::sequential`] to avoid oversubscribing cores with
/// per-member thread fan-outs.
pub struct MemberScratch {
    pub overrides: Vec<Vec<i8>>,
    pub policy: KernelPolicy,
}

impl Default for MemberScratch {
    fn default() -> Self {
        MemberScratch { overrides: Vec::new(), policy: KernelPolicy::default() }
    }
}

impl MemberScratch {
    /// Scratch whose perturbation fill runs inline on the calling thread
    /// — for callers that are themselves one of many parallel workers.
    pub fn sequential() -> Self {
        MemberScratch { overrides: Vec::new(), policy: KernelPolicy::scalar() }
    }
}

/// [`eval_member_gen`] with caller-owned perturbation buffers.
#[allow(clippy::too_many_arguments)]
pub fn eval_member_gen_with(
    session: &Session,
    task: &dyn GenTask,
    store: &ParamStore,
    spec: &PopulationSpec,
    member: usize,
    batch: &GenBatch,
    tau: f32,
    qmax: i8,
    scratch: &mut MemberScratch,
) -> Result<f32> {
    apply_perturbation_into(store, spec, member, qmax, &mut scratch.overrides, scratch.policy);
    let gumbel_seed = if tau > 0.0 {
        Some(spec.gen_seed ^ GUMBEL_SALT ^ (member as u64) << 17)
    } else {
        None
    };
    let completions =
        session.generate(store, Some(&scratch.overrides), batch, tau, gumbel_seed)?;
    let mut total = 0.0f32;
    for (i, c) in completions.iter().enumerate() {
        total += task.reward(&batch.problems[i].key, c);
    }
    Ok(total / batch.n_real as f32)
}

/// Evaluate one member on an SFT task: fitness = -mean CE over the k-shot
/// batches (ES ascends fitness, so this descends the loss).
pub fn eval_member_cls(
    session: &Session,
    store: &ParamStore,
    spec: &PopulationSpec,
    member: usize,
    batches: &[ClsBatch],
    qmax: i8,
) -> Result<f32> {
    let mut scratch = MemberScratch::default();
    eval_member_cls_with(session, store, spec, member, batches, qmax, &mut scratch)
}

/// [`eval_member_cls`] with caller-owned perturbation buffers.
pub fn eval_member_cls_with(
    session: &Session,
    store: &ParamStore,
    spec: &PopulationSpec,
    member: usize,
    batches: &[ClsBatch],
    qmax: i8,
    scratch: &mut MemberScratch,
) -> Result<f32> {
    apply_perturbation_into(store, spec, member, qmax, &mut scratch.overrides, scratch.policy);
    let mut loss = 0.0f32;
    for b in batches {
        let (ce, _) = session.cls_eval(store, Some(&scratch.overrides), b)?;
        loss += ce;
    }
    Ok(-loss / batches.len() as f32)
}

/// Unperturbed greedy evaluation on a reasoning task: accuracy (% of
/// problems with reward 1.0) over an eval problem set.
pub fn eval_accuracy_gen(
    session: &Session,
    task: &dyn GenTask,
    store: &ParamStore,
    problems: &[crate::tasks::GenProblem],
) -> Result<f32> {
    let cfg = &session.cfg;
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in problems.chunks(cfg.b_gen) {
        let batch = GenBatch::build(cfg, chunk.to_vec());
        let completions = session.generate(store, None, &batch, 0.0, None)?;
        for (i, c) in completions.iter().enumerate() {
            if task.reward(&batch.problems[i].key, c) >= 1.0 {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(100.0 * correct as f32 / total.max(1) as f32)
}

/// Unperturbed classification accuracy (%) over eval batches.
pub fn eval_accuracy_cls(
    session: &Session,
    store: &ParamStore,
    batches: &[ClsBatch],
) -> Result<f32> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in batches {
        let (_, c) = session.cls_eval(store, None, b)?;
        correct += c;
        total += b.n_real;
    }
    Ok(100.0 * correct as f32 / total.max(1) as f32)
}
