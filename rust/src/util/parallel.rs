//! Minimal deterministic task-parallel executor on `std::thread::scope`.
//!
//! The offline build vendors no thread-pool crate, and the kernels in
//! `opt::kernels` don't need one: their determinism contract ("bit-identical
//! results for any chunk size and thread count") means the executor only
//! decides *which thread* runs a task, never what the task computes. Tasks
//! carry disjoint mutable slices, results come back in task order, and a
//! panicking task propagates when the scope joins.
//!
//! Threads are spawned per call. The kernels run on multi-millisecond
//! workloads (whole-lattice updates), so spawn cost (~tens of µs) is noise;
//! if a persistent pool ever becomes worthwhile, it slots in behind
//! [`map_tasks`] without touching any kernel.

/// Number of worker threads to use by default (the machine's available
/// parallelism, falling back to 1 when it cannot be queried).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every task, distributing tasks round-robin across up to
/// `threads` OS threads, and return the results in task order.
///
/// With `threads <= 1` (or fewer than two tasks) everything runs inline on
/// the caller's thread — the sequential reference path.
pub fn map_tasks<T, R, F>(tasks: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = tasks.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return tasks.into_iter().map(f).collect();
    }
    let mut buckets: Vec<Vec<(usize, T)>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, t) in tasks.into_iter().enumerate() {
        buckets[i % threads].push((i, t));
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let fref = &f;
    std::thread::scope(|s| {
        let (tx, rx) = std::sync::mpsc::channel::<(usize, R)>();
        for bucket in buckets {
            let tx = tx.clone();
            s.spawn(move || {
                for (i, t) in bucket {
                    let _ = tx.send((i, fref(t)));
                }
            });
        }
        drop(tx);
        for (i, r) in rx {
            out[i] = Some(r);
        }
    });
    out.into_iter().map(|r| r.expect("parallel worker dropped a task")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_task_order() {
        for threads in [1usize, 2, 8] {
            let tasks: Vec<usize> = (0..97).collect();
            let got = map_tasks(tasks, threads, |i| i * i);
            let want: Vec<usize> = (0..97).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={}", threads);
        }
    }

    #[test]
    fn mutable_slice_tasks_work() {
        let mut data = vec![0u32; 1000];
        let chunks: Vec<&mut [u32]> = data.chunks_mut(64).collect();
        map_tasks(chunks, 4, |c| {
            for x in c.iter_mut() {
                *x += 1;
            }
        });
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn empty_and_single_task() {
        let got: Vec<u32> = map_tasks(Vec::<u32>::new(), 8, |x| x);
        assert!(got.is_empty());
        assert_eq!(map_tasks(vec![5u32], 8, |x| x + 1), vec![6]);
    }
}
