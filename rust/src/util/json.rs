//! Minimal JSON parser — just enough to read `artifacts/manifest.json` and
//! experiment configuration files. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (the manifest is pure ASCII).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Exact non-negative integer value, if this number is one. Numbers
    /// arrive through an `f64`, so this rejects negatives, fractions,
    /// and magnitudes at or above 2^53 — past that the float cannot
    /// represent every integer, so the original digits can't be trusted
    /// (a cast would silently return a *different* integer; 2^53 itself
    /// is excluded because 2^53+1 rounds onto it during parsing).
    pub fn as_u64_exact(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < MAX_EXACT => Some(*n as u64),
            _ => None,
        }
    }

    /// Exact non-negative integer as `usize` (same rules as
    /// [`Json::as_u64_exact`] — a negative or fractional number is None,
    /// never a saturated cast).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64_exact().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to a compact JSON string.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("utf8"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("utf8"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad hex"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"n":-3}}"#;
        let j = Json::parse(src).unwrap();
        let s = j.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn exact_integer_extraction() {
        // negatives and fractions are None, never a saturating cast
        assert_eq!(Json::parse("-1").unwrap().as_u64_exact(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64_exact(), None);
        assert_eq!(Json::parse("0").unwrap().as_u64_exact(), Some(0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        // from 2^53 up the f64 path loses integer precision (2^53+1
        // already rounds onto 2^53 during parsing), so extraction refuses
        assert_eq!(
            Json::parse("9007199254740991").unwrap().as_u64_exact(),
            Some(9007199254740991)
        );
        assert_eq!(Json::parse("9007199254740992").unwrap().as_u64_exact(), None);
        assert_eq!(Json::parse("9007199254740993").unwrap().as_u64_exact(), None);
        assert_eq!(Json::parse("1e300").unwrap().as_u64_exact(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_u64_exact(), None);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }
}
