//! Tiny property-testing harness (proptest is not available offline).
//!
//! ```ignore
//! prop_check("gate never exceeds range", 200, |g| {
//!     let v: Vec<i8> = g.vec_i8(100, -7, 7);
//!     ...
//!     Ok(())
//! });
//! ```
//!
//! On failure the failing case's seed is printed so it can be replayed with
//! `Gen::from_seed(seed)`.

use crate::rng::SplitMix64;

/// Input generator handed to each property iteration.
pub struct Gen {
    pub rng: SplitMix64,
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Gen { rng: SplitMix64::new(seed), seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.uniform01() * (hi - lo)
    }

    pub fn normal(&mut self, std: f32) -> f32 {
        self.rng.normal() * std
    }

    pub fn vec_i8(&mut self, n: usize, lo: i8, hi: i8) -> Vec<i8> {
        (0..n).map(|_| self.i64_in(lo as i64, hi as i64) as i8).collect()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }
}

/// Run `prop` for `cases` random inputs; panic with the seed on failure.
pub fn prop_check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        // Deterministic but well-spread seeds so failures replay exactly.
        let seed = 0x9e37_79b9_7f4a_7c15u64
            .wrapping_mul(case + 1)
            .wrapping_add(0xdead_beef);
        let mut g = Gen::from_seed(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {:?} failed on case {} (replay with Gen::from_seed({:#x})): {}",
                name, case, seed, msg
            );
        }
    }
}
