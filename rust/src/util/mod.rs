//! Small in-repo utilities.
//!
//! The build environment is offline and the vendored crate set is limited to
//! `xla` + `anyhow`, so JSON parsing, CLI parsing, benchmarking and
//! property-test harnesses are implemented here instead of pulled in.

pub mod args;
pub mod bench;
pub mod f16;
pub mod fault;
pub mod json;
pub mod parallel;
pub mod prop;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32).sqrt()
}

/// Format a byte count with binary units.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[1.0, 1.0, 1.0])).abs() < 1e-9);
        assert!((std_dev(&[0.0, 2.0]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MB");
    }
}
