//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Unknown flags are an error so typos don't silently default.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    known: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Self> {
        let mut a = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    a.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else {
                    // value-taking if next token exists and isn't a flag
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            a.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            a.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    /// Declare a known flag (for the final unknown-flag check) and fetch it.
    pub fn opt(&mut self, key: &str) -> Option<String> {
        self.known.push(key.to_string());
        self.flags.get(key).cloned()
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&mut self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{} expects an integer, got {:?}", key, v)),
        }
    }

    pub fn get_f32(&mut self, key: &str, default: f32) -> anyhow::Result<f32> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{} expects a float, got {:?}", key, v)),
        }
    }

    pub fn get_u64(&mut self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{} expects an integer, got {:?}", key, v)),
        }
    }

    pub fn get_bool(&mut self, key: &str) -> bool {
        matches!(self.opt(key).as_deref(), Some("true") | Some("1") | Some("yes"))
    }

    /// Call after all opt()/get_*() declarations: errors on unknown flags.
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.flags.keys() {
            if !self.known.contains(k) {
                anyhow::bail!("unknown flag --{}", k);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let mut a = parse(&["exp", "table2", "--gens", "50", "--fast", "--x=1.5"]);
        assert_eq!(a.positional, vec!["exp", "table2"]);
        assert_eq!(a.get_usize("gens", 0).unwrap(), 50);
        assert!(a.get_bool("fast"));
        assert_eq!(a.get_f32("x", 0.0).unwrap(), 1.5);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_errors() {
        let mut a = parse(&["--typo", "3"]);
        let _ = a.get_usize("gens", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults() {
        let mut a = parse(&[]);
        assert_eq!(a.get_or("size", "nano"), "nano");
        assert_eq!(a.get_f32("sigma", 0.01).unwrap(), 0.01);
    }
}
