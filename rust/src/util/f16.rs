//! IEEE-754 binary16 conversion (no `half` crate offline).
//!
//! The paper's Full-Residual oracle stores the error state in FP16
//! (Algorithm 1, line 3); storing residuals as `u16` bits keeps our Table 8
//! memory accounting byte-exact with the paper's.

/// f32 -> f16 bits, round-to-nearest-even, with overflow to ±inf and
/// gradual underflow to subnormals.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m;
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal half
        let half_exp = ((e + 15) as u16) << 10;
        let mut half_mant = (mant >> 13) as u16;
        // round to nearest even on the 13 dropped bits
        let rest = mant & 0x1fff;
        if rest > 0x1000 || (rest == 0x1000 && (half_mant & 1) == 1) {
            let r = (half_exp | half_mant).wrapping_add(1);
            return sign | r; // mantissa overflow carries into exponent correctly
        }
        half_mant &= 0x3ff;
        return sign | half_exp | half_mant;
    }
    if e >= -24 {
        // subnormal half
        let shift = (-14 - e) as u32 + 13;
        let full = mant | 0x0080_0000; // implicit leading 1
        let half_mant = (full >> shift) as u16;
        let rest = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rest > halfway || (rest == halfway && (half_mant & 1) == 1) {
            half_mant + 1
        } else {
            half_mant
        };
        return sign | rounded;
    }
    if e == -25 && mant != 0 {
        // (2^-25, 2^-24): closer to the smallest subnormal than to zero,
        // so round-to-nearest lands on 0x0001 (exactly 2^-25 ties to the
        // even candidate, zero). Matches hardware vcvtps2ph bit-for-bit.
        return sign | 1;
    }
    sign // underflow to zero
}

/// Decode a slice of f16 bit patterns into an f32 buffer of equal length.
/// The batch form of [`f16_bits_to_f32`] — dispatches to the active SIMD
/// microkernel (`crate::kernel`; hardware `vcvtph2ps` on AVX2 hosts),
/// bit-identical to the per-element converter on every backend.
pub fn f16_decode_slice(bits: &[u16], out: &mut [f32]) {
    assert_eq!(bits.len(), out.len(), "f16 decode length mismatch");
    crate::kernel::active_kernel().f16_decode(bits, out);
}

/// Encode a slice of f32 values into f16 bit patterns of equal length
/// (round-to-nearest-even, like [`f32_to_f16_bits`]) — dispatches to the
/// active SIMD microkernel (hardware `vcvtps2ph` on AVX2 hosts); the
/// conversion is uniquely defined by IEEE 754, so every backend produces
/// the same bits for non-NaN inputs.
pub fn f16_encode_slice(xs: &[f32], out: &mut [u16]) {
    assert_eq!(xs.len(), out.len(), "f16 encode length mismatch");
    crate::kernel::active_kernel().f16_encode(xs, out);
}

/// f16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut m = mant;
            let mut e = -14i32;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e + 127) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn exact_values() {
        for &(f, h) in &[
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff), // f16 max
        ] {
            assert_eq!(f32_to_f16_bits(f), h, "f={}", f);
            assert_eq!(f16_bits_to_f32(h), f, "h={:#06x}", h);
        }
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(f32_to_f16_bits(1e6), 0x7c00);
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
    }

    #[test]
    fn nan_preserved() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn tiny_subnormal_boundary_rounds_to_nearest() {
        // IEEE round-to-nearest-even at the bottom of the f16 range
        // (matches hardware vcvtps2ph bit-for-bit): values in
        // (2^-25, 2^-24) round to the smallest subnormal 0x0001;
        // exactly 2^-25 ties to the even candidate (zero); below that
        // underflows to zero.
        let q = 2f32.powi(-25);
        assert_eq!(f32_to_f16_bits(1.5 * q), 0x0001);
        assert_eq!(f32_to_f16_bits(-1.5 * q), 0x8001);
        assert_eq!(f32_to_f16_bits(1.0001 * q), 0x0001);
        assert_eq!(f32_to_f16_bits(q), 0x0000); // tie -> even (zero)
        assert_eq!(f32_to_f16_bits(0.9 * q), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.9 * q), 0x8000);
        // and the smallest subnormal decodes back to 2^-24
        assert_eq!(f16_bits_to_f32(0x0001), 2f32.powi(-24));
    }

    #[test]
    fn subnormals_roundtrip() {
        let tiny = 1.0f32 / 65536.0; // 2^-16: comfortably subnormal in f16
        let h = f32_to_f16_bits(tiny);
        assert!(h > 0 && h < 0x0400, "h={:#06x}", h);
        let back = f16_bits_to_f32(h);
        assert!((back - tiny).abs() / tiny < 0.01, "back={}", back);
    }

    #[test]
    fn roundtrip_error_within_eps() {
        // |x - f16(x)| <= 2^-11 * |x| for normal range
        prop_check("f16 roundtrip relative error", 300, |g| {
            let x = g.f32_in(-100.0, 100.0);
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            let tol = x.abs() * (1.0 / 2048.0) + 1e-7;
            if (x - back).abs() > tol {
                return Err(format!("x={} back={}", x, back));
            }
            Ok(())
        });
    }

    #[test]
    fn slice_helpers_match_scalar_conversions() {
        let xs: Vec<f32> = (0..4096)
            .map(|i| ((i as f32) - 2048.0) / 739.0)
            .chain([0.0, -0.0, 1e-6, -65504.0, 65504.0, 1e6])
            .collect();
        let mut bits = vec![0u16; xs.len()];
        f16_encode_slice(&xs, &mut bits);
        for (j, (&x, &h)) in xs.iter().zip(bits.iter()).enumerate() {
            assert_eq!(h, f32_to_f16_bits(x), "elem {}", j);
        }
        let mut back = vec![0.0f32; xs.len()];
        f16_decode_slice(&bits, &mut back);
        for (j, (&h, &b)) in bits.iter().zip(back.iter()).enumerate() {
            assert_eq!(b.to_bits(), f16_bits_to_f32(h).to_bits(), "elem {}", j);
        }
    }

    #[test]
    fn residual_range_is_representable() {
        // QES residuals live in (-1, 1); f16 resolution there is <= 2^-11.
        for i in 0..2000 {
            let x = (i as f32 / 1000.0) - 1.0;
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            assert!((x - back).abs() <= 0.0005, "x={} back={}", x, back);
        }
    }
}
