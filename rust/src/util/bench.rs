//! Micro-benchmark harness (criterion is not available offline).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::new("delta_gen");
//! b.run("micro/d=221k", || { ... });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to exceed a
//! minimum measurement window; mean / p50 / p95 per-iteration times are
//! reported in a table.

use std::time::{Duration, Instant};

pub struct CaseResult {
    pub name: String,
    /// ISA microkernel dispatched while this case ran (captured at `run`
    /// time, so benches that toggle `kernel::force` label each case with
    /// the backend that actually executed it).
    pub kernel: &'static str,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

pub struct Bench {
    pub group: String,
    pub min_window: Duration,
    pub warmup: Duration,
    pub results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        Bench {
            group: group.to_string(),
            min_window: Duration::from_millis(400),
            warmup: Duration::from_millis(100),
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform ONE iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &CaseResult {
        // Warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Estimate a single-iteration time to size the batch.
        let e0 = Instant::now();
        f();
        let est = e0.elapsed().max(Duration::from_nanos(50));
        let target_iters =
            (self.min_window.as_nanos() / est.as_nanos()).clamp(10, 100_000) as u64;

        let mut samples: Vec<Duration> = Vec::with_capacity(target_iters as usize);
        for _ in 0..target_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let total: Duration = samples.iter().sum();
        let res = CaseResult {
            name: name.to_string(),
            kernel: crate::kernel::active().name(),
            iters: target_iters,
            mean: total / target_iters as u32,
            p50: samples[samples.len() / 2],
            p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        };
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<40} {:>10} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "p95"
        );
        for r in &self.results {
            println!(
                "{:<40} {:>10} {:>12} {:>12} {:>12}",
                r.name,
                r.iters,
                fmt_dur(r.mean),
                fmt_dur(r.p50),
                fmt_dur(r.p95)
            );
        }
    }

    /// Emit one `BENCH {json}` line per case — the machine-readable record
    /// perf tracking greps out of bench logs (see PERF.md). Keys:
    /// group, case, kernel (the dispatched ISA microkernel — what makes
    /// records comparable across machines), iters, mean_ns, p50_ns,
    /// p95_ns.
    pub fn report_json(&self) {
        for r in &self.results {
            println!(
                "BENCH {{\"group\":{},\"case\":{},\"kernel\":{},\"iters\":{},\"mean_ns\":{},\"p50_ns\":{},\"p95_ns\":{}}}",
                json_str(&self.group),
                json_str(&r.name),
                json_str(r.kernel),
                r.iters,
                r.mean.as_nanos(),
                r.p50.as_nanos(),
                r.p95.as_nanos()
            );
        }
    }

    /// Mean time of a recorded case (panics if the case was never run).
    pub fn mean_ns(&self, name: &str) -> u128 {
        self.results
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("no bench case named {:?}", name))
            .mean
            .as_nanos()
    }
}

/// Emit a `BENCH` speedup record comparing a baseline case to an optimized
/// one (ratio > 1 means the optimized case is faster). `kernel` names the
/// microkernel backend the OPTIMIZED leg executed on — passed explicitly
/// because speedup records print after the cases ran, when the ambient
/// dispatch may have been restored to something else.
pub fn report_speedup(
    group: &str,
    case: &str,
    kernel: &str,
    baseline_ns: u128,
    optimized_ns: u128,
) {
    let ratio = baseline_ns as f64 / optimized_ns.max(1) as f64;
    println!(
        "BENCH {{\"group\":{},\"case\":{},\"kernel\":{},\"baseline_ns\":{},\"optimized_ns\":{},\"speedup\":{:.3}}}",
        json_str(group),
        json_str(case),
        json_str(kernel),
        baseline_ns,
        optimized_ns,
        ratio
    );
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{} ns", ns)
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
