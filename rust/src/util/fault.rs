//! Deterministic fault-injection plan for the rollout plane.
//!
//! A `FaultPlan` is a pure function from *logical counters* to fault
//! decisions — no wall clock, no global state. Every decision hashes
//! `(seed, salt, key…)` through [`SplitMix64`](crate::rng::SplitMix64)
//! and compares a uniform draw against the configured probability, so a
//! plan replays identically across runs, worker counts and thread
//! interleavings.
//!
//! Two fault families with deliberately different keying:
//!
//! * **Eval faults** are keyed on `(round_id, member, attempt)` only.
//!   Whether member `m` of round `r` fails its `a`-th scoring attempt
//!   does not depend on which worker ran it — so the set of
//!   *permanently failed* members (all attempts faulted) is a pure
//!   function of the plan, independent of scheduling. This is what
//!   makes degraded rounds reproducible inline (no pool at all).
//! * **Transient faults** (worker kills, dropped sends, delays) are
//!   keyed on `(worker, incarnation, counter)`. They perturb
//!   scheduling and delivery but never the committed results; a
//!   respawned worker is a fresh incarnation and draws fresh
//!   decisions, so with p < 1 the pool always makes progress.

use std::time::Duration;

use crate::rng::SplitMix64;

const SALT_EVAL: u64 = 0x6f61_5f65_7661_6c21;
const SALT_KILL: u64 = 0x6b69_6c6c_5f77_6b72;
const SALT_DROP: u64 = 0x6472_6f70_5f73_6e64;
const SALT_DELAY: u64 = 0x6465_6c61_795f_7278;

/// Retry budget shared by the supervised pool and the inline
/// fault-simulation path in `finetune` — both must agree on how many
/// attempts a member gets before it is declared permanently failed, or
/// the failed-member set (and therefore the committed lattice) would
/// differ between the two execution topologies.
pub const DEFAULT_MAX_RETRIES: u32 = 3;

/// Seeded, counter-keyed fault injection plan. All probabilities are
/// in `[0, 1]`; a default plan (all zero) injects nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a member-scoring attempt errors (keyed on
    /// round/member/attempt — worker-independent).
    pub p_eval: f32,
    /// Probability a worker panics before running a received job.
    pub p_kill: f32,
    /// Probability a scored result is silently dropped before send.
    pub p_drop: f32,
    /// Probability a result send is delayed by `delay_ms`.
    pub p_delay: f32,
    pub delay_ms: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            p_eval: 0.0,
            p_kill: 0.0,
            p_drop: 0.0,
            p_delay: 0.0,
            delay_ms: 10,
        }
    }
}

impl FaultPlan {
    pub fn is_active(&self) -> bool {
        self.p_eval > 0.0 || self.p_kill > 0.0 || self.p_drop > 0.0 || self.p_delay > 0.0
    }

    fn decide(&self, salt: u64, keys: &[u64], p: f32) -> bool {
        if p <= 0.0 {
            return false;
        }
        let mut h = SplitMix64::new(self.seed ^ salt);
        let mut acc = h.next_u64();
        for &k in keys {
            let mut m = SplitMix64::new(acc ^ k);
            acc = m.next_u64();
        }
        let mut draw = SplitMix64::new(acc);
        draw.uniform01() < p as f64
    }

    /// Does scoring attempt `attempt` of `member` in round `round_id`
    /// fail? Worker-independent by construction.
    pub fn eval_fault(&self, round_id: u64, member: usize, attempt: u32) -> bool {
        self.decide(
            SALT_EVAL,
            &[round_id, member as u64, attempt as u64],
            self.p_eval,
        )
    }

    /// Is `member` of `round_id` permanently failed under this plan —
    /// i.e. do ALL attempts `0..=max_retries` fault? Pure function of
    /// the plan; the inline execution path in `finetune` uses this to
    /// reproduce exactly the degraded rounds a pool run commits.
    pub fn member_fails(&self, round_id: u64, member: usize, max_retries: u32) -> bool {
        (0..=max_retries).all(|a| self.eval_fault(round_id, member, a))
    }

    /// Does worker `worker` (incarnation `incarnation`) panic upon
    /// receiving its `jobs_seen`-th job?
    pub fn worker_kill(&self, worker: usize, incarnation: u32, jobs_seen: u64) -> bool {
        self.decide(
            SALT_KILL,
            &[worker as u64, incarnation as u64, jobs_seen],
            self.p_kill,
        )
    }

    /// Is the `sent`-th result of worker `worker` silently dropped?
    pub fn drop_result(&self, worker: usize, incarnation: u32, sent: u64) -> bool {
        self.decide(
            SALT_DROP,
            &[worker as u64, incarnation as u64, sent],
            self.p_drop,
        )
    }

    /// Delay (if any) before sending the `sent`-th result of `worker`.
    pub fn delay(&self, worker: usize, incarnation: u32, sent: u64) -> Option<Duration> {
        if self.decide(
            SALT_DELAY,
            &[worker as u64, incarnation as u64, sent],
            self.p_delay,
        ) {
            Some(Duration::from_millis(self.delay_ms))
        } else {
            None
        }
    }

    /// Parse a spec like `seed=7,eval=0.2,kill=0.1,drop=0.1,delay=0.1,delay_ms=20`.
    /// Unknown keys error; omitted keys keep their defaults.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec entry {:?} is not key=value", part))?;
            let fv = || -> anyhow::Result<f32> {
                let f: f32 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad value {:?} for fault key {:?}", v, k))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&f),
                    "fault probability {}={} out of [0,1]",
                    k,
                    f
                );
                Ok(f)
            };
            match k {
                "seed" => {
                    plan.seed = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad value {:?} for fault key seed", v))?
                }
                "eval" => plan.p_eval = fv()?,
                "kill" => plan.p_kill = fv()?,
                "drop" => plan.p_drop = fv()?,
                "delay" => plan.p_delay = fv()?,
                "delay_ms" => {
                    plan.delay_ms = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad value {:?} for fault key delay_ms", v))?
                }
                _ => anyhow::bail!("unknown fault key {:?} in QES_FAULTS spec", k),
            }
        }
        Ok(plan)
    }

    /// Read a plan from the `QES_FAULTS` environment variable; an unset
    /// or empty variable yields the inert default plan.
    pub fn from_env() -> anyhow::Result<FaultPlan> {
        match std::env::var("QES_FAULTS") {
            Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s),
            _ => Ok(FaultPlan::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic() {
        let p = FaultPlan {
            seed: 42,
            p_eval: 0.3,
            p_kill: 0.2,
            p_drop: 0.2,
            p_delay: 0.5,
            delay_ms: 7,
        };
        for m in 0..64usize {
            assert_eq!(p.eval_fault(3, m, 1), p.eval_fault(3, m, 1));
            assert_eq!(p.worker_kill(1, 2, m as u64), p.worker_kill(1, 2, m as u64));
            assert_eq!(p.drop_result(0, 0, m as u64), p.drop_result(0, 0, m as u64));
            assert_eq!(p.delay(2, 1, m as u64), p.delay(2, 1, m as u64));
        }
        // Different seeds must decorrelate at least one decision over a
        // reasonable key range.
        let q = FaultPlan { seed: 43, ..p };
        assert!((0..256usize).any(|m| p.eval_fault(0, m, 0) != q.eval_fault(0, m, 0)));
    }

    #[test]
    fn inert_plan_never_fires() {
        let p = FaultPlan::default();
        assert!(!p.is_active());
        for m in 0..128usize {
            assert!(!p.eval_fault(0, m, 0));
            assert!(!p.worker_kill(0, 0, m as u64));
            assert!(!p.drop_result(0, 0, m as u64));
            assert!(p.delay(0, 0, m as u64).is_none());
        }
    }

    #[test]
    fn member_fails_matches_attempt_conjunction() {
        let p = FaultPlan { seed: 9, p_eval: 0.6, ..FaultPlan::default() };
        for r in 0..4u64 {
            for m in 0..32usize {
                let manual = (0..=2u32).all(|a| p.eval_fault(r, m, a));
                assert_eq!(p.member_fails(r, m, 2), manual);
            }
        }
        // With p=0.6 and 3 attempts, some members fail and some don't
        // over a modest sweep — the plan is neither all-pass nor
        // all-fail.
        let fails = (0..64usize).filter(|&m| p.member_fails(0, m, 2)).count();
        assert!(fails > 0 && fails < 64, "fails={}", fails);
    }

    #[test]
    fn empirical_rate_tracks_probability() {
        let p = FaultPlan { seed: 1234, p_eval: 0.25, ..FaultPlan::default() };
        let n = 4000usize;
        let hits = (0..n).filter(|&m| p.eval_fault(0, m, 0)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.05, "rate={}", rate);
    }

    #[test]
    fn parse_roundtrip_and_errors() {
        let p = FaultPlan::parse("seed=7,eval=0.2,kill=0.1,drop=0.05,delay=0.3,delay_ms=20")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert!((p.p_eval - 0.2).abs() < 1e-6);
        assert!((p.p_kill - 0.1).abs() < 1e-6);
        assert!((p.p_drop - 0.05).abs() < 1e-6);
        assert!((p.p_delay - 0.3).abs() < 1e-6);
        assert_eq!(p.delay_ms, 20);
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("eval=2.0").is_err());
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("eval").is_err());
    }
}
