//! # QES — Quantized Evolution Strategies
//!
//! Reproduction of "Quantized Evolution Strategies: High-precision
//! Fine-tuning of Quantized LLMs at Low-precision Cost" as a three-layer
//! Rust + JAX + Pallas system (see DESIGN.md).
//!
//! * [`quant`] — lattice formats, PTQ, GPTQ, packing
//! * [`rng`] — deterministic seed-replayable noise streams
//! * [`model`] — manifest-mirrored parameter store + checkpoints
//! * [`runtime`] — PJRT engines over AOT HLO artifacts
//! * [`kernel`] — runtime-dispatched SIMD microkernels (scalar/AVX2/NEON)
//! * [`sched`] — continuous-batching generation scheduler + `qes serve`
//! * [`obs`] — metrics registry, Prometheus `/metrics`, trace spans
//! * [`util`] — offline stand-ins for json/clap/criterion/proptest
pub mod coordinator;
pub mod exp;
pub mod kernel;
pub mod model;
pub mod obs;
pub mod opt;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod tasks;
pub mod util;
